#!/usr/bin/env bash
# Tier-1 verify: the exact pytest line CI and the PR driver run.
# CPU-only container: pin the platform so jax never probes for TPU.
#
# Tiers:
#   ./test.sh           full tier — whole suite (slow cells included) plus a
#                       benchmarks.run smoke so BENCH json emission can't rot,
#                       plus the docs gates (link + docstring coverage)
#   ./test.sh --fast    fast tier — deselects @pytest.mark.slow (the heavy
#                       pallas-interpret cells; markers in pyproject.toml)
#   ./test.sh --docs    docs tier only — intra-repo markdown links must
#                       resolve, public docstring coverage in
#                       src/repro/{core,kernels,serving} must hold at 100%,
#                       and the public API surface of repro.serving +
#                       repro.core.agcn.engine must match the checked-in
#                       docs/api_surface.txt (tools/check_api.py --update
#                       regenerates it on intentional changes)
#   ./test.sh --dist    distributed tier — tests/test_distributed.py under
#                       XLA_FLAGS=--xla_force_host_platform_device_count=4
#                       (mesh-sharded slab parity, cross-replica migration
#                       parity, router pinning/rebalance units); the full
#                       tier runs it too
#   ./test.sh --traces  traffic/trace tier — tests/test_traffic.py (traffic
#                       model properties, trace serialization, SLO
#                       controller units; Monte-Carlo cells are @slow) +
#                       tests/test_traces_golden.py (golden trace replay
#                       locks + the demand-vs-slo acceptance A/B); the
#                       full tier runs both via normal collection
# Extra args pass through to pytest (e.g. ./test.sh --fast -k streaming).
set -euo pipefail
cd "$(dirname "$0")"

export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}
export JAX_PLATFORMS=cpu

FAST=0
DOCS=0
DIST=0
TRACES=0
ARGS=()
for a in "$@"; do
  case "$a" in
    --fast) FAST=1 ;;
    --docs) DOCS=1 ;;
    --dist) DIST=1 ;;
    --traces) TRACES=1 ;;
    *) ARGS+=("$a") ;;
  esac
done

run_dist() {
  # 4 fake host devices make the 1-D batch mesh real on CPU; the flag must
  # reach a *fresh* interpreter before jax initialises its backend
  XLA_FLAGS="--xla_force_host_platform_device_count=4${XLA_FLAGS:+ $XLA_FLAGS}" \
    python -m pytest -x -q tests/test_distributed.py ${ARGS[@]+"${ARGS[@]}"}
}

if [ "$DIST" = 1 ]; then
  run_dist
elif [ "$TRACES" = 1 ]; then
  python -m pytest -x -q tests/test_traffic.py tests/test_traces_golden.py \
    ${ARGS[@]+"${ARGS[@]}"}
elif [ "$DOCS" = 1 ]; then
  python tools/check_docs.py
  python tools/check_api.py
elif [ "$FAST" = 1 ]; then
  python -m pytest -x -q -m "not slow" ${ARGS[@]+"${ARGS[@]}"}
else
  python -m pytest -x -q ${ARGS[@]+"${ARGS[@]}"}
  # BENCH json emission smoke: one timed iteration, must produce the artifact.
  # Emit into a temp dir so the 1-iteration junk timings never dirty the
  # *tracked* BENCH_kernels_bench.json (an empty dir also means no stale copy
  # can mask a rotted emission path)
  SMOKE_DIR=$(mktemp -d)
  trap 'rm -rf "$SMOKE_DIR"' EXIT
  python -m benchmarks.run --only kernels --smoke --out-dir "$SMOKE_DIR" > /dev/null
  test -s "$SMOKE_DIR/BENCH_kernels_bench.json"
  # the sconv_csr axis (dense vs CSR spatial conv at 25/50 joints across a
  # density sweep) must be emitted by the smoke run and present in the
  # *tracked* artifact — a regenerated BENCH_kernels_bench.json that loses
  # the variable-topology rows fails here
  python - "$SMOKE_DIR/BENCH_kernels_bench.json" <<'EOF'
import json, sys
for path in (sys.argv[1], "BENCH_kernels_bench.json"):
    names = {r["name"] for r in json.load(open(path))}
    for topo in ("ntu25", "ntu50"):
        for d in ("d25", "d50"):
            for impl in ("dense_ref", "csr_ref", "dense_pallas",
                         "csr_pallas"):
                want = f"kernels/sconv_csr/{topo}/{d}/{impl}"
                assert want in names, f"{path} missing {want}"
EOF
  # one-dispatch tick smoke: the throughput module's tick_fused axis must
  # run the fused serving tick end-to-end (S=4, reference backend) and
  # emit its rows; the tracked BENCH_throughput.json must carry the full
  # fused-vs-legacy axis at the serving slot counts
  python -m benchmarks.run --only throughput --smoke --backend reference \
    --out-dir "$SMOKE_DIR" > /dev/null
  python - "$SMOKE_DIR/BENCH_throughput.json" <<'EOF'
import json, sys
names = {r["name"] for r in json.load(open(sys.argv[1]))}
for path, wl in (("fused", "fifo"), ("fused", "preempt"),
                 ("legacy", "fifo"), ("legacy", "preempt")):
    want = f"throughput/measured/tick_fused/reference/S4/{path}/{wl}"
    assert want in names, f"smoke run missing {want}"
names = {r["name"] for r in json.load(open("BENCH_throughput.json"))}
for backend in ("reference", "pallas"):
    for S in (16, 64, 256):
        for path in ("fused", "legacy"):
            want = f"throughput/measured/tick_fused/{backend}/S{S}/{path}/fifo"
            assert want in names, f"tracked BENCH_throughput.json missing {want}"
EOF
  # adaptive-streaming axis: the smoke run must emit the ck x saliency
  # grid (S=16, reference) and the tracked artifact must carry it for
  # both backends — a regenerated BENCH_throughput.json that loses the
  # adaptive rows fails here
  python - "$SMOKE_DIR/BENCH_throughput.json" <<'EOF'
import json, sys
names = {r["name"] for r in json.load(open(sys.argv[1]))}
for ck in (0, 1):
    for sal in (0, 1):
        want = f"throughput/measured/ck_saliency/reference/S16/ck{ck}/sal{sal}"
        assert want in names, f"smoke run missing {want}"
names = {r["name"] for r in json.load(open("BENCH_throughput.json"))}
for backend in ("reference", "pallas"):
    for ck in (0, 1):
        for sal in (0, 1):
            want = f"throughput/measured/ck_saliency/{backend}/S16/ck{ck}/sal{sal}"
            assert want in names, \
                f"tracked BENCH_throughput.json missing {want}"
EOF
  # distributed tier rides the full tier (a separate interpreter: the
  # fake-device flag only takes effect before jax's backend initialises)
  run_dist
  # the tracked BENCH_sessions.json must carry the distributed axes: a
  # mesh-sharded row with its collective cost and a routed multi-replica
  # row with its rebalance count
  python - <<'EOF'
import json
rows = json.load(open("BENCH_sessions.json"))
assert any(r.get("mesh", 1) > 1 and "collective_ms_per_tick" in r
           for r in rows), "no mesh-sharded row in BENCH_sessions.json"
assert any(r.get("replicas", 1) > 1 and "rebalances" in r
           for r in rows), "no routed-replica row in BENCH_sessions.json"
EOF
  # docs gates ride the full tier: broken intra-repo links, a public
  # docstring coverage regression in core/kernels/serving, or undeclared
  # public-API drift (docs/api_surface.txt) fail the build
  python tools/check_docs.py
  python tools/check_api.py
fi
