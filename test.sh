#!/usr/bin/env bash
# Tier-1 verify: the exact pytest line CI and the PR driver run.
# CPU-only container: pin the platform so jax never probes for TPU.
set -euo pipefail
cd "$(dirname "$0")"

export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}
export JAX_PLATFORMS=cpu

python -m pytest -x -q "$@"
