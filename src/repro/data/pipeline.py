"""Deterministic synthetic data pipelines.

Everything is generated from a seed so multi-host shards are reproducible:
each host materialises only its slice of the global batch (host_index /
host_count), which is how a real 1000-node data pipeline would shard files.

Two generators:
  * token LM batches (+ vlm patch embeds / audio frames per family),
  * NTU-style skeleton clips for the paper's 2s-AGCN — a kinematic-chain
    random-walk so joints move smoothly, giving realistic post-ReLU feature
    sparsity for the RFC experiments.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np

from repro.common.config import ModelConfig
from repro.core.agcn.graph import NTU_EDGES


@dataclasses.dataclass
class DataConfig:
    global_batch: int
    seq_len: int
    seed: int = 0
    host_index: int = 0
    host_count: int = 1


def _host_slice(cfg: DataConfig):
    per = cfg.global_batch // cfg.host_count
    lo = cfg.host_index * per
    return lo, per


def lm_batches(mcfg: ModelConfig, dcfg: DataConfig) -> Iterator[Dict[str, np.ndarray]]:
    """Markov-chain token stream (so losses actually decrease in examples)."""
    lo, per = _host_slice(dcfg)
    vocab = mcfg.vocab_size
    rng = np.random.default_rng(dcfg.seed)
    # sparse row-stochastic transition structure with a few strong modes
    next_tok = rng.integers(0, vocab, size=(vocab, 4))
    step = 0
    while True:
        brng = np.random.default_rng(
            (dcfg.seed, step, dcfg.host_index, 0xD47A))
        s_text = dcfg.seq_len
        if mcfg.family == "vlm":
            s_text = dcfg.seq_len - mcfg.num_image_tokens
        toks = np.empty((per, s_text), np.int64)
        toks[:, 0] = brng.integers(0, vocab, size=per)
        choice = brng.integers(0, 4, size=(per, s_text))
        noise = brng.random((per, s_text)) < 0.1
        rand = brng.integers(0, vocab, size=(per, s_text))
        for t in range(1, s_text):
            nxt = next_tok[toks[:, t - 1], choice[:, t]]
            toks[:, t] = np.where(noise[:, t], rand[:, t], nxt)
        batch = {
            "tokens": toks.astype(np.int32),
            "labels": toks.astype(np.int32),
        }
        if mcfg.family == "vlm":
            batch["image_embeds"] = brng.standard_normal(
                (per, mcfg.num_image_tokens, mcfg.d_model), np.float32)
        if mcfg.family == "audio":
            batch["frames"] = brng.standard_normal(
                (per, mcfg.encoder_frames, mcfg.d_model), np.float32)
        yield batch
        step += 1


def _skeleton_edges(num_joints: int):
    """The kinematic chain for a clip generator at ``num_joints``: the
    legacy NTU bone list at 25 joints (byte-compatible with every pinned
    trace), the matching registry topology's edges at any other
    registered width, and a plain chain as the last-resort fallback."""
    if num_joints == 25:
        return NTU_EDGES
    from repro.core.agcn.graph import get_topology, topology_names

    for name in topology_names():
        tp = get_topology(name)
        if tp.num_joints == num_joints:
            return tp.edges
    return [(j + 1, j) for j in range(1, num_joints)]


def skeleton_batches(mcfg: ModelConfig, dcfg: DataConfig,
                     num_classes: Optional[int] = None
                     ) -> Iterator[Dict[str, np.ndarray]]:
    """Synthetic NTU-like clips: class-conditioned joint oscillations on
    the skeleton's kinematic chain (the real 25-joint NTU bone list at
    the default width, the registry topology's bones for other widths).
    (N*M, T, V, C) + labels."""
    lo, per = _host_slice(dcfg)
    ncls = num_classes or mcfg.gcn_num_classes
    V, T, M, C = (mcfg.gcn_joints, mcfg.gcn_frames, mcfg.gcn_persons,
                  mcfg.gcn_in_channels)
    # static rest pose from the bone chain
    rest = np.zeros((V, 3))
    rng = np.random.default_rng(dcfg.seed)
    offsets = rng.standard_normal((V, 3)) * 0.1
    for j, p in _skeleton_edges(V):
        rest[j - 1] = rest[p - 1] + offsets[j - 1]
    step = 0
    while True:
        brng = np.random.default_rng((dcfg.seed, step, dcfg.host_index, 0x5CE1))
        labels = brng.integers(0, ncls, size=per)
        t = np.arange(T)[None, :, None, None] / T
        freq = (labels[:, None, None, None] % 7 + 1.0)
        phase = (labels[:, None, None, None] % 5) * 1.3
        amp = brng.random((per, 1, V, C)) * 0.5
        x = rest[None, None, :, :C] + amp * np.sin(
            2 * np.pi * freq * t + phase + np.arange(V)[None, None, :, None])
        x = x + brng.standard_normal((per, T, V, C)) * 0.02
        x = np.repeat(x, M, axis=0).astype(np.float32)      # persons folded
        yield {"x": x, "labels": np.repeat(labels, M).astype(np.int32)}
        step += 1


def make_batches(mcfg: ModelConfig, dcfg: DataConfig):
    if mcfg.family == "gcn":
        return skeleton_batches(mcfg, dcfg)
    return lm_batches(mcfg, dcfg)
