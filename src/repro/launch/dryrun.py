import os
os.environ["XLA_FLAGS"] = os.environ.get("DRYRUN_XLA_FLAGS",
    "--xla_force_host_platform_device_count=512")
"""Multi-pod dry-run: .lower().compile() every (arch × shape × mesh) cell.

MUST be run as its own process (the XLA_FLAGS line above executes before any
jax import, and jax locks the device count on first init):

    PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
        --mesh both --out experiments/dryrun

Each cell lowers the full production step function with real in/out
shardings (ShapeDtypeStruct inputs — no allocation), compiles it, and
records memory_analysis / cost_analysis / the collective schedule parsed
from the compiled HLO into one JSON per cell.
"""
import argparse
import json
import pathlib
import sys
import time
import traceback
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.common.config import GCN_SHAPES, SHAPES, ModelConfig, TrainConfig
from repro.configs import (
    CONFIGS, applicable_shapes, get_config, input_specs, shape_applicable,
)
from repro.distributed import sharding as shd
from repro.distributed.params import param_shardings
from repro.launch.hlo_cost import analyze_hlo
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import roofline_terms
from repro.models import registry
from repro.optim import adamw
from repro.train.steps import make_prefill_step, make_serve_step, make_train_step


def _rules_for(cfg: ModelConfig, shape_name: str, mesh) -> Dict:
    rules = dict(shd.DEFAULT_RULES)
    shp = (GCN_SHAPES | SHAPES)[shape_name]
    batch = shp.global_batch * (cfg.gcn_persons if cfg.family == "gcn" else 1)
    dp = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
    if cfg.sharding == "dp_only":
        # weights replicated; every mesh axis carries batch
        rules["batch"] = ("pod", "data", "model")
        dp *= mesh.shape.get("model", 1)
    if batch % dp != 0:
        # tiny-batch decode (long_500k): shard the KV sequence instead
        rules["batch"] = None
        rules["kv_seq"] = "data"
    return rules


def _shardings_for_tree(tree_axes, mesh):
    return jax.tree_util.tree_map(
        lambda axes: NamedSharding(mesh, shd.logical_spec(*axes)),
        tree_axes,
        is_leaf=lambda x: isinstance(x, tuple),
    )


def model_flops(cfg: ModelConfig, shape_name: str) -> float:
    shp = (GCN_SHAPES | SHAPES)[shape_name]
    n_active = cfg.active_param_count_estimate()
    if cfg.family == "gcn":
        tokens = shp.global_batch * cfg.gcn_persons * (
            cfg.gcn_frames // max(1, cfg.input_skip))
        return 2.0 * n_active * tokens * (3 if shp.kind == "train" else 1)
    if shp.kind == "train":
        return 6.0 * n_active * shp.global_batch * shp.seq_len
    if shp.kind == "prefill":
        return 2.0 * n_active * shp.global_batch * shp.seq_len
    return 2.0 * n_active * shp.global_batch        # decode: one token


def model_bytes(cfg: ModelConfig, shape_name: str) -> float:
    """Mandatory per-step HBM traffic (whole system): params must be read
    once (weights); decode additionally reads the KV/state cache; train
    reads params + writes grads + touches fp32 moments (~2+2+8+8 B/param)."""
    shp = (GCN_SHAPES | SHAPES)[shape_name]
    n = cfg.param_count_estimate()
    if shp.kind == "train":
        return n * 20.0
    base = n * 2.0
    if shp.kind == "decode" and cfg.family not in ("gcn",):
        # KV cache bytes (attention archs) or state bytes (ssm/hybrid)
        if cfg.family in ("dense", "moe", "vlm", "audio"):
            layers = cfg.num_layers
            kv_len = shp.seq_len
            if cfg.window_size > 0 and cfg.local_global_ratio == 0:
                kv_len = min(kv_len, cfg.window_size)   # SWA ring buffer
            base += (2 * layers * shp.global_batch * kv_len
                     * cfg.num_kv_heads * cfg.head_dim * 2.0)
        elif cfg.family == "hybrid":
            ng = cfg.num_layers // (cfg.shared_attn_every + 1)
            base += (2 * ng * shp.global_batch * shp.seq_len
                     * cfg.num_kv_heads * cfg.head_dim * 2.0)
            base += (cfg.num_layers * shp.global_batch
                     * (cfg.ssm_expand * cfg.d_model) * cfg.ssm_state * 4.0)
        elif cfg.family == "ssm":
            d_inner = cfg.ssm_expand * cfg.d_model
            dh = d_inner // cfg.num_heads
            base += (cfg.num_layers * shp.global_batch * cfg.num_heads
                     * dh * (dh + 1) * 4.0)
    return base


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: pathlib.Path, verbose: bool = True,
             dump_hlo: bool = False) -> Optional[Dict]:
    cfg = get_config(arch)
    ok, reason = shape_applicable(cfg, shape_name)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    cell_id = f"{cfg.name}__{shape_name}__{mesh_name}"
    out_path = out_dir / f"{cell_id}.json"
    if not ok:
        rec = {"cell": cell_id, "status": "skipped", "reason": reason}
        out_path.write_text(json.dumps(rec, indent=2))
        if verbose:
            print(f"[skip] {cell_id}: {reason}")
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(list(mesh.shape.values())))
    shp = (GCN_SHAPES | SHAPES)[shape_name]
    tcfg = TrainConfig()
    t0 = time.time()

    with shd.axis_rules(mesh, _rules_for(cfg, shape_name, mesh)):
        dtype = jnp.bfloat16
        params_shape = jax.eval_shape(
            lambda: registry.init_params(cfg, jax.random.PRNGKey(0), dtype))
        # ZeRO-2 (TP-only params) exists to keep the fp32 optimizer states
        # 2D-sharded — inference cells have no optimizer, and 1D weights
        # push GSPMD into weight-gather + full-width compute inside scans
        # (EXPERIMENTS §Perf open item), so they keep 2D weights.
        if cfg.sharding == "2d":
            policy = "zero2" if shp.kind == "train" else "2d"
        else:
            policy = cfg.sharding
        p_shardings = param_shardings(
            params_shape, mesh,
            expert_dim=cfg.padded_experts or None, policy=policy)
        batch_shape, batch_axes = input_specs(cfg, shape_name)
        b_shardings = _shardings_for_tree(batch_axes, mesh)

        if shp.kind == "train":
            # gradient accumulation so the activation temp fits the
            # 16 GB/chip HBM budget (global batch preserved)
            tcfg = TrainConfig(microbatches=cfg.train_microbatches)
            opt_shape = jax.eval_shape(adamw.init, params_shape)
            # ZeRO-2: fp32 moments stay fully (2D) sharded even when the
            # bf16 params are TP-only — one reshard per step at the update;
            # gradients are constrained to the same 2D specs so the data-
            # parallel grad sync lowers as reduce-scatter, not all-reduce
            opt_policy = "2d" if cfg.sharding != "dp_only" else "dp_only"
            o_2d = param_shardings(
                params_shape, mesh,
                expert_dim=cfg.padded_experts or None, policy=opt_policy)
            step = make_train_step(cfg, tcfg, grad_shardings=o_2d)
            o_shardings = adamw.OptState(
                step=NamedSharding(mesh, P()), m=o_2d, v=o_2d)
            jitted = jax.jit(
                step,
                in_shardings=(p_shardings, o_shardings, b_shardings),
                donate_argnums=(0, 1),
            )
            args = (params_shape, opt_shape, batch_shape)
        elif shp.kind == "prefill":
            step = make_prefill_step(cfg)
            jitted = jax.jit(step, in_shardings=(p_shardings, b_shardings))
            args = (params_shape, batch_shape)
        else:                                        # decode
            step = make_serve_step(cfg)
            cache_shape = jax.eval_shape(
                lambda: registry.init_cache(
                    cfg, shp.global_batch, shp.seq_len, jnp.bfloat16))
            c_shardings = _shardings_for_tree(registry.cache_specs(cfg), mesh)
            # align spec tree ranks with cache tree (specs are per-leaf tuples)
            jitted = jax.jit(
                step,
                in_shardings=(p_shardings, c_shardings, b_shardings),
                donate_argnums=(1,),
            )
            args = (params_shape, cache_shape, batch_shape)

        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    hc = analyze_hlo(hlo)   # trip-count-aware static analysis (per chip)
    terms = roofline_terms(hc, model_flops(cfg, shape_name), chips,
                           model_bytes_total=model_bytes(cfg, shape_name))
    if dump_hlo:
        (out_dir / f"{cell_id}.hlo").write_text(hlo)

    mem_rec = {}
    if mem is not None:
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "generated_code_size_in_bytes",
                     "alias_size_in_bytes"):
            v = getattr(mem, attr, None)
            if v is not None:
                mem_rec[attr] = int(v)

    rec = {
        "cell": cell_id,
        "status": "ok",
        "arch": cfg.name,
        "shape": shape_name,
        "mesh": mesh_name,
        "chips": chips,
        "kind": shp.kind,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory_analysis": mem_rec or str(mem),
        "cost_analysis": {k: float(v) for k, v in cost.items()
                          if isinstance(v, (int, float))},
        "roofline": terms,
    }
    out_path.write_text(json.dumps(rec, indent=2))
    if verbose:
        dom = terms["dominant"]
        print(
            f"[ok]   {cell_id}: compile={t_compile:.1f}s "
            f"flops={terms['hlo_flops']:.3e} coll={terms['collective_bytes']:.3e}B "
            f"dominant={dom} roofline_frac={terms['roofline_fraction']:.3f}"
        )
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--dump-hlo", action="store_true")
    args = ap.parse_args()

    out_dir = pathlib.Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)

    archs = list(CONFIGS) if args.arch == "all" else [args.arch]
    meshes = (
        [False, True] if args.mesh == "both"
        else [args.mesh == "multi"]
    )

    failures = []
    for arch in archs:
        cfg = get_config(arch)
        if args.shape == "all":
            pool = GCN_SHAPES if cfg.family == "gcn" else SHAPES
            shapes = list(pool)          # run_cell records skips with reason
        else:
            shapes = [args.shape]
        for shape_name in shapes:
            for mp in meshes:
                mesh_name = "pod2x16x16" if mp else "pod16x16"
                cell = f"{cfg.name}__{shape_name}__{mesh_name}"
                if args.skip_existing and (out_dir / f"{cell}.json").exists():
                    print(f"[keep] {cell}")
                    continue
                try:
                    run_cell(arch, shape_name, mp, out_dir,
                             dump_hlo=args.dump_hlo)
                except Exception as e:  # noqa: BLE001 — record and continue
                    failures.append((cell, repr(e)))
                    (out_dir / f"{cell}.json").write_text(json.dumps(
                        {"cell": cell, "status": "error", "error": repr(e),
                         "traceback": traceback.format_exc()}, indent=2))
                    print(f"[FAIL] {cell}: {e}")

    print(f"\n{len(failures)} failures")
    for c, e in failures:
        print(f"  {c}: {e[:200]}")
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
