"""Serving driver.

LM families: batched prefill + decode with the KV cache, greedy or top-k
sampling.  Runs reduced configs on CPU; the same step functions are what
the decode_32k / long_500k dry-run cells lower at production shapes.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --reduced \
        --batch 4 --prompt-len 16 --gen 32

GCN family: batched clip inference through the execution engine — the
ExecutionPlans for both streams are compiled once per backend, then a
jitted two-stream ensemble step drains clip batches and reports clips/s
for every requested backend (reference and pallas by default).

    PYTHONPATH=src python -m repro.launch.serve --arch agcn-2s --reduced

``--stream`` switches the GCN family to per-frame continual inference:
one jitted ``step_frame`` per backend consumes raw skeleton frames against
a StreamState (ring buffers + running logit pool) and reports frames/s and
per-frame latency, plus top-1 agreement with the clip engine post-drain.

    PYTHONPATH=src python -m repro.launch.serve --arch agcn-2s --reduced --stream

``--sessions S`` serves *multi-session* live traffic: a fixed-capacity
S-slot session slab (one jitted ``step_frames`` tick for all slots) driven
by the host-side SlabScheduler — Poisson session arrivals, admission into
free slots, flush-drain eviction with per-session logits.  Reports
aggregate frames/s, per-session latency p50/p99, slot occupancy and
admission-to-first-logit delay, and merges rows into
``BENCH_sessions.json``.  ``--qos fifo|preempt|deadline`` selects the
scheduler policy (``preempt`` snapshot-evicts low-priority sessions for
queued high-priority ones via ``engine.snapshot_slots``/``restore_slots``;
``deadline`` drops expired sessions), ``--preempt-ratio`` the
high-priority traffic mix.

    PYTHONPATH=src python -m repro.launch.serve --arch agcn-2s --reduced \
        --sessions 4 [--qos preempt --preempt-ratio 0.25]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import registry
from repro.train.steps import (make_gcn_infer_step, make_gcn_stream_step,
                               make_serve_step)


def serve_gcn(arch: str, *, reduced: bool = True, batch: int = 8,
              clips: int = 64, seed: int = 0, backends=("reference", "pallas")):
    """Batched skeleton-clip inference: two-stream 2s-AGCN ensemble.

    Compiles one ExecutionPlan per (stream, backend) from the config's
    pruning plan, jits the ensemble step with the plans as pytree args, and
    measures steady-state clips/s per backend.  Returns
    {backend: {"clips_per_s": float, "top1": np.ndarray}}.
    """
    from repro.core.agcn import engine
    from repro.core.pruning.plan import plan_from_config
    from repro.data.pipeline import DataConfig, skeleton_batches

    cfg = get_config(arch, reduced=reduced)
    assert cfg.family == "gcn", f"{arch} is not a gcn-family arch"
    prune_plan = plan_from_config(cfg)
    kj, kb = jax.random.split(jax.random.PRNGKey(seed))
    params_joint = registry.init_params(cfg, kj)
    params_bone = registry.init_params(cfg, kb)

    dcfg = DataConfig(global_batch=batch, seq_len=cfg.gcn_frames, seed=seed)
    stream = skeleton_batches(cfg, dcfg)
    batches = [next(stream)["x"] for _ in range(max(1, clips // batch))]

    step = jax.jit(make_gcn_infer_step(cfg))
    results = {}
    for backend in backends:
        plans = tuple(
            engine.build_execution_plan(
                p, cfg, prune_plan, quant=True, backend=backend)
            for p in (params_joint, params_bone))
        logits = step(plans, jnp.asarray(batches[0]))   # compile
        jax.block_until_ready(logits)
        preds, n = [], 0
        t0 = time.monotonic()
        for xb in batches:
            logits = step(plans, jnp.asarray(xb))
            preds.append(np.asarray(jnp.argmax(logits, -1)))
            n += xb.shape[0]
        jax.block_until_ready(logits)
        dt = time.monotonic() - t0
        results[backend] = {
            "clips_per_s": n / dt,
            "top1": np.concatenate(preds),
        }
    return results


def serve_gcn_stream(arch: str, *, reduced: bool = True, batch: int = 4,
                     seed: int = 0, backends=("reference", "pallas")):
    """Per-frame continual inference: two-stream ensemble on a live stream.

    One ExecutionPlan per (stream, backend) is compiled from the config's
    pruning plan (quantized), the StreamStates are calibrated on the clip
    batch (frozen BN statistics), and a single jitted ``step_frame``
    consumes the clip frame-by-frame followed by the flush drain.  Returns
    {backend: {"frames_per_s", "latency_ms_p50", "latency_ms_mean",
    "clip_agreement", "top1"}} — ``clip_agreement`` is post-drain top-1
    agreement with the batched clip engine on the same plans (the streaming
    correctness contract)."""
    from repro.core.agcn import engine
    from repro.core.agcn.model import bone_stream
    from repro.core.pruning.plan import plan_from_config
    from repro.data.pipeline import DataConfig, skeleton_batches

    cfg = get_config(arch, reduced=reduced)
    assert cfg.family == "gcn", f"{arch} is not a gcn-family arch"
    prune_plan = plan_from_config(cfg)
    kj, kb = jax.random.split(jax.random.PRNGKey(seed))
    params_joint = registry.init_params(cfg, kj)
    params_bone = registry.init_params(cfg, kb)

    dcfg = DataConfig(global_batch=batch, seq_len=cfg.gcn_frames, seed=seed)
    clip = jnp.asarray(next(skeleton_batches(cfg, dcfg))["x"])
    T = clip.shape[1]
    zeros = jnp.zeros_like(clip[:, 0])

    step = jax.jit(make_gcn_stream_step(cfg))
    clip_step = jax.jit(make_gcn_infer_step(cfg))
    results = {}
    for backend in backends:
        plans = tuple(
            engine.build_execution_plan(
                p, cfg, prune_plan, quant=True, backend=backend)
            for p in (params_joint, params_bone))
        states = (
            engine.init_stream_state(plans[0], batch, x_calib=clip),
            engine.init_stream_state(plans[1], batch,
                                     x_calib=bone_stream(clip)),
        )
        total = T + engine.stream_flush_frames(plans[0], T)
        # compile both validity variants before timing
        _ = step(plans, states, clip[:, 0], jnp.asarray(True))
        warm, logits = step(plans, states, zeros, jnp.asarray(False))
        jax.block_until_ready(logits)
        lat = []
        for r in range(total):
            frame = clip[:, r] if r < T else zeros
            t0 = time.monotonic()
            states, logits = step(plans, states, frame, jnp.asarray(r < T))
            jax.block_until_ready(logits)
            lat.append(time.monotonic() - t0)
        lat_ms = np.sort(np.asarray(lat)) * 1e3
        stream_top1 = np.asarray(jnp.argmax(logits, -1))
        clip_top1 = np.asarray(jnp.argmax(clip_step(plans, clip), -1))
        results[backend] = {
            # one step advances every stream in the batch by one frame:
            # aggregate frame throughput, latency is the per-step wall time
            "frames_per_s": batch * total / float(np.sum(lat)),
            "latency_ms_p50": float(lat_ms[len(lat_ms) // 2]),
            "latency_ms_mean": float(lat_ms.mean()),
            "clip_agreement": float((stream_top1 == clip_top1).mean()),
            "top1": stream_top1,
        }
    return results


def serve_gcn_sessions(arch: str, *, reduced: bool = True, sessions: int = 4,
                       n_sessions: int = 0, rate: float = 0.0, seed: int = 0,
                       backends=("reference", "pallas"), qos: str = "fifo",
                       preempt_ratio: float = 0.25, deadline_slack: int = 25):
    """Multi-session stream serving: Poisson traffic through a session slab.

    One ``sessions``-slot slab per backend (two-stream ensemble), driven by
    ``repro.launch.sessions.SlabScheduler`` under the ``qos`` policy
    (``fifo`` run-to-completion, ``preempt`` priority snapshot-eviction,
    ``deadline`` expiry drops) — see that module for the slab/scheduler
    split.  ``preempt_ratio`` sets the high-priority traffic mix (every
    policy; same seed draws the same labels, so a fifo run is the preempt
    run's baseline).  Returns the per-backend metrics dicts from
    :func:`repro.launch.sessions.run_sessions` (aggregate frames/s,
    per-priority latency p50/p99, busy + time-weighted occupancy,
    preemption/restore counts, deadline-miss rate)."""
    from repro.launch import sessions as sess

    cfg = get_config(arch, reduced=reduced)
    assert cfg.family == "gcn", f"{arch} is not a gcn-family arch"
    n = n_sessions or 3 * sessions
    # default mean inter-arrival ~ clip_len / slots keeps the slab busy
    # without unbounded queueing (offered load ≈ capacity)
    mean_gap = rate if rate > 0 else max(2.0, cfg.gcn_frames / sessions)
    results = []
    for backend in backends:
        r = sess.run_sessions(cfg, slots=sessions, n_sessions=n,
                              mean_interarrival=mean_gap, backend=backend,
                              seed=seed, qos=qos, preempt_ratio=preempt_ratio,
                              deadline_slack=deadline_slack)
        results.append(r)
    sess.write_bench(results)
    return results


def generate(arch: str, *, reduced: bool = True, batch: int = 4,
             prompt_len: int = 16, gen: int = 32, seed: int = 0,
             greedy: bool = True, temperature: float = 1.0):
    cfg = get_config(arch, reduced=reduced)
    if cfg.family == "gcn":
        raise ValueError("gcn family serving goes through serve_gcn()")
    key = jax.random.PRNGKey(seed)
    params = registry.init_params(cfg, key)
    max_len = prompt_len + gen
    cache = registry.init_cache(cfg, batch, max_len, jnp.float32)
    serve = jax.jit(make_serve_step(cfg), donate_argnums=(1,))

    rng = np.random.default_rng(seed)
    prompt = rng.integers(0, cfg.vocab_size, size=(batch, prompt_len))
    extra = {}
    if cfg.family == "audio":
        extra["memory"] = jnp.asarray(
            rng.standard_normal((batch, cfg.encoder_frames, cfg.d_model)),
            jnp.float32)

    # prefill token-by-token through the same step (functional parity with
    # the chunked prefill exercised by the prefill_32k dry-run cells)
    tok = jnp.asarray(prompt[:, :1], jnp.int32)
    out_tokens = [np.asarray(tok)]
    t0 = time.monotonic()
    for pos in range(max_len - 1):
        b = {"tokens": tok, "pos": jnp.asarray(pos, jnp.int32), **extra}
        next_tok, cache = serve(params, cache, b)
        if pos + 1 < prompt_len:
            tok = jnp.asarray(prompt[:, pos + 1 : pos + 2], jnp.int32)
        else:
            tok = next_tok[:, None]
        out_tokens.append(np.asarray(tok))
    dt = time.monotonic() - t0
    seqs = np.concatenate(out_tokens, axis=1)
    tps = batch * (max_len - 1) / dt
    return seqs, tps


def main():
    from repro.core.agcn.engine import BACKENDS
    from repro.launch.sessions import QOS_POLICIES

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=0)   # 0 -> family default
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--clips", type=int, default=64,
                    help="gcn: total clips to drain per backend")
    ap.add_argument("--backend", default="both", choices=(*BACKENDS, "both"),
                    help="gcn: engine backend(s) to serve with")
    ap.add_argument("--stream", action="store_true",
                    help="gcn: per-frame continual inference (frames/s + "
                         "per-frame latency) instead of batched clips")
    ap.add_argument("--sessions", type=int, default=0,
                    help="gcn: serve Poisson multi-session traffic through "
                         "an S-slot session slab (writes BENCH_sessions.json)")
    ap.add_argument("--n-sessions", type=int, default=0,
                    help="gcn: total sessions to serve (default 3×slots)")
    ap.add_argument("--qos", default="fifo", choices=QOS_POLICIES,
                    help="gcn sessions: scheduler policy — fifo "
                         "run-to-completion, preempt (priority snapshot-"
                         "eviction), deadline (expiry drops)")
    ap.add_argument("--preempt-ratio", type=float, default=0.25,
                    help="gcn sessions: fraction of high-priority sessions "
                         "in the generated load (every policy — a fifo run "
                         "with the same seed baselines a preempt run)")
    ap.add_argument("--deadline-slack", type=int, default=25,
                    help="gcn sessions: extra ticks past each session's "
                         "minimal service time before its deadline")
    args = ap.parse_args()
    cfg = get_config(args.arch, reduced=args.reduced)
    if cfg.family == "gcn":
        backends = BACKENDS if args.backend == "both" else (args.backend,)
        if args.sessions:
            results = serve_gcn_sessions(
                args.arch, reduced=args.reduced, sessions=args.sessions,
                n_sessions=args.n_sessions, backends=backends, qos=args.qos,
                preempt_ratio=args.preempt_ratio,
                deadline_slack=args.deadline_slack)
            for r in results:
                print(f"backend={r['backend']} [sessions qos={r['qos']}]: "
                      f"{r['sessions']} sessions over {r['slots']} slots, "
                      f"{r['frames_per_s']:.1f} frames/s aggregate, "
                      f"occupancy {r['occupancy']*100:.0f}% time-weighted "
                      f"({r['occupancy_busy']*100:.0f}% busy), "
                      f"session latency p50={r['latency_ms_p50']:.0f}ms "
                      f"p99={r['latency_ms_p99']:.0f}ms, "
                      f"first-logit p50={r['first_logit_ms_p50']:.0f}ms "
                      f"({r['first_logit_frames']} frames, "
                      f"{r['sessions_no_first_logit']} without), "
                      f"queue wait {r['queue_wait_ticks_mean']:.1f} ticks")
                for p, pl in sorted(r["latency_ms_by_priority"].items()):
                    print(f"  priority {p}: n={pl['n']} "
                          f"p50={pl['p50_ms']:.0f}ms p99={pl['p99_ms']:.0f}ms "
                          f"(arrival→finish p50={pl['e2e_p50_ticks']:.0f} "
                          f"p99={pl['e2e_p99_ticks']:.0f} ticks)")
                if r["qos"] == "preempt":
                    print(f"  preemptions={r['preemptions']} "
                          f"restores={r['restores']}")
                if r["qos"] == "deadline":
                    print(f"  deadline missed={r['deadline_missed']} "
                          f"(miss rate {r['deadline_miss_rate']*100:.0f}%)")
            print("# merged BENCH_sessions.json")
            return
        if args.stream:
            res = serve_gcn_stream(args.arch, reduced=args.reduced,
                                   batch=args.batch or 4, backends=backends)
            for name, r in res.items():
                print(f"backend={name} [stream]: "
                      f"{r['frames_per_s']:.1f} frames/s "
                      f"({args.batch or 4} streams), per-frame latency "
                      f"p50={r['latency_ms_p50']:.2f}ms "
                      f"mean={r['latency_ms_mean']:.2f}ms, "
                      f"clip-engine top-1 agreement "
                      f"{r['clip_agreement']*100:.1f}%")
            if len(res) == 2:
                a, b = (res[k]["top1"] for k in ("reference", "pallas"))
                print("backend top-1 agreement: "
                      f"{float((a == b).mean())*100:.1f}%")
            return
        res = serve_gcn(args.arch, reduced=args.reduced,
                        batch=args.batch or 8, clips=args.clips,
                        backends=backends)
        for name, r in res.items():
            print(f"backend={name}: {r['clips_per_s']:.1f} clips/s "
                  f"({len(r['top1'])} clips, 2-stream ensemble)")
        if len(res) == 2:
            a, b = (res[k]["top1"] for k in ("reference", "pallas"))
            agree = float((a == b).mean())
            print(f"backend top-1 agreement: {agree*100:.1f}%")
        return
    seqs, tps = generate(args.arch, reduced=args.reduced,
                         batch=args.batch or 4,
                         prompt_len=args.prompt_len, gen=args.gen)
    print(f"generated {seqs.shape} tokens at {tps:.1f} tok/s")
    print("sample:", seqs[0, : args.prompt_len + 8].tolist())


if __name__ == "__main__":
    main()
