"""Serving driver: batched prefill + decode with the KV cache, greedy or
top-k sampling.  Runs reduced configs on CPU; the same step functions are
what the decode_32k / long_500k dry-run cells lower at production shapes.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --reduced \
        --batch 4 --prompt-len 16 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import registry
from repro.train.steps import make_serve_step


def generate(arch: str, *, reduced: bool = True, batch: int = 4,
             prompt_len: int = 16, gen: int = 32, seed: int = 0,
             greedy: bool = True, temperature: float = 1.0):
    cfg = get_config(arch, reduced=reduced)
    if cfg.family == "gcn":
        raise ValueError("gcn family has no autoregressive serving")
    key = jax.random.PRNGKey(seed)
    params = registry.init_params(cfg, key)
    max_len = prompt_len + gen
    cache = registry.init_cache(cfg, batch, max_len, jnp.float32)
    serve = jax.jit(make_serve_step(cfg), donate_argnums=(1,))

    rng = np.random.default_rng(seed)
    prompt = rng.integers(0, cfg.vocab_size, size=(batch, prompt_len))
    extra = {}
    if cfg.family == "audio":
        extra["memory"] = jnp.asarray(
            rng.standard_normal((batch, cfg.encoder_frames, cfg.d_model)),
            jnp.float32)

    # prefill token-by-token through the same step (functional parity with
    # the chunked prefill exercised by the prefill_32k dry-run cells)
    tok = jnp.asarray(prompt[:, :1], jnp.int32)
    out_tokens = [np.asarray(tok)]
    t0 = time.monotonic()
    for pos in range(max_len - 1):
        b = {"tokens": tok, "pos": jnp.asarray(pos, jnp.int32), **extra}
        next_tok, cache = serve(params, cache, b)
        if pos + 1 < prompt_len:
            tok = jnp.asarray(prompt[:, pos + 1 : pos + 2], jnp.int32)
        else:
            tok = next_tok[:, None]
        out_tokens.append(np.asarray(tok))
    dt = time.monotonic() - t0
    seqs = np.concatenate(out_tokens, axis=1)
    tps = batch * (max_len - 1) / dt
    return seqs, tps


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()
    seqs, tps = generate(args.arch, reduced=args.reduced, batch=args.batch,
                         prompt_len=args.prompt_len, gen=args.gen)
    print(f"generated {seqs.shape} tokens at {tps:.1f} tok/s")
    print("sample:", seqs[0, : args.prompt_len + 8].tolist())


if __name__ == "__main__":
    main()
