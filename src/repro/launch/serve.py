"""Serving driver — subcommand CLI over the serving stack.

    PYTHONPATH=src python -m repro.launch.serve <mode> --arch ... [flags]

Modes:

  clip      — GCN batched two-stream clip inference through the execution
              engine (one ExecutionPlan per stream per backend, jitted
              ensemble step, clips/s per backend):

                  serve clip --arch agcn-2s --reduced [--backend both]

  stream    — GCN per-frame continual inference: one jitted ``step_frame``
              per backend consumes raw skeleton frames against a
              StreamState and reports frames/s, per-frame latency and
              post-drain clip-engine agreement:

                  serve stream --arch agcn-2s --reduced

  sessions  — multi-session live traffic through a
              :class:`repro.serving.GcnService`: Poisson (or bursty)
              arrivals, QoS policies (``--qos fifo|preempt|deadline``),
              and **elastic slot capacity** (``--capacity-tiers 2,4,8``:
              one pre-built slab per tier, hysteresis grow/shrink,
              session migration via snapshot/restore).  ``--mesh N``
              shards the slab tick over an N-device 1-D batch mesh (on
              CPU the fake-device flag is set automatically);
              ``--replicas R`` additionally serves the load through a
              :class:`repro.distributed.router.ReplicaRouter` over R
              service replicas with periodic drain-and-rebalance.
              Merges rows into ``BENCH_sessions.json``:

                  serve sessions --arch agcn-2s --reduced --slots 4 \\
                      [--qos preempt] [--capacity-tiers 2,4,8 --load burst] \\
                      [--mesh 4] [--replicas 2]

  lm        — LM families: batched prefill + decode with the KV cache:

                  serve lm --arch smollm-360m --reduced --prompt-len 16 --gen 32

``--batch 0`` (the default everywhere) resolves through
``ModelConfig.serve_batch`` — the one place family/mode defaults live.
The pre-PR-5 flag spelling (``serve --arch ... [--stream|--sessions S]``)
still parses, with a deprecation note."""
from __future__ import annotations

import argparse
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import registry
from repro.train.steps import (make_gcn_infer_step, make_gcn_stream_step,
                               make_serve_step)


def serve_gcn(arch: str, *, reduced: bool = True, batch: int = 8,
              clips: int = 64, seed: int = 0, backends=("reference", "pallas")):
    """Batched skeleton-clip inference: two-stream 2s-AGCN ensemble.

    Compiles one ExecutionPlan per (stream, backend) from the config's
    pruning plan, jits the ensemble step with the plans as pytree args, and
    measures steady-state clips/s per backend.  Returns
    {backend: {"clips_per_s": float, "top1": np.ndarray}}.
    """
    from repro.core.agcn import engine
    from repro.core.pruning.plan import plan_from_config
    from repro.data.pipeline import DataConfig, skeleton_batches

    cfg = get_config(arch, reduced=reduced)
    assert cfg.family == "gcn", f"{arch} is not a gcn-family arch"
    prune_plan = plan_from_config(cfg)
    kj, kb = jax.random.split(jax.random.PRNGKey(seed))
    params_joint = registry.init_params(cfg, kj)
    params_bone = registry.init_params(cfg, kb)

    dcfg = DataConfig(global_batch=batch, seq_len=cfg.gcn_frames, seed=seed)
    stream = skeleton_batches(cfg, dcfg)
    batches = [next(stream)["x"] for _ in range(max(1, clips // batch))]

    step = jax.jit(make_gcn_infer_step(cfg))
    results = {}
    for backend in backends:
        plans = tuple(
            engine.build_execution_plan(
                p, cfg, prune_plan, quant=True, backend=backend)
            for p in (params_joint, params_bone))
        logits = step(plans, jnp.asarray(batches[0]))   # compile
        jax.block_until_ready(logits)
        preds, n = [], 0
        t0 = time.monotonic()
        for xb in batches:
            logits = step(plans, jnp.asarray(xb))
            preds.append(np.asarray(jnp.argmax(logits, -1)))
            n += xb.shape[0]
        jax.block_until_ready(logits)
        dt = time.monotonic() - t0
        results[backend] = {
            "clips_per_s": n / dt,
            "top1": np.concatenate(preds),
        }
    return results


def serve_gcn_stream(arch: str, *, reduced: bool = True, batch: int = 4,
                     seed: int = 0, backends=("reference", "pallas")):
    """Per-frame continual inference: two-stream ensemble on a live stream.

    One ExecutionPlan per (stream, backend) is compiled from the config's
    pruning plan (quantized), the StreamStates are calibrated on the clip
    batch (frozen BN statistics), and a single jitted ``step_frame``
    consumes the clip frame-by-frame followed by the flush drain.  Returns
    {backend: {"frames_per_s", "latency_ms_p50", "latency_ms_mean",
    "clip_agreement", "top1"}} — ``clip_agreement`` is post-drain top-1
    agreement with the batched clip engine on the same plans (the streaming
    correctness contract)."""
    from repro.core.agcn import engine
    from repro.core.agcn.model import bone_stream
    from repro.core.pruning.plan import plan_from_config
    from repro.data.pipeline import DataConfig, skeleton_batches

    cfg = get_config(arch, reduced=reduced)
    assert cfg.family == "gcn", f"{arch} is not a gcn-family arch"
    prune_plan = plan_from_config(cfg)
    kj, kb = jax.random.split(jax.random.PRNGKey(seed))
    params_joint = registry.init_params(cfg, kj)
    params_bone = registry.init_params(cfg, kb)

    dcfg = DataConfig(global_batch=batch, seq_len=cfg.gcn_frames, seed=seed)
    clip = jnp.asarray(next(skeleton_batches(cfg, dcfg))["x"])
    T = clip.shape[1]
    zeros = jnp.zeros_like(clip[:, 0])

    step = jax.jit(make_gcn_stream_step(cfg))
    clip_step = jax.jit(make_gcn_infer_step(cfg))
    results = {}
    for backend in backends:
        plans = tuple(
            engine.build_execution_plan(
                p, cfg, prune_plan, quant=True, backend=backend)
            for p in (params_joint, params_bone))
        states = (
            engine.init_stream_state(plans[0], batch, x_calib=clip),
            engine.init_stream_state(plans[1], batch,
                                     x_calib=bone_stream(clip)),
        )
        total = T + engine.stream_flush_frames(plans[0], T)
        # compile both validity variants before timing
        _ = step(plans, states, clip[:, 0], jnp.asarray(True))
        warm, logits = step(plans, states, zeros, jnp.asarray(False))
        jax.block_until_ready(logits)
        lat = []
        for r in range(total):
            frame = clip[:, r] if r < T else zeros
            t0 = time.monotonic()
            states, logits = step(plans, states, frame, jnp.asarray(r < T))
            jax.block_until_ready(logits)
            lat.append(time.monotonic() - t0)
        lat_ms = np.sort(np.asarray(lat)) * 1e3
        stream_top1 = np.asarray(jnp.argmax(logits, -1))
        clip_top1 = np.asarray(jnp.argmax(clip_step(plans, clip), -1))
        results[backend] = {
            # one step advances every stream in the batch by one frame:
            # aggregate frame throughput, latency is the per-step wall time
            "frames_per_s": batch * total / float(np.sum(lat)),
            "latency_ms_p50": float(lat_ms[len(lat_ms) // 2]),
            "latency_ms_mean": float(lat_ms.mean()),
            "clip_agreement": float((stream_top1 == clip_top1).mean()),
            "top1": stream_top1,
        }
    return results


def serve_gcn_sessions(arch: str, *, reduced: bool = True, slots: int = 4,
                       n_sessions: int = 0, rate: float = 0.0, seed: int = 0,
                       backends=("reference", "pallas"), qos: str = "fifo",
                       preempt_ratio: float = 0.25, deadline_slack: int = 25,
                       capacity_tiers=None, load: str = "poisson",
                       mesh: int = 0, replicas: int = 1,
                       policy: str = "demand", slo_config=None,
                       trace: str = "", topology: str = "",
                       use_ck: bool = False, saliency_thresh: float = 0.0):
    """Multi-session stream serving through :class:`repro.serving.GcnService`.

    One service per backend (two-stream ensemble) under the ``qos`` policy
    (``fifo`` run-to-completion, ``preempt`` priority snapshot-eviction,
    ``deadline`` expiry drops).  ``capacity_tiers`` (e.g. ``(2, 4, 8)``)
    makes the service **elastic**: one pre-built slab per tier, hysteresis
    grow/shrink on queue depth + occupancy, and active-session migration
    across tiers via the engine's snapshot/restore; ``slots`` alone is a
    fixed-capacity run.  ``load`` picks the arrival process (``poisson``
    steady vs ``burst`` peaks-and-lulls — the elastic stress shape).
    ``mesh > 1`` shards the slab tick over a 1-D device mesh (the row
    gains ``mesh`` + ``collective_ms_per_tick``); ``replicas > 1`` also
    runs the load through a :class:`~repro.distributed.router.
    ReplicaRouter` and appends the merged routed row (``replicas`` +
    ``rebalances`` axes).

    ``trace`` replays a recorded :class:`~repro.serving.Trace` file
    byte-identically instead of generating load (``--trace FILE``): the
    arrivals, clip lengths, priorities and clip bytes are pinned by the
    trace, so two invocations differing only in ``policy`` A/B the
    controllers on identical traffic.  ``policy="slo"`` swaps the
    demand-driven capacity manager for the :class:`~repro.serving.
    SloController` (grow on measured p99 first-logit regression, shed via
    admission control at the top tier).  ``topology`` names a registered
    skeleton (``repro.core.agcn.graph``, e.g. ``ntu50`` / ``hand21``) —
    the service compiles its plans for that graph and generates matching
    clips; default is the NTU 25-joint skeleton.

    The adaptive-streaming knobs: ``use_ck`` (``--ck``) serves with the
    windowed data-dependent C_k graph (``repro.core.agcn.adaptive``) and
    ``saliency_thresh`` (``--saliency-thresh``) > 0 skips uninformative
    frames per session through a :class:`~repro.serving.saliency.
    SaliencyGate` — both tag the merged rows (``ck``/``saliency`` axes)
    only when on, so feature-off rows are byte-identical to before the
    knobs existed.  Returns the metrics dicts from
    :func:`repro.serving.run_sessions` / :func:`repro.serving.replay`
    (and the routed runs) and merges them into ``BENCH_sessions.json``."""
    from repro.serving import Trace, replay, run_sessions, write_bench

    import dataclasses

    cfg = get_config(arch, reduced=reduced)
    assert cfg.family == "gcn", f"{arch} is not a gcn-family arch"
    if use_ck and not cfg.use_ck:
        # both paths build plans from cfg, so the flag rides replay too
        cfg = dataclasses.replace(cfg, use_ck=True)
    if trace:
        if topology:
            raise ValueError("--topology is not available with --trace: a "
                             "recorded trace pins its clip bytes to the "
                             "skeleton it was captured with")
        rec = Trace.load(trace)
        results = [
            replay(cfg, rec, backend=backend, qos=qos, policy=policy,
                   capacity_tiers=tuple(capacity_tiers or (slots,)),
                   slo_config=slo_config, deadline_slack=deadline_slack,
                   seed=seed, saliency_thresh=saliency_thresh)
            for backend in backends
        ]
        write_bench(results)
        return results
    n = n_sessions or 3 * slots
    # default mean inter-arrival ~ clip_len / slots keeps the slab busy
    # without unbounded queueing (offered load ≈ capacity)
    mean_gap = rate if rate > 0 else max(2.0, cfg.gcn_frames / slots)
    results = []
    for backend in backends:
        r = run_sessions(cfg, slots=slots, n_sessions=n,
                         mean_interarrival=mean_gap, backend=backend,
                         seed=seed, qos=qos, preempt_ratio=preempt_ratio,
                         deadline_slack=deadline_slack,
                         capacity_tiers=capacity_tiers, load=load,
                         mesh=mesh, policy=policy, slo_config=slo_config,
                         topology=topology or None, use_ck=use_ck,
                         saliency_thresh=saliency_thresh)
        results.append(r)
        if replicas > 1:
            if topology:
                raise ValueError("--topology is not threaded through the "
                                 "replica router yet — drop --replicas")
            from repro.distributed.router import run_routed_sessions
            results.append(run_routed_sessions(
                cfg, replicas=replicas, slots=slots, n_sessions=n,
                mean_interarrival=mean_gap, backend=backend, seed=seed,
                qos=qos, preempt_ratio=preempt_ratio,
                deadline_slack=deadline_slack,
                capacity_tiers=capacity_tiers, load=load))
    write_bench(results)
    return results


def generate(arch: str, *, reduced: bool = True, batch: int = 4,
             prompt_len: int = 16, gen: int = 32, seed: int = 0,
             greedy: bool = True, temperature: float = 1.0):
    cfg = get_config(arch, reduced=reduced)
    if cfg.family == "gcn":
        raise ValueError(f"{arch} is a gcn-family arch — use "
                         "`serve clip|stream|sessions`, not `serve lm`")
    key = jax.random.PRNGKey(seed)
    params = registry.init_params(cfg, key)
    max_len = prompt_len + gen
    cache = registry.init_cache(cfg, batch, max_len, jnp.float32)
    serve = jax.jit(make_serve_step(cfg), donate_argnums=(1,))

    rng = np.random.default_rng(seed)
    prompt = rng.integers(0, cfg.vocab_size, size=(batch, prompt_len))
    extra = {}
    if cfg.family == "audio":
        extra["memory"] = jnp.asarray(
            rng.standard_normal((batch, cfg.encoder_frames, cfg.d_model)),
            jnp.float32)

    # prefill token-by-token through the same step (functional parity with
    # the chunked prefill exercised by the prefill_32k dry-run cells)
    tok = jnp.asarray(prompt[:, :1], jnp.int32)
    out_tokens = [np.asarray(tok)]
    t0 = time.monotonic()
    for pos in range(max_len - 1):
        b = {"tokens": tok, "pos": jnp.asarray(pos, jnp.int32), **extra}
        next_tok, cache = serve(params, cache, b)
        if pos + 1 < prompt_len:
            tok = jnp.asarray(prompt[:, pos + 1 : pos + 2], jnp.int32)
        else:
            tok = next_tok[:, None]
        out_tokens.append(np.asarray(tok))
    dt = time.monotonic() - t0
    seqs = np.concatenate(out_tokens, axis=1)
    tps = batch * (max_len - 1) / dt
    return seqs, tps


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

SUBCOMMANDS = ("clip", "stream", "sessions", "lm")


def _parse_tiers(spec: str):
    """``"2,4,8"`` -> (2, 4, 8); empty/None -> None (fixed capacity)."""
    if not spec:
        return None
    return tuple(int(t) for t in spec.split(","))


def _ensure_fake_devices(n: int) -> None:
    """Make at least ``n`` host devices visible for ``--mesh n``.

    Must run before jax's backend initializes (the flag is read once);
    a user-provided ``--xla_force_host_platform_device_count`` wins.  If
    the platform still comes up short, ``make_batch_mesh`` raises with
    the same flag in the message."""
    if n <= 1:
        return
    flags = os.environ.get("XLA_FLAGS", "")
    if "--xla_force_host_platform_device_count" in flags:
        return
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={n}"
        + (f" {flags}" if flags else ""))


def _add_common(ap: argparse.ArgumentParser) -> None:
    from repro.core.agcn.engine import BACKENDS

    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=0,
                    help="0 -> family/mode default "
                         "(ModelConfig.serve_batch, the single source)")
    ap.add_argument("--backend", default="both", choices=(*BACKENDS, "both"),
                    help="gcn: engine backend(s) to serve with")


def build_parser() -> argparse.ArgumentParser:
    """The subcommand CLI: ``serve clip|stream|sessions|lm [flags]``."""
    from repro.serving import CONTROL_POLICIES, QOS_POLICIES, SHED_MODES

    ap = argparse.ArgumentParser(prog="repro.launch.serve")
    sub = ap.add_subparsers(dest="mode", required=True)

    p = sub.add_parser("clip", help="gcn: batched two-stream clip inference")
    _add_common(p)
    p.add_argument("--clips", type=int, default=64,
                   help="total clips to drain per backend")

    p = sub.add_parser("stream", help="gcn: per-frame continual inference")
    _add_common(p)

    p = sub.add_parser("sessions",
                       help="gcn: multi-session traffic through GcnService")
    _add_common(p)
    p.add_argument("--slots", type=int, default=4,
                   help="slot capacity of a fixed run (with "
                        "--capacity-tiers the capacity comes from the "
                        "tiers instead, but --slots still sets the load "
                        "defaults: --n-sessions 3×slots, --rate "
                        "clip_len/slots)")
    p.add_argument("--n-sessions", type=int, default=0,
                   help="total sessions to serve (default 3×slots)")
    p.add_argument("--rate", type=float, default=0.0,
                   help="mean inter-arrival ticks (0 -> clip_len/slots)")
    p.add_argument("--qos", default="fifo", choices=QOS_POLICIES,
                   help="scheduler policy: fifo run-to-completion, preempt "
                        "(priority snapshot-eviction), deadline (expiry "
                        "drops)")
    p.add_argument("--preempt-ratio", type=float, default=0.25,
                   help="fraction of high-priority sessions in the "
                        "generated load (every policy — a fifo run with "
                        "the same seed baselines a preempt run)")
    p.add_argument("--deadline-slack", type=int, default=25,
                   help="extra ticks past each session's minimal service "
                        "time before its deadline")
    p.add_argument("--capacity-tiers", default="",
                   help="comma-separated slot tiers, e.g. 2,4,8 — enables "
                        "elastic capacity (pre-built slab per tier, "
                        "hysteresis grow/shrink, snapshot/restore "
                        "migration)")
    p.add_argument("--load", default="poisson", choices=("poisson", "burst"),
                   help="arrival process: steady poisson or bursty "
                        "peaks-and-lulls (the elastic stress shape)")
    p.add_argument("--trace", default="",
                   help="replay a recorded Trace JSON file instead of "
                        "generating load — arrivals, lengths, priorities "
                        "and clip bytes are pinned by the trace, so runs "
                        "differing only in --policy A/B the controllers "
                        "on identical traffic")
    p.add_argument("--policy", default="demand", choices=CONTROL_POLICIES,
                   help="capacity control: demand (grow on raw "
                        "busy+queued) or slo (grow on measured p99 "
                        "first-logit regression, shed low-priority opens "
                        "via admission control at the top tier)")
    p.add_argument("--slo-target", type=int, default=0,
                   help="SLO bound: p99 arrival→first-logit latency in "
                        "scheduler ticks (0 -> SloConfig default; only "
                        "with --policy slo)")
    p.add_argument("--slo-window", type=int, default=0,
                   help="sliding latency-sample window of the SLO "
                        "controller (0 -> SloConfig default)")
    p.add_argument("--slo-shed-mode", default="", choices=("", *SHED_MODES),
                   help="what shedding does to low-priority opens: reject "
                        "turns them away, degrade serves every stride-th "
                        "frame (default: SloConfig default)")
    p.add_argument("--mesh", type=int, default=0,
                   help="shard the slab tick over an N-device 1-D batch "
                        "mesh (0/1 -> single device; on CPU the "
                        "fake-device XLA flag is set automatically)")
    p.add_argument("--replicas", type=int, default=1,
                   help="also serve the load through a ReplicaRouter over "
                        "R service replicas (adds the routed BENCH row)")
    p.add_argument("--topology", default="",
                   help="registered skeleton topology to serve (e.g. "
                        "ntu25, ntu50, hand21, body_hand46) — plans "
                        "compile for that graph and the generated clips "
                        "match its joint count (default: ntu25)")
    p.add_argument("--ck", action="store_true",
                   help="serve with the windowed data-dependent C_k graph "
                        "(repro.core.agcn.adaptive) folded into every "
                        "block's spatial conv")
    p.add_argument("--saliency-thresh", type=float, default=0.0,
                   help="> 0 skips uninformative frames per session below "
                        "this attention-ratio threshold "
                        "(repro.serving.saliency; default 0 = off)")

    p = sub.add_parser("lm", help="LM families: prefill + decode")
    _add_common(p)
    p.add_argument("--prompt-len", type=int, default=16)
    p.add_argument("--gen", type=int, default=32)
    return ap


def _legacy_argv(argv):
    """Map the pre-subcommand flag spelling onto the new CLI.

    ``--sessions S`` -> ``sessions --slots S``, ``--stream`` ->
    ``stream``, a gcn arch without either -> ``clip``, LM arches ->
    ``lm``.  Prints a one-line deprecation note naming the new form."""
    legacy = argparse.ArgumentParser(add_help=False)
    legacy.add_argument("--arch", required=True)
    legacy.add_argument("--reduced", action="store_true")
    legacy.add_argument("--stream", action="store_true")
    legacy.add_argument("--sessions", type=int, default=0)
    known, _ = legacy.parse_known_args(argv)
    cfg = get_config(known.arch, reduced=known.reduced)
    out = list(argv)
    if cfg.family != "gcn":
        mode = "lm"
    elif known.sessions:
        mode = "sessions"
        for i, a in enumerate(out):
            if a == "--sessions":
                out[i] = "--slots"
                break
            if a.startswith("--sessions="):
                out[i] = "--slots=" + a.split("=", 1)[1]
                break
    elif known.stream:
        mode = "stream"
        out.remove("--stream")
    else:
        mode = "clip"
    print(f"# note: flag-style invocation is deprecated — use "
          f"`serve {mode} ...` (mapped automatically)", file=sys.stderr)
    return [mode] + out


def _print_sessions(results) -> None:
    for r in results:
        cap = (f" capacity={r['capacity']}" if r["capacity"] != "fixed"
               else "")
        if r.get("replicas", 1) > 1:
            # merged router row: totals + percentiles only (per-replica
            # detail rides under "per_replica" in the BENCH row)
            print(f"backend={r['backend']} [sessions routed "
                  f"replicas={r['replicas']} qos={r['qos']}{cap}]: "
                  f"{r['sessions']} sessions over "
                  f"{r['replicas']}x{r['slots']} slots, "
                  f"{r['frames_per_s']:.1f} frames/s aggregate, "
                  f"occupancy {r['occupancy']*100:.0f}%, "
                  f"latency p50={r['latency_ms_p50']:.0f}ms "
                  f"p99={r['latency_ms_p99']:.0f}ms, "
                  f"{r['rebalances']} rebalance moves")
            continue
        mesh = f" mesh={r['mesh']}" if r.get("mesh", 1) > 1 else ""
        pol = (f" policy=slo trace={r.get('trace', '')}"
               if r.get("policy", "demand") != "demand"
               else (f" trace={r['trace']}" if r.get("trace") else ""))
        if r.get("ck"):
            mesh += " ck"
        if r.get("saliency"):
            mesh += (f" saliency={r['saliency']} "
                     f"(skip {r['skip_rate']*100:.0f}%)")
        print(f"backend={r['backend']} [sessions{mesh}{pol} qos={r['qos']}"
              f"{cap} load={r['load']}]: "
              f"{r['sessions']} sessions over {r['slots']} slots, "
              f"{r['frames_per_s']:.1f} frames/s aggregate, "
              f"occupancy {r['occupancy']*100:.0f}% time-weighted "
              f"({r['occupancy_busy']*100:.0f}% busy), "
              f"session latency p50={r['latency_ms_p50']:.0f}ms "
              f"p99={r['latency_ms_p99']:.0f}ms, "
              f"first-logit p50={r['first_logit_ms_p50']:.0f}ms "
              f"({r['first_logit_frames']} frames, "
              f"{r['sessions_no_first_logit']} without), "
              f"queue wait {r['queue_wait_ticks_mean']:.1f} ticks")
        for p, pl in sorted(r["latency_ms_by_priority"].items()):
            print(f"  priority {p}: n={pl['n']} "
                  f"p50={pl['p50_ms']:.0f}ms p99={pl['p99_ms']:.0f}ms "
                  f"(arrival→finish p50={pl['e2e_p50_ticks']:.0f} "
                  f"p99={pl['e2e_p99_ticks']:.0f} ticks, "
                  f"first-logit p99={pl['first_logit_p99_ticks']:.0f} "
                  f"ticks)")
        if r.get("policy", "demand") == "slo":
            print(f"  slo: target p99 {r['slo_target_p99_ticks']} ticks, "
                  f"shed_mode={r['shed_mode']} "
                  f"rejected={r['sessions_rejected']} "
                  f"degraded={r['sessions_degraded']} "
                  f"({r['shed_windows']} shed windows)")
        if r["qos"] == "preempt":
            print(f"  preemptions={r['preemptions']} "
                  f"restores={r['restores']}")
        if r["qos"] == "deadline":
            print(f"  deadline missed={r['deadline_missed']} "
                  f"(miss rate {r['deadline_miss_rate']*100:.0f}%)")
        if r["capacity"] != "fixed":
            print(f"  elastic: {r['migrations_grow']} grows / "
                  f"{r['migrations_shrink']} shrinks, "
                  f"migration {r['migration_ms_mean']:.1f}ms mean, "
                  f"final capacity {r['capacity_final']}, "
                  f"tier ticks {r['tier_ticks']}")
        if r.get("mesh", 1) > 1:
            print(f"  sharded: {r['mesh']} devices, collective cost "
                  f"{r['collective_ms_per_tick']:.2f}ms/tick")
    print("# merged BENCH_sessions.json")


def main(argv=None):
    """CLI entry: subcommand form, with the legacy flag form mapped."""
    from repro.core.agcn.engine import BACKENDS

    argv = list(sys.argv[1:] if argv is None else argv)
    legacy = False
    if not argv or argv[0] not in SUBCOMMANDS:
        # the legacy flag spelling is recognized by its required --arch;
        # map it first so `serve --arch ... --help` reaches the right
        # subcommand's help instead of an 'invalid choice' error
        if any(a == "--arch" or a.startswith("--arch=") for a in argv):
            argv = _legacy_argv(argv)
            legacy = True
        else:
            build_parser().parse_args(argv or ["-h"])
            return
    if legacy:
        # the old single parser accepted every flag in every mode (extras
        # were ignored); keep that contract for mapped invocations
        args, extra = build_parser().parse_known_args(argv)
        if extra:
            print(f"# note: ignoring legacy flags not used by "
                  f"`serve {argv[0]}`: {' '.join(extra)}", file=sys.stderr)
    else:
        args = build_parser().parse_args(argv)
    cfg = get_config(args.arch, reduced=args.reduced)
    backends = BACKENDS if args.backend == "both" else (args.backend,)

    if args.mode == "sessions":
        assert cfg.family == "gcn", f"{args.arch} is not a gcn-family arch"
        _ensure_fake_devices(getattr(args, "mesh", 0))
        slo_config = None
        if getattr(args, "policy", "demand") == "slo":
            from repro.serving import SloConfig
            overrides = {}
            if getattr(args, "slo_target", 0):
                overrides["target_p99_ticks"] = args.slo_target
            if getattr(args, "slo_window", 0):
                overrides["window"] = args.slo_window
            if getattr(args, "slo_shed_mode", ""):
                overrides["shed_mode"] = args.slo_shed_mode
            slo_config = SloConfig(**overrides)
        results = serve_gcn_sessions(
            args.arch, reduced=args.reduced, slots=args.slots,
            n_sessions=args.n_sessions, rate=args.rate, backends=backends,
            qos=args.qos, preempt_ratio=args.preempt_ratio,
            deadline_slack=args.deadline_slack,
            capacity_tiers=_parse_tiers(args.capacity_tiers),
            load=args.load, mesh=getattr(args, "mesh", 0),
            replicas=getattr(args, "replicas", 1),
            policy=getattr(args, "policy", "demand"), slo_config=slo_config,
            trace=getattr(args, "trace", ""),
            topology=getattr(args, "topology", ""),
            use_ck=getattr(args, "ck", False),
            saliency_thresh=getattr(args, "saliency_thresh", 0.0))
        _print_sessions(results)
        return
    if args.mode == "stream":
        assert cfg.family == "gcn", f"{args.arch} is not a gcn-family arch"
        batch = cfg.serve_batch("stream", args.batch)
        res = serve_gcn_stream(args.arch, reduced=args.reduced,
                               batch=batch, backends=backends)
        for name, r in res.items():
            print(f"backend={name} [stream]: "
                  f"{r['frames_per_s']:.1f} frames/s "
                  f"({batch} streams), per-frame latency "
                  f"p50={r['latency_ms_p50']:.2f}ms "
                  f"mean={r['latency_ms_mean']:.2f}ms, "
                  f"clip-engine top-1 agreement "
                  f"{r['clip_agreement']*100:.1f}%")
        if len(res) == 2:
            a, b = (res[k]["top1"] for k in ("reference", "pallas"))
            print("backend top-1 agreement: "
                  f"{float((a == b).mean())*100:.1f}%")
        return
    if args.mode == "clip":
        assert cfg.family == "gcn", f"{args.arch} is not a gcn-family arch"
        res = serve_gcn(args.arch, reduced=args.reduced,
                        batch=cfg.serve_batch("clip", args.batch),
                        clips=args.clips, backends=backends)
        for name, r in res.items():
            print(f"backend={name}: {r['clips_per_s']:.1f} clips/s "
                  f"({len(r['top1'])} clips, 2-stream ensemble)")
        if len(res) == 2:
            a, b = (res[k]["top1"] for k in ("reference", "pallas"))
            agree = float((a == b).mean())
            print(f"backend top-1 agreement: {agree*100:.1f}%")
        return
    seqs, tps = generate(args.arch, reduced=args.reduced,
                         batch=cfg.serve_batch("lm", args.batch),
                         prompt_len=args.prompt_len, gen=args.gen)
    print(f"generated {seqs.shape} tokens at {tps:.1f} tok/s")
    print("sample:", seqs[0, : args.prompt_len + 8].tolist())


if __name__ == "__main__":
    main()
