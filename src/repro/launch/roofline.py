"""Roofline-term computation from the static HLO analysis (hlo_cost.py).

  compute term    = HLO_FLOPs / peak_FLOP/s          (per chip)
  memory term     = HLO_bytes / HBM_bw               (per chip)
  collective term = collective_bytes / link_bw       (per chip)

All inputs are per-chip (the analyzed module is the post-GSPMD per-device
program), so no further division by chip count is needed; the equivalent
whole-system statement divides totals by chips — identical numbers.
"""
from __future__ import annotations

from typing import Dict

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16


def roofline_terms(
    hlo_cost: Dict,
    model_flops_total: float,
    chips: int,
    model_bytes_total: float = 0.0,
    peak_flops: float = PEAK_FLOPS_BF16,
    hbm_bw: float = HBM_BW,
    ici_bw: float = ICI_BW,
) -> Dict:
    flops = float(hlo_cost.get("flops", 0.0))
    hbm_bytes = float(hlo_cost.get("bytes", 0.0))
    coll = float(hlo_cost.get("collective_bytes", 0.0))

    t_compute = flops / peak_flops
    t_memory = hbm_bytes / hbm_bw
    t_collective = coll / ici_bw
    dominant = max(
        ("compute", t_compute), ("memory", t_memory),
        ("collective", t_collective), key=lambda kv: kv[1],
    )[0]
    bound = max(t_compute, t_memory, t_collective, 1e-30)
    model_per_chip = model_flops_total / chips
    # The ideal step time is bounded below by BOTH the model's mandatory
    # FLOPs at peak AND its mandatory HBM traffic (params, caches) at full
    # bandwidth — a memory-bound decode step at full HBM bw IS at roofline.
    ideal = max(model_per_chip / peak_flops,
                (model_bytes_total / chips) / hbm_bw)
    return {
        "hlo_flops": flops,
        "hlo_bytes": hbm_bytes,
        "collective_bytes": coll,
        "collectives": hlo_cost.get("collectives", {}),
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_collective,
        "dominant": dominant,
        "model_flops_per_chip": model_per_chip,
        "model_bytes_per_chip": model_bytes_total / chips,
        "useful_flop_ratio": (model_per_chip / flops) if flops else 0.0,
        "ideal_s": ideal,
        # fraction of the roofline-ideal step time actually achievable given
        # the dominant term — the §Perf score
        "roofline_fraction": ideal / bound,
    }
