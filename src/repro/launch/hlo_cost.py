"""Static cost analyzer over compiled (post-SPMD-partitioning) HLO text.

XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE, which
under-reports scanned layer stacks by their trip count.  This analyzer
parses the HLO module, multiplies loop bodies by their
``known_trip_count`` backend config, and produces:

    flops            — dot/convolution FLOPs, trip-count-weighted
    bytes            — approximate HBM traffic: result + operand bytes of
                       every materialising top-level op (fusion boundaries),
                       trip-count-weighted
    collective_bytes — result-shape bytes of all-gather / all-reduce /
                       reduce-scatter / all-to-all / collective-permute,
                       trip-count-weighted (per kind and total)

The module text is the per-device program after GSPMD partitioning, so all
quantities are per-chip.
"""
from __future__ import annotations

import math
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s32": 4, "u32": 4, "f32": 4, "c64": 8, "s64": 8, "u64": 8, "f64": 8,
    "c128": 16, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"\b([a-z]\d*[a-z0-9]*)\[([\d,]*)\]")
_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)
# Ops that actually materialise HBM traffic on TPU.  Top-level elementwise /
# broadcast / convert chains would be fused by the TPU backend, so we treat
# them as free here (the CPU backend fuses less aggressively; counting its
# unfused elementwise ops would overstate the memory term ~3-5x).
_BYTES_OPS = {
    "fusion", "dot", "convolution", "copy", "dynamic-slice",
    "dynamic-update-slice", "gather", "scatter", "reduce", "reduce-window",
    "select-and-scatter", "sort", "cholesky", "triangular-solve", "fft",
    "rng", "rng-bit-generator", "pad", "concatenate", "custom-call",
    *_COLLECTIVES,
}


def _parse_shapes(text: str) -> List[Tuple[str, Tuple[int, ...]]]:
    out = []
    for m in _SHAPE_RE.finditer(text):
        dt = m.group(1)
        if dt not in _DTYPE_BYTES:
            continue
        dims = tuple(int(d) for d in m.group(2).split(",")) if m.group(2) else ()
        out.append((dt, dims))
    return out


def _shape_bytes(shapes) -> int:
    return sum(_DTYPE_BYTES[dt] * math.prod(dims) for dt, dims in shapes)


def _numel(shapes) -> int:
    return sum(math.prod(dims) for _, dims in shapes)


@dataclass
class Instr:
    name: str
    op: str
    result: List
    operands: List[str]
    line: str


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)
    shapes: Dict[str, List] = field(default_factory=dict)


_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\("
)
_CALLS_RE = re.compile(r"calls=%([\w.\-]+)")
_COND_RE = re.compile(r"condition=%([\w.\-]+)")
_BODY_RE = re.compile(r"body=%([\w.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count[":{]+n["\s:]+["]?(\d+)')
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->")


def parse_module(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry: Optional[str] = None
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        s = line.strip()
        if cur is None:
            if s.endswith("{"):
                m = _COMP_HDR_RE.match(s)
                if m:
                    cur = Computation(name=m.group(1))
                    if s.startswith("ENTRY"):
                        entry = m.group(1)
            continue
        if s == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR_RE.match(s)
        if not m:
            # parameter declarations inside computations:
            pm = re.match(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+?)\s+parameter\(", s)
            if pm:
                shp = _parse_shapes(pm.group(2))
                cur.shapes[pm.group(1)] = shp
                cur.instrs.append(Instr(pm.group(1), "parameter", shp, [], s))
            continue
        name, result_ty, op = m.group(1), m.group(2), m.group(3)
        shp = _parse_shapes(result_ty)
        rest = s[m.end():]
        # operand names: inside the first (...) — approximate by all %refs
        # before any attribute markers
        arg_str = rest.split("), ")[0]
        operands = _OPERAND_RE.findall(arg_str)
        cur.shapes[name] = shp
        cur.instrs.append(Instr(name, op, shp, operands, s))
    return comps, entry


def _dot_flops(instr: Instr, comp: Computation) -> float:
    out_elems = _numel(instr.result)
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", instr.line)
    contract = 1
    if m and instr.operands:
        lhs_shape = comp.shapes.get(instr.operands[0])
        if lhs_shape:
            dims = lhs_shape[0][1]
            for i in (int(x) for x in m.group(1).split(",") if x):
                if i < len(dims):
                    contract *= dims[i]
    return 2.0 * out_elems * contract


def _conv_flops(instr: Instr, comp: Computation) -> float:
    out_elems = _numel(instr.result)
    if len(instr.operands) < 2:
        return 2.0 * out_elems
    rhs_shape = comp.shapes.get(instr.operands[1])
    if not rhs_shape:
        return 2.0 * out_elems
    rhs_dims = rhs_shape[0][1]
    rhs_total = math.prod(rhs_dims) if rhs_dims else 1
    m = re.search(r"dim_labels=\w+_(\w+)->", instr.line)
    out_feat = 1
    if m:
        labels = m.group(1)
        if "o" in labels and labels.index("o") < len(rhs_dims):
            out_feat = rhs_dims[labels.index("o")]
    fg = re.search(r"feature_group_count=(\d+)", instr.line)
    groups = int(fg.group(1)) if fg else 1
    return 2.0 * out_elems * (rhs_total / max(1, out_feat)) / max(1, groups) * 1.0


class HloCost:
    def __init__(self, text: str):
        self.comps, self.entry = parse_module(text)
        self._memo: Dict[str, Dict[str, float]] = {}

    def _cost(self, comp_name: str) -> Dict[str, float]:
        if comp_name in self._memo:
            return self._memo[comp_name]
        comp = self.comps.get(comp_name)
        zero = {"flops": 0.0, "bytes": 0.0, "collective_bytes": 0.0,
                **{f"coll_{k}": 0.0 for k in _COLLECTIVES}}
        if comp is None:
            return zero
        total = dict(zero)
        # guard against recursion
        self._memo[comp_name] = zero
        for ins in comp.instrs:
            if ins.op == "while":
                body = _BODY_RE.search(ins.line)
                cond = _COND_RE.search(ins.line)
                trip_m = _TRIP_RE.search(ins.line)
                trip = int(trip_m.group(1)) if trip_m else 1
                if body:
                    c = self._cost(body.group(1))
                    for k in total:
                        total[k] += trip * c[k]
                if cond:
                    c = self._cost(cond.group(1))
                    for k in total:
                        total[k] += (trip + 1) * c[k]
                continue
            sub = _CALLS_RE.search(ins.line)
            if sub and ins.op in ("fusion", "call", "custom-call", "map",
                                  "reduce", "reduce-window", "scatter",
                                  "select-and-scatter", "sort"):
                c = self._cost(sub.group(1))
                for k in total:
                    if k != "bytes":     # fusion interiors never touch HBM
                        total[k] += c[k]
            if ins.op == "conditional":
                branches = re.findall(r"%([\w.\-]+)", ins.line.split("branch")[-1])
                if branches:
                    costs = [self._cost(b) for b in branches]
                    best = max(costs, key=lambda c: c["flops"])
                    for k in total:
                        total[k] += best[k]
                continue

            if ins.op == "dot":
                total["flops"] += _dot_flops(ins, comp)
            elif ins.op == "convolution":
                total["flops"] += _conv_flops(ins, comp)

            if ins.op in _COLLECTIVES or any(
                ins.op == f"{k}-start" for k in _COLLECTIVES
            ):
                kind = ins.op.replace("-start", "")
                b = _shape_bytes(ins.result)
                total["collective_bytes"] += b
                total[f"coll_{kind}"] += b

            # HBM-traffic approximation at fusion boundaries
            if ins.op in _BYTES_OPS and not ins.op.endswith("-done"):
                b = _shape_bytes(ins.result)
                for oname in ins.operands:
                    oshape = comp.shapes.get(oname)
                    if oshape:
                        b += _shape_bytes(oshape)
                total["bytes"] += b
        self._memo[comp_name] = total
        return total

    def analyze(self) -> Dict[str, float]:
        # Top-level computations reachable only from entry are counted via
        # the call graph; fusion-internal computations are excluded because
        # we never descend into them for bytes (only for flops via `calls=`,
        # which double-counts bytes — accepted approximation biased high).
        if self.entry is None:
            # fall back: largest computation
            if not self.comps:
                return {"flops": 0.0, "bytes": 0.0, "collective_bytes": 0.0}
            self.entry = max(self.comps, key=lambda c: len(self.comps[c].instrs))
        out = dict(self._cost(self.entry))
        out["collectives"] = {k: out.pop(f"coll_{k}") for k in _COLLECTIVES}
        return out


def analyze_hlo(text: str) -> Dict[str, float]:
    return HloCost(text).analyze()
