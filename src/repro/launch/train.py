"""Training driver: wires config -> data -> sharded train_step -> checkpoint
-> fault monitors.  On this CPU container it runs reduced configs end-to-end
(examples/ use it); on a real cluster the same driver runs under
``jax.distributed.initialize`` with the production mesh.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \
        --reduced --steps 50 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.config import TrainConfig
from repro.configs import get_config
from repro.checkpoint import store
from repro.data.pipeline import DataConfig, make_batches
from repro.distributed import sharding as shd
from repro.distributed.params import param_shardings
from repro.fault.monitor import HeartbeatMonitor, StragglerDetector
from repro.models import registry
from repro.optim import adamw
from repro.train.steps import make_train_step


def train_loop(
    arch: str,
    tcfg: TrainConfig,
    *,
    reduced: bool = True,
    batch: int = 8,
    seq: int = 128,
    mesh=None,
    log_every: int = 10,
    resume: bool = True,
):
    cfg = get_config(arch, reduced=reduced)
    if mesh is None:
        mesh = jax.make_mesh((1, 1), ("data", "model"))
    hosts = jax.process_count()
    heart = HeartbeatMonitor(num_hosts=hosts)
    strag = StragglerDetector(num_hosts=hosts)

    dcfg = DataConfig(global_batch=batch, seq_len=seq, seed=tcfg.seed,
                      host_index=jax.process_index(), host_count=hosts)
    data = make_batches(cfg, dcfg)

    with shd.axis_rules(mesh):
        params = registry.init_params(cfg, jax.random.PRNGKey(tcfg.seed))
        p_shardings = param_shardings(params, mesh,
                                      expert_dim=cfg.padded_experts or None)
        params = jax.device_put(params, p_shardings)
        opt_state = adamw.init(params)

        start = 0
        if resume:
            last = store.latest_step(tcfg.checkpoint_dir)
            if last is not None:
                params = store.restore(tcfg.checkpoint_dir, last, params)
                params = jax.device_put(params, p_shardings)
                opt_state = adamw.init(params)   # moments restart (see DESIGN)
                ckpt = store.restore(
                    tcfg.checkpoint_dir + "/opt", last, opt_state)
                opt_state = ckpt
                start = last
                print(f"[resume] from step {last}")

        step_fn = jax.jit(make_train_step(cfg, tcfg), donate_argnums=(0, 1))

        losses = []
        pending_ckpt: Optional[object] = None
        for step in range(start, tcfg.total_steps):
            t0 = time.monotonic()
            raw = next(data)
            b = jax.tree_util.tree_map(jnp.asarray, raw)
            params, opt_state, metrics = step_fn(params, opt_state, b)
            loss = float(metrics["loss"])
            losses.append(loss)
            dt = time.monotonic() - t0
            heart.beat(jax.process_index())
            strag.record(jax.process_index(), dt)
            if step % log_every == 0 or step == tcfg.total_steps - 1:
                print(f"step {step:5d}  loss {loss:8.4f}  "
                      f"gnorm {float(metrics.get('grad_norm', 0)):7.3f}  "
                      f"{dt*1e3:7.1f} ms")
            if tcfg.checkpoint_every and (step + 1) % tcfg.checkpoint_every == 0:
                store.save(tcfg.checkpoint_dir, step + 1, params)
                store.save(tcfg.checkpoint_dir + "/opt", step + 1, opt_state)
            if not heart.healthy():
                raise RuntimeError(f"dead hosts: {heart.dead_hosts()}")
        return params, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=0)
    args = ap.parse_args()
    tcfg = TrainConfig(
        learning_rate=args.lr, total_steps=args.steps,
        warmup_steps=max(1, args.steps // 10),
        microbatches=args.microbatches,
        checkpoint_every=args.ckpt_every, checkpoint_dir=args.ckpt_dir,
    )
    _, losses = train_loop(args.arch, tcfg, reduced=args.reduced,
                           batch=args.batch, seq=args.seq)
    if losses:
        print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f})")
    else:
        print("nothing to do (checkpoint already past --steps)")


if __name__ == "__main__":
    main()
