"""Multi-session stream serving: a session-slab scheduler over the engine's
per-frame step, with session QoS (priority admission, snapshot-preemption,
deadline eviction).

The streaming engine (PR 2) serves *one* lockstep batch of streams; live
traffic is many independent skeleton sessions arriving and ending at
different times — the continual-inference regime of CoST-GCN (Hedegaard et
al., 2022) at the throughput target of the ROADMAP.  This module is the
host-side half of that service:

  device  — a fixed-capacity **session slab**: one ``engine.StreamState``
            whose leading axis is S slots, advanced by one jitted
            ``engine.step_frames(plan, slab, frames[S], valid[S], reset[S])``
            per tick (compiled once per ExecutionPlan, any occupancy).
            Preemption is the engine's ``snapshot_slots`` (one traced
            gather over every per-slot leaf) and resume is
            ``restore_slots`` (the inverse scatter).
  host    — :class:`SlabScheduler`: a slot table + priority admission
            queue (:class:`AdmissionQueue`, strict (priority, arrival)
            order) with a pluggable QoS policy:

              fifo     — run-to-completion (the default; with uniform
                         priorities this is exactly FIFO admission).
              preempt  — a queued strictly-higher-priority session may
                         snapshot-evict the lowest-priority active slot;
                         the victim re-queues (keeping its progress and
                         device snapshot) and later restores into a free
                         slot and resumes.
              deadline — sessions whose completion deadline has passed
                         are dropped from the queue or evicted from their
                         slot and counted as ``missed``.

The scheduler is pure host bookkeeping (numpy in, numpy out) so it unit-
tests without jax — device snapshots never enter it; :meth:`tick_inputs`
returns a :class:`TickPlan` naming which slots to snapshot/restore and the
driver (:func:`run_sessions`) holds the captures.  :func:`run_sessions`
couples it to the jitted two-stream slab step and measures the serving
metrics the ROADMAP asks for: aggregate frames/s, per-session (and
per-priority-class) completion latency p50/p99, busy and time-weighted
slot occupancy, admission-to-first-logit delay, preemption/restore counts
and the deadline-miss rate.
"""
from __future__ import annotations

import dataclasses
import heapq
import json
import os
import time
from collections import deque
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

DEFAULT_BENCH_PATH = "BENCH_sessions.json"

QOS_POLICIES = ("fifo", "preempt", "deadline")


# ---------------------------------------------------------------------------
# load generation
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SessionRequest:
    """One incoming stream session: a skeleton clip arriving at a tick.

    ``priority`` orders admission (larger = more urgent; ties are FIFO by
    arrival) and selects preemption victims under the ``preempt`` policy;
    ``deadline`` is the absolute tick by which the session must *complete*
    under the ``deadline`` policy (None = no deadline)."""

    sid: int
    arrival: int             # tick index at which the session arrives
    clip: np.ndarray         # (T, V, C) raw skeleton frames
    priority: int = 0
    deadline: Optional[int] = None


@dataclasses.dataclass
class SessionRecord:
    """A completed session: identity, timing, QoS history, final logits."""

    sid: int
    frames: int              # clip length T (real frames)
    arrival: int             # tick of arrival (queue entry)
    admitted: int            # tick of first slot admission
    finished: int            # tick the drained logits were captured
    wall_admitted: float     # monotonic seconds
    wall_first_logit: float  # first *valid* logit contribution for this slot
                             # (-1.0 sentinel: the session never produced one)
    wall_finished: float
    logits: np.ndarray       # (num_classes,) post-drain prediction
    priority: int = 0
    preemptions: int = 0     # times this session was snapshot-evicted


def poisson_arrivals(
    n_sessions: int,
    mean_interarrival: float,
    lengths: Sequence[int],
    joints: int,
    channels: int,
    seed: int = 0,
    clip_source: Optional[Callable[[int, int], np.ndarray]] = None,
    priorities: Optional[Sequence[int]] = None,
    high_priority_ratio: float = 0.0,
) -> List[SessionRequest]:
    """Poisson-process session arrivals (exponential inter-arrival ticks).

    Each session draws a clip length uniformly from ``lengths`` and clip
    content from ``clip_source(sid, T) -> (T, V, C)`` (standard-normal
    synthetic skeletons by default — the serving driver swaps in the data
    pipeline).  The priority mix is either explicit (``priorities``, one
    int per session) or a Bernoulli draw: ``high_priority_ratio`` of the
    sessions get priority 1, the rest priority 0.  Returns requests sorted
    by arrival tick."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(mean_interarrival, size=n_sessions)
    arrivals = np.floor(np.cumsum(gaps) - gaps[0]).astype(int)
    if priorities is None:
        priorities = (rng.random(n_sessions)
                      < high_priority_ratio).astype(int)
    reqs = []
    for sid, at in enumerate(arrivals):
        T = int(rng.choice(np.asarray(lengths)))
        if clip_source is not None:
            clip = np.asarray(clip_source(sid, T), np.float32)
        else:
            clip = rng.standard_normal((T, joints, channels)).astype(np.float32)
        reqs.append(SessionRequest(sid=sid, arrival=int(at), clip=clip,
                                   priority=int(priorities[sid])))
    return reqs


# ---------------------------------------------------------------------------
# the scheduler
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _Slot:
    """Host-side view of one slab slot holding an admitted session.

    A preempted session is re-queued as this same object (progress,
    first-logit latch and preemption count travel with it), which is also
    how re-admission knows to restore its device snapshot rather than
    reset the slot."""

    req: SessionRequest
    admitted: int            # first admission tick
    rel: int                 # raw frames fed so far (clip + flush)
    total: int               # clip length + flush drain
    wall_admitted: float
    wall_first_logit: float = -1.0
    preemptions: int = 0


class AdmissionQueue:
    """Priority admission queue: strict (priority desc, arrival, seq) order.

    With uniform priorities the (arrival, seq) tie-break makes this exactly
    a FIFO — today's behavior is the degenerate case, not a second code
    path.  Items are fresh :class:`SessionRequest`\\ s or preempted
    :class:`_Slot`\\ s awaiting re-admission (both carry the same ordering
    key through their request)."""

    def __init__(self):
        self._heap: List[Tuple[int, int, int, Any]] = []
        self._seq = 0

    @staticmethod
    def _req(item) -> SessionRequest:
        return item.req if isinstance(item, _Slot) else item

    def push(self, item) -> None:
        """Queue a session (or a preempted slot) by (priority, arrival)."""
        r = self._req(item)
        heapq.heappush(self._heap, (-r.priority, r.arrival, self._seq, item))
        self._seq += 1

    def pop(self):
        """Remove and return the highest-priority (then earliest) item."""
        return heapq.heappop(self._heap)[-1]

    def peek_priority(self) -> int:
        """Priority of the head item (the next admission)."""
        return -self._heap[0][0]

    def drop_if(self, pred: Callable[[Any], bool]) -> List[Any]:
        """Remove and return every queued item for which ``pred`` holds
        (deadline expiry sweep); the queue keeps its heap order."""
        kept, dropped = [], []
        for entry in self._heap:
            (dropped if pred(entry[-1]) else kept).append(entry)
        if dropped:
            self._heap = kept
            heapq.heapify(self._heap)
        return [e[-1] for e in dropped]

    def __len__(self) -> int:
        return len(self._heap)

    def __bool__(self) -> bool:
        return bool(self._heap)


@dataclasses.dataclass
class TickPlan:
    """One tick's device work order, built by ``SlabScheduler.tick_inputs``.

    ``frames``/``valid``/``reset`` feed ``engine.step_frames`` unchanged
    (the class iterates as that triple for drivers that ignore QoS).
    ``snapshot`` lists (slot, sid) pairs the driver must capture with
    ``engine.snapshot_slots`` *before* the step (preemption evictions);
    ``restore`` lists (slot, sid) pairs whose stored snapshot must be
    scattered back with ``engine.restore_slots`` before the step."""

    frames: np.ndarray
    valid: np.ndarray
    reset: np.ndarray
    snapshot: List[Tuple[int, int]] = dataclasses.field(default_factory=list)
    restore: List[Tuple[int, int]] = dataclasses.field(default_factory=list)

    def __iter__(self):
        """Back-compat unpacking: ``frames, valid, reset = tick_inputs()``."""
        return iter((self.frames, self.valid, self.reset))


class SlabScheduler:
    """Slot table + priority admission queue driving ``engine.step_frames``.

    Pure host logic over numpy arrays: each tick, :meth:`tick_inputs`
    applies the QoS policy (deadline sweep, admissions, preemptions) and
    builds the :class:`TickPlan` the jitted slab step consumes, and
    :meth:`tick_outputs` consumes the step's logits — finalising any
    session whose flush drain completed this tick and recycling its slot.

    Timing is delegated to two plan-derived callables so the scheduler
    itself stays jax-free: ``flush_frames(T)`` (the per-block 'same'-padding
    drain after a T-frame clip, ``engine.stream_flush_frames``) and
    ``first_logit_delay`` (raw frames from admission to the first valid
    logit, ``engine.stream_first_logit_delay``).  Device snapshots never
    enter the scheduler either: preemption/restore are *named* in the
    TickPlan and executed by the driver."""

    def __init__(self, slots: int, joints: int, channels: int,
                 flush_frames: Callable[[int], int],
                 first_logit_delay: int,
                 policy: str = "fifo"):
        if policy not in QOS_POLICIES:
            raise ValueError(
                f"unknown QoS policy {policy!r} (expected one of "
                f"{QOS_POLICIES})")
        self.slots: List[Optional[_Slot]] = [None] * slots
        self.joints, self.channels = joints, channels
        self.flush_frames = flush_frames
        self.first_logit_delay = first_logit_delay
        self.policy = policy
        self.queue = AdmissionQueue()
        self.completed: List[SessionRecord] = []
        self.missed: List[SessionRequest] = []   # deadline-policy casualties
        self.occupancy_samples: List[float] = []
        self.valid_frames = 0        # real (clip) frames fed across all slots
        self.preemptions = 0         # snapshot-evictions performed
        self.restores = 0            # preempted sessions re-admitted

    # -- admission -----------------------------------------------------------

    def submit(self, req: SessionRequest) -> None:
        """Queue an arrived session (strict (priority, arrival) order —
        plain FIFO when every priority is equal)."""
        self.queue.push(req)

    def busy(self) -> int:
        """Occupied slot count (active + draining)."""
        return sum(s is not None for s in self.slots)

    def idle(self) -> bool:
        """True when no session is queued or occupying a slot."""
        return not self.queue and self.busy() == 0

    # -- policy helpers ------------------------------------------------------

    def _expired(self, item, tick: int) -> bool:
        r = AdmissionQueue._req(item)
        return r.deadline is not None and tick > r.deadline

    def _miss(self, item, tick: int) -> None:
        r = AdmissionQueue._req(item)
        self.missed.append(r)

    def _admit(self, s: int, item, tick: int, now: float,
               reset: np.ndarray, restore: List[Tuple[int, int]]) -> None:
        """Place a queue item into free slot ``s``: fresh sessions get a
        traced reset, preempted sessions get a snapshot restore."""
        if isinstance(item, _Slot):                  # resume a preemption
            self.slots[s] = item
            restore.append((s, item.req.sid))
            self.restores += 1
        else:
            self.slots[s] = _Slot(
                req=item, admitted=tick, rel=0,
                total=len(item.clip) + self.flush_frames(len(item.clip)),
                wall_admitted=now)
            reset[s] = True

    # -- one tick ------------------------------------------------------------

    def tick_inputs(self, tick: int, now: float) -> TickPlan:
        """Apply the QoS policy, admit into free slots, build step inputs.

        Returns a :class:`TickPlan` whose ``frames (S, V, C) f32``,
        ``valid (S,) bool`` and ``reset (S,) bool`` feed the slab step
        (reset marks this tick's fresh admissions — the traced slot
        zeroing; valid marks slots feeding real clip frames, False = flush
        drain or free slot — both take the zero-padding path), plus the
        snapshot/restore slot lists the driver must execute around it."""
        S = len(self.slots)
        reset = np.zeros((S,), bool)
        snapshot: List[Tuple[int, int]] = []
        restore: List[Tuple[int, int]] = []

        if self.policy == "deadline":
            # queue sweep: expired sessions never reach a slot (only fresh
            # requests can be queued here — preempted _Slots exist only
            # under the mutually-exclusive preempt policy, so no stored
            # snapshot can be orphaned by a drop)
            for item in self.queue.drop_if(lambda it: self._expired(it, tick)):
                self._miss(item, tick)
            # slot sweep: evict sessions whose deadline passed mid-service
            for s, slot in enumerate(self.slots):
                if slot is not None and self._expired(slot, tick):
                    self.slots[s] = None
                    self._miss(slot, tick)

        for s in range(S):
            if self.slots[s] is None and self.queue:
                self._admit(s, self.queue.pop(), tick, now, reset, restore)

        if self.policy == "preempt":
            # a queued strictly-higher-priority session snapshot-evicts the
            # lowest-priority active slot (latest admission breaks ties —
            # the session with the least sunk progress yields first)
            while self.queue:
                head_p = self.queue.peek_priority()
                cands = [(slot.req.priority, -slot.admitted, s)
                         for s, slot in enumerate(self.slots)
                         if slot is not None]
                if not cands:
                    break
                vp, _, vs = min(cands)
                if vp >= head_p:
                    break
                victim = self.slots[vs]
                snapshot.append((vs, victim.req.sid))
                victim.preemptions += 1
                self.preemptions += 1
                self.slots[vs] = None
                self.queue.push(victim)
                self._admit(vs, self.queue.pop(), tick, now, reset, restore)

        frames = np.zeros((S, self.joints, self.channels), np.float32)
        valid = np.zeros((S,), bool)
        for s, slot in enumerate(self.slots):
            if slot is None:
                continue
            if slot.rel < len(slot.req.clip):
                frames[s] = slot.req.clip[slot.rel]
                valid[s] = True
                self.valid_frames += 1
        self.occupancy_samples.append(self.busy() / S)
        return TickPlan(frames=frames, valid=valid, reset=reset,
                        snapshot=snapshot, restore=restore)

    def tick_outputs(self, tick: int, logits: np.ndarray, now: float
                     ) -> List[SessionRecord]:
        """Advance slot clocks with this tick's logits; evict drained slots.

        ``logits`` is the slab step's (S, num_classes) output.  The first
        tick a slot's clock reaches the first-logit delay latches the wall
        time (a ``>=`` latch, set once — the session keeps it across
        preemptions); a slot whose flush drain completed captures its
        logits row as the session's final prediction, is freed, and the
        finished :class:`SessionRecord` is returned (and appended to
        ``self.completed``)."""
        done: List[SessionRecord] = []
        for s, slot in enumerate(self.slots):
            if slot is None:
                continue
            if (slot.wall_first_logit < 0
                    and slot.rel >= self.first_logit_delay - 1):
                slot.wall_first_logit = now
            if slot.rel == slot.total - 1:
                rec = SessionRecord(
                    sid=slot.req.sid, frames=len(slot.req.clip),
                    arrival=slot.req.arrival, admitted=slot.admitted,
                    finished=tick, wall_admitted=slot.wall_admitted,
                    wall_first_logit=slot.wall_first_logit,
                    wall_finished=now,
                    logits=np.asarray(logits[s]),
                    priority=slot.req.priority,
                    preemptions=slot.preemptions)
                done.append(rec)
                self.completed.append(rec)
                self.slots[s] = None
            else:
                slot.rel += 1
        return done


# ---------------------------------------------------------------------------
# the serving loop
# ---------------------------------------------------------------------------

def run_sessions(
    cfg,
    *,
    slots: int = 8,
    n_sessions: int = 16,
    mean_interarrival: float = 8.0,
    lengths: Optional[Sequence[int]] = None,
    backend: str = "reference",
    quant: bool = True,
    seed: int = 0,
    max_ticks: int = 100_000,
    qos: str = "fifo",
    preempt_ratio: float = 0.25,
    deadline_slack: int = 25,
    priorities: Optional[Sequence[int]] = None,
) -> Dict:
    """Serve ``n_sessions`` Poisson-arriving skeleton sessions through an
    ``slots``-slot slab with the two-stream (joint + bone) ensemble.

    Compiles one ExecutionPlan per stream for ``backend``, calibrates the
    shared frozen BN statistics once from a pipeline clip batch, then runs
    the scheduler tick loop under the ``qos`` policy: one jitted
    ``make_gcn_slab_step`` call per tick serves every slot (admissions via
    the traced reset mask, drains via per-slot validity), and preemptions
    execute the jitted ``engine.snapshot_slots`` / ``restore_slots`` pair
    around it.  ``preempt_ratio`` sets the load generator's high-priority
    mix (priority 1 vs 0) under every policy — same seed, same labels, so
    a fifo run baselines the preempt run directly; under ``deadline``
    each session's completion deadline is its minimal service time
    (clip + flush) plus ``deadline_slack`` ticks past arrival.  Returns the
    metrics dict (also the row merged into ``BENCH_sessions.json`` by
    ``serve --sessions``) plus the completed :class:`SessionRecord` list
    under ``"records"``."""
    import jax
    import jax.numpy as jnp

    from repro.core.agcn import engine
    from repro.core.agcn.model import bone_stream
    from repro.core.pruning.plan import plan_from_config
    from repro.data.pipeline import DataConfig, skeleton_batches
    from repro.models import registry
    from repro.train.steps import make_gcn_slab_step

    prune_plan = plan_from_config(cfg)
    kj, kb = jax.random.split(jax.random.PRNGKey(seed))
    params_joint = registry.init_params(cfg, kj)
    params_bone = registry.init_params(cfg, kb)
    plans = tuple(
        engine.build_execution_plan(p, cfg, prune_plan, quant=quant,
                                    backend=backend)
        for p in (params_joint, params_bone))

    # calibration + load: clips come from the same synthetic NTU pipeline
    dcfg = DataConfig(global_batch=max(4, slots), seq_len=cfg.gcn_frames,
                      seed=seed)
    calib = jnp.asarray(next(skeleton_batches(cfg, dcfg))["x"])
    slabs = (
        engine.init_session_slab(plans[0], slots, x_calib=calib),
        engine.init_session_slab(plans[1], slots,
                                 x_calib=bone_stream(calib)),
    )

    if lengths is None:
        lengths = (cfg.gcn_frames, max(2, cfg.gcn_frames // 2))
    pool = np.asarray(next(skeleton_batches(
        cfg, DataConfig(global_batch=n_sessions, seq_len=cfg.gcn_frames,
                        seed=seed + 1)))["x"])

    def clip_source(sid: int, T: int) -> np.ndarray:
        return pool[sid % len(pool), :T]

    # the priority mix applies under every policy (same seed -> identical
    # labels), so a fifo run is the directly comparable baseline for the
    # preempt run: priority admission without preemption
    reqs = poisson_arrivals(
        n_sessions, mean_interarrival, lengths,
        cfg.gcn_joints, cfg.gcn_in_channels, seed=seed,
        clip_source=clip_source, priorities=priorities,
        high_priority_ratio=preempt_ratio)
    flush = lambda T: engine.stream_flush_frames(plans[0], T)  # noqa: E731
    if qos == "deadline":
        for r in reqs:
            r.deadline = (r.arrival + len(r.clip) + flush(len(r.clip))
                          + deadline_slack)
    sched = SlabScheduler(
        slots, cfg.gcn_joints, cfg.gcn_in_channels,
        flush_frames=flush,
        first_logit_delay=engine.stream_first_logit_delay(plans[0]),
        policy=qos)

    step = jax.jit(make_gcn_slab_step(cfg))
    snap_fn = jax.jit(engine.snapshot_slots)
    rest_fn = jax.jit(engine.restore_slots)
    # compile outside the timed loop (both reset variants trace identically
    # — reset is a traced mask — so one warmup call suffices)
    zf = jnp.zeros((slots, cfg.gcn_joints, cfg.gcn_in_channels))
    zb = jnp.zeros((slots,), bool)
    warm, wl = step(plans, slabs, zf, zb, zb)
    jax.block_until_ready(wl)
    if qos == "preempt":
        w = tuple(snap_fn(s, jnp.asarray(0)) for s in slabs)
        ws = tuple(rest_fn(s, jnp.asarray(0), x) for s, x in zip(slabs, w))
        jax.block_until_ready(ws)

    snaps: Dict[int, Tuple] = {}     # sid -> per-stream slot snapshots
    pending = deque(reqs)
    tick = 0
    t0 = time.monotonic()
    while tick < max_ticks:
        while pending and pending[0].arrival <= tick:
            sched.submit(pending.popleft())
        if sched.idle():
            if not pending:
                break
            tick = pending[0].arrival       # fast-forward empty gaps
            continue
        now = time.monotonic()
        tp = sched.tick_inputs(tick, now)
        for s, sid in tp.snapshot:          # capture before restore/step
            snaps[sid] = tuple(snap_fn(slab, jnp.asarray(s))
                               for slab in slabs)
        for s, sid in tp.restore:
            slabs = tuple(rest_fn(slab, jnp.asarray(s), sn)
                          for slab, sn in zip(slabs, snaps.pop(sid)))
        slabs, logits = step(plans, slabs, jnp.asarray(tp.frames),
                             jnp.asarray(tp.valid), jnp.asarray(tp.reset))
        logits_np = np.asarray(logits)      # blocks until the tick is done
        sched.tick_outputs(tick, logits_np, time.monotonic())
        tick += 1
    wall = time.monotonic() - t0

    recs = sched.completed
    lat = np.asarray([r.wall_finished - r.wall_admitted for r in recs])
    first = np.asarray([r.wall_first_logit - r.wall_admitted
                        for r in recs if r.wall_first_logit >= 0])
    no_first = sum(r.wall_first_logit < 0 for r in recs)
    qwait = np.asarray([r.admitted - r.arrival for r in recs], np.float64)
    # per-class latency, both anchors: service time (admission→finish, wall
    # ms) and end-to-end (arrival→finish, scheduler ticks — queue wait and
    # preemption requeues included, which is where the QoS policies differ;
    # tick-denominated so the comparison is deterministic, not wall noise)
    by_prio: Dict[str, Dict[str, float]] = {}
    for p in sorted({r.priority for r in recs}):
        pl = np.asarray([r.wall_finished - r.wall_admitted
                         for r in recs if r.priority == p])
        pt = np.asarray([r.finished - r.arrival
                         for r in recs if r.priority == p], np.float64)
        by_prio[str(p)] = {
            "n": int(len(pl)),
            "p50_ms": float(np.percentile(pl, 50) * 1e3),
            "p99_ms": float(np.percentile(pl, 99) * 1e3),
            "e2e_p50_ticks": float(np.percentile(pt, 50)),
            "e2e_p99_ticks": float(np.percentile(pt, 99)),
        }
    n_missed = len(sched.missed)
    # occupancy_samples are busy/S on *processed* ticks only; the true
    # time-weighted occupancy counts fast-forwarded idle gaps as zero
    # (tick spans the whole serving window, gaps included)
    occ_busy = float(np.mean(sched.occupancy_samples)
                     if sched.occupancy_samples else 0.0)
    occ_time = float(np.sum(sched.occupancy_samples) / max(tick, 1))
    return {
        "backend": backend,
        "slots": slots,
        "qos": qos,
        "sessions": len(recs),
        "ticks": tick,
        "wall_s": wall,
        "frames_per_s": sched.valid_frames / wall if wall > 0 else 0.0,
        "ticks_per_s": tick / wall if wall > 0 else 0.0,
        "occupancy": occ_time,
        "occupancy_busy": occ_busy,
        "latency_ms_p50": float(np.percentile(lat, 50) * 1e3) if len(lat) else 0.0,
        "latency_ms_p99": float(np.percentile(lat, 99) * 1e3) if len(lat) else 0.0,
        "latency_ms_by_priority": by_prio,
        "first_logit_ms_p50": (float(np.percentile(first, 50) * 1e3)
                               if len(first) else 0.0),
        "first_logit_frames": engine.stream_first_logit_delay(plans[0]),
        "sessions_no_first_logit": int(no_first),
        "queue_wait_ticks_mean": float(qwait.mean()) if len(qwait) else 0.0,
        "preemptions": sched.preemptions,
        "restores": sched.restores,
        "deadline_missed": n_missed,
        "deadline_miss_rate": (n_missed / (n_missed + len(recs))
                               if (n_missed + len(recs)) else 0.0),
        "records": recs,
    }


def write_bench(results: List[Dict], path: str = DEFAULT_BENCH_PATH) -> None:
    """Merge the multi-session serving rows into ``BENCH_sessions.json``.

    Rows are keyed by ``(backend, slots, qos)`` (rows written before the
    QoS axis existed default to ``fifo``): an existing row with the same
    key is replaced in place, every other row survives, and new keys are
    appended — so ``serve --sessions --backend pallas`` refreshes only the
    pallas rows instead of clobbering the reference rows the README tables
    are rendered from (``tools/bench_tables.py``)."""
    def key(r: Dict) -> Tuple:
        return (r.get("backend"), r.get("slots"), r.get("qos", "fifo"))

    existing: List[Dict] = []
    if os.path.exists(path):
        try:
            with open(path) as f:
                existing = json.load(f)
            if not isinstance(existing, list):
                existing = []
        except (json.JSONDecodeError, OSError):
            existing = []
    fresh = {key(r): {k: v for k, v in r.items() if k != "records"}
             for r in results}
    rows = []
    for r in existing:
        rows.append(fresh.pop(key(r), r))
    rows.extend(fresh.values())
    with open(path, "w") as f:
        json.dump(rows, f, indent=1)
