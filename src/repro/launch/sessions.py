"""Multi-session stream serving: a session-slab scheduler over the engine's
per-frame step.

The streaming engine (PR 2) serves *one* lockstep batch of streams; live
traffic is many independent skeleton sessions arriving and ending at
different times — the continual-inference regime of CoST-GCN (Hedegaard et
al., 2022) at the throughput target of the ROADMAP.  This module is the
host-side half of that service:

  device  — a fixed-capacity **session slab**: one ``engine.StreamState``
            whose leading axis is S slots, advanced by one jitted
            ``engine.step_frames(plan, slab, frames[S], valid[S], reset[S])``
            per tick (compiled once per ExecutionPlan, any occupancy).
  host    — :class:`SlabScheduler`: a slot table + FIFO admission queue.
            Arrivals wait for a free slot, admission zeroes the slot's
            rings/pool via the traced reset mask, active sessions feed real
            frames (valid=True), finished clips drain their per-block
            'same'-padding latency with flush frames (valid=False), and the
            drained slot's logits row is captured as the session's
            prediction before the slot is recycled.

The scheduler is pure host bookkeeping (numpy in, numpy out) so it unit-
tests without jax; :func:`run_sessions` couples it to the jitted two-stream
slab step and measures the serving metrics the ROADMAP asks for: aggregate
frames/s, per-session completion latency p50/p99, slot occupancy, and
admission-to-first-logit delay.
"""
from __future__ import annotations

import dataclasses
import json
import time
from collections import deque
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

DEFAULT_BENCH_PATH = "BENCH_sessions.json"


# ---------------------------------------------------------------------------
# load generation
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SessionRequest:
    """One incoming stream session: a skeleton clip arriving at a tick."""

    sid: int
    arrival: int             # tick index at which the session arrives
    clip: np.ndarray         # (T, V, C) raw skeleton frames


@dataclasses.dataclass
class SessionRecord:
    """A completed session: identity, timing, and the final logits."""

    sid: int
    frames: int              # clip length T (real frames)
    arrival: int             # tick of arrival (queue entry)
    admitted: int            # tick of slot admission
    finished: int            # tick the drained logits were captured
    wall_admitted: float     # monotonic seconds
    wall_first_logit: float  # first *valid* logit contribution for this slot
    wall_finished: float
    logits: np.ndarray       # (num_classes,) post-drain prediction


def poisson_arrivals(
    n_sessions: int,
    mean_interarrival: float,
    lengths: Sequence[int],
    joints: int,
    channels: int,
    seed: int = 0,
    clip_source: Optional[Callable[[int, int], np.ndarray]] = None,
) -> List[SessionRequest]:
    """Poisson-process session arrivals (exponential inter-arrival ticks).

    Each session draws a clip length uniformly from ``lengths`` and clip
    content from ``clip_source(sid, T) -> (T, V, C)`` (standard-normal
    synthetic skeletons by default — the serving driver swaps in the data
    pipeline).  Returns requests sorted by arrival tick."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(mean_interarrival, size=n_sessions)
    arrivals = np.floor(np.cumsum(gaps) - gaps[0]).astype(int)
    reqs = []
    for sid, at in enumerate(arrivals):
        T = int(rng.choice(np.asarray(lengths)))
        if clip_source is not None:
            clip = np.asarray(clip_source(sid, T), np.float32)
        else:
            clip = rng.standard_normal((T, joints, channels)).astype(np.float32)
        reqs.append(SessionRequest(sid=sid, arrival=int(at), clip=clip))
    return reqs


# ---------------------------------------------------------------------------
# the scheduler
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _Slot:
    """Host-side view of one slab slot holding an admitted session."""

    req: SessionRequest
    admitted: int            # admission tick
    rel: int                 # raw frames fed so far (clip + flush)
    total: int               # clip length + flush drain
    wall_admitted: float
    wall_first_logit: float = -1.0


class SlabScheduler:
    """Slot table + FIFO admission queue driving ``engine.step_frames``.

    Pure host logic over numpy arrays: each tick, :meth:`tick_inputs`
    builds the (frames, valid, reset) triple the jitted slab step consumes,
    and :meth:`tick_outputs` consumes the step's logits — finalising any
    session whose flush drain completed this tick and recycling its slot.

    Timing is delegated to two plan-derived callables so the scheduler
    itself stays jax-free: ``flush_frames(T)`` (the per-block 'same'-padding
    drain after a T-frame clip, ``engine.stream_flush_frames``) and
    ``first_logit_delay`` (raw frames from admission to the first valid
    logit, ``engine.stream_first_logit_delay``)."""

    def __init__(self, slots: int, joints: int, channels: int,
                 flush_frames: Callable[[int], int],
                 first_logit_delay: int):
        self.slots: List[Optional[_Slot]] = [None] * slots
        self.joints, self.channels = joints, channels
        self.flush_frames = flush_frames
        self.first_logit_delay = first_logit_delay
        self.queue: deque[SessionRequest] = deque()
        self.completed: List[SessionRecord] = []
        self.occupancy_samples: List[float] = []
        self.valid_frames = 0        # real (clip) frames fed across all slots

    # -- admission -----------------------------------------------------------

    def submit(self, req: SessionRequest) -> None:
        """Queue an arrived session (FIFO until a slot frees up)."""
        self.queue.append(req)

    def busy(self) -> int:
        """Occupied slot count (active + draining)."""
        return sum(s is not None for s in self.slots)

    def idle(self) -> bool:
        """True when no session is queued or occupying a slot."""
        return not self.queue and self.busy() == 0

    # -- one tick ------------------------------------------------------------

    def tick_inputs(self, tick: int, now: float):
        """Admit queued sessions into free slots and build the step inputs.

        Returns ``(frames (S, V, C) f32, valid (S,) bool, reset (S,) bool)``:
        reset marks this tick's admissions (the traced slot zeroing), valid
        marks slots feeding real clip frames (False = flush drain or free
        slot — both take the zero-padding path)."""
        S = len(self.slots)
        for s in range(S):
            if self.slots[s] is None and self.queue:
                req = self.queue.popleft()
                self.slots[s] = _Slot(
                    req=req, admitted=tick, rel=0,
                    total=len(req.clip) + self.flush_frames(len(req.clip)),
                    wall_admitted=now)
        frames = np.zeros((S, self.joints, self.channels), np.float32)
        valid = np.zeros((S,), bool)
        reset = np.zeros((S,), bool)
        for s, slot in enumerate(self.slots):
            if slot is None:
                continue
            reset[s] = slot.admitted == tick
            if slot.rel < len(slot.req.clip):
                frames[s] = slot.req.clip[slot.rel]
                valid[s] = True
                self.valid_frames += 1
        self.occupancy_samples.append(self.busy() / S)
        return frames, valid, reset

    def tick_outputs(self, tick: int, logits: np.ndarray, now: float
                     ) -> List[SessionRecord]:
        """Advance slot clocks with this tick's logits; evict drained slots.

        ``logits`` is the slab step's (S, num_classes) output.  A slot whose
        session just produced its first valid logit records the wall time
        (admission-to-first-logit delay); a slot whose flush drain completed
        captures its logits row as the session's final prediction, is freed,
        and the finished :class:`SessionRecord` is returned (and appended to
        ``self.completed``)."""
        done: List[SessionRecord] = []
        for s, slot in enumerate(self.slots):
            if slot is None:
                continue
            if slot.rel == self.first_logit_delay - 1:
                slot.wall_first_logit = now
            if slot.rel == slot.total - 1:
                rec = SessionRecord(
                    sid=slot.req.sid, frames=len(slot.req.clip),
                    arrival=slot.req.arrival, admitted=slot.admitted,
                    finished=tick, wall_admitted=slot.wall_admitted,
                    wall_first_logit=slot.wall_first_logit,
                    wall_finished=now,
                    logits=np.asarray(logits[s]))
                done.append(rec)
                self.completed.append(rec)
                self.slots[s] = None
            else:
                slot.rel += 1
        return done


# ---------------------------------------------------------------------------
# the serving loop
# ---------------------------------------------------------------------------

def run_sessions(
    cfg,
    *,
    slots: int = 8,
    n_sessions: int = 16,
    mean_interarrival: float = 8.0,
    lengths: Optional[Sequence[int]] = None,
    backend: str = "reference",
    quant: bool = True,
    seed: int = 0,
    max_ticks: int = 100_000,
) -> Dict:
    """Serve ``n_sessions`` Poisson-arriving skeleton sessions through an
    ``slots``-slot slab with the two-stream (joint + bone) ensemble.

    Compiles one ExecutionPlan per stream for ``backend``, calibrates the
    shared frozen BN statistics once from a pipeline clip batch, then runs
    the scheduler tick loop: one jitted ``make_gcn_slab_step`` call per
    tick serves every slot (admissions via the traced reset mask, drains
    via per-slot validity).  Returns the metrics dict (also the row written
    to ``BENCH_sessions.json`` by ``serve --sessions``) plus the completed
    :class:`SessionRecord` list under ``"records"``."""
    import jax
    import jax.numpy as jnp

    from repro.core.agcn import engine
    from repro.core.agcn.model import bone_stream
    from repro.core.pruning.plan import plan_from_config
    from repro.data.pipeline import DataConfig, skeleton_batches
    from repro.models import registry
    from repro.train.steps import make_gcn_slab_step

    prune_plan = plan_from_config(cfg)
    kj, kb = jax.random.split(jax.random.PRNGKey(seed))
    params_joint = registry.init_params(cfg, kj)
    params_bone = registry.init_params(cfg, kb)
    plans = tuple(
        engine.build_execution_plan(p, cfg, prune_plan, quant=quant,
                                    backend=backend)
        for p in (params_joint, params_bone))

    # calibration + load: clips come from the same synthetic NTU pipeline
    dcfg = DataConfig(global_batch=max(4, slots), seq_len=cfg.gcn_frames,
                      seed=seed)
    calib = jnp.asarray(next(skeleton_batches(cfg, dcfg))["x"])
    slabs = (
        engine.init_session_slab(plans[0], slots, x_calib=calib),
        engine.init_session_slab(plans[1], slots,
                                 x_calib=bone_stream(calib)),
    )

    if lengths is None:
        lengths = (cfg.gcn_frames, max(2, cfg.gcn_frames // 2))
    pool = np.asarray(next(skeleton_batches(
        cfg, DataConfig(global_batch=n_sessions, seq_len=cfg.gcn_frames,
                        seed=seed + 1)))["x"])

    def clip_source(sid: int, T: int) -> np.ndarray:
        return pool[sid % len(pool), :T]

    reqs = poisson_arrivals(
        n_sessions, mean_interarrival, lengths,
        cfg.gcn_joints, cfg.gcn_in_channels, seed=seed,
        clip_source=clip_source)
    sched = SlabScheduler(
        slots, cfg.gcn_joints, cfg.gcn_in_channels,
        flush_frames=lambda T: engine.stream_flush_frames(plans[0], T),
        first_logit_delay=engine.stream_first_logit_delay(plans[0]))

    step = jax.jit(make_gcn_slab_step(cfg))
    # compile outside the timed loop (both reset variants trace identically
    # — reset is a traced mask — so one warmup call suffices)
    zf = jnp.zeros((slots, cfg.gcn_joints, cfg.gcn_in_channels))
    zb = jnp.zeros((slots,), bool)
    warm, wl = step(plans, slabs, zf, zb, zb)
    jax.block_until_ready(wl)

    pending = deque(reqs)
    tick = 0
    t0 = time.monotonic()
    while tick < max_ticks:
        while pending and pending[0].arrival <= tick:
            sched.submit(pending.popleft())
        if sched.idle():
            if not pending:
                break
            tick = pending[0].arrival       # fast-forward empty gaps
            continue
        now = time.monotonic()
        frames, valid, reset = sched.tick_inputs(tick, now)
        slabs, logits = step(plans, slabs, jnp.asarray(frames),
                             jnp.asarray(valid), jnp.asarray(reset))
        logits_np = np.asarray(logits)      # blocks until the tick is done
        sched.tick_outputs(tick, logits_np, time.monotonic())
        tick += 1
    wall = time.monotonic() - t0

    recs = sched.completed
    lat = np.asarray([r.wall_finished - r.wall_admitted for r in recs])
    first = np.asarray([r.wall_first_logit - r.wall_admitted
                        for r in recs if r.wall_first_logit >= 0])
    qwait = np.asarray([r.admitted - r.arrival for r in recs], np.float64)
    return {
        "backend": backend,
        "slots": slots,
        "sessions": len(recs),
        "ticks": tick,
        "wall_s": wall,
        "frames_per_s": sched.valid_frames / wall if wall > 0 else 0.0,
        "ticks_per_s": tick / wall if wall > 0 else 0.0,
        "occupancy": float(np.mean(sched.occupancy_samples)
                           if sched.occupancy_samples else 0.0),
        "latency_ms_p50": float(np.percentile(lat, 50) * 1e3) if len(lat) else 0.0,
        "latency_ms_p99": float(np.percentile(lat, 99) * 1e3) if len(lat) else 0.0,
        "first_logit_ms_p50": (float(np.percentile(first, 50) * 1e3)
                               if len(first) else 0.0),
        "first_logit_frames": engine.stream_first_logit_delay(plans[0]),
        "queue_wait_ticks_mean": float(qwait.mean()) if len(qwait) else 0.0,
        "records": recs,
    }


def write_bench(results: List[Dict], path: str = DEFAULT_BENCH_PATH) -> None:
    """Write the multi-session serving rows to ``BENCH_sessions.json`` —
    the artifact ``serve --sessions`` emits (aggregate frames/s, occupancy,
    latency percentiles per backend)."""
    rows = []
    for r in results:
        rows.append({k: v for k, v in r.items() if k != "records"})
    with open(path, "w") as f:
        json.dump(rows, f, indent=1)
