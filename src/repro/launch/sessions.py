"""Deprecated shim — the session stack moved to :mod:`repro.serving`.

The PR-3/PR-4 serving surface (``SlabScheduler``, ``AdmissionQueue``,
``TickPlan``, ``run_sessions``, the load generators and the BENCH row
merge) now lives behind the :class:`repro.serving.GcnService` facade:

    from repro.serving import GcnService, run_sessions, SlabScheduler

Every public name this module used to define resolves lazily from
``repro.serving`` with a :class:`DeprecationWarning`; new code should
import from ``repro.serving`` directly.  This shim will be removed once
no caller hits the warning."""
from __future__ import annotations

import warnings

_MOVED = (
    "AdmissionQueue",
    "DEFAULT_BENCH_PATH",
    "QOS_POLICIES",
    "SessionRecord",
    "SessionRequest",
    "SlabScheduler",
    "TickPlan",
    "bench_key",
    "bursty_arrivals",
    "poisson_arrivals",
    "run_sessions",
    "write_bench",
)


def __getattr__(name: str):
    """Lazily forward moved names to ``repro.serving`` (with a warning)."""
    if name in _MOVED:
        warnings.warn(
            f"repro.launch.sessions.{name} moved to repro.serving.{name}; "
            "this shim will be removed in a future PR",
            DeprecationWarning, stacklevel=2)
        import repro.serving as serving
        return getattr(serving, name)
    raise AttributeError(
        f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    """Expose the forwarded surface to introspection."""
    return sorted(_MOVED)
