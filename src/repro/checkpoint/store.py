"""Sharded checkpointing: save/restore of (params, opt_state, step) with a
manifest (tree structure + shapes + dtypes + per-leaf checksums) so restores
are integrity-checked and resharding-safe.

Layout:  <dir>/step_<n>/manifest.json + leaf_<i>.npy (one file per leaf —
the analogue of per-shard files in a multi-host run; on a real cluster each
host writes its own address-able shards, see fault/elastic.py for the
re-sharding path).  Writes are atomic (tmp dir + rename) and an optional
background thread makes them async.
"""
from __future__ import annotations

import hashlib
import json
import os
import pathlib
import shutil
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _leaf_paths(tree) -> Dict[str, Any]:
    flat = jax.tree_util.tree_leaves_with_path(tree)
    return {
        "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path): leaf
        for path, leaf in flat
    }


def save(ckpt_dir: str, step: int, tree, *, keep: int = 3) -> str:
    base = pathlib.Path(ckpt_dir)
    tmp = base / f".tmp_step_{step}"
    final = base / f"step_{step}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    manifest = {"step": step, "leaves": {}}
    for i, (name, leaf) in enumerate(_leaf_paths(tree).items()):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"leaf_{i}.npy"
        # np.save can't represent ml_dtypes (bf16 etc.) — store raw uint view
        stored = arr.view(np.uint16) if arr.dtype.itemsize == 2 and \
            arr.dtype.kind == "V" or str(arr.dtype) == "bfloat16" else arr
        np.save(tmp / fname, stored)
        manifest["leaves"][name] = {
            "file": fname,
            "shape": list(arr.shape),
            "dtype": str(arr.dtype),
            "sha256": hashlib.sha256(arr.tobytes()).hexdigest()[:16],
        }
    (tmp / "manifest.json").write_text(json.dumps(manifest, indent=2))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)                                   # atomic publish
    _gc(base, keep)
    return str(final)


def save_async(ckpt_dir: str, step: int, tree, *, keep: int = 3) -> threading.Thread:
    """Device-get happens on the caller thread (cheap, blocks until the step
    is done), the file I/O on a worker thread — overlap with the next step."""
    host_tree = jax.device_get(tree)
    t = threading.Thread(target=save, args=(ckpt_dir, step, host_tree),
                         kwargs={"keep": keep}, daemon=True)
    t.start()
    return t


def latest_step(ckpt_dir: str) -> Optional[int]:
    base = pathlib.Path(ckpt_dir)
    if not base.exists():
        return None
    steps = [
        int(p.name.split("_")[1]) for p in base.iterdir()
        if p.name.startswith("step_") and (p / "manifest.json").exists()
    ]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, like, *, verify: bool = True):
    """Restore into the structure of ``like`` (shapes must match)."""
    final = pathlib.Path(ckpt_dir) / f"step_{step}"
    manifest = json.loads((final / "manifest.json").read_text())
    names = list(_leaf_paths(like).keys())
    missing = [n for n in names if n not in manifest["leaves"]]
    if missing:
        raise ValueError(f"checkpoint missing leaves: {missing[:5]}")

    out = []
    for name in names:
        meta = manifest["leaves"][name]
        arr = np.load(final / meta["file"])
        if str(arr.dtype) != meta["dtype"]:
            import ml_dtypes
            arr = arr.view(np.dtype(meta["dtype"]) if meta["dtype"] in
                           np.sctypeDict else getattr(ml_dtypes, meta["dtype"]))
        if verify:
            h = hashlib.sha256(arr.tobytes()).hexdigest()[:16]
            if h != meta["sha256"]:
                raise IOError(f"checksum mismatch for {name}")
        out.append(arr)
    leaves, treedef = jax.tree_util.tree_flatten(like)
    return jax.tree_util.tree_unflatten(treedef, out)


def _gc(base: pathlib.Path, keep: int):
    steps = sorted(
        int(p.name.split("_")[1]) for p in base.iterdir()
        if p.name.startswith("step_")
    )
    for s in steps[:-keep]:
        shutil.rmtree(base / f"step_{s}", ignore_errors=True)
