"""Elastic slot-capacity management for the session slab.

The ROADMAP's elastic-capacity item: slot capacity S is a *compiled* shape
(one jit cache entry of ``engine.step_frames`` per S), so growing and
shrinking with traffic means hopping between **pre-built capacity tiers**
— one slab (and one warmed compiled step) per tier — and migrating the
active sessions' device state across slabs with the same
``engine.snapshot_slots``/``restore_slots`` primitives that QoS preemption
uses.  High-performance GCN serving hinges on keeping compiled capacity
matched to load (cf. arXiv:2305.18710): a fixed small slab queues traffic
peaks, a fixed large slab pays the full-S tick cost through the lulls.

This module is the pure-host *decision* half (unit-testable without jax):
:class:`CapacityManager` watches queue depth + slot occupancy each tick
and emits grow/shrink decisions under hysteresis; the :class:`GcnService`
facade executes them (slab reset + snapshot/restore migration + scheduler
:meth:`~repro.serving.scheduler.SlabScheduler.resize`).

Hysteresis: demand must exceed the current tier for ``grow_patience``
consecutive ticks before growing (to the smallest tier that fits), fit
inside the next smaller tier for ``shrink_patience`` consecutive ticks
before shrinking (one tier at a time), and any resize starts a
``cooldown`` window during which no further resize is considered — so a
grow is never immediately undone by the next tick's lull (locked by
tests/test_serving.py: no grow→shrink→grow inside 3 ticks)."""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class CapacityConfig:
    """Hysteresis knobs for :class:`CapacityManager`.

    ``tiers`` are the available slot capacities (sorted ascending at use);
    ``grow_patience``/``shrink_patience`` are the consecutive-tick
    thresholds demand must hold before a resize fires, and ``cooldown``
    is the post-resize window during which no new decision is taken.
    ``cooldown`` must be ≥ 3 to make the no-thrash guarantee (no
    grow→shrink→grow within 3 ticks) structural."""

    tiers: Tuple[int, ...] = (2, 4, 8, 16)
    grow_patience: int = 2
    shrink_patience: int = 8
    cooldown: int = 4

    def __post_init__(self):
        if len(self.tiers) < 1 or any(t <= 0 for t in self.tiers):
            raise ValueError(f"invalid capacity tiers {self.tiers!r}")
        if len(set(self.tiers)) != len(self.tiers):
            raise ValueError(f"duplicate capacity tiers {self.tiers!r}")
        if self.cooldown < 3:
            raise ValueError("cooldown must be >= 3 ticks (the no-thrash "
                             "hysteresis guarantee)")


@dataclasses.dataclass
class ResizeEvent:
    """One committed capacity change (for metrics / BENCH rows).

    ``wall_ms`` is filled in by the service after it executes the
    migration (snapshot occupied slots → reset target slab → restore)."""

    tick: int
    old: int
    new: int
    busy: int                # active sessions migrated
    queued: int              # queue depth at decision time
    wall_ms: float = 0.0


class CapacityManager:
    """Hysteresis-based grow/shrink decisions over a fixed tier ladder.

    Pure host logic: call :meth:`observe` once per scheduler tick with the
    current busy-slot count and queue depth; it returns the target tier
    capacity when a resize should happen *this tick* (the caller executes
    the migration and must honor the decision), else None.

    Policy: demand = busy + queued.
      grow   — demand > current capacity for ``grow_patience`` consecutive
               ticks → jump to the smallest tier that fits demand (capped
               at the top tier).
      shrink — demand ≤ the next smaller tier for ``shrink_patience``
               consecutive ticks → step down exactly one tier (repeated
               lulls walk down the ladder one cooldown at a time).
      cooldown — for ``cooldown`` ticks after any resize, pressure
               counters are frozen at zero and no decision is taken."""

    def __init__(self, config: CapacityConfig = CapacityConfig(),
                 start_tier: Optional[int] = None):
        self.config = config
        self.tiers: Tuple[int, ...] = tuple(sorted(config.tiers))
        if start_tier is None:
            self._idx = 0
        else:
            if start_tier not in self.tiers:
                raise ValueError(
                    f"start_tier {start_tier} not in tiers {self.tiers}")
            self._idx = self.tiers.index(start_tier)
        self._grow = 0
        self._shrink = 0
        self._cooldown_until = -1
        self.events: List[ResizeEvent] = []

    @property
    def capacity(self) -> int:
        """The current tier's slot capacity."""
        return self.tiers[self._idx]

    def observe(self, busy: int, queued: int, tick: int) -> Optional[int]:
        """One tick's load sample → an optional resize target (slots).

        Must be called before the scheduler's admissions for the tick so a
        grow decision admits queued sessions into the new slots
        immediately.  Returns the new capacity (the caller migrates and
        resizes), or None."""
        if tick < self._cooldown_until:
            return None
        demand = busy + queued
        can_grow = self._idx < len(self.tiers) - 1
        can_shrink = self._idx > 0
        if can_grow and demand > self.capacity:
            self._grow += 1
            self._shrink = 0
        elif can_shrink and demand <= self.tiers[self._idx - 1]:
            self._shrink += 1
            self._grow = 0
        else:
            self._grow = self._shrink = 0
        if self._grow >= self.config.grow_patience:
            target = self._idx + 1
            while (target < len(self.tiers) - 1
                   and self.tiers[target] < demand):
                target += 1
            return self._commit(target, busy, queued, tick)
        if self._shrink >= self.config.shrink_patience:
            return self._commit(self._idx - 1, busy, queued, tick)
        return None

    def _commit(self, idx: int, busy: int, queued: int, tick: int) -> int:
        self.events.append(ResizeEvent(
            tick=tick, old=self.capacity, new=self.tiers[idx],
            busy=busy, queued=queued))
        self._idx = idx
        self._grow = self._shrink = 0
        self._cooldown_until = tick + self.config.cooldown
        return self.capacity
