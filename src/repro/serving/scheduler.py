"""Session-slab scheduling internals behind the :class:`GcnService` facade.

This module is the host-side half of multi-session stream serving (moved
here from ``repro.launch.sessions`` — that path is now a deprecation
shim).  The streaming engine serves *one* lockstep batch of streams; live
traffic is many independent skeleton sessions arriving and ending at
different times — the continual-inference regime of CoST-GCN (Hedegaard et
al., 2022) at the throughput target of the ROADMAP:

  device  — a fixed-capacity **session slab**: one ``engine.StreamState``
            whose leading axis is S slots, advanced by one jitted
            ``engine.step_frames(plan, slab, frames[S], valid[S],
            reset[S], hold[S])`` per tick (compiled once per
            ExecutionPlan, any occupancy).  Preemption is the engine's
            ``snapshot_slots`` (one traced gather over every per-slot
            leaf) and resume is ``restore_slots`` (the inverse scatter).
  host    — :class:`SlabScheduler`: a slot table + priority admission
            queue (:class:`AdmissionQueue`, strict (priority, arrival)
            order) with a pluggable QoS policy:

              fifo     — run-to-completion (the default; with uniform
                         priorities this is exactly FIFO admission).
              preempt  — a queued strictly-higher-priority session may
                         snapshot-evict the lowest-priority active slot;
                         the victim re-queues (keeping its progress and
                         device snapshot) and later restores into a free
                         slot and resumes.
              deadline — sessions whose completion deadline has passed
                         are dropped from the queue or evicted from their
                         slot and counted as ``missed``.

Sessions come in two flavors sharing one code path: **closed** sessions
arrive with their whole clip (``SessionRequest(clip=...)`` — the batch
load-generator path), while **open** sessions (the ``GcnService``
open/submit/poll/close path) grow a frame buffer incrementally and are
*held* — per-slot frozen in place via the engine's ``hold`` mask, not
zero-padded — whenever the buffer is empty but the stream has not been
closed.

The scheduler is pure host bookkeeping (numpy in, numpy out) so it unit-
tests without jax — device snapshots never enter it; :meth:`tick_inputs`
returns a :class:`TickPlan` naming which slots to snapshot/restore and the
driver (:class:`repro.serving.GcnService`) holds the captures.
"""
from __future__ import annotations

import dataclasses
import heapq
import json
import os
import warnings
from collections import deque
from typing import Any, Callable, Deque, Dict, List, Optional, Sequence, Tuple

import numpy as np

DEFAULT_BENCH_PATH = "BENCH_sessions.json"

QOS_POLICIES = ("fifo", "preempt", "deadline")

# sentinel (slot, ring row) value padding the fixed-shape event-order
# buffers consumed by the fused serving tick — must equal
# ``repro.core.agcn.engine.SNAP_SENTINEL`` (redefined here as a plain int
# so this module stays jax-free; equality is locked in tests)
SNAP_SENTINEL = 2 ** 30

# per-tick snapshot/restore event budget cap: the fused tick's order
# buffers are padded to a *static* ``max_events_for(slots)`` rows, so
# every sentinel row costs one (dropped) gather/scatter per leaf per tick
# — capping keeps that overhead bounded at large slot counts while the
# scheduler defers surplus preemptions/restores to later ticks
MAX_EVENTS_PER_TICK = 8


def max_events_for(slots: int) -> int:
    """The static per-tick snapshot/restore event-buffer width for a
    ``slots``-slot tier: ``min(slots, max(MAX_EVENTS_PER_TICK,
    slots // 8))``.  One tick can structurally produce at most ``slots``
    events of either kind (each slot is evicted/admitted at most once per
    tick); the floor bounds the padded no-op gather/scatter cost at small
    tiers, while the ``slots // 8`` term scales the budget with capacity
    so a big slab's preemption throughput isn't starved at 8 events/tick
    (at S=256 a fixed budget would need 32 ticks to turn the slab over)."""
    slots = int(slots)
    return min(slots, max(MAX_EVENTS_PER_TICK, slots // 8))


def pad_event_orders(events: Sequence[Tuple[int, int]],
                     max_events: int) -> np.ndarray:
    """Pad a list of (slot, ring row) events to the fixed-shape
    ``(max_events, 2)`` int32 order buffer the fused tick consumes, with
    :data:`SNAP_SENTINEL` no-op rows — any event count from 0 to
    ``max_events`` reuses one compilation per tier.  Raises when the
    events overflow the static buffer (the scheduler's own budgets make
    that structurally impossible; direct callers must size ahead)."""
    if len(events) > max_events:
        raise ValueError(
            f"{len(events)} snapshot/restore events overflow the static "
            f"max_events={max_events} order buffer — the fused tick's "
            "shapes are compiled per tier and cannot grow at traffic time")
    out = np.full((max_events, 2), SNAP_SENTINEL, np.int32)
    for i, (slot, row) in enumerate(events):
        out[i] = (slot, row)
    return out


# ---------------------------------------------------------------------------
# load generation
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SessionRequest:
    """One incoming stream session.

    Two construction modes share this type:

    * **closed** — ``clip`` is the whole (T, V, C) skeleton clip up front
      (the load-generator path); the session's service time is known at
      admission.
    * **open** — ``clip=None``; frames arrive incrementally via
      :meth:`push_frame` and the stream ends with :meth:`close` (the
      ``GcnService.submit``/``close`` path).  Until closed, a starved slot
      is held in place rather than flushed.

    ``priority`` orders admission (larger = more urgent; ties are FIFO by
    arrival) and selects preemption victims under the ``preempt`` policy;
    ``deadline`` is the absolute tick by which the session must *complete*
    under the ``deadline`` policy (None = no deadline).  ``degrade`` is the
    frame-skip stride (1 = full fidelity): a degraded session is served on
    every ``degrade``-th raw frame only — the SLO controller's shed-by-
    fidelity mode — so it occupies its slot for ~1/stride the ticks.

    When the scheduler runs a :class:`~repro.serving.saliency.SaliencyGate`
    the gate attaches ``sal_kept`` (the kept raw-frame indices) and its
    scorer state to this object — request attributes, so they ride
    preemption re-queues and cross-replica export/import — and the
    degrade stride then decimates the *kept* subsequence."""

    sid: int
    arrival: int             # tick index at which the session arrives
    clip: Optional[np.ndarray] = None   # (T, V, C) raw frames (closed mode)
    priority: int = 0
    deadline: Optional[int] = None
    degrade: int = 1         # frame-skip stride (1 = every frame)
    topology: Optional[str] = None      # skeleton name (None = the
                                        # service's primary topology)

    def __post_init__(self):
        self._buf: List[np.ndarray] = []
        self._closed = self.clip is not None
        self._released: Optional[int] = None

    def push_frame(self, frame: np.ndarray) -> None:
        """Append one (V, C) raw frame to an open session's buffer."""
        if self._closed:
            raise ValueError(f"session {self.sid} is closed")
        self._buf.append(np.asarray(frame, np.float32))

    def close(self) -> None:
        """End an open session's stream: no more frames will arrive, so the
        scheduler can compute the flush-drain budget and finish it."""
        self._closed = True

    def is_closed(self) -> bool:
        """True once the stream has ended (closed clips always are)."""
        return self._closed

    def n_frames(self) -> int:
        """Raw frames available so far (clip length for closed sessions;
        the final count survives :meth:`release_frames`)."""
        if self._released is not None:
            return self._released
        return len(self.clip) if self.clip is not None else len(self._buf)

    def kept_frames(self) -> int:
        """Frames surviving the saliency gate (``len(sal_kept)`` once a
        gate has scored this session; all raw frames otherwise).  The
        count the scheduler's feed clock and service-time budget run on —
        saliency-skipped frames simply don't exist to the slab."""
        kept = getattr(self, "sal_kept", None)
        return len(kept) if kept is not None else self.n_frames()

    def eff_frames(self) -> int:
        """Frames the scheduler will actually feed: ``kept_frames``
        (saliency-gated; raw when no gate) decimated by the ``degrade``
        stride (``ceil(kept / degrade)`` — frame 0 is always kept and
        served, so a non-empty session always feeds at least 1)."""
        return -(-self.kept_frames() // max(1, int(self.degrade)))

    def frame(self, i: int) -> np.ndarray:
        """The i-th raw (V, C) frame."""
        return self.clip[i] if self.clip is not None else self._buf[i]

    def release_frames(self) -> None:
        """Drop the frame payload once the session has finished (or been
        dropped) and its outcome is recorded — a long-lived service must
        not pin every served clip in memory.  ``n_frames`` keeps
        answering with the final count; ``frame`` is no longer valid."""
        self._released = self.n_frames()
        self.clip = None
        self._buf = []


@dataclasses.dataclass
class SessionRecord:
    """A completed session: identity, timing, QoS history, final logits."""

    sid: int
    frames: int              # clip length T (real frames)
    arrival: int             # tick of arrival (queue entry)
    admitted: int            # tick of first slot admission
    finished: int            # tick the drained logits were captured
    wall_admitted: float     # monotonic seconds
    wall_first_logit: float  # first *valid* logit contribution for this slot
                             # (-1.0 sentinel: the session never produced one)
    wall_finished: float
    logits: np.ndarray       # (num_classes,) post-drain prediction
    priority: int = 0
    preemptions: int = 0     # times this session was snapshot-evicted
    first_logit_tick: int = -1   # tick of the first valid logit (-1: never)
    degrade: int = 1         # frame-skip stride the session was served at
    frames_skipped: int = 0  # raw frames the saliency gate dropped


def _requests_from_arrivals(
    arrivals: np.ndarray,
    lengths: Sequence[int],
    joints: int,
    channels: int,
    rng: np.random.Generator,
    clip_source: Optional[Callable[[int, int], np.ndarray]],
    priorities: Optional[Sequence[int]],
    high_priority_ratio: float,
) -> List[SessionRequest]:
    """Shared request-building tail of the load generators: the priority
    mix (explicit ``priorities`` win over the ``high_priority_ratio``
    Bernoulli draw), the uniform clip-length choice, and clip content
    from ``clip_source(sid, T) -> (T, V, C)`` (standard-normal synthetic
    skeletons by default).  Draw order is part of the determinism
    contract: priorities first, then one (length, clip) pair per session
    in sid order, all from the caller's ``rng``."""
    if priorities is None:
        priorities = (rng.random(len(arrivals))
                      < high_priority_ratio).astype(int)
    reqs = []
    for sid, at in enumerate(arrivals):
        T = int(rng.choice(np.asarray(lengths)))
        if clip_source is not None:
            clip = np.asarray(clip_source(sid, T), np.float32)
        else:
            clip = rng.standard_normal((T, joints, channels)).astype(np.float32)
        reqs.append(SessionRequest(sid=sid, arrival=int(at), clip=clip,
                                   priority=int(priorities[sid])))
    return reqs


def poisson_arrivals(
    n_sessions: int,
    mean_interarrival: float,
    lengths: Sequence[int],
    joints: int,
    channels: int,
    seed: int = 0,
    clip_source: Optional[Callable[[int, int], np.ndarray]] = None,
    priorities: Optional[Sequence[int]] = None,
    high_priority_ratio: float = 0.0,
    rng: Optional[np.random.Generator] = None,
) -> List[SessionRequest]:
    """Poisson-process session arrivals (exponential inter-arrival ticks).

    Clip/priority semantics per :func:`_requests_from_arrivals`.  Returns
    requests sorted by arrival tick (the first arrival anchors tick 0).
    All randomness comes from ``rng`` when given (``default_rng(seed)``
    otherwise) — never from numpy's global state, so interleaved
    generators and concurrent benchmark runs cannot cross-contaminate."""
    rng = rng if rng is not None else np.random.default_rng(seed)
    gaps = rng.exponential(mean_interarrival, size=n_sessions)
    arrivals = np.floor(np.cumsum(gaps) - gaps[0]).astype(int)
    return _requests_from_arrivals(arrivals, lengths, joints, channels, rng,
                                   clip_source, priorities,
                                   high_priority_ratio)


def bursty_arrivals(
    n_sessions: int,
    lengths: Sequence[int],
    joints: int,
    channels: int,
    *,
    burst_size: int = 4,
    burst_gap: float = 1.0,
    lull_gap: float = 60.0,
    seed: int = 0,
    clip_source: Optional[Callable[[int, int], np.ndarray]] = None,
    priorities: Optional[Sequence[int]] = None,
    high_priority_ratio: float = 0.0,
    rng: Optional[np.random.Generator] = None,
) -> List[SessionRequest]:
    """Bursty Poisson arrivals: alternating traffic peaks and lulls.

    Sessions arrive in bursts of ``burst_size`` spaced by exponential
    ``burst_gap`` ticks, with an exponential ``lull_gap`` idle stretch
    between bursts — the elastic-capacity stress load (a fixed small slab
    queues the bursts, a fixed large slab idles through the lulls; the
    elastic tier manager should do neither).  Clip/priority semantics per
    :func:`_requests_from_arrivals`; as with :func:`poisson_arrivals`, all
    randomness comes from the explicit ``rng`` (or ``default_rng(seed)``),
    never numpy's global state."""
    rng = rng if rng is not None else np.random.default_rng(seed)
    gaps = []
    for i in range(n_sessions):
        if i == 0:
            gaps.append(0.0)
        elif i % burst_size == 0:
            gaps.append(rng.exponential(lull_gap))
        else:
            gaps.append(rng.exponential(burst_gap))
    arrivals = np.floor(np.cumsum(gaps)).astype(int)
    return _requests_from_arrivals(arrivals, lengths, joints, channels, rng,
                                   clip_source, priorities,
                                   high_priority_ratio)


# ---------------------------------------------------------------------------
# the scheduler
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _Slot:
    """Host-side view of one slab slot holding an admitted session.

    A preempted session is re-queued as this same object (progress,
    first-logit latch and preemption count travel with it), which is also
    how re-admission knows to restore its device snapshot rather than
    reset the slot.  ``total`` is None while the session is still open
    (clip length unknown); ``held`` marks a starved open session this tick
    (no step was taken for it)."""

    req: SessionRequest
    admitted: int            # first admission tick
    rel: int                 # frames fed so far (decimated clip + flush)
    total: Optional[int]     # eff. clip length + flush drain (None: open)
    wall_admitted: float
    wall_first_logit: float = -1.0
    first_logit_tick: int = -1    # tick-denominated twin of the wall latch
    preemptions: int = 0
    held: bool = False


class AdmissionQueue:
    """Priority admission queue: strict (priority desc, arrival, seq) order.

    With uniform priorities the (arrival, seq) tie-break makes this exactly
    a FIFO — today's behavior is the degenerate case, not a second code
    path.  Items are fresh :class:`SessionRequest`\\ s or preempted
    :class:`_Slot`\\ s awaiting re-admission (both carry the same ordering
    key through their request)."""

    def __init__(self):
        self._heap: List[Tuple[int, int, int, Any]] = []
        self._seq = 0
        self._by_sid: Dict[int, Any] = {}

    @staticmethod
    def _req(item) -> SessionRequest:
        return item.req if isinstance(item, _Slot) else item

    def push(self, item) -> None:
        """Queue a session (or a preempted slot) by (priority, arrival)."""
        r = self._req(item)
        heapq.heappush(self._heap, (-r.priority, r.arrival, self._seq, item))
        self._seq += 1
        self._by_sid[r.sid] = item

    def pop(self):
        """Remove and return the highest-priority (then earliest) item."""
        item = heapq.heappop(self._heap)[-1]
        self._by_sid.pop(self._req(item).sid, None)
        return item

    def peek(self):
        """The head item (next admission) without removing it, or None."""
        return self._heap[0][-1] if self._heap else None

    def peek_priority(self) -> Optional[int]:
        """Priority of the head item (the next admission), or None when
        the queue is empty (guarded: an empty heap used to IndexError)."""
        if not self._heap:
            return None
        return -self._heap[0][0]

    def remove(self, sid: int):
        """Remove and return the queued item with session id ``sid`` (a
        fresh request or a preempted slot awaiting re-admission), or None
        if that session is not queued — the cross-replica drain pulls a
        pinned session out of the admission queue here."""
        dropped = self.drop_if(lambda it: self._req(it).sid == sid)
        return dropped[0] if dropped else None

    def get(self, sid: int):
        """O(1) lookup by session id: the queued item (fresh request or
        preempted slot awaiting re-admission), or None if not queued —
        ``GcnService.poll`` runs this per call, so no linear scans."""
        return self._by_sid.get(sid)

    def drop_if(self, pred: Callable[[Any], bool]) -> List[Any]:
        """Remove and return every queued item for which ``pred`` holds
        (deadline expiry sweep); the queue keeps its heap order."""
        kept, dropped = [], []
        for entry in self._heap:
            (dropped if pred(entry[-1]) else kept).append(entry)
        if dropped:
            self._heap = kept
            heapq.heapify(self._heap)
        for e in dropped:
            self._by_sid.pop(self._req(e[-1]).sid, None)
        return [e[-1] for e in dropped]

    def __len__(self) -> int:
        return len(self._heap)

    def __bool__(self) -> bool:
        return bool(self._heap)

    def __iter__(self):
        """Iterate the queued items in heap (not pop) order — read-only
        inspection (e.g. ``GcnService.poll`` finding a preempted slot)."""
        return (entry[-1] for entry in self._heap)


@dataclasses.dataclass
class TickPlan:
    """One tick's device work order, built by ``SlabScheduler.tick_inputs``.

    ``frames``/``valid``/``reset``/``hold`` feed ``engine.step_frames``
    unchanged.  ``snapshot`` lists (slot, sid) pairs the driver must
    capture with ``engine.snapshot_slots`` *before* the step (preemption
    evictions); ``restore`` lists (slot, sid) pairs whose stored snapshot
    must be scattered back with ``engine.restore_slots`` before the step.

    When the scheduler was built with a snapshot ring (``snap_ring``),
    ``snap_order``/``rest_order`` additionally carry the same events as
    fixed-shape ``(max_events, 2)`` int32 (slot, ring row) buffers padded
    with :data:`SNAP_SENTINEL` — the one-dispatch form consumed by
    ``engine.fused_tick`` (None otherwise)."""

    frames: np.ndarray
    valid: np.ndarray
    reset: np.ndarray
    snapshot: List[Tuple[int, int]] = dataclasses.field(default_factory=list)
    restore: List[Tuple[int, int]] = dataclasses.field(default_factory=list)
    hold: Optional[np.ndarray] = None
    snap_order: Optional[np.ndarray] = None
    rest_order: Optional[np.ndarray] = None

    def __iter__(self):
        """Deprecated back-compat unpacking: ``frames, valid, reset =
        tick_inputs()`` — silently drops the ``hold`` mask (and the
        snapshot/restore orders), so drivers must migrate to the named
        fields."""
        warnings.warn(
            "unpacking TickPlan as a (frames, valid, reset) 3-tuple is "
            "deprecated: it drops the hold mask and the snapshot/restore "
            "orders — use the named fields (.frames/.valid/.reset/.hold/"
            ".snapshot/.restore)",
            DeprecationWarning, stacklevel=2)
        return iter((self.frames, self.valid, self.reset))


class SlabScheduler:
    """Slot table + priority admission queue driving ``engine.step_frames``.

    Pure host logic over numpy arrays: each tick, :meth:`tick_inputs`
    applies the QoS policy (deadline sweep, admissions, preemptions) and
    builds the :class:`TickPlan` the jitted slab step consumes, and
    :meth:`tick_outputs` consumes the step's logits — finalising any
    session whose flush drain completed this tick and recycling its slot.

    Timing is delegated to two plan-derived callables so the scheduler
    itself stays jax-free: ``flush_frames(T)`` (the per-block 'same'-padding
    drain after a T-frame clip, ``engine.stream_flush_frames``) and
    ``first_logit_delay`` (raw frames from admission to the first valid
    logit, ``engine.stream_first_logit_delay``).  Device snapshots never
    enter the scheduler either: preemption/restore are *named* in the
    TickPlan and executed by the driver.  Slot capacity is elastic through
    :meth:`resize` — the :class:`repro.serving.GcnService` capacity
    manager compacts active sessions into a different-size slot table and
    migrates their device state with the same snapshot/restore
    primitives."""

    def __init__(self, slots: int, joints: int, channels: int,
                 flush_frames: Callable[[int], int],
                 first_logit_delay: int,
                 policy: str = "fifo",
                 snap_ring: Optional[int] = None,
                 retain: int = 1024,
                 saliency: Optional[Any] = None):
        if policy not in QOS_POLICIES:
            raise ValueError(
                f"unknown QoS policy {policy!r} (expected one of "
                f"{QOS_POLICIES})")
        if retain < 1:
            raise ValueError(f"retain must be >= 1, got {retain}")
        self.slots: List[Optional[_Slot]] = [None] * slots
        self.joints, self.channels = joints, channels
        self.flush_frames = flush_frames
        self.first_logit_delay = first_logit_delay
        self.policy = policy
        self.queue = AdmissionQueue()
        # per-session bookkeeping is retention-bounded: a long-lived
        # service must not pin every served session's record forever, so
        # completed/missed keep only the most recent ``retain`` entries
        # while the running aggregates below carry the lifetime totals
        self.retain = int(retain)
        self.completed: Deque[SessionRecord] = deque(maxlen=self.retain)
        self.missed: Deque[SessionRequest] = deque()  # deadline casualties
        self.missed_sids: set = set()            # O(1) poll-side mirror
        self._occ_window: Deque[float] = deque(maxlen=self.retain)
        self.n_completed = 0         # lifetime finished-session count
        self.n_missed = 0            # lifetime deadline-miss count
        self.occ_sum = 0.0           # lifetime sum of busy/S samples
        self.occ_ticks = 0           # processed ticks (occupancy samples)
        self.qwait_sum = 0           # lifetime sum of arrival->admit waits
        # optional callback fired when the deadline policy drops a session
        # (after its frames are released) — the service uses it to bound
        # its own per-sid bookkeeping in lockstep
        self.on_miss: Optional[Callable[[SessionRequest], None]] = None
        # optional callback fired the tick a session's first valid logit
        # latches: (priority, arrival->latch ticks) — the measurement the
        # SLO controller's control loop closes on
        self.on_first_logit: Optional[Callable[[int, int], None]] = None
        self.valid_frames = 0        # real (clip) frames fed across all slots
        self.preemptions = 0         # snapshot-evictions performed
        self.restores = 0            # preempted sessions re-admitted
        # optional repro.serving.saliency.SaliencyGate: scores each
        # occupied slot's unscored frames every tick and the feed clock
        # serves only the kept subsequence (None = saliency off, the feed
        # path is byte-identical to the pre-saliency scheduler)
        self.saliency = saliency
        self.frames_skipped = 0      # lifetime saliency-dropped frames
                                     # across *finished* sessions
        # per-tick event budget: the fused tick's order buffers are padded
        # to this static width, and the QoS loops below never schedule more
        # snapshot (or restore) events per tick than it — surplus work
        # defers to later ticks.  Applied under every policy so the fused
        # and legacy drivers see identical TickPlans.
        self.max_events = max_events_for(slots)
        # optional host-side allocator for the on-device snapshot ring
        # (``engine.init_snapshot_ring``): rows are S-independent, so one
        # ring serves every capacity tier and survives elastic migrations.
        self.snap_ring = snap_ring
        self._ring_free: List[int] = (
            list(range(int(snap_ring))) if snap_ring is not None else [])
        self._ring_of: Dict[int, int] = {}       # sid -> occupied ring row

    @property
    def occupancy_samples(self) -> List[float]:
        """The most recent ``retain`` busy/S samples (one per processed
        tick) as a plain list — the retention window behind the lifetime
        ``occ_sum``/``occ_ticks`` aggregates."""
        return list(self._occ_window)

    # -- admission -----------------------------------------------------------

    def submit(self, req: SessionRequest) -> None:
        """Queue an arrived session (strict (priority, arrival) order —
        plain FIFO when every priority is equal)."""
        self.queue.push(req)

    def busy(self) -> int:
        """Occupied slot count (active + draining)."""
        return sum(s is not None for s in self.slots)

    def idle(self) -> bool:
        """True when no session is queued or occupying a slot."""
        return not self.queue and self.busy() == 0

    def resize(self, new_slots: int) -> Dict[int, int]:
        """Compact the occupied slots into a ``new_slots``-slot table.

        The elastic-capacity slot remap: active sessions keep their host
        state and are packed into slots ``0..k-1`` of the new table (k =
        busy count, which must fit — the capacity manager only shrinks
        when it does).  Returns the ``{old_slot: new_slot}`` mapping the
        driver uses to migrate the matching device rows via
        ``engine.snapshot_slots``/``restore_slots``.  Queue, records and
        counters are untouched."""
        occupied = [(s, slot) for s, slot in enumerate(self.slots)
                    if slot is not None]
        if len(occupied) > new_slots:
            raise ValueError(
                f"cannot resize to {new_slots} slots: {len(occupied)} "
                "sessions are active")
        mapping: Dict[int, int] = {}
        slots: List[Optional[_Slot]] = [None] * new_slots
        for ns, (s, slot) in enumerate(occupied):
            slots[ns] = slot
            mapping[s] = ns
        self.slots = slots
        self.max_events = max_events_for(new_slots)
        return mapping

    # -- policy helpers ------------------------------------------------------

    def _expired(self, item, tick: int) -> bool:
        r = AdmissionQueue._req(item)
        return r.deadline is not None and tick > r.deadline

    def _miss(self, item, tick: int) -> None:
        r = AdmissionQueue._req(item)
        # the outcome is recorded; drop the frame payload immediately so a
        # long-lived deadline service never pins dropped clips in memory
        r.release_frames()
        self.missed.append(r)
        self.missed_sids.add(r.sid)
        self.n_missed += 1
        while len(self.missed) > self.retain:
            old = self.missed.popleft()
            self.missed_sids.discard(old.sid)
        if self.on_miss is not None:
            self.on_miss(r)

    def sweep_expired(self, tick: int) -> int:
        """Drop every queued or active session whose deadline has passed;
        returns the number of sessions missed.  A no-op under non-deadline
        policies, and idempotent within a tick — the service calls this
        *before* the capacity manager observes demand (expired sessions
        are not demand and must not trigger a grow), and
        :meth:`tick_inputs` calls it again as part of the tick."""
        if self.policy != "deadline":
            return 0
        n = 0
        # queue sweep: expired sessions never reach a slot (only fresh
        # requests can be queued here — preempted _Slots exist only under
        # the mutually-exclusive preempt policy, so no stored snapshot can
        # be orphaned by a drop)
        for item in self.queue.drop_if(lambda it: self._expired(it, tick)):
            self._miss(item, tick)
            n += 1
        # slot sweep: evict sessions whose deadline passed mid-service
        for s, slot in enumerate(self.slots):
            if slot is not None and self._expired(slot, tick):
                self.slots[s] = None
                self._miss(slot, tick)
                n += 1
        return n

    def _admit(self, s: int, item, tick: int, now: float,
               reset: np.ndarray, restore: List[Tuple[int, int]]) -> None:
        """Place a queue item into free slot ``s``: fresh sessions get a
        traced reset, preempted sessions get a snapshot restore.  The
        service-time budget (``total``) stays None until the session's
        stream is closed (a closed clip resolves it on the first tick)."""
        if isinstance(item, _Slot):                  # resume a preemption
            self.slots[s] = item
            restore.append((s, item.req.sid))
            self.restores += 1
        else:
            self.slots[s] = _Slot(
                req=item, admitted=tick, rel=0, total=None,
                wall_admitted=now)
            reset[s] = True

    # -- one tick ------------------------------------------------------------

    def tick_inputs(self, tick: int, now: float) -> TickPlan:
        """Apply the QoS policy, admit into free slots, build step inputs.

        Returns a :class:`TickPlan` whose ``frames (S, V, C) f32``,
        ``valid (S,) bool``, ``reset (S,) bool`` and ``hold (S,) bool``
        feed the slab step (reset marks this tick's fresh admissions — the
        traced slot zeroing; valid marks slots feeding real clip frames,
        False = flush drain or free slot — both take the zero-padding
        path; hold marks starved *open* sessions frozen in place), plus
        the snapshot/restore slot lists the driver must execute around
        it."""
        S = len(self.slots)
        reset = np.zeros((S,), bool)
        snapshot: List[Tuple[int, int]] = []
        restore: List[Tuple[int, int]] = []

        self.sweep_expired(tick)

        for s in range(S):
            if self.slots[s] is None and self.queue:
                # restore-budget gate: re-admitting a preempted head costs
                # one restore event; once the tick's budget is spent, stop
                # admitting (skipping the head would break strict priority
                # order) — the queue drains next tick
                if (isinstance(self.queue.peek(), _Slot)
                        and len(restore) >= self.max_events):
                    break
                self._admit(s, self.queue.pop(), tick, now, reset, restore)

        if self.policy == "preempt":
            # a queued strictly-higher-priority session snapshot-evicts the
            # lowest-priority active slot (latest admission breaks ties —
            # the session with the least sunk progress yields first);
            # capped at max_events snapshots (and restores) per tick so
            # the fused tick's fixed-shape order buffers always fit —
            # surplus preemptions simply happen a tick later
            while self.queue:
                if len(snapshot) >= self.max_events:
                    break
                if (isinstance(self.queue.peek(), _Slot)
                        and len(restore) >= self.max_events):
                    break
                head_p = self.queue.peek_priority()
                cands = [(slot.req.priority, -slot.admitted, s)
                         for s, slot in enumerate(self.slots)
                         if slot is not None]
                if not cands:
                    break
                vp, _, vs = min(cands)
                if vp >= head_p:
                    break
                victim = self.slots[vs]
                snapshot.append((vs, victim.req.sid))
                victim.preemptions += 1
                self.preemptions += 1
                self.slots[vs] = None
                self.queue.push(victim)
                self._admit(vs, self.queue.pop(), tick, now, reset, restore)

        frames = np.zeros((S, self.joints, self.channels), np.float32)
        valid = np.zeros((S,), bool)
        hold = np.zeros((S,), bool)
        for s, slot in enumerate(self.slots):
            if slot is None:
                continue
            slot.held = False
            req = slot.req
            if self.saliency is not None and slot.total is None:
                # score any frames that arrived since last tick *before*
                # the budget/feed math below — the kept list is this
                # tick's ground truth for both
                self.saliency.extend(req)
            if slot.total is None and req.is_closed():
                # service-time budget in *effective* frames: a degraded
                # session's clip is stride-decimated (of the saliency-kept
                # subsequence when a gate runs), so both the clip phase
                # and the flush drain shrink by ~the stride
                n = req.eff_frames()
                slot.total = n + self.flush_frames(n)
            stride = max(1, int(req.degrade))
            kept = getattr(req, "sal_kept", None)
            if slot.rel * stride < req.kept_frames():
                # feed effective frame ``rel`` = kept frame ``rel*stride``
                # (raw index when no saliency gate; stride 1 = every kept
                # frame): the device sees a contiguous decimated stream —
                # no engine change, no hold-mask cost.  An open session
                # whose fresh frames were all saliency-skipped fails this
                # bound and is *held* below, exactly like a starved one.
                raw = (kept[slot.rel * stride] if kept is not None
                       else slot.rel * stride)
                f = req.frame(raw)
                # a narrower-topology frame rides zero-padded to the slab
                # width (its plan masks the padded joints)
                frames[s, : f.shape[0]] = f
                valid[s] = True
                self.valid_frames += 1
            elif slot.total is None:
                # open session with an empty buffer: freeze the slot (a
                # flush step here would inject zero padding mid-stream)
                hold[s] = True
                slot.held = True
        occ = self.busy() / S
        self._occ_window.append(occ)
        self.occ_sum += occ
        self.occ_ticks += 1
        snap_order = rest_order = None
        if self.snap_ring is not None:
            snap_order, rest_order = self._ring_orders(snapshot, restore)
        return TickPlan(frames=frames, valid=valid, reset=reset,
                        snapshot=snapshot, restore=restore, hold=hold,
                        snap_order=snap_order, rest_order=rest_order)

    def _ring_orders(self, snapshot: List[Tuple[int, int]],
                     restore: List[Tuple[int, int]]
                     ) -> Tuple[np.ndarray, np.ndarray]:
        """Assign ring rows to this tick's events and build the padded
        (slot, ring row) order buffers for ``engine.fused_tick``.

        Snapshot rows are allocated *before* restored rows are returned to
        the free list, so a row being read by this tick's restore scatter
        can never be handed to this tick's snapshot gather — within the
        fused dispatch the snapshot writes land first, and across ticks
        device execution follows dispatch order, so next-tick reuse is
        safe.  A same-tick snapshot→restore of one session (preempt-then-
        readmit) reads the row the snapshot just wrote, by construction of
        ``engine.fused_tick``."""
        snap_events = []
        for s, sid in snapshot:
            if not self._ring_free:
                raise RuntimeError(
                    f"snapshot ring exhausted ({self.snap_ring} rows, "
                    f"{len(self._ring_of)} live snapshots) — raise the "
                    "service's snap_capacity")
            row = self._ring_free.pop()
            self._ring_of[sid] = row
            snap_events.append((s, row))
        rest_events = []
        freed = []
        for s, sid in restore:
            row = self._ring_of.pop(sid)
            rest_events.append((s, row))
            freed.append(row)
        self._ring_free.extend(freed)
        return (pad_event_orders(snap_events, self.max_events),
                pad_event_orders(rest_events, self.max_events))

    def ring_adopt(self, sid: int) -> int:
        """Allocate a snapshot-ring row for session ``sid`` and return it —
        the import half of a cross-replica migration: the driver uploads
        the session's host snapshot into this row, and the next admission
        restores it exactly like a local preemption resume."""
        if self.snap_ring is None:
            raise RuntimeError("scheduler was built without a snapshot "
                               "ring (fused path only)")
        if not self._ring_free:
            raise RuntimeError(
                f"snapshot ring exhausted ({self.snap_ring} rows, "
                f"{len(self._ring_of)} live snapshots) — raise the "
                "service's snap_capacity")
        row = self._ring_free.pop()
        self._ring_of[sid] = row
        return row

    def ring_release(self, sid: int) -> int:
        """Free session ``sid``'s snapshot-ring row and return it — the
        export half of a cross-replica migration: the driver reads the row
        out of the device ring before the allocator recycles it (device
        execution follows dispatch order, so the read always lands before
        any later snapshot reuses the row)."""
        row = self._ring_of.pop(sid)
        self._ring_free.append(row)
        return row

    def tick_outputs(self, tick: int, logits: np.ndarray, now: float
                     ) -> List[SessionRecord]:
        """Advance slot clocks with this tick's logits; evict drained slots.

        ``logits`` is the slab step's (S, num_classes) output.  Held slots
        took no step and are skipped.  The first tick a slot's clock
        reaches the first-logit delay latches the wall time (a ``>=``
        latch, set once — the session keeps it across preemptions); a slot
        whose flush drain completed captures its logits row as the
        session's final prediction, is freed, and the finished
        :class:`SessionRecord` is returned (and appended to
        ``self.completed``)."""
        done: List[SessionRecord] = []
        for s, slot in enumerate(self.slots):
            if slot is None or slot.held:
                continue
            if (slot.wall_first_logit < 0
                    and slot.rel >= self.first_logit_delay - 1):
                slot.wall_first_logit = now
                slot.first_logit_tick = tick
                if self.on_first_logit is not None:
                    self.on_first_logit(slot.req.priority,
                                        tick - slot.req.arrival)
            if slot.total is not None and slot.rel == slot.total - 1:
                skipped = slot.req.n_frames() - slot.req.kept_frames()
                self.frames_skipped += skipped
                rec = SessionRecord(
                    sid=slot.req.sid, frames=slot.req.n_frames(),
                    arrival=slot.req.arrival, admitted=slot.admitted,
                    finished=tick, wall_admitted=slot.wall_admitted,
                    wall_first_logit=slot.wall_first_logit,
                    wall_finished=now,
                    logits=np.asarray(logits[s]),
                    priority=slot.req.priority,
                    preemptions=slot.preemptions,
                    first_logit_tick=slot.first_logit_tick,
                    degrade=max(1, int(slot.req.degrade)),
                    frames_skipped=skipped)
                done.append(rec)
                self.completed.append(rec)   # bounded deque (maxlen=retain)
                self.n_completed += 1
                self.qwait_sum += rec.admitted - rec.arrival
                self.slots[s] = None
            else:
                slot.rel += 1
        return done


# ---------------------------------------------------------------------------
# benchmark row persistence
# ---------------------------------------------------------------------------

def bench_key(row: Dict) -> Tuple:
    """Merge key of one ``BENCH_sessions.json`` row: ``(backend, slots,
    qos, capacity, load, mesh, replicas, policy, trace, topologies, ck,
    saliency)``.

    ``capacity`` distinguishes fixed-capacity runs (``"fixed"``, the
    default for rows written before the elastic axis existed) from elastic
    runs (``"elastic:2,4,8"`` — the tier tuple), and ``load`` the arrival
    process (``"poisson"`` default vs ``"burst"`` vs ``"trace"`` for
    trace replays) — without them an elastic run and its fixed baselines
    under the same (backend, slots, qos) would collide and clobber each
    other.  ``mesh`` (device-mesh size, default 1 = single device) and
    ``replicas`` (router replica count, default 1 = one service) are the
    distributed axes: a sharded or routed run must not clobber its
    single-device baseline.  ``policy`` (capacity-control policy,
    default ``"demand"`` for every pre-SLO row) and ``trace`` (the
    replayed trace's name/digest, default ``""`` for generated loads)
    are the A/B axes of the trace-replay harness: the same trace under
    ``demand`` vs ``slo`` must land as two comparable rows, not one
    clobbering the other.  ``topologies`` (the served skeleton set,
    default ``"ntu25"`` for every pre-variable-topology row) keeps an
    ``--topology ntu50`` run from clobbering its 25-joint baseline.
    ``ck`` (windowed C_k graph on, default False) and ``saliency`` (the
    gate threshold, default 0 = off) are the adaptive-streaming axes —
    legacy rows predate both features, so the defaults key them as
    feature-off runs."""
    return (row.get("backend"), row.get("slots"), row.get("qos", "fifo"),
            row.get("capacity", "fixed"), row.get("load", "poisson"),
            row.get("mesh", 1), row.get("replicas", 1),
            row.get("policy", "demand"), row.get("trace", ""),
            row.get("topologies", "ntu25"),
            bool(row.get("ck", False)), float(row.get("saliency", 0.0)))


def write_bench(results: List[Dict], path: str = DEFAULT_BENCH_PATH) -> None:
    """Merge the multi-session serving rows into ``BENCH_sessions.json``.

    Rows are keyed by :func:`bench_key` — ``(backend, slots, qos,
    capacity, load, mesh, replicas, policy, trace, topologies, ck,
    saliency)``, with legacy
    defaults (``qos="fifo"``, ``capacity="fixed"``, ``load="poisson"``,
    ``policy="demand"``, ``ck=False``, ``saliency=0``, …) for rows
    written before each
    axis existed: an existing row with the same key is replaced in place,
    every other row survives, and new keys are appended — so
    ``serve sessions --backend pallas`` refreshes only the pallas rows
    instead of clobbering the reference rows the README tables are
    rendered from (``tools/bench_tables.py``)."""
    existing: List[Dict] = []
    if os.path.exists(path):
        try:
            with open(path) as f:
                existing = json.load(f)
            if not isinstance(existing, list):
                existing = []
        except (json.JSONDecodeError, OSError):
            existing = []
    fresh = {bench_key(r): {k: v for k, v in r.items()
                            if k not in ("records", "outcomes")}
             for r in results}
    rows = []
    for r in existing:
        rows.append(fresh.pop(bench_key(r), r))
    rows.extend(fresh.values())
    with open(path, "w") as f:
        json.dump(rows, f, indent=1)
