"""SLO-driven capacity control + admission shedding for the session slab.

The paper's accelerator holds throughput under a *fixed hardware budget*;
this module is the serving analogue of that discipline.  The demand-driven
:class:`~repro.serving.capacity.CapacityManager` grows whenever raw demand
(busy + queued) exceeds the tier — which can leave a p99 latency SLO blown
while occupancy looks healthy (queued sessions are "demand" whether or not
anyone is still waiting within budget), and keeps queueing forever once
even the top tier is saturated.  :class:`SloController` instead closes the
loop on the *measured* service-level objective:

  grow    — the p99 admission-to-first-logit latency (tick-denominated,
            over a sliding window of completed latches, plus the age of
            the oldest queued session — a session that has already waited
            past the bound has breached it even though it never latched)
            exceeds ``target_p99_ticks`` for ``breach_patience``
            consecutive ticks → hop one tier up the ladder.
  shed    — the breach persists at the **top** tier → enter shedding:
            new low-priority opens are *rejected* or *degraded*
            (``shed_mode``) until the SLO recovers, so the protected
            class's latency bound survives overload instead of every
            class queueing forever.
  degrade — the principled shed (PAPERS.md 2010.12221's
            temporal-attention frame skip): a degraded session is served
            at ``degrade_stride``-decimated fidelity — the scheduler
            feeds every stride-th frame through the existing per-slot
            hold/input-skip path, so the session finishes in ~1/stride
            the ticks and the slab serves more sessions at lower
            fidelity instead of turning them away.
  shrink  — demand fits the next smaller tier *and* the measured p99 sits
            under ``shrink_margin × target`` for ``recover_patience``
            consecutive ticks → step one tier down (SLO-safe shrink: a
            healthy latency trend is required, not just low occupancy).

Pure host logic (numpy-free, jax-free) mirroring the
:class:`CapacityManager` interface — ``observe(busy, queued, tick)`` →
optional resize target — so :class:`~repro.serving.service.GcnService`
swaps controllers behind one ``policy={demand,slo}`` knob and the
trace-replay harness (:mod:`repro.serving.traffic`) can A/B both on
identical traffic."""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, List, Optional, Tuple

from repro.serving.capacity import ResizeEvent

CONTROL_POLICIES = ("demand", "slo")

SHED_MODES = ("reject", "degrade")


@dataclasses.dataclass(frozen=True)
class SloConfig:
    """Knobs for :class:`SloController`.

    ``target_p99_ticks`` is the SLO itself: the p99 admission-to-first-
    logit latency bound, denominated in scheduler ticks (arrival →
    first-logit latch) so A/B comparisons are deterministic, not wall
    noise.  ``window`` bounds the sliding sample window; ``breach_patience``
    / ``recover_patience`` are the consecutive-tick thresholds before a
    grow/shed (resp. un-shed/shrink) fires; ``cooldown`` freezes decisions
    after any resize (same no-thrash discipline as
    :class:`~repro.serving.capacity.CapacityConfig`).  ``protect_priority``
    marks the protected classes (priority ≥ it is never shed);
    ``shed_mode`` picks what happens to unprotected opens while shedding
    (``"reject"`` turns them away, ``"degrade"`` serves them at
    ``degrade_stride``-decimated fidelity); ``shrink_margin`` is the
    fraction of the target the measured p99 must sit under before a
    shrink is considered SLO-safe.

    ``degrade_stride_max`` > 0 makes the degrade stride *adapt to breach
    depth*: each additional ``breach_patience``-long breach streak while
    already shedding doubles the stride applied to newly degraded opens
    (``degrade_stride · 2^(depth−1)``, capped at the max), so a deepening
    overload sheds harder instead of queueing at a fidelity that already
    proved insufficient.  0 (the default) keeps the legacy fixed
    stride."""

    target_p99_ticks: int = 50
    window: int = 64
    breach_patience: int = 2
    recover_patience: int = 8
    cooldown: int = 4
    protect_priority: int = 1
    shed_mode: str = "degrade"
    degrade_stride: int = 2
    shrink_margin: float = 0.5
    degrade_stride_max: int = 0

    def __post_init__(self):
        if self.target_p99_ticks < 1:
            raise ValueError(
                f"target_p99_ticks must be >= 1, got {self.target_p99_ticks}")
        if self.window < 1:
            raise ValueError(f"window must be >= 1, got {self.window}")
        if self.shed_mode not in SHED_MODES:
            raise ValueError(f"unknown shed_mode {self.shed_mode!r} "
                             f"(expected one of {SHED_MODES})")
        if self.degrade_stride < 2:
            raise ValueError("degrade_stride must be >= 2 (1 would make "
                             f"degrade a no-op), got {self.degrade_stride}")
        if self.degrade_stride_max != 0 and \
                self.degrade_stride_max < self.degrade_stride:
            raise ValueError(
                "degrade_stride_max must be 0 (fixed stride) or >= "
                f"degrade_stride ({self.degrade_stride}), got "
                f"{self.degrade_stride_max}")
        if self.cooldown < 3:
            raise ValueError("cooldown must be >= 3 ticks (the no-thrash "
                             "hysteresis guarantee)")
        if not 0.0 < self.shrink_margin <= 1.0:
            raise ValueError(
                f"shrink_margin must be in (0, 1], got {self.shrink_margin}")


def _p99(samples: List[int]) -> float:
    """Tick-denominated p99 by rank (nearest-rank percentile over ints —
    numpy-free so the controller unit-tests without it)."""
    xs = sorted(samples)
    return float(xs[min(len(xs) - 1, (len(xs) * 99 + 99) // 100 - 1)])


class SloController:
    """SLO-closed-loop capacity + admission control over a tier ladder.

    Drop-in for :class:`~repro.serving.capacity.CapacityManager` on the
    resize side (:meth:`observe` returns an optional target capacity, the
    caller migrates) plus two SLO-specific surfaces the service wires in:
    :meth:`record_first_logit` feeds each session's tick-denominated
    admission-to-first-logit latency as it latches, and :meth:`admit`
    gates every ``open_session`` — returning ``"accept"``, ``"reject"``
    or ``"degrade"`` — which is the admission-control half the demand
    policy doesn't have."""

    def __init__(self, config: SloConfig = SloConfig(),
                 tiers: Tuple[int, ...] = (8,),
                 start_tier: Optional[int] = None,
                 latency_floor: int = 0):
        self.config = config
        # the pipeline's intrinsic first-logit latency in ticks (the
        # engine's stream_first_logit_delay): a session that has queued
        # for w ticks cannot latch before w + floor, so the controller
        # anticipates the breach ``floor`` ticks before it is measurable
        # — latency is a trailing signal; this is the leading correction
        self.latency_floor = int(latency_floor)
        self.tiers: Tuple[int, ...] = tuple(sorted(tiers))
        if not self.tiers or any(t <= 0 for t in self.tiers):
            raise ValueError(f"invalid capacity tiers {tiers!r}")
        if start_tier is None:
            self._idx = 0
        else:
            if start_tier not in self.tiers:
                raise ValueError(
                    f"start_tier {start_tier} not in tiers {self.tiers}")
            self._idx = self.tiers.index(start_tier)
        # sliding window of (priority, first-logit ticks) latch samples
        self._samples: Deque[Tuple[int, int]] = deque(maxlen=config.window)
        self._breach = 0
        self._recover = 0
        self._cooldown_until = -1
        self.shedding = False
        # breach depth while shedding: 1 when shedding switches on, +1 per
        # further breach_patience-long streak that fires with shedding
        # already active, 0 when it switches off — the severity signal
        # behind degrade_stride_now()
        self.shed_depth = 0
        self.events: List[ResizeEvent] = []     # committed resizes
        self.shed_rejected = 0                  # opens turned away
        self.shed_degraded = 0                  # opens served at stride
        self.shed_windows = 0                   # times shedding switched on

    @property
    def capacity(self) -> int:
        """The current tier's slot capacity."""
        return self.tiers[self._idx]

    def record_first_logit(self, priority: int, ticks: int) -> None:
        """Feed one latched admission-to-first-logit latency (in scheduler
        ticks, arrival → latch) into the sliding window — the measurement
        the whole control loop closes on."""
        self._samples.append((int(priority), int(ticks)))

    def measured_p99(self, *, protected_only: bool = True) -> Optional[float]:
        """The window's p99 first-logit latency in ticks; with
        ``protected_only`` restricted to the protected classes (priority ≥
        ``protect_priority``), falling back to all classes when no
        protected sample exists yet.  None while the window is empty."""
        if not self._samples:
            return None
        if protected_only:
            prot = [t for p, t in self._samples
                    if p >= self.config.protect_priority]
            if prot:
                return _p99(prot)
        return _p99([t for _, t in self._samples])

    def breached(self, queue_age: int = 0, inflight_age: int = 0) -> bool:
        """True when the SLO trend is currently blown: the measured p99
        exceeds the target, or the oldest queued session is already
        *committed* to breaching — it has waited ``queue_age`` ticks and
        cannot latch sooner than ``queue_age + latency_floor``, so
        waiting for the latch would let an unserved queue look healthy
        for a whole pipeline delay longer.

        ``inflight_age`` closes the other half of that blind spot: the
        worst *committed* first-logit latency among sessions already
        admitted to a slot but not yet latched (admission tick + pipeline
        delay − arrival).  Those sessions appear in neither the sample
        window (no latch yet) nor the queue (already admitted), so
        without this term a recovery streak could un-shed while the slab
        is still full of sessions guaranteed to breach when they latch."""
        if queue_age + self.latency_floor > self.config.target_p99_ticks:
            return True
        if inflight_age > self.config.target_p99_ticks:
            return True
        p99 = self.measured_p99()
        return p99 is not None and p99 > self.config.target_p99_ticks

    def admit(self, priority: int) -> str:
        """Admission-control verdict for one ``open_session``:
        ``"accept"``, or — while shedding and the session is below the
        protected class — the configured ``shed_mode`` (``"reject"`` /
        ``"degrade"``).  Counts every shed decision."""
        if not self.shedding or priority >= self.config.protect_priority:
            return "accept"
        if self.config.shed_mode == "reject":
            self.shed_rejected += 1
            return "reject"
        self.shed_degraded += 1
        return "degrade"

    def degrade_stride_now(self) -> int:
        """The frame-skip stride for a session degraded *right now*: the
        configured ``degrade_stride`` doubled per breach-depth level past
        the first (``stride · 2^(depth−1)``) and capped at
        ``degrade_stride_max`` — identical to the fixed stride when the
        max is 0 (the legacy default) or shedding just switched on.
        Already-admitted sessions keep the stride they were admitted at;
        only new degrade verdicts see the deepened stride."""
        cfg = self.config
        if cfg.degrade_stride_max <= 0 or self.shed_depth <= 1:
            return cfg.degrade_stride
        return min(cfg.degrade_stride_max,
                   cfg.degrade_stride * (2 ** (self.shed_depth - 1)))

    def idle_reset(self) -> None:
        """Forget the latency window and stop shedding — called when the
        service fast-forwards an *idle* gap: every session has drained, so
        the windowed samples describe a traffic regime that no longer
        exists and would otherwise pin the controller in breach/shedding
        forever (the window only ages out by new samples, not by time)."""
        self._samples.clear()
        self._breach = self._recover = 0
        self.shedding = False
        self.shed_depth = 0

    def observe(self, busy: int, queued: int, tick: int,
                queue_age: int = 0, inflight_age: int = 0) -> Optional[int]:
        """One tick's control decision → an optional resize target (slots).

        Same contract as :meth:`CapacityManager.observe` (call once per
        tick before admissions; the caller executes any returned resize),
        plus ``queue_age`` — the oldest queued session's wait in ticks —
        and ``inflight_age`` — the worst committed latency among admitted-
        but-unlatched sessions — as the leading-edge breach signals.
        Shedding toggles happen here too: a persistent breach at the top
        tier turns shedding on, a persistent recovery turns it off (and
        may shrink)."""
        if tick < self._cooldown_until:
            return None
        cfg = self.config
        if self.breached(queue_age, inflight_age):
            self._breach += 1
            self._recover = 0
        else:
            self._recover += 1
            self._breach = 0
        if self._breach >= cfg.breach_patience:
            self._breach = 0
            if self._idx < len(self.tiers) - 1:
                return self._commit(self._idx + 1, busy, queued, tick)
            if not self.shedding:
                self.shedding = True
                self.shed_windows += 1
                self.shed_depth = 1
            else:
                # breach persisted through another whole patience streak
                # while already shedding: the overload is deepening —
                # escalate the degrade stride for newly shed opens
                self.shed_depth += 1
            return None
        if self._recover >= cfg.recover_patience:
            self._recover = 0
            if self.shedding:
                # recover in two steps: stop shedding first, then (next
                # recovery window) consider shrinking — never both at once
                self.shedding = False
                self.shed_depth = 0
                return None
            p99 = self.measured_p99()
            demand = busy + queued
            if (self._idx > 0
                    and demand <= self.tiers[self._idx - 1]
                    and (p99 is None
                         or p99 <= cfg.shrink_margin * cfg.target_p99_ticks)):
                return self._commit(self._idx - 1, busy, queued, tick)
        return None

    def _commit(self, idx: int, busy: int, queued: int, tick: int) -> int:
        """Commit a resize to tier ``idx``: log the event, reset pressure
        counters, start the cooldown window."""
        self.events.append(ResizeEvent(
            tick=tick, old=self.capacity, new=self.tiers[idx],
            busy=busy, queued=queued))
        self._idx = idx
        self._breach = self._recover = 0
        self._cooldown_until = tick + self.config.cooldown
        return self.capacity
