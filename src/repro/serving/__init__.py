"""`repro.serving` — the multi-session GCN serving package.

Public surface (snapshotted in ``docs/api_surface.txt`` and gated by
``tools/check_api.py``):

* :class:`GcnService` — the session-handle facade
  (``open_session``/``submit``/``poll``/``close`` + ``tick``), owning the
  compiled plans, the per-tier session slabs, QoS and elastic capacity.
* :func:`run_sessions` — the batch driver (Poisson/bursty load through a
  service; the ``serve sessions`` / BENCH row path).
* :class:`SlabScheduler`, :class:`AdmissionQueue`, :class:`TickPlan`,
  :class:`SessionRequest`, :class:`SessionRecord` — scheduling internals
  (host-side, jax-free), importable for tests and custom drivers.
* :class:`CapacityManager`, :class:`CapacityConfig` — the demand-driven
  elastic-tier decision logic (``policy="demand"``).
* :class:`SloController`, :class:`SloConfig` — the SLO-closed-loop
  controller (``policy="slo"``): grow on measured p99 first-logit
  regression, shed (reject/degrade) via admission control at the top
  tier.
* :func:`poisson_arrivals`, :func:`bursty_arrivals` — load generators;
  :class:`TrafficConfig`, :class:`TraceGenerator`, :func:`generate_trace`
  — the richer traffic model (diurnal cycle, flash crowds, heavy-tailed
  lengths) emitting serializable :class:`TraceEvent` records.
* :class:`Trace`, :func:`replay` — the deterministic trace-replay
  harness: feed a recorded trace byte-identically into any service
  configuration (the ``serve sessions --trace`` / golden-test path).
* :func:`write_bench`, :func:`bench_key` — BENCH_sessions.json row merge.

The legacy import path ``repro.launch.sessions`` is a deprecation shim
over this package."""
from repro.serving.capacity import (CapacityConfig, CapacityManager,
                                    ResizeEvent)
from repro.serving.scheduler import (DEFAULT_BENCH_PATH, QOS_POLICIES,
                                     AdmissionQueue, SessionRecord,
                                     SessionRequest, SlabScheduler,
                                     TickPlan, bench_key, bursty_arrivals,
                                     poisson_arrivals, write_bench)
from repro.serving.service import (SESSION_STATES, GcnService,
                                   SessionHandle, SessionStatus,
                                   run_sessions)
from repro.serving.slo import (CONTROL_POLICIES, SHED_MODES, SloConfig,
                               SloController)
from repro.serving.traffic import (LENGTH_DISTS, TRACE_SCHEMA_VERSION,
                                   Trace, TraceEvent, TraceGenerator,
                                   TrafficConfig, event_clip,
                                   generate_trace, outcome_digest, replay,
                                   trace_requests)

__all__ = [
    "AdmissionQueue",
    "CONTROL_POLICIES",
    "CapacityConfig",
    "CapacityManager",
    "DEFAULT_BENCH_PATH",
    "GcnService",
    "LENGTH_DISTS",
    "QOS_POLICIES",
    "ResizeEvent",
    "SESSION_STATES",
    "SHED_MODES",
    "SessionHandle",
    "SessionRecord",
    "SessionRequest",
    "SessionStatus",
    "SlabScheduler",
    "SloConfig",
    "SloController",
    "TRACE_SCHEMA_VERSION",
    "TickPlan",
    "Trace",
    "TraceEvent",
    "TraceGenerator",
    "TrafficConfig",
    "bench_key",
    "bursty_arrivals",
    "event_clip",
    "generate_trace",
    "outcome_digest",
    "poisson_arrivals",
    "replay",
    "run_sessions",
    "trace_requests",
    "write_bench",
]
