"""`repro.serving` — the multi-session GCN serving package.

Public surface (snapshotted in ``docs/api_surface.txt`` and gated by
``tools/check_api.py``):

* :class:`GcnService` — the session-handle facade
  (``open_session``/``submit``/``poll``/``close`` + ``tick``), owning the
  compiled plans, the per-tier session slabs, QoS and elastic capacity.
* :func:`run_sessions` — the batch driver (Poisson/bursty load through a
  service; the ``serve sessions`` / BENCH row path).
* :class:`SlabScheduler`, :class:`AdmissionQueue`, :class:`TickPlan`,
  :class:`SessionRequest`, :class:`SessionRecord` — scheduling internals
  (host-side, jax-free), importable for tests and custom drivers.
* :class:`CapacityManager`, :class:`CapacityConfig` — the elastic-tier
  decision logic.
* :func:`poisson_arrivals`, :func:`bursty_arrivals` — load generators.
* :func:`write_bench`, :func:`bench_key` — BENCH_sessions.json row merge.

The legacy import path ``repro.launch.sessions`` is a deprecation shim
over this package."""
from repro.serving.capacity import (CapacityConfig, CapacityManager,
                                    ResizeEvent)
from repro.serving.scheduler import (DEFAULT_BENCH_PATH, QOS_POLICIES,
                                     AdmissionQueue, SessionRecord,
                                     SessionRequest, SlabScheduler,
                                     TickPlan, bench_key, bursty_arrivals,
                                     poisson_arrivals, write_bench)
from repro.serving.service import (SESSION_STATES, GcnService,
                                   SessionHandle, SessionStatus,
                                   run_sessions)

__all__ = [
    "AdmissionQueue",
    "CapacityConfig",
    "CapacityManager",
    "DEFAULT_BENCH_PATH",
    "GcnService",
    "QOS_POLICIES",
    "ResizeEvent",
    "SESSION_STATES",
    "SessionHandle",
    "SessionRecord",
    "SessionRequest",
    "SessionStatus",
    "SlabScheduler",
    "TickPlan",
    "bench_key",
    "bursty_arrivals",
    "poisson_arrivals",
    "run_sessions",
    "write_bench",
]
