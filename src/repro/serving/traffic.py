"""Realistic traffic modelling + deterministic trace replay for serving.

The ROADMAP's missing half of SLO-driven autoscaling: controller changes
are only trustworthy when two policies can be A/B'd on **identical**
traffic.  This module provides

* a traffic model richer than the poisson/bursty load generators —
  :class:`TrafficConfig` + :class:`TraceGenerator` compose a diurnal rate
  cycle (thinned non-homogeneous Poisson), correlated flash crowds (an
  accepted arrival seeds a burst of follow-on arrivals within a short
  span) and heavy-tailed session lengths (log-normal or Pareto, the
  measured shape of real stream sessions) — emitted one event at a time
  from an explicit ``numpy.random.Generator`` (no global RNG state, so
  interleaved generators reproduce their solo sequences);
* a serializable trace format — :class:`TraceEvent` rows inside a
  versioned :class:`Trace` envelope with exact JSON round-tripping
  (``replay(serialize(trace))`` is event-for-event identical), checked
  into ``tests/data/traces/`` as the repo's canonical regression loads;
* the replay harness — :func:`replay` feeds a recorded trace
  byte-identically (clip content derives from each event's ``clip_seed``,
  never from generator state) into any
  :class:`~repro.serving.service.GcnService` configuration and returns
  the same metrics row shape as :func:`~repro.serving.service.
  run_sessions`, tagged with the ``policy``/``trace`` merge axes — so
  ``serve sessions --trace FILE --policy {demand,slo}`` benchmarks the
  demand-driven and SLO-driven controllers on the same events, and the
  golden tests lock scheduler-tick-level outcomes per (qos, policy).
"""
from __future__ import annotations

import dataclasses
import hashlib
import heapq
import json
import math
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

TRACE_SCHEMA_VERSION = 1

LENGTH_DISTS = ("lognormal", "pareto", "fixed")


@dataclasses.dataclass(frozen=True)
class TraceEvent:
    """One recorded session arrival — the unit of a serialized trace.

    ``arrival`` is the scheduler tick the session opens; ``frames`` its
    clip length; ``clip_seed`` the self-contained seed its clip content
    derives from at replay time (``default_rng(clip_seed)`` — byte-
    identical across processes, independent of any generator state);
    ``deadline`` the optional absolute completion-deadline tick (filled
    by the replay driver under ``qos="deadline"`` when None)."""

    sid: int
    arrival: int
    frames: int
    priority: int = 0
    clip_seed: int = 0
    deadline: Optional[int] = None

    def to_json(self) -> Dict:
        """The event as a plain-JSON dict (ints + optional deadline)."""
        d = {"sid": self.sid, "arrival": self.arrival,
             "frames": self.frames, "priority": self.priority,
             "clip_seed": self.clip_seed}
        if self.deadline is not None:
            d["deadline"] = self.deadline
        return d

    @classmethod
    def from_json(cls, d: Dict) -> "TraceEvent":
        """Inverse of :meth:`to_json` (exact round-trip)."""
        return cls(sid=int(d["sid"]), arrival=int(d["arrival"]),
                   frames=int(d["frames"]), priority=int(d["priority"]),
                   clip_seed=int(d["clip_seed"]),
                   deadline=(int(d["deadline"])
                             if d.get("deadline") is not None else None))


@dataclasses.dataclass(frozen=True)
class TrafficConfig:
    """The traffic model behind :class:`TraceGenerator`.

    Arrival process — a thinned non-homogeneous Poisson with rate
    ``λ(t) = (1/mean_interarrival) · (1 + diurnal_amplitude ·
    sin(2πt/diurnal_period))`` (``diurnal_amplitude=0`` degenerates to
    the plain Poisson process), plus **flash crowds**: each accepted base
    arrival seeds, with probability ``flash_crowd_prob``, a correlated
    burst of ``1 + Geometric(1/flash_crowd_size)`` follow-on arrivals
    uniformly inside the next ``flash_crowd_span`` ticks (the "everyone
    opens the app at once" shape a homogeneous process cannot produce).

    Session lengths — ``length_dist``: ``"lognormal"`` (σ =
    ``length_sigma``, mean = ``mean_frames``), ``"pareto"`` (tail index
    ``pareto_alpha`` > 1, mean = ``mean_frames``) or ``"fixed"``;
    clamped to [``min_frames``, ``max_frames``] (``max_frames=0`` =
    uncapped).  Priorities are a Bernoulli(``high_priority_ratio``)
    high(1)/low(0) mix.  ``seed`` is the default generator seed when no
    explicit ``numpy.random.Generator`` is threaded in."""

    n_sessions: int = 32
    mean_interarrival: float = 8.0
    diurnal_amplitude: float = 0.0
    diurnal_period: float = 200.0
    flash_crowd_prob: float = 0.0
    flash_crowd_size: float = 3.0
    flash_crowd_span: float = 4.0
    length_dist: str = "lognormal"
    mean_frames: float = 16.0
    length_sigma: float = 0.6
    pareto_alpha: float = 2.5
    min_frames: int = 2
    max_frames: int = 0
    high_priority_ratio: float = 0.0
    seed: int = 0

    def __post_init__(self):
        if self.n_sessions < 1:
            raise ValueError(f"n_sessions must be >= 1, got {self.n_sessions}")
        if self.mean_interarrival <= 0:
            raise ValueError("mean_interarrival must be > 0, got "
                             f"{self.mean_interarrival}")
        if not 0.0 <= self.diurnal_amplitude < 1.0:
            raise ValueError("diurnal_amplitude must be in [0, 1) (the "
                             f"rate must stay positive), got "
                             f"{self.diurnal_amplitude}")
        if self.diurnal_period <= 0:
            raise ValueError(
                f"diurnal_period must be > 0, got {self.diurnal_period}")
        if not 0.0 <= self.flash_crowd_prob <= 1.0:
            raise ValueError("flash_crowd_prob must be in [0, 1], got "
                             f"{self.flash_crowd_prob}")
        if self.flash_crowd_size < 1.0:
            raise ValueError("flash_crowd_size must be >= 1, got "
                             f"{self.flash_crowd_size}")
        if self.length_dist not in LENGTH_DISTS:
            raise ValueError(f"unknown length_dist {self.length_dist!r} "
                             f"(expected one of {LENGTH_DISTS})")
        if self.length_dist == "pareto" and self.pareto_alpha <= 1.0:
            raise ValueError("pareto_alpha must be > 1 (the mean must be "
                             f"finite), got {self.pareto_alpha}")
        if self.min_frames < 1:
            raise ValueError(f"min_frames must be >= 1, got {self.min_frames}")
        if self.max_frames and self.max_frames < self.min_frames:
            raise ValueError(
                f"max_frames {self.max_frames} < min_frames {self.min_frames}")

    def rate(self, t: float) -> float:
        """The instantaneous arrival rate λ(t) (sessions per tick) — the
        diurnal modulation the generator thins against, exposed so tests
        can integrate it analytically."""
        base = 1.0 / self.mean_interarrival
        return base * (1.0 + self.diurnal_amplitude
                       * math.sin(2.0 * math.pi * t / self.diurnal_period))


class TraceGenerator:
    """Streaming event generator over an explicit RNG — iterate to draw
    :class:`TraceEvent`\\ s one at a time, in arrival order.

    All randomness comes from the single ``numpy.random.Generator`` the
    instance owns (``rng`` argument, else ``default_rng(config.seed)``):
    no module-level or global numpy state is ever touched, so two
    interleaved generators reproduce their solo sequences exactly and
    concurrent benchmark runs cannot cross-contaminate.  The draw order
    per event is part of the determinism contract: the thinned arrival
    draws (and, on acceptance, the crowd-seeding draws) first, then
    length, priority and clip seed at emission."""

    def __init__(self, config: TrafficConfig,
                 rng: Optional[np.random.Generator] = None):
        self.config = config
        self.rng = rng if rng is not None else np.random.default_rng(
            config.seed)
        self._t = 0.0                    # continuous clock of the base process
        self._pending: List[float] = []  # crowd arrivals (min-heap)
        self._next_base: Optional[float] = None
        self._emitted = 0

    def __iter__(self) -> Iterator[TraceEvent]:
        return self

    def _draw_base(self) -> float:
        """Advance the thinned non-homogeneous base process to its next
        accepted arrival; a crowd seeded by the acceptance enqueues its
        follow-on arrivals immediately (one draw block per acceptance)."""
        cfg = self.config
        lam_max = (1.0 + cfg.diurnal_amplitude) / cfg.mean_interarrival
        while True:
            self._t += self.rng.exponential(1.0 / lam_max)
            if self.rng.random() * lam_max <= cfg.rate(self._t):
                break
        t = self._t
        if cfg.flash_crowd_prob > 0 and self.rng.random() < cfg.flash_crowd_prob:
            k = 1 + self.rng.geometric(1.0 / cfg.flash_crowd_size)
            for dt in self.rng.uniform(0.0, cfg.flash_crowd_span, size=k):
                heapq.heappush(self._pending, t + float(dt))
        return t

    def _length(self) -> int:
        cfg = self.config
        if cfg.length_dist == "lognormal":
            mu = math.log(cfg.mean_frames) - 0.5 * cfg.length_sigma ** 2
            x = self.rng.lognormal(mu, cfg.length_sigma)
        elif cfg.length_dist == "pareto":
            xm = cfg.mean_frames * (cfg.pareto_alpha - 1.0) / cfg.pareto_alpha
            x = xm * (1.0 + self.rng.pareto(cfg.pareto_alpha))
        else:
            x = cfg.mean_frames
        n = max(cfg.min_frames, int(round(x)))
        if cfg.max_frames:
            n = min(n, cfg.max_frames)
        return n

    def _emit(self, t: float) -> TraceEvent:
        cfg = self.config
        ev = TraceEvent(
            sid=self._emitted, arrival=int(math.floor(t)),
            frames=self._length(),
            priority=int(self.rng.random() < cfg.high_priority_ratio),
            clip_seed=int(self.rng.integers(0, 2 ** 31 - 1)))
        self._emitted += 1
        return ev

    def __next__(self) -> TraceEvent:
        if self._emitted >= self.config.n_sessions:
            raise StopIteration
        if self._next_base is None:
            self._next_base = self._draw_base()
        if self._pending and self._pending[0] <= self._next_base:
            return self._emit(heapq.heappop(self._pending))
        t, self._next_base = self._next_base, None
        return self._emit(t)


@dataclasses.dataclass(frozen=True)
class Trace:
    """A recorded traffic trace: versioned envelope + event rows.

    ``config`` is the generating :class:`TrafficConfig` as a plain dict
    (informational — replay never re-draws from it), ``name`` the merge-
    key label BENCH rows carry.  Serialization is exact: ``Trace.
    from_json(trace.to_json()) == trace`` field-for-field, which is the
    determinism contract golden tests replay against."""

    events: Tuple[TraceEvent, ...]
    name: str = ""
    config: Optional[Dict] = None
    version: int = TRACE_SCHEMA_VERSION

    def to_json(self) -> str:
        """Serialize to a stable, human-diffable JSON document."""
        return json.dumps(
            {"version": self.version, "name": self.name,
             "config": self.config,
             "events": [e.to_json() for e in self.events]},
            indent=1, sort_keys=True)

    @classmethod
    def from_json(cls, text: str) -> "Trace":
        """Parse a serialized trace; rejects unknown schema versions
        loudly (the trace files are regression inputs — silently
        reinterpreting an old schema would unlock the goldens)."""
        d = json.loads(text)
        version = int(d.get("version", -1))
        if version != TRACE_SCHEMA_VERSION:
            raise ValueError(
                f"trace schema version {version} != supported "
                f"{TRACE_SCHEMA_VERSION} — regenerate the trace "
                "(tools/gen_traces.py) or replay it with a matching "
                "repo revision")
        return cls(events=tuple(TraceEvent.from_json(e)
                                for e in d["events"]),
                   name=str(d.get("name", "")), config=d.get("config"),
                   version=version)

    def save(self, path: str) -> None:
        """Write the trace to ``path`` (the checked-in-trace format)."""
        with open(path, "w") as f:
            f.write(self.to_json() + "\n")

    @classmethod
    def load(cls, path: str) -> "Trace":
        """Read a trace written by :meth:`save`."""
        with open(path) as f:
            return cls.from_json(f.read())

    def digest(self) -> str:
        """Short content hash of the event rows — the default ``name``
        stand-in so unnamed traces still merge-key distinctly."""
        h = hashlib.sha256(
            json.dumps([e.to_json() for e in self.events],
                       sort_keys=True).encode())
        return h.hexdigest()[:12]


def generate_trace(config: TrafficConfig,
                   rng: Optional[np.random.Generator] = None,
                   name: str = "") -> Trace:
    """Draw a full :class:`Trace` from the traffic model — the batch
    convenience over iterating :class:`TraceGenerator` (same event
    sequence; events arrive sorted by construction)."""
    events = tuple(TraceGenerator(config, rng=rng))
    return Trace(events=events, name=name,
                 config=dataclasses.asdict(config))


def event_clip(event: TraceEvent, joints: int, channels: int) -> np.ndarray:
    """The (frames, V, C) clip content a trace event replays with:
    standard-normal skeleton frames from the event's own ``clip_seed`` —
    a fresh ``default_rng`` per event, so replay is byte-identical across
    processes and independent of every other event."""
    rng = np.random.default_rng(event.clip_seed)
    return rng.standard_normal(
        (event.frames, joints, channels)).astype(np.float32)


def trace_requests(trace: Trace, joints: int, channels: int) -> List:
    """Materialize a trace into scheduler :class:`~repro.serving.
    scheduler.SessionRequest`\\ s (clip content via :func:`event_clip`) —
    the bridge from recorded events to the live-session drivers."""
    from repro.serving.scheduler import SessionRequest
    return [SessionRequest(sid=e.sid, arrival=e.arrival,
                           clip=event_clip(e, joints, channels),
                           priority=e.priority, deadline=e.deadline)
            for e in trace.events]


# ---------------------------------------------------------------------------
# the replay harness
# ---------------------------------------------------------------------------

def replay(
    cfg,
    trace: Trace,
    *,
    backend: str = "reference",
    qos: str = "fifo",
    policy: str = "demand",
    capacity_tiers: Sequence[int] = (4,),
    slo_config=None,
    deadline_slack: int = 25,
    quant: bool = True,
    seed: int = 0,
    fused: bool = True,
    record_outcomes: bool = False,
    max_ticks: int = 100_000,
    plans=None,
    bn_stats=None,
    saliency_thresh: float = 0.0,
) -> Dict:
    """Replay a recorded trace through one :class:`~repro.serving.service.
    GcnService` configuration and return its metrics row.

    The standing A/B rig for controller and scheduler changes: every
    knob of the service (backend, qos, ``policy={demand,slo}``, tiers)
    varies while the *traffic* — arrival ticks, clip lengths, priorities
    and clip bytes — is pinned by the trace, so two configurations are
    benchmarked on identical events and replaying the same trace twice
    yields identical scheduler-tick outcomes (locked by the golden
    tests).  The returned row carries the ``policy``/``load="trace"``/
    ``trace=<name>`` merge axes for ``BENCH_sessions.json``, plus the
    per-tick ``outcomes`` log when ``record_outcomes`` is set (the
    golden-lock shape; stripped from BENCH rows like ``records``).

    Sessions a shedding SLO controller *rejects* never enter the
    scheduler; their clips are dropped and they count under
    ``shed_rejected`` — the queue-forever alternative is exactly what the
    policy exists to avoid.  Under ``qos="deadline"``, events without an
    explicit deadline get arrival + minimal service time +
    ``deadline_slack`` (same rule as :func:`~repro.serving.service.
    run_sessions`).  ``saliency_thresh`` > 0 replays through a
    :class:`~repro.serving.saliency.SaliencyGate` — the gate is
    deterministic over the trace's pinned clip bytes, so gated replays
    golden-lock exactly like ungated ones (tests/data/traces/
    golden_saliency.json)."""
    from collections import deque

    from repro.serving.service import GcnService

    svc = GcnService(cfg, backend=backend, qos=qos, policy=policy,
                     capacity_tiers=tuple(capacity_tiers), quant=quant,
                     seed=seed, fused=fused, slo_config=slo_config,
                     plans=plans, bn_stats=bn_stats,
                     record_outcomes=record_outcomes,
                     saliency_thresh=saliency_thresh)
    reqs = trace_requests(trace, cfg.gcn_joints, cfg.gcn_in_channels)
    if qos == "deadline":
        for r in reqs:
            if r.deadline is None:
                r.deadline = (r.arrival + len(r.clip)
                              + svc.flush_frames(len(r.clip))
                              + deadline_slack)
    pending = deque(sorted(reqs, key=lambda r: (r.arrival, r.sid)))
    while svc.now < max_ticks:
        while pending and pending[0].arrival <= svc.now:
            r = pending.popleft()
            h = svc.open_session(priority=r.priority, deadline=r.deadline,
                                 arrival=r.arrival)
            if svc.poll(h).state != "rejected":
                svc.submit_clip(h, r.clip)
        if svc.idle():
            if not pending:
                break
            svc.advance_clock(pending[0].arrival)
            continue
        svc.tick()
    out = svc.metrics()
    out["load"] = "trace"
    out["trace"] = trace.name or trace.digest()
    if record_outcomes:
        out["outcomes"] = svc.outcomes
    return out


def outcome_digest(outcomes: List[Dict]) -> str:
    """Stable hash of a replay's per-tick outcome log — the compact form
    the determinism lock compares (full logs live in the goldens)."""
    return hashlib.sha256(
        json.dumps(outcomes, sort_keys=True).encode()).hexdigest()
