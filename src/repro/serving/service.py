"""`GcnService`: the session-handle serving facade over the AGCN engine.

The paper's accelerator is a *serving* design — all layers resident,
runtime-compressed features, dynamic per-PE scheduling — and this module
is its service surface: one object owns the compiled ExecutionPlans, the
per-tier session slabs, the QoS scheduler and the elastic capacity
manager, and exposes the four-call session protocol:

    svc = GcnService(cfg, backend="pallas", qos="preempt",
                     capacity_tiers=(2, 4, 8, 16))
    h = svc.open_session(priority=1)
    svc.submit(h, frame)          # one (V, C) raw skeleton frame at a time
    svc.tick()                    # one scheduler tick serves every session
    svc.poll(h)                   # state + running logits
    svc.close(h)                  # end of stream -> flush drain -> record

Everything under the facade is the existing machinery recomposed: the
host-side :class:`~repro.serving.scheduler.SlabScheduler` builds each
tick's :class:`~repro.serving.scheduler.TickPlan`, one jitted
``make_gcn_slab_step`` call advances every slot (admission resets, flush
drains and starved-session holds are traced masks — no retrace within a
tier), and QoS preemption/elastic migration both ride the engine's
``snapshot_slots``/``restore_slots`` gather/scatter pair.

**Elastic capacity** (the ROADMAP item): slot capacity is a compiled
shape, so the service pre-builds one slab per ``capacity_tiers`` entry
(and warms the compiled step for each), watches queue depth + occupancy
through a hysteresis :class:`~repro.serving.capacity.CapacityManager`,
and on a grow/shrink decision migrates every active session across slabs:
snapshot the occupied rows, scatter them into the (pristine) target tier,
remap the scheduler's slot table.  The locked invariant
(tests/test_serving.py, both backends): a session migrated across tiers
produces the same logits as the uninterrupted fixed-capacity session.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.serving.capacity import CapacityConfig, CapacityManager
from repro.serving.saliency import SaliencyConfig, SaliencyGate
from repro.serving.scheduler import (QOS_POLICIES, AdmissionQueue,
                                     SessionRecord, SessionRequest,
                                     SlabScheduler, bursty_arrivals,
                                     max_events_for, pad_event_orders,
                                     poisson_arrivals)
from repro.serving.slo import CONTROL_POLICIES, SloConfig, SloController

SESSION_STATES = ("queued", "active", "draining", "done", "missed",
                  "rejected")


@dataclasses.dataclass(frozen=True)
class SessionHandle:
    """Opaque ticket for one open session (returned by ``open_session``)."""

    sid: int


@dataclasses.dataclass
class SessionStatus:
    """One ``poll`` result: where the session is and what it predicts.

    ``state`` ∈ ``SESSION_STATES``: *queued* (awaiting a slot — including
    a preempted session awaiting re-admission), *active* (in a slot,
    consuming frames; a starved open session holds here), *draining*
    (stream closed, flush latency draining through the blocks), *done*
    (final record available), *missed* (dropped by the deadline
    policy) or *rejected* (turned away at open by the SLO controller's
    admission shed — it never entered the scheduler; ``submit``/``close``
    on it are no-ops).  ``logits`` is the slot's running prediction while
    active/draining, the final post-drain prediction when done, None
    otherwise."""

    sid: int
    state: str
    frames_submitted: int
    frames_consumed: int
    priority: int
    logits: Optional[np.ndarray] = None
    record: Optional[SessionRecord] = None


class GcnService:
    """Multi-session GCN serving facade: open/submit/poll/close + tick.

    One instance owns, per ensemble stream (joint + bone by default):
    a compiled ``ExecutionPlan``, frozen BN calibration, and one pristine
    session slab per capacity tier.  ``tick()`` advances every admitted
    session by one raw frame through a single jitted slab step; admission,
    preemption (``qos="preempt"``), deadline eviction (``qos="deadline"``)
    and elastic tier migration all happen between steps on the host.

    Parameters:
      cfg              — a gcn-family ``ModelConfig``.
      backend          — engine backend (``reference`` | ``pallas``).
      qos              — scheduler policy (``fifo`` | ``preempt`` |
                         ``deadline``).
      capacity_tiers   — slot capacities; one entry = fixed capacity,
                         several = elastic (service starts at the smallest
                         tier and the capacity manager hops the ladder).
      capacity_config  — hysteresis knobs (tiers taken from
                         ``capacity_tiers``).
      policy           — capacity-control policy: ``"demand"`` (the
                         :class:`CapacityManager` — grow on raw
                         busy+queued demand) or ``"slo"`` (the
                         :class:`~repro.serving.slo.SloController` — grow
                         on measured p99 first-logit regression, shed via
                         admission control when even the top tier can't
                         hold the SLO).
      slo_config       — :class:`~repro.serving.slo.SloConfig` knobs for
                         ``policy="slo"`` (defaults when None; ignored
                         under ``"demand"``).
      record_outcomes  — keep a per-tick scheduler-outcome log under
                         ``self.outcomes`` (admissions, restores,
                         preemptions, finishes, misses, sheds, capacity)
                         — the pure-host, float-free record the golden
                         trace-replay tests lock.  Off by default: a
                         long-lived service must not grow an unbounded
                         log.
      quant            — Q8.8-quantize the plans (the paper's C5 target).
      seed             — parameter/init seed (ignored when ``plans`` is
                         given).
      plans            — prebuilt ExecutionPlan tuple: ``(joint,)`` or
                         ``(joint, bone)``; built from ``cfg`` when None.
      bn_stats         — frozen BN statistics per plan (tuple, or one dict
                         shared when a single plan is given); calibrated
                         from ``x_calib`` (or a synthetic pipeline batch)
                         when None.
      x_calib          — (N, T, V, C) calibration clip batch.
      warm             — pre-compile the slab step for every tier (and the
                         preempt gather/scatter) at construction so no
                         session ever pays compile latency.
      fused            — serve each tick as **one** device dispatch with
                         async logit readback.  Ticks carrying snapshot or
                         restore events run ``engine.fused_tick`` (gathers,
                         scatters, hold/reset masking and the slab step in
                         a single donated-slab jit, snapshots in an
                         on-device ring); event-free ticks run the plain
                         slab step — still one dispatch, no ring plumbing.
                         False restores the legacy multi-dispatch tick (one
                         jit per snapshot/restore event + a synchronous
                         readback) — kept for A/B parity tests and the
                         throughput benchmark baseline.
      snap_capacity    — snapshot-ring rows (fused path only): live
                         preempted sessions a tick can hold device state
                         for; defaults to ``2 * max(capacity_tiers)``.
      topologies       — skeleton graphs this service serves (registry
                         names, see ``repro.core.agcn.graph``).  The first
                         entry is the *primary* topology (what
                         ``open_session`` without ``topology=`` gets); the
                         slab is sized to the widest skeleton (``vmax``
                         joints) and every topology's ExecutionPlans are
                         padded to that width, so sessions with different
                         skeletons share one slab (narrow sessions ride
                         zero-padded, their plans mask the padded joints).
                         A mixed tick runs one dispatch per occupied
                         skeleton group — the primary group (plus all
                         snapshot/restore events and free slots) first,
                         then each other group with its own plans and BN
                         stats, everything outside the group held.
      sconv            — spatial-conv path selection forwarded to
                         ``engine.build_execution_plan`` (``auto`` |
                         ``dense`` | ``csr``); with the default
                         ``auto``/``csr_eps=0`` the learned dense B_k keeps
                         every graph dense — today's path.
      csr_eps          — |G| threshold below which entries are dropped
                         when measuring density / packing CSR.
      mesh             — optional 1-D ``jax.sharding.Mesh``: the live
                         slab, tier slabs and snapshot rings are placed
                         under it (slot axis sharded across the mesh,
                         BN stats and ring rows replicated) and every
                         jitted entry point is compiled with matching
                         output shardings, so one service tick runs
                         SPMD across the mesh devices.  Every capacity
                         tier must divide the mesh size.  None (default)
                         = single-device service, unchanged.
      retain_records   — bound on per-session host bookkeeping: only the
                         most recent ``retain_records`` finished/missed
                         sessions keep their request/record entries
                         (lifetime totals live in running aggregates),
                         so a service that stays up for days holds
                         constant memory.
      saliency_thresh  — > 0 runs a
                         :class:`~repro.serving.saliency.SaliencyGate` at
                         that attention-ratio threshold: uninformative
                         frames are skipped per session (the scheduler
                         feeds only the kept subsequence; starved open
                         sessions ride the existing hold mask), so the
                         same slab serves more sessions at bounded
                         fidelity loss.  0 (default) = off — the feed
                         path and every metric row are byte-identical to
                         the pre-saliency service.
    """

    def __init__(self, cfg, *, backend: str = "reference", qos: str = "fifo",
                 capacity_tiers: Sequence[int] = (8,),
                 capacity_config: Optional[CapacityConfig] = None,
                 policy: str = "demand",
                 slo_config: Optional[SloConfig] = None,
                 record_outcomes: bool = False,
                 quant: bool = True, seed: int = 0,
                 plans: Optional[Tuple] = None,
                 bn_stats: Optional[Any] = None,
                 x_calib: Optional[np.ndarray] = None,
                 warm: bool = True, fused: bool = True,
                 snap_capacity: Optional[int] = None,
                 topologies: Sequence[str] = ("ntu25",),
                 sconv: str = "auto", csr_eps: float = 0.0,
                 mesh: Optional[Any] = None,
                 retain_records: int = 1024,
                 saliency_thresh: float = 0.0):
        import jax
        import jax.numpy as jnp

        from repro.core.agcn import engine
        from repro.core.agcn.graph import get_topology
        from repro.core.agcn.model import bone_stream_parents
        from repro.train.steps import make_gcn_fused_tick, make_gcn_slab_step

        if qos not in QOS_POLICIES:
            raise ValueError(f"unknown QoS policy {qos!r}")
        if policy not in CONTROL_POLICIES:
            raise ValueError(f"unknown capacity policy {policy!r} "
                             f"(expected one of {CONTROL_POLICIES})")
        tiers = tuple(sorted(int(t) for t in capacity_tiers))
        if not tiers:
            raise ValueError("capacity_tiers must name at least one tier")
        if retain_records < 1:
            raise ValueError(
                f"retain_records must be >= 1, got {retain_records}")
        self.mesh = mesh
        if mesh is not None:
            if len(mesh.axis_names) != 1:
                raise ValueError(
                    f"GcnService expects a 1-D slot mesh, got axes "
                    f"{mesh.axis_names}")
            bad = [t for t in tiers if t % mesh.size]
            if bad:
                raise ValueError(
                    f"capacity tiers {bad} do not divide the mesh size "
                    f"{mesh.size} — the slot axis is sharded evenly "
                    "across the mesh devices")
        self.cfg = cfg
        self.backend = backend
        self.qos = qos
        self.tiers = tiers
        self.retain_records = int(retain_records)
        self._jax, self._jnp, self._engine = jax, jnp, engine

        # --- topology registry: one plan set per declared skeleton --------
        names = tuple(dict.fromkeys(topologies))
        if not names:
            raise ValueError("topologies must name at least one skeleton")
        self._topos = {t: get_topology(t, cfg.gcn_kv) for t in names}
        self.topologies = names
        self.primary = names[0]
        # the slab's joint width: every topology's plans are padded to it
        self.vmax = max(tp.num_joints for tp in self._topos.values())

        # --- plans (joint [+ bone]) and their input-stream transforms -----
        # one ExecutionPlan tuple per declared topology, each padded to the
        # service's vmax so all of them step the same slab; ``self.plans``
        # stays the primary tuple (slab init / router back-compat view)
        if plans is not None and len(names) > 1:
            raise ValueError(
                "prebuilt plans are single-topology — a multi-topology "
                "service builds its own per-skeleton plans from cfg")
        self._topo_plans: Dict[str, Tuple] = {}
        if plans is None:
            from repro.core.pruning.plan import plan_from_config
            from repro.models import registry
            # the same PRNG keys for every topology: joint-count-free
            # parameters (conv stacks, fc head) come out identical, so the
            # last dispatch of a mixed tick reports every held slot's
            # logits through the same head its own plan would use
            keys = jax.random.split(jax.random.PRNGKey(seed))
            for t in names:
                topo = self._topos[t]
                cfg_t = dataclasses.replace(cfg, gcn_joints=topo.num_joints)
                prune_plan = plan_from_config(cfg_t)
                self._topo_plans[t] = tuple(
                    engine.build_execution_plan(
                        registry.init_params(cfg_t, k), cfg_t, prune_plan,
                        quant=quant, backend=backend, topology=topo,
                        pad_joints=self.vmax, sconv=sconv, csr_eps=csr_eps)
                    for k in keys)
        else:
            self._topo_plans[self.primary] = tuple(plans)
        self.plans = self._topo_plans[self.primary]
        self.vmax = int(self.plans[0].static.joints)

        # --- frozen BN calibration (per topology, shared by every tier) ---
        # each skeleton calibrates at its own joint count (the padded plan
        # slices itself to the clip's width), then the stem stats are
        # padded to the slab width once, so every topology's stats pytree
        # carries identical leaf shapes into the per-group dispatches
        if len(names) > 1 and (bn_stats is not None or x_calib is not None):
            raise ValueError(
                "bn_stats/x_calib override a single topology's calibration "
                "— a multi-topology service calibrates each skeleton from "
                "its own synthetic batch")
        self._topo_stats: Dict[str, Tuple] = {}
        for t in names:
            plans_t = self._topo_plans[t]
            topo = self._topos[t]
            transforms = [
                lambda x: x,
                lambda x, p=topo.parents: bone_stream_parents(x, p),
            ][: len(plans_t)]
            if bn_stats is not None:
                st = ((bn_stats,) * len(plans_t)
                      if isinstance(bn_stats, dict) else tuple(bn_stats))
            else:
                xc = x_calib
                if xc is None:
                    from repro.data.pipeline import (DataConfig,
                                                     skeleton_batches)
                    cfg_t = dataclasses.replace(
                        cfg, gcn_joints=topo.num_joints)
                    dcfg = DataConfig(global_batch=4, seq_len=cfg.gcn_frames,
                                      seed=seed)
                    xc = jnp.asarray(next(skeleton_batches(cfg_t, dcfg))["x"])
                st = tuple(
                    engine.collect_bn_stats(p, tf(jnp.asarray(xc)))
                    for p, tf in zip(plans_t, transforms))
            self._topo_stats[t] = tuple(
                engine._pad_data_bn_stats(s, p.static)
                for s, p in zip(st, plans_t))
        self.bn_stats = self._topo_stats[self.primary]

        # --- one pristine slab per capacity tier --------------------------
        # tier slabs are never mutated in place (every step/restore is a
        # functional update), so the pool entry a migration reads is always
        # the all-zero init: entering a tier needs no reset pass
        self._tier_slabs = {
            S: tuple(engine.init_session_slab(p, S, bn_stats=bs)
                     for p, bs in zip(self.plans, self.bn_stats))
            for S in tiers}

        # --- mesh placement (distributed tier) ----------------------------
        # per-slot leaves shard their leading slot axis across the 1-D
        # mesh; plan-level BN stats (no slot axis) and snapshot-ring rows
        # (ring axis, not slot axis) replicate.  One sharding tree per
        # stream serves every tier — specs are shape-independent.
        self._slab_shardings = None   # per-stream StreamState of shardings
        self._ring_sharding = None    # per-stream ring pytree of shardings
        self._row_sharding = None     # (S, ...) leaves, e.g. tick logits
        if mesh is not None:
            from jax.sharding import NamedSharding, PartitionSpec
            row = NamedSharding(mesh, PartitionSpec(mesh.axis_names[0]))
            rep = NamedSharding(mesh, PartitionSpec())

            def _slab_sharding(slab):
                sh = jax.tree_util.tree_map(lambda _: row, slab)
                sh.bn_stats = jax.tree_util.tree_map(
                    lambda _: rep, slab.bn_stats)
                return sh

            self._slab_shardings = tuple(
                _slab_sharding(s) for s in self._tier_slabs[tiers[0]])
            # ring rows are slot-shaped snapshots (no slot axis) — same
            # pytree structure as ``engine.snapshot_slots``, replicated
            self._ring_sharding = tuple(
                jax.tree_util.tree_map(
                    lambda _: rep, engine.init_snapshot_ring(s, 1))
                for s in self._tier_slabs[tiers[0]])
            self._row_sharding = row
            self._tier_slabs = {
                S: tuple(jax.device_put(s, sh) for s, sh in
                         zip(slabs, self._slab_shardings))
                for S, slabs in self._tier_slabs.items()}
        # the *live* slab is a deep copy, never an alias of a tier entry:
        # the fused tick donates its slab argument (XLA reuses the buffers
        # in place and deletes them Python-side), and a donated alias
        # would destroy the pristine tier slab and the shared BN stats
        self.slabs = tuple(jax.tree_util.tree_map(jnp.copy, s)
                           for s in self._tier_slabs[tiers[0]])

        # --- scheduler + capacity manager ---------------------------------
        self.fused = bool(fused)
        self.snap_capacity = int(snap_capacity if snap_capacity is not None
                                 else 2 * max(tiers))
        self.saliency: Optional[SaliencyGate] = None
        if saliency_thresh and saliency_thresh > 0.0:
            self.saliency = SaliencyGate(
                SaliencyConfig(threshold=float(saliency_thresh)))
        self.sched = SlabScheduler(
            tiers[0], self.vmax, cfg.gcn_in_channels,
            flush_frames=self.flush_frames,
            first_logit_delay=engine.stream_first_logit_delay(self.plans[0]),
            policy=qos,
            snap_ring=self.snap_capacity if self.fused else None,
            retain=self.retain_records,
            saliency=self.saliency)
        # deadline drops retire through the same bounded window as
        # completions, so service-side bookkeeping stays constant under a
        # miss-heavy load too
        self.sched.on_miss = self._on_miss
        self.policy = policy
        self.capman: Optional[CapacityManager] = None
        self.slo: Optional[SloController] = None
        if policy == "slo":
            # the SLO controller replaces the demand manager outright —
            # one `policy` knob swaps the whole control loop, and it is
            # useful even at a single tier (pure admission control)
            self.slo = SloController(
                slo_config or SloConfig(), tiers=tiers, start_tier=tiers[0],
                latency_floor=self.sched.first_logit_delay)
            self.sched.on_first_logit = self.slo.record_first_logit
        elif len(tiers) > 1:
            ccfg = capacity_config or CapacityConfig(tiers=tiers)
            if tuple(sorted(ccfg.tiers)) != tiers:
                ccfg = dataclasses.replace(ccfg, tiers=tiers)
            self.capman = CapacityManager(ccfg, start_tier=tiers[0])
        # per-tick scheduler-outcome log (golden-test shape; opt-in)
        self.record_outcomes = bool(record_outcomes)
        self.outcomes: List[Dict] = []
        self._shed_tick: List[Dict] = []    # sheds since the last tick
        self._missed_tick: List[int] = []   # misses within this tick
        self._rejected: set = set()         # rejected sids (poll-side)
        self.n_rejected = 0                 # lifetime rejected-open count

        # --- jitted device entry points ------------------------------------
        # under a mesh, every entry point pins its output shardings to the
        # slab/ring placement above: inputs (always the live sharded
        # buffers) and outputs then agree, so donation stays effective and
        # the compiled signature never flip-flops between placements
        step_out = fused_out = migrate_out = None
        if mesh is not None:
            step_out = (self._slab_shardings, self._row_sharding)
            fused_out = (self._slab_shardings, self._row_sharding,
                         self._ring_sharding)
            migrate_out = self._slab_shardings[0]
        self._step = jax.jit(make_gcn_slab_step(cfg), out_shardings=step_out)
        self._snap_fn = jax.jit(engine.snapshot_slots)
        self._rest_fn = jax.jit(engine.restore_slots)
        # the one-dispatch tick: slab and snapshot-ring pytrees are
        # DONATED (argnums 1 and 8) — XLA updates them in place and the
        # Python-side inputs die at the call; tick() must only ever pass
        # buffers it owns (self.slabs / self._rings) and immediately
        # rebind them to the outputs
        self._fused_tick = jax.jit(make_gcn_fused_tick(cfg),
                                   donate_argnums=(1, 8),
                                   out_shardings=fused_out)
        # per-stream on-device snapshot rings (fused path): ring rows are
        # slot-shaped (S-independent), so one ring serves every capacity
        # tier and rides through elastic migrations untouched
        self._rings: Optional[Tuple] = None
        if self.fused:
            self._rings = tuple(
                engine.init_snapshot_ring(s, self.snap_capacity)
                for s in self._tier_slabs[tiers[0]])
            if mesh is not None:
                self._rings = tuple(
                    jax.device_put(r, sh)
                    for r, sh in zip(self._rings, self._ring_sharding))
        # the tier-migration pair fused into one jit: gather rows out of
        # the source slab, scatter into the (pristine) target slab
        self._migrate_fn = jax.jit(
            lambda src, dst, old_idx, new_idx: engine.restore_slots(
                dst, new_idx, engine.snapshot_slots(src, old_idx)),
            out_shardings=migrate_out)
        if mesh is not None:
            # every dispatch runs inside the mesh's axis-rule scope so the
            # engine's logical "batch" constraints resolve at trace time
            self._step = self._under_mesh(self._step)
            self._fused_tick = self._under_mesh(self._fused_tick)
            self._migrate_fn = self._under_mesh(self._migrate_fn)
            self._snap_fn = self._under_mesh(self._snap_fn)
            self._rest_fn = self._under_mesh(self._rest_fn)

        # --- session bookkeeping -------------------------------------------
        self._next_sid = 0
        self._sessions: Dict[int, SessionRequest] = {}
        self._records: Dict[int, SessionRecord] = {}
        self._snaps: Dict[int, Tuple] = {}    # sid -> per-stream snapshots
                                              # (legacy tick path only)
        # retirement window: finished/missed sids in order; once more than
        # retain_records sessions have retired after one, its request/
        # record entries are dropped (lifetime totals live in the
        # scheduler's running aggregates)
        self._retired: deque = deque()
        self._tick = 0
        self._last_logits: Optional[Any] = None   # device array until forced
        self.wall_host_s = 0.0                # host scheduling inside tick()
        self.wall_device_s = 0.0              # forced-readback device waits
        self.device_dispatches = 0            # jitted calls issued by tick()
        self.tier_ticks: Dict[int, int] = {S: 0 for S in tiers}

        if warm:
            self._warm()

    # -- construction helpers ------------------------------------------------

    def _under_mesh(self, fn):
        """Wrap a jitted entry point so every call (hence its trace) runs
        inside the mesh's logical-axis rule scope — the engine's
        ``constrain(x, "batch", ...)`` hints then resolve onto the service
        mesh and the step compiles SPMD.  Only applied when ``mesh`` is
        set; donation semantics pass straight through."""
        import functools

        from repro.distributed.sharding import axis_rules

        @functools.wraps(fn)
        def wrapped(*args, **kwargs):
            with axis_rules(self.mesh):
                return fn(*args, **kwargs)

        return wrapped

    def _retire(self, sid: int) -> None:
        """Enter ``sid`` into the bounded retirement window; the oldest
        retiree beyond ``retain_records`` loses its host-side bookkeeping
        (request, record, legacy snapshot, missed/rejected-sid mirrors) —
        its outcome already lives in the lifetime aggregates."""
        self._retired.append(sid)
        while len(self._retired) > self.retain_records:
            old = self._retired.popleft()
            self._sessions.pop(old, None)
            self._records.pop(old, None)
            self._snaps.pop(old, None)
            self.sched.missed_sids.discard(old)
            self._rejected.discard(old)

    def _on_miss(self, req: SessionRequest) -> None:
        """Scheduler ``on_miss`` hook: retire the dropped session's
        bookkeeping and note the miss in this tick's outcome log."""
        self._retire(req.sid)
        if self.record_outcomes:
            self._missed_tick.append(req.sid)

    def _warm(self) -> None:
        """Compile the active tick path for every tier (plus the preempt
        gather/scatter pair on the legacy path) before traffic arrives —
        post-warmup, no admission/hold/occupancy/event-count combination
        retraces within a tier."""
        jnp, jax = self._jnp, self._jax
        engine = self._engine
        V, C = self.vmax, self.cfg.gcn_in_channels
        for S, slabs in self._tier_slabs.items():
            zf = jnp.zeros((S, V, C))
            zb = jnp.zeros((S,), bool)
            # the no-event tick (fused and legacy paths alike) is the
            # plain slab step
            _, wl = self._step(self.plans, slabs, zf, zb, zb, zb)
            jax.block_until_ready(wl)
            # every non-primary skeleton group's dispatch (its own plans +
            # BN-stats override over the same slab shape)
            for t in self.topologies[1:]:
                _, wl = self._step(self._topo_plans[t], slabs, zf, zb, zb,
                                   zb, stats=self._topo_stats[t])
                jax.block_until_ready(wl)
            if self.fused:
                # the fused event tick donates its slab/ring arguments, so
                # warm it on throwaway copies — never on the pristine tier
                # slabs or the live ring.  One trace per tier covers any
                # event count: the order buffers are traced values of the
                # static (max_events_for(S), 2) shape.
                wslabs = tuple(jax.tree_util.tree_map(jnp.copy, s)
                               for s in slabs)
                wrings = tuple(engine.init_snapshot_ring(
                    s, self.snap_capacity) for s in slabs)
                if self.mesh is not None:
                    # match the live rings' placement so warmup compiles
                    # the same input signature traffic will use
                    wrings = tuple(
                        jax.device_put(r, sh)
                        for r, sh in zip(wrings, self._ring_sharding))
                zo = jnp.asarray(pad_event_orders([], max_events_for(S)))
                out = self._fused_tick(self.plans, wslabs, zf, zb, zb, zb,
                                       zo, zo, wrings)
                jax.block_until_ready(out[1])
        if self.qos == "preempt" and not self.fused:
            # the legacy preempt gather/scatter traces per tier shape —
            # warm it at every tier so the first preemption after a grow
            # is free (the fused path carries its events in-dispatch)
            for slabs in self._tier_slabs.values():
                w = tuple(self._snap_fn(s, jnp.asarray(0)) for s in slabs)
                ws = tuple(self._rest_fn(s, jnp.asarray(0), x)
                           for s, x in zip(slabs, w))
                jax.block_until_ready(ws)
        # every ordered tier pair compiles its fixed-shape migration
        # (min(S_old, S_new) rows regardless of occupancy), so a traffic-
        # time grow/shrink never pays trace latency
        for a in self.tiers:
            for b in self.tiers:
                if a == b:
                    continue
                k = min(a, b)
                idx = jnp.arange(k, dtype=jnp.int32)
                out = tuple(self._migrate_fn(sa, sb, idx, idx)
                            for sa, sb in zip(self._tier_slabs[a],
                                              self._tier_slabs[b]))
                jax.block_until_ready(out)

    # -- plan-derived timing --------------------------------------------------

    def flush_frames(self, frames: int) -> int:
        """Flush-drain ticks after a ``frames``-long stream (the per-block
        'same'-padding latency, ``engine.stream_flush_frames``)."""
        return self._engine.stream_flush_frames(self.plans[0], frames)

    @property
    def first_logit_delay(self) -> int:
        """Raw frames from admission to the first valid logit."""
        return self._engine.stream_first_logit_delay(self.plans[0])

    # -- the session protocol -------------------------------------------------

    @property
    def now(self) -> int:
        """The service clock: index of the next tick to run."""
        return self._tick

    @property
    def wall_s(self) -> float:
        """Total serving time inside ``tick()``: host scheduling
        (``wall_host_s``) plus forced-readback device waits
        (``wall_device_s``) — kept as a property for back-compat with the
        old single counter."""
        return self.wall_host_s + self.wall_device_s

    @property
    def capacity(self) -> int:
        """Current slot capacity (the active tier)."""
        return len(self.sched.slots)

    def open_session(self, *, priority: int = 0,
                     deadline: Optional[int] = None,
                     arrival: Optional[int] = None,
                     topology: Optional[str] = None) -> SessionHandle:
        """Open a new session and enter it into the admission queue.

        The session is *open*: frames arrive via :meth:`submit` and the
        stream ends with :meth:`close` (an admitted session with an empty
        buffer is held in place, never zero-padded).  ``priority`` orders
        admission and selects preemption victims; ``deadline`` is the
        absolute completion-deadline tick under ``qos="deadline"``;
        ``arrival`` backdates the queueing clock (defaults to now);
        ``topology`` declares the session's skeleton (one of the
        service's ``topologies``; default the primary) — its frames are
        (V_topo, C) and are served by that topology's plans.

        Under ``policy="slo"`` every open passes the controller's
        admission gate first: while shedding, an unprotected open is
        *rejected* (the handle polls as ``"rejected"``; it never enters
        the scheduler and its frames are dropped) or *degraded* (served
        at the configured frame-skip stride) per ``shed_mode``."""
        topo = topology or self.primary
        if topo not in self._topos:
            raise ValueError(
                f"unknown topology {topo!r} — this service serves "
                f"{self.topologies}; construct it with topologies=(...) "
                "to add a skeleton")
        sid = self._next_sid
        self._next_sid += 1
        req = SessionRequest(
            sid=sid, arrival=self._tick if arrival is None else int(arrival),
            clip=None, priority=priority, deadline=deadline, topology=topo)
        self._sessions[sid] = req
        if self.slo is not None:
            verdict = self.slo.admit(priority)
            if verdict == "reject":
                # turned away at the door: the queue-forever alternative
                # is exactly what the SLO policy exists to avoid
                self._rejected.add(sid)
                self.n_rejected += 1
                if self.record_outcomes:
                    self._shed_tick.append(
                        {"sid": sid, "mode": "reject"})
                self._retire(sid)
                return SessionHandle(sid=sid)
            if verdict == "degrade":
                req.degrade = self.slo.degrade_stride_now()
                if self.record_outcomes:
                    self._shed_tick.append(
                        {"sid": sid, "mode": "degrade",
                         "stride": req.degrade})
        self.sched.submit(req)
        return SessionHandle(sid=sid)

    def _req(self, h: SessionHandle) -> SessionRequest:
        try:
            return self._sessions[h.sid]
        except KeyError:
            raise KeyError(f"unknown session handle {h!r}") from None

    def submit(self, h: SessionHandle, frame: np.ndarray) -> None:
        """Append one raw (V, C) skeleton frame to the session's stream.
        A no-op on a rejected session (the frames would never be served;
        batch drivers need not special-case the shed path)."""
        if h.sid in self._rejected:
            return
        frame = np.asarray(frame, np.float32)
        req = self._req(h)
        t = req.topology or self.primary
        vt = self._topos[t].num_joints
        if frame.shape != (vt, self.cfg.gcn_in_channels):
            raise ValueError(
                f"expected one ({vt}, {self.cfg.gcn_in_channels}) frame "
                f"for topology {t!r}, got {frame.shape}")
        req.push_frame(frame)

    def submit_clip(self, h: SessionHandle, clip: np.ndarray) -> None:
        """Submit a whole (T, V, C) clip and close the stream — the batch
        convenience over per-frame :meth:`submit` + :meth:`close` (and,
        like them, a no-op on a rejected session)."""
        if h.sid in self._rejected:
            return
        for frame in np.asarray(clip, np.float32):
            self._req(h).push_frame(frame)
        self.close(h)

    def close(self, h: SessionHandle) -> None:
        """End the session's stream.  The scheduler drains the flush
        latency and the final record becomes available via :meth:`poll`.
        A no-op on a rejected session."""
        if h.sid in self._rejected:
            return
        self._req(h).close()

    def poll(self, h: SessionHandle, *, wait: bool = False) -> SessionStatus:
        """Non-blocking status: state, progress and the latest logits.

        For an active/draining session the default returns the logits of
        the most recent *forced* tick — possibly ``None`` right after a
        tick whose async readback is still pending — so a client polling
        every tick costs no device sync (the fused path's readback
        overlap survives the polling).  ``wait=True`` forces the pending
        readback first (the wait is timed into ``wall_device_s``),
        guaranteeing the logits reflect the latest tick."""
        req = self._req(h)
        rec = self._records.get(h.sid)
        if rec is not None:
            return SessionStatus(
                sid=h.sid, state="done", frames_submitted=req.n_frames(),
                frames_consumed=rec.frames, priority=req.priority,
                logits=rec.logits, record=rec)
        if h.sid in self.sched.missed_sids:      # O(1) sid index
            return SessionStatus(
                sid=h.sid, state="missed", frames_submitted=req.n_frames(),
                frames_consumed=0, priority=req.priority)
        if h.sid in self._rejected:              # shed at open, never queued
            return SessionStatus(
                sid=h.sid, state="rejected",
                frames_submitted=req.n_frames(),
                frames_consumed=0, priority=req.priority)
        for s, slot in enumerate(self.sched.slots):
            if slot is not None and slot.req is req:
                # slot.rel counts *effective* (stride-decimated) frames;
                # report consumption in raw frames so clients see clip
                # progress regardless of the fidelity the SLO shed picked
                stride = max(1, int(req.degrade))
                state = ("active" if slot.rel < req.eff_frames()
                         or not req.is_closed() else "draining")
                if wait:
                    self._force_logits()
                logits = (np.asarray(self._last_logits[s])
                          if isinstance(self._last_logits, np.ndarray)
                          else None)
                return SessionStatus(
                    sid=h.sid, state=state, frames_submitted=req.n_frames(),
                    frames_consumed=min(slot.rel * stride, req.n_frames()),
                    priority=req.priority, logits=logits)
        # queued — either never admitted, or a preempted slot awaiting
        # re-admission (which keeps its consumed-frame progress); O(1)
        # sid lookup instead of a queue scan
        item = self.sched.queue.get(h.sid)
        consumed = (min(getattr(item, "rel", 0), req.n_frames())
                    if item is not None else 0)
        return SessionStatus(
            sid=h.sid, state="queued", frames_submitted=req.n_frames(),
            frames_consumed=consumed, priority=req.priority)

    def idle(self) -> bool:
        """True when no session is queued or occupying a slot."""
        return self.sched.idle()

    def advance_clock(self, tick: int) -> None:
        """Fast-forward an idle service to ``tick`` (Poisson lulls cost no
        compute; occupancy accounting weights them as empty).

        The skipped gap is fed to the elastic capacity manager as empty
        demand — enough observations to walk the tier ladder to the
        bottom, followed by **one** physical migration — so a long lull
        shrinks the slab and the first post-lull tick runs at bottom-tier
        cost (an idle elastic service used to stay pinned at whatever
        tier the last burst grew it to)."""
        if not self.idle():
            raise ValueError("cannot fast-forward a busy service")
        tick = int(tick)
        if self.capman is not None and tick > self._tick:
            cc = self.capman.config
            # worst case one full ladder walk: each rung needs its shrink
            # patience plus the post-resize cooldown before the next
            budget = len(self.tiers) * (cc.shrink_patience + cc.cooldown + 1)
            start = self.capman.capacity
            t = self._tick
            while (t < tick and budget > 0
                   and self.capman.capacity > self.tiers[0]):
                self.capman.observe(0, 0, t)
                t += 1
                budget -= 1
            if self.capman.capacity != start:
                self._migrate(self.capman.capacity)
        elif self.slo is not None and tick > self._tick:
            sc = self.slo.config
            # idle means every session drained: drop the stale latency
            # window (it describes a regime that no longer exists and
            # would pin the controller in breach forever), then feed
            # enough empty observations to walk the ladder down
            self.slo.idle_reset()
            budget = len(self.tiers) * (sc.recover_patience + sc.cooldown + 1)
            start = self.slo.capacity
            t = self._tick
            while (t < tick and budget > 0
                   and self.slo.capacity > self.tiers[0]):
                self.slo.observe(0, 0, t, queue_age=0)
                t += 1
                budget -= 1
            if self.slo.capacity != start:
                self._migrate(self.slo.capacity)
        self._tick = max(self._tick, tick)

    # -- the serving tick -----------------------------------------------------

    def _force_logits(self) -> Optional[np.ndarray]:
        """Force the pending tick's logits to host (no-op once forced).

        The fused tick keeps ``_last_logits`` as a device array — a
        future the host only waits on when someone actually reads it
        (``poll``, a finishing session, ``metrics``).  The block is timed
        into ``wall_device_s``: this is the forced-readback point that
        separates device time from host scheduling time."""
        if (self._last_logits is not None
                and not isinstance(self._last_logits, np.ndarray)):
            t0 = time.monotonic()
            self._last_logits = np.asarray(self._last_logits)
            self.wall_device_s += time.monotonic() - t0
        return self._last_logits

    def _topology_groups(self) -> List[Tuple[str, np.ndarray]]:
        """Partition the slot table by session topology: ``[(name, (S,)
        bool mask), ...]`` with the primary group first (free slots ride
        the primary — their dead-weight step happens exactly once, where
        it always did).  Empty non-primary groups are dropped, so a
        mixed-capable service serving only primary traffic pays no extra
        dispatch."""
        S = len(self.sched.slots)
        masks = {t: np.zeros(S, bool) for t in self.topologies}
        for s, slot in enumerate(self.sched.slots):
            t = self.primary
            if slot is not None and slot.req.topology:
                t = slot.req.topology
            masks[t][s] = True
        out = [(self.primary, masks[self.primary])]
        out += [(t, masks[t]) for t in self.topologies[1:]
                if masks[t].any()]
        return out

    def _step_groups(self, tp, groups, logits):
        """Step each non-primary skeleton group: one plain dispatch per
        group with that topology's plans and BN stats over the shared
        slab, everything outside the group held (held slots keep their
        state bit-for-bit and report their running prediction).  Returns
        the last dispatch's logits — it covers the whole slab, because
        held rows are recomputed from the post-step pool and the fc head
        is identical across topology plans by construction."""
        jnp = self._jnp
        for t, m in groups:
            self.slabs, logits = self._step(
                self._topo_plans[t], self.slabs, jnp.asarray(tp.frames),
                jnp.asarray(tp.valid & m), jnp.asarray(tp.reset & m),
                jnp.asarray(tp.hold | ~m), stats=self._topo_stats[t])
            self.device_dispatches += 1
        return logits

    def tick(self) -> List[SessionRecord]:
        """Run one scheduler tick: capacity decision (elastic), QoS policy
        + admissions, snapshot/restore orders, one device dispatch for
        all slots (the donated fused megakernel on event ticks, the plain
        slab step on no-event ticks; or the legacy multi-dispatch
        sequence when ``fused=False``), drain accounting.  Returns the sessions that
        finished this tick (their records are also kept for
        :meth:`poll`).

        On the fused path the logits stay on device: the host queues the
        dispatch and immediately resumes scheduling — the transfer is
        only forced when a session finishes this tick, someone polls, or
        metrics are read, so tick *t*'s device work overlaps tick
        *t+1*'s host-side planning."""
        jnp = self._jnp
        t0 = time.monotonic()
        dev0 = self.wall_device_s
        if self.capman is not None or self.slo is not None:
            # sweep deadline-expired sessions *before* the controller
            # looks: expired slots/queue entries are not demand,
            # and counting them used to trigger spurious grows
            self.sched.sweep_expired(self._tick)
        if self.slo is not None:
            # the leading-edge breach signal: the oldest queued session's
            # wait so far — a saturated queue never latches first logits,
            # so the p99 window alone would look healthy while everyone
            # starves
            queue_age = max(
                (self._tick - AdmissionQueue._req(it).arrival
                 for it in self.sched.queue), default=0)
            # the in-flight twin: an admitted-but-unlatched session's
            # first logit cannot land before admission + pipeline delay,
            # so its committed latency is already known — without it, a
            # recovery streak could un-shed while the slab is still full
            # of sessions guaranteed to breach when they latch
            inflight_age = max(
                (slot.admitted + self.sched.first_logit_delay - 1
                 - slot.req.arrival
                 for slot in self.sched.slots
                 if slot is not None and slot.first_logit_tick < 0),
                default=0)
            target = self.slo.observe(
                self.sched.busy(), len(self.sched.queue), self._tick,
                queue_age=queue_age, inflight_age=inflight_age)
            if target is not None and target != self.capacity:
                self._migrate(target)
        elif self.capman is not None:
            target = self.capman.observe(
                self.sched.busy(), len(self.sched.queue), self._tick)
            if target is not None:
                self._migrate(target)
        tp = self.sched.tick_inputs(self._tick, t0)
        outcome = None
        if self.record_outcomes:
            # pure host ints, no wall times / logits: the per-tick shape
            # the golden replay tests lock byte-for-byte.  Captured right
            # after tick_inputs (a tiny degraded session can finish on
            # its own admission tick, freeing the slot before outputs).
            outcome = {
                "tick": self._tick,
                "capacity": self.capacity,
                "busy": self.sched.busy(),
                "queued": len(self.sched.queue),
                "admitted": sorted(
                    self.sched.slots[s].req.sid
                    for s in np.flatnonzero(tp.reset)
                    if self.sched.slots[s] is not None),
                "restored": sorted(sid for _, sid in tp.restore),
                "preempted": sorted(sid for _, sid in tp.snapshot),
                "held": int(tp.hold.sum()),
                "shed": self._shed_tick,
            }
            self._shed_tick = []
        # mixed-skeleton slab: partition the slots by topology.  The
        # primary group carries the events and the free slots; every
        # other group is stepped by its own plans afterwards.  Group
        # masks: valid/reset only inside the group (reset must be
        # group-masked — step_frames resets *before* the hold select),
        # hold everything outside it.  None = single-topology service,
        # which takes exactly the legacy dispatch.
        groups = (self._topology_groups()
                  if len(self.topologies) > 1 else None)
        valid, reset, hold = tp.valid, tp.reset, tp.hold
        if groups is not None:
            mp = groups[0][1]
            valid, reset, hold = valid & mp, reset & mp, hold | ~mp
        if self.fused:
            if tp.snapshot or tp.restore:
                # event tick — one donated dispatch: snapshot gathers ->
                # restore scatters -> reset/hold-masked slab step, all
                # inside _fused_tick.  self.slabs/self._rings die at this
                # call (donated) and are rebound to the outputs — never
                # re-read the old references.
                self.slabs, logits, self._rings = self._fused_tick(
                    self.plans, self.slabs, jnp.asarray(tp.frames),
                    jnp.asarray(valid), jnp.asarray(reset),
                    jnp.asarray(hold), jnp.asarray(tp.snap_order),
                    jnp.asarray(tp.rest_order), self._rings)
            else:
                # no-event tick (the common case): the plain slab step is
                # the same single dispatch minus the ring plumbing — the
                # fused win here is skipping the per-tick readback, not
                # the kernel shape
                self.slabs, logits = self._step(
                    self.plans, self.slabs, jnp.asarray(tp.frames),
                    jnp.asarray(valid), jnp.asarray(reset),
                    jnp.asarray(hold))
            self.device_dispatches += 1
            if groups is not None:
                logits = self._step_groups(tp, groups[1:], logits)
            self._last_logits = logits           # device array; forced lazily
            # a session finishing this tick needs its logits row now —
            # force the readback (timed as device wait) before drain
            # accounting; otherwise leave the future pending
            if any(slot is not None and not slot.held
                   and slot.total is not None and slot.rel == slot.total - 1
                   for slot in self.sched.slots):
                self._force_logits()
        else:
            for s, sid in tp.snapshot:      # capture before restore/step
                self._snaps[sid] = tuple(
                    self._snap_fn(slab, jnp.asarray(s))
                    for slab in self.slabs)
                self.device_dispatches += len(self.slabs)
            for s, sid in tp.restore:
                snaps = self._snaps.pop(sid)
                self.slabs = tuple(
                    self._rest_fn(slab, jnp.asarray(s), sn)
                    for slab, sn in zip(self.slabs, snaps))
                self.device_dispatches += len(self.slabs)
            self.slabs, logits = self._step(
                self.plans, self.slabs, jnp.asarray(tp.frames),
                jnp.asarray(valid), jnp.asarray(reset),
                jnp.asarray(hold))
            self.device_dispatches += 1
            if groups is not None:
                logits = self._step_groups(tp, groups[1:], logits)
            self._last_logits = logits
            self._force_logits()                 # legacy: synchronous tick
        done = self.sched.tick_outputs(self._tick, self._last_logits,
                                       time.monotonic())
        for rec in done:
            self._records[rec.sid] = rec
            # the record holds the outcome; drop the frame payload so a
            # long-lived service doesn't pin every served clip in memory
            self._sessions[rec.sid].release_frames()
            self._retire(rec.sid)
        # (deadline misses release + retire through the scheduler's
        # on_miss hook the moment they are swept)
        if outcome is not None:
            outcome["finished"] = sorted(r.sid for r in done)
            outcome["missed"] = sorted(self._missed_tick)
            self._missed_tick = []
            self.outcomes.append(outcome)
        self.tier_ticks[self.capacity] += 1
        self._tick += 1
        self.wall_host_s += ((time.monotonic() - t0)
                             - (self.wall_device_s - dev0))
        return done

    def run_until_idle(self, max_ticks: int = 100_000) -> int:
        """Tick until every queued/active session has drained; returns the
        number of ticks run.  Raises if the budget is exhausted (an open
        session that is never closed holds its slot forever)."""
        n = 0
        while not self.idle():
            if n >= max_ticks:
                raise RuntimeError(
                    f"service did not drain within {max_ticks} ticks — "
                    "is an open session missing its close()?")
            self.tick()
            n += 1
        return n

    # -- elastic migration ----------------------------------------------------

    def _migrate(self, new_S: int) -> None:
        """Hop capacity tiers: compact the scheduler slot table, gather
        the occupied rows out of the old slabs and scatter them into the
        pristine target-tier slabs.  The gather/scatter is **fixed-shape**
        — always ``min(S_old, S_new)`` rows, occupied first, padded with
        free rows (their stale content lands in *free* target slots, which
        the admission reset zeroes before reuse) — so each ordered tier
        pair reuses one compiled migration regardless of occupancy, and
        :meth:`_warm` pre-compiles every pair.  Same primitives as QoS
        preemption, so the migrated-session parity invariant is the
        preemption invariant."""
        jax, jnp = self._jax, self._jnp
        t0 = time.monotonic()
        S_old = self.capacity
        occupied = [s for s, slot in enumerate(self.sched.slots)
                    if slot is not None]
        mapping = self.sched.resize(new_S)
        free = [s for s in range(S_old) if s not in mapping]
        k = min(S_old, new_S)
        old_idx = jnp.asarray((occupied + free)[:k], jnp.int32)
        new_idx = jnp.arange(k, dtype=jnp.int32)   # == mapped targets
        new_slabs = tuple(
            self._migrate_fn(slab, nsl, old_idx, new_idx)
            for slab, nsl in zip(self.slabs, self._tier_slabs[new_S]))
        jax.block_until_ready(new_slabs)
        self.slabs = new_slabs
        # _last_logits is NOT remapped: _migrate only runs inside tick(),
        # which overwrites it with the step's fresh logits before any
        # poll() can observe the stale rows
        ctrl = self.capman if self.capman is not None else self.slo
        if ctrl is not None and ctrl.events:
            ctrl.events[-1].wall_ms = (time.monotonic() - t0) * 1e3

    # -- cross-replica migration ----------------------------------------------

    def export_session(self, h: SessionHandle) -> Dict:
        """Drain one live session out of this service so another replica
        can adopt it — the router's rebalance primitive.

        Returns a host-side package: the session's scheduler item (the
        request, or the in-flight slot bookkeeping) plus per-stream numpy
        snapshots of its device state (``engine.snapshot_slots`` shape;
        None when the session was never admitted and has no device
        state).  The session stops existing here: its slot/queue entry
        and per-sid bookkeeping are dropped, bystander slots untouched.
        Finished or missed sessions cannot be exported.  The locked
        parity invariant (tests/test_distributed.py): exporting at any
        tick and resuming via :meth:`import_session` on another replica
        reproduces the uninterrupted run's logits ≤1e-3, and bystanders
        on both replicas are bit-identical."""
        jax, jnp = self._jax, self._jnp
        req = self._req(h)
        sid = h.sid
        if sid in self._records or sid in self.sched.missed_sids:
            raise ValueError(
                f"session {sid} already finished — nothing to export")
        item: Any = None
        snaps: Optional[Tuple] = None
        for s, slot in enumerate(self.sched.slots):
            if slot is not None and slot.req is req:
                # active: its live state is slab row s — same gather as a
                # preemption capture, then the slot is freed (admission
                # reset zeroes the stale row before reuse)
                snaps = tuple(
                    jax.device_get(self._snap_fn(slab, jnp.asarray(s)))
                    for slab in self.slabs)
                self.sched.slots[s] = None
                item = slot
                break
        if item is None:
            item = self.sched.queue.remove(sid)
            if item is None:
                raise ValueError(f"session {sid} is in no exportable state")
            if item is not req:
                # a preempted slot awaiting re-admission: its device state
                # is a ring row (fused) or a host snapshot tuple (legacy)
                if self.fused:
                    row = self.sched.ring_release(sid)
                    snaps = tuple(
                        jax.device_get(jax.tree_util.tree_map(
                            lambda leaf: leaf[row], ring))
                        for ring in self._rings)
                else:
                    snaps = tuple(jax.device_get(sn)
                                  for sn in self._snaps.pop(sid))
        self._sessions.pop(sid, None)
        return {"item": item, "snaps": snaps}

    def import_session(self, package: Dict) -> SessionHandle:
        """Adopt a session exported from another replica.

        The package's scheduler item re-enters the admission queue under
        a fresh local sid (the handle returned here supersedes the
        origin replica's).  A package carrying device snapshots uploads
        them first — into a snapshot-ring row (fused) or the host
        snapshot table (legacy) — so the next admission restores the
        session exactly like a local preemption resume: same ring
        phases, same block clocks, same running pool."""
        jax, jnp = self._jax, self._jnp
        item = package["item"]
        snaps = package["snaps"]
        req = item if isinstance(item, SessionRequest) else item.req
        if req.topology and req.topology not in self._topos:
            raise ValueError(
                f"cannot adopt a {req.topology!r} session — this replica "
                f"serves {self.topologies}")
        sid = self._next_sid
        self._next_sid += 1
        req.sid = sid
        self._sessions[sid] = req
        if snaps is not None:
            if self.fused:
                row = self.sched.ring_adopt(sid)
                self._rings = tuple(
                    jax.tree_util.tree_map(
                        lambda r, sv: r.at[row].set(jnp.asarray(sv, r.dtype)),
                        ring, sn)
                    for ring, sn in zip(self._rings, snaps))
                if self.mesh is not None:
                    # keep the rings on their replicated mesh placement so
                    # the fused tick's compiled signature never changes
                    self._rings = tuple(
                        jax.device_put(r, sh)
                        for r, sh in zip(self._rings, self._ring_sharding))
            else:
                self._snaps[sid] = tuple(snaps)
        self.sched.queue.push(item)
        return SessionHandle(sid=sid)

    # -- metrics --------------------------------------------------------------

    def metrics(self, *, keep_records: Optional[int] = None) -> Dict:
        """Aggregate serving metrics over everything served so far — the
        row shape merged into ``BENCH_sessions.json`` (fps, per-priority
        latency p50/p99, occupancy both ways, first-logit delay, QoS and
        elastic-capacity accounting) plus recent completed
        :class:`SessionRecord`\\ s under ``"records"``.

        Totals (``sessions``, ``deadline_missed``, occupancy, mean queue
        wait) come from lifetime running aggregates; percentile fields are
        computed over the retention window (the most recent
        ``retain_records`` completions).  ``keep_records`` bounds the
        returned record list further (``0`` drops it entirely — the
        long-lived-service polling shape); None returns the whole window.

        Reading metrics forces any pending async logits first, so
        ``wall_device_s`` settles before the row is built."""
        self._force_logits()
        sched, wall = self.sched, self.wall_s
        recs = list(sched.completed)
        lat = np.asarray([r.wall_finished - r.wall_admitted for r in recs])
        first = np.asarray([r.wall_first_logit - r.wall_admitted
                            for r in recs if r.wall_first_logit >= 0])
        no_first = sum(r.wall_first_logit < 0 for r in recs)
        # per-class latency, both anchors: service time (admission→finish,
        # wall ms) and end-to-end (arrival→finish, scheduler ticks — queue
        # wait and preemption requeues included, which is where the QoS
        # policies differ; tick-denominated so the comparison is
        # deterministic, not wall noise)
        by_prio: Dict[str, Dict[str, float]] = {}
        for p in sorted({r.priority for r in recs}):
            pl = np.asarray([r.wall_finished - r.wall_admitted
                             for r in recs if r.priority == p])
            pt = np.asarray([r.finished - r.arrival
                             for r in recs if r.priority == p], np.float64)
            # first-logit latency in scheduler ticks (arrival -> latch):
            # the SLO's own denomination, per class — the number the
            # controller is judged on
            ft = np.asarray([r.first_logit_tick - r.arrival
                             for r in recs
                             if r.priority == p and r.first_logit_tick >= 0],
                            np.float64)
            by_prio[str(p)] = {
                "n": int(len(pl)),
                "p50_ms": float(np.percentile(pl, 50) * 1e3),
                "p99_ms": float(np.percentile(pl, 99) * 1e3),
                "e2e_p50_ticks": float(np.percentile(pt, 50)),
                "e2e_p99_ticks": float(np.percentile(pt, 99)),
                "first_logit_p50_ticks": (float(np.percentile(ft, 50))
                                          if len(ft) else -1.0),
                "first_logit_p99_ticks": (float(np.percentile(ft, 99))
                                          if len(ft) else -1.0),
                "degraded": int(sum(r.degrade > 1 for r in recs
                                    if r.priority == p)),
            }
        n_missed = sched.n_missed
        ticks = self._tick
        # occ_sum/occ_ticks are lifetime aggregates over *processed* ticks
        # only; the true time-weighted occupancy counts fast-forwarded
        # idle gaps as zero (ticks spans the whole serving window, gaps
        # included)
        occ_busy = float(sched.occ_sum / max(sched.occ_ticks, 1))
        occ_time = float(sched.occ_sum / max(ticks, 1))
        ctrl = self.capman if self.capman is not None else self.slo
        events = ctrl.events if ctrl is not None else []
        out = {
            "backend": self.backend,
            "slots": self.tiers[0],
            "mesh": self.mesh.size if self.mesh is not None else 1,
            "topologies": ",".join(self.topologies),
            "joints": self.vmax,
            "qos": self.qos,
            "policy": self.policy,
            "capacity": ("fixed" if len(self.tiers) == 1 else
                         "elastic:" + ",".join(str(t) for t in self.tiers)),
            "sessions": sched.n_completed,
            "ticks": ticks,
            "wall_s": wall,
            "wall_host_s": self.wall_host_s,
            "wall_device_s": self.wall_device_s,
            "tick_path": "fused" if self.fused else "legacy",
            "device_dispatches": self.device_dispatches,
            "frames_per_s": sched.valid_frames / wall if wall > 0 else 0.0,
            "ticks_per_s": ticks / wall if wall > 0 else 0.0,
            "occupancy": occ_time,
            "occupancy_busy": occ_busy,
            "latency_ms_p50": (float(np.percentile(lat, 50) * 1e3)
                               if len(lat) else 0.0),
            "latency_ms_p99": (float(np.percentile(lat, 99) * 1e3)
                               if len(lat) else 0.0),
            "latency_ms_by_priority": by_prio,
            "first_logit_ms_p50": (float(np.percentile(first, 50) * 1e3)
                                   if len(first) else 0.0),
            "first_logit_frames": self.first_logit_delay,
            "sessions_no_first_logit": int(no_first),
            "queue_wait_ticks_mean": (sched.qwait_sum / sched.n_completed
                                      if sched.n_completed else 0.0),
            "preemptions": sched.preemptions,
            "restores": sched.restores,
            "deadline_missed": n_missed,
            "deadline_miss_rate": (
                n_missed / (n_missed + sched.n_completed)
                if (n_missed + sched.n_completed) else 0.0),
            "capacity_final": self.capacity,
            "migrations": len(events),
            "migrations_grow": sum(e.new > e.old for e in events),
            "migrations_shrink": sum(e.new < e.old for e in events),
            "migration_ms_mean": (float(np.mean([e.wall_ms for e in events]))
                                  if events else 0.0),
            # the tier walk itself (tick-denominated, wall-free) — what
            # the golden trace tests lock alongside the outcome log
            "resize_events": [[e.tick, e.old, e.new] for e in events],
            "tier_ticks": {str(S): n for S, n in self.tier_ticks.items()},
            "records": (recs if keep_records is None
                        else recs[len(recs) - min(keep_records, len(recs)):]),
        }
        # adaptive-streaming axes ride the row ONLY when enabled, so every
        # feature-off row (and the tracked legacy BENCH artifacts) stays
        # byte-identical; bench_key defaults the absent keys to off
        if getattr(self.cfg, "use_ck", False):
            out["ck"] = True
        if self.saliency is not None:
            gate = self.saliency
            out["saliency"] = gate.config.threshold
            out["frames_scored"] = gate.frames_scored
            out["frames_skipped"] = gate.frames_skipped
            out["frames_skipped_finished"] = sched.frames_skipped
            out["skip_rate"] = (gate.frames_skipped / gate.frames_scored
                                if gate.frames_scored else 0.0)
            # the headline: sessions one slab-slot-tick buys — a gated run
            # packs more sessions into the same slab * tick budget
            out["sessions_per_slot_tick"] = (
                sched.n_completed / (self.capacity * max(sched.occ_ticks, 1)))
        if self.slo is not None:
            out["slo_target_p99_ticks"] = self.slo.config.target_p99_ticks
            out["shed_mode"] = self.slo.config.shed_mode
            out["shed_rejected"] = self.slo.shed_rejected
            out["shed_degraded"] = self.slo.shed_degraded
            out["shed_windows"] = self.slo.shed_windows
            out["sessions_rejected"] = self.n_rejected
            out["sessions_degraded"] = int(
                sum(r.degrade > 1 for r in recs))
        return out


# ---------------------------------------------------------------------------
# the batch serving driver (serve sessions / BENCH rows)
# ---------------------------------------------------------------------------

def run_sessions(
    cfg,
    *,
    slots: int = 8,
    n_sessions: int = 16,
    mean_interarrival: float = 8.0,
    lengths: Optional[Sequence[int]] = None,
    backend: str = "reference",
    quant: bool = True,
    seed: int = 0,
    max_ticks: int = 100_000,
    qos: str = "fifo",
    preempt_ratio: float = 0.25,
    deadline_slack: int = 25,
    priorities: Optional[Sequence[int]] = None,
    capacity_tiers: Optional[Sequence[int]] = None,
    load: str = "poisson",
    fused: bool = True,
    mesh: int = 0,
    policy: str = "demand",
    slo_config: Optional[SloConfig] = None,
    topology: Optional[str] = None,
    rng: Optional[np.random.Generator] = None,
    use_ck: bool = False,
    saliency_thresh: float = 0.0,
) -> Dict:
    """Serve ``n_sessions`` generated skeleton sessions through a
    :class:`GcnService` with the two-stream (joint + bone) ensemble.

    The batch driver over the session-handle API: each arrival becomes
    ``open_session`` + ``submit_clip``; idle stretches fast-forward the
    service clock.  ``capacity_tiers`` switches the service elastic (one
    slab per tier, hysteresis grow/shrink + migration); ``slots`` alone is
    a fixed-capacity run.  ``load`` selects the arrival process:
    ``"poisson"`` (steady, ``mean_interarrival``) or ``"burst"`` (bursty
    peaks and lulls — the elastic stress shape).  ``preempt_ratio`` sets
    the load generator's high-priority mix (priority 1 vs 0) under every
    policy — same seed, same labels, so a fifo run baselines the preempt
    run directly; under ``qos="deadline"`` each session's completion
    deadline is its minimal service time (clip + flush) plus
    ``deadline_slack`` ticks past arrival.  ``mesh`` > 1 runs the slab
    sharded across that many devices (a 1-D batch mesh; the row gains a
    ``collective_ms_per_tick`` estimate).  ``policy`` selects the
    capacity controller (``"demand"`` | ``"slo"``, knobs via
    ``slo_config``); ``rng`` threads an explicit generator into the load
    generators (``default_rng(seed)`` otherwise — numpy's global state is
    never touched, so concurrent runs can't cross-contaminate);
    ``topology`` serves the whole run on a named registry skeleton
    (``ntu50``, ``hand21``, ...) — clips are generated at that skeleton's
    joint count (None = the default ``ntu25``).  ``use_ck`` switches the
    model to the windowed data-dependent C_k graph
    (``repro.core.agcn.adaptive``) and ``saliency_thresh`` > 0 gates
    uninformative frames (``repro.serving.saliency``) — the two
    adaptive-streaming knobs, tagged onto the row only when on.  Returns
    the :meth:`GcnService.metrics` dict (also the row merged into
    ``BENCH_sessions.json`` by ``serve sessions``)."""
    from repro.data.pipeline import DataConfig, skeleton_batches

    mesh_obj = None
    if mesh and mesh > 1:
        from repro.distributed.serving import make_batch_mesh
        mesh_obj = make_batch_mesh(mesh)
    tiers = tuple(capacity_tiers) if capacity_tiers else (slots,)
    if use_ck and not cfg.use_ck:
        cfg = dataclasses.replace(cfg, use_ck=True)
    svc = GcnService(cfg, backend=backend, qos=qos, capacity_tiers=tiers,
                     policy=policy, slo_config=slo_config,
                     topologies=(topology,) if topology else ("ntu25",),
                     quant=quant, seed=seed, fused=fused, mesh=mesh_obj,
                     saliency_thresh=saliency_thresh)

    if lengths is None:
        lengths = (cfg.gcn_frames, max(2, cfg.gcn_frames // 2))
    # clips are generated at the served skeleton's own joint count (the
    # scheduler zero-pads them to the slab width at tick time)
    vt = svc._topos[svc.primary].num_joints
    cfg_clips = (dataclasses.replace(cfg, gcn_joints=vt)
                 if vt != cfg.gcn_joints else cfg)
    pool = np.asarray(next(skeleton_batches(
        cfg_clips, DataConfig(global_batch=n_sessions,
                              seq_len=cfg.gcn_frames,
                              seed=seed + 1)))["x"])

    def clip_source(sid: int, T: int) -> np.ndarray:
        return pool[sid % len(pool), :T]

    # the priority mix applies under every policy (same seed -> identical
    # labels), so a fifo run is the directly comparable baseline for the
    # preempt run: priority admission without preemption
    if load == "burst":
        reqs = bursty_arrivals(
            n_sessions, lengths, vt, cfg.gcn_in_channels,
            burst_gap=max(1.0, mean_interarrival / 8.0),
            lull_gap=mean_interarrival * 8.0,
            seed=seed, clip_source=clip_source, priorities=priorities,
            high_priority_ratio=preempt_ratio, rng=rng)
    elif load == "poisson":
        reqs = poisson_arrivals(
            n_sessions, mean_interarrival, lengths,
            vt, cfg.gcn_in_channels, seed=seed,
            clip_source=clip_source, priorities=priorities,
            high_priority_ratio=preempt_ratio, rng=rng)
    else:
        raise ValueError(f"unknown load {load!r} (poisson | burst)")
    if qos == "deadline":
        for r in reqs:
            r.deadline = (r.arrival + len(r.clip)
                          + svc.flush_frames(len(r.clip)) + deadline_slack)

    pending = deque(reqs)
    while svc.now < max_ticks:
        while pending and pending[0].arrival <= svc.now:
            r = pending.popleft()
            h = svc.open_session(priority=r.priority, deadline=r.deadline,
                                 arrival=r.arrival)
            svc.submit_clip(h, r.clip)
        if svc.idle():
            if not pending:
                break
            svc.advance_clock(pending[0].arrival)   # fast-forward the lull
            continue
        svc.tick()

    out = svc.metrics()          # "slots" = the service's (sorted) base tier
    out["load"] = load
    if mesh_obj is not None:
        from repro.distributed.serving import collective_cost_ms
        out["collective_ms_per_tick"] = collective_cost_ms(svc)
    return out
