"""Temporal-attention saliency gating: skip uninformative frames per slot.

The adaptive-streaming subsystem's input-side half (the graph-side half is
the windowed C_k in ``repro.core.agcn.adaptive``).  Skeleton streams are
temporally redundant — a subject holding a pose contributes near-identical
frames for ticks on end — and the slab charges one tick per fed frame
regardless.  Following the temporal-attention frame selection of PAPERS.md
2010.12221 (and the paper's own input-skip C5 optimization, which zero-
suppresses *joints*; this gate suppresses whole *frames*), each incoming
frame is scored against the session's recent motion history and marked
*uninformative* when its attention ratio falls under a threshold:

    m_t = ||f_t − f_{t−1}||₂                 (raw inter-frame motion)
    α_t = m_t / (mean(m_1..m_t−1) + ε)        (attention vs. running mean)
    keep ⇔ t = 0  ∨  α_t ≥ threshold  ∨  consecutive skips = max cap

The consecutive-skip cap bounds the worst-case information loss (a long
freeze still samples every ``max_consecutive_skips + 1``-th frame), and
frame 0 is always kept so every session produces a logit.  Skipped frames
are never fed: the scheduler serves the *kept* subsequence — composing
with the SLO controller's degrade stride, which further decimates the kept
list — and starves (→ the engine's per-slot ``hold`` mask) when an open
stream's fresh frames were all skipped.  The session finishes in
~``kept/raw`` of the ticks, so the same slab serves proportionally more
sessions.

Everything here is deterministic host-side numpy — no RNG, no jax — and
the scorer state plus the kept-index list live **on the request**
(``req.sal_kept`` / ``req.sal_state``), so they ride preemption re-queues
and cross-replica ``export_session``/``import_session`` unchanged: a
migrated session skips exactly the frames it would have skipped in place
(bit-identity locked in tests/test_saliency.py).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

__all__ = ["SaliencyConfig", "SaliencyGate"]


@dataclasses.dataclass(frozen=True)
class SaliencyConfig:
    """Knobs for :class:`SaliencyGate`.

    ``threshold`` is the attention-ratio keep bound (α_t ≥ it keeps the
    frame; ≤ 0 is rejected — use no gate at all to disable saliency, so a
    configured gate always means the feature is on).
    ``max_consecutive_skips`` caps how many frames in a row may be
    dropped; ``eps`` regularizes the running-mean denominator (also what
    keeps the first motion sample, scored against an empty history)."""

    threshold: float = 1.0
    max_consecutive_skips: int = 3
    eps: float = 1e-6

    def __post_init__(self):
        if self.threshold <= 0.0:
            raise ValueError(
                f"threshold must be > 0, got {self.threshold} (omit the "
                "gate entirely to disable saliency)")
        if self.max_consecutive_skips < 1:
            raise ValueError("max_consecutive_skips must be >= 1, got "
                             f"{self.max_consecutive_skips}")
        if self.eps <= 0.0:
            raise ValueError(f"eps must be > 0, got {self.eps}")


@dataclasses.dataclass
class _ScorerState:
    """Per-session incremental scorer state (rides on the request)."""

    scored: int = 0                      # raw frames scored so far
    prev: Optional[np.ndarray] = None    # flattened previous frame
    mean: float = 0.0                    # causal running mean of motion
    nm: int = 0                          # motion samples folded into mean
    consec: int = 0                      # current consecutive-skip streak


class SaliencyGate:
    """Incremental per-session frame scorer feeding the scheduler.

    One gate serves every session (it is stateless across sessions); the
    per-session state lives on the :class:`SessionRequest` itself.
    :meth:`extend` scores any raw frames that arrived since the last call
    and appends the kept raw indices to ``req.sal_kept`` — the scheduler
    then feeds ``sal_kept[rel * degrade_stride]`` instead of
    ``rel * degrade_stride``, so saliency and SLO degrade compose."""

    def __init__(self, config: SaliencyConfig):
        self.config = config
        self.frames_scored = 0           # lifetime, across sessions
        self.frames_skipped = 0

    def extend(self, req) -> None:
        """Score ``req``'s unscored raw frames, growing ``req.sal_kept``.

        Idempotent per frame (each raw index is scored exactly once, in
        order) and safe to call every tick on open sessions — new frames
        pushed between calls are scored on the next call.  Must run before
        the session's frame payload is released."""
        st: Optional[_ScorerState] = getattr(req, "sal_state", None)
        if st is None:
            st = _ScorerState()
            req.sal_state = st
            req.sal_kept: List[int] = []
        cfg = self.config
        n = req.n_frames()
        while st.scored < n:
            t = st.scored
            f = np.asarray(req.frame(t), np.float32).ravel()
            if t == 0:
                keep = True              # first frame anchors the stream
            else:
                m = float(np.linalg.norm(f - st.prev))
                alpha = m / (st.mean + cfg.eps)
                st.nm += 1
                st.mean += (m - st.mean) / st.nm
                keep = (alpha >= cfg.threshold
                        or st.consec >= cfg.max_consecutive_skips)
            if keep:
                req.sal_kept.append(t)
                st.consec = 0
            else:
                st.consec += 1
                self.frames_skipped += 1
            st.prev = f
            st.scored = t + 1
            self.frames_scored += 1
