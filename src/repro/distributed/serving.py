"""Mesh-sharded session serving: the slab tick as an SPMD program.

The paper's throughput story — every layer resident, runtime-compressed
features, many streams at once — caps out at one device's slab capacity.
This module scales the *slot axis* instead of per-clip batches (the
continual-inference regime of CoST-GCN): a 1-D device mesh shards the
session slab's leading S axis, so one :class:`repro.serving.GcnService`
tick runs as a single SPMD dispatch across every mesh device, while the
host-side scheduler stays exactly the single-device scheduler (slots are
global indices; XLA routes each row's work to its shard).

Wiring (all of it reuses existing machinery):

* the engine's ``step_frame`` already constrains frames/logits to the
  logical ``"batch"`` axis (``repro.distributed.sharding.constrain``);
  under :func:`make_batch_mesh` those hints resolve to the mesh's
  ``data`` axis at trace time,
* ``GcnService(mesh=...)`` places the live slab, tier slabs and snapshot
  rings (slot leaves sharded, BN stats + ring rows replicated) and pins
  matching ``out_shardings`` on every jitted entry point, so donation
  and the one-compilation-per-tier property survive sharding,
* admission resets, preemption snapshot/restore and elastic tier
  migration are traced gathers/scatters over the sharded slab — XLA
  inserts the collectives; the host never notices.

No hardware needed: ``XLA_FLAGS=--xla_force_host_platform_device_count=4``
makes the mesh real on CPU (how tests/test_distributed.py and the
``--dist`` CI tier run).  :func:`collective_cost_ms` measures what the
sharding costs per tick — the ``collective_ms_per_tick`` axis of
``BENCH_sessions.json``.
"""
from __future__ import annotations

import time
from typing import Dict, Optional

BATCH_AXIS = "data"


def make_batch_mesh(n_devices: Optional[int] = None):
    """Build the 1-D slot mesh: ``n_devices`` devices under the single
    axis ``"data"`` (the axis the logical ``"batch"`` rule resolves to,
    see ``repro.distributed.sharding.DEFAULT_RULES``).

    ``n_devices`` defaults to every visible device.  Raises with the
    ``--xla_force_host_platform_device_count`` hint when the platform
    exposes fewer devices than asked — on CPU the fake-device flag is
    how a mesh becomes real."""
    import jax
    from jax.sharding import Mesh

    devices = jax.devices()
    if n_devices is None:
        n_devices = len(devices)
    n_devices = int(n_devices)
    if n_devices < 1:
        raise ValueError(f"mesh needs at least 1 device, got {n_devices}")
    if len(devices) < n_devices:
        raise RuntimeError(
            f"asked for a {n_devices}-device mesh but only {len(devices)} "
            "devices are visible — on CPU set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={n_devices} before "
            "jax initialises")
    import numpy as np
    return Mesh(np.asarray(devices[:n_devices]), (BATCH_AXIS,))


def collective_cost_ms(svc, iters: int = 16) -> float:
    """Per-tick collective overhead of the mesh-sharded slab step, in ms.

    Times the service's own (sharded) no-event slab step against a
    freshly-jitted single-device copy of the same step on the same slab
    content, and returns the difference (floored at 0) — the price of
    the cross-shard collectives the sharded tick pays, which is the
    ``collective_ms_per_tick`` column of the sharded
    ``BENCH_sessions.json`` rows.  Run on an idle service (the slab is
    read, not donated)."""
    import jax
    import jax.numpy as jnp

    from repro.train.steps import make_gcn_slab_step

    S = svc.capacity
    zf = jnp.zeros((S, svc.vmax, svc.cfg.gcn_in_channels))
    zb = jnp.zeros((S,), bool)

    def timed(step, slabs) -> float:
        out = step(svc.plans, slabs, zf, zb, zb, zb)   # compile + warm
        jax.block_until_ready(out[1])
        t0 = time.perf_counter()
        for _ in range(iters):
            out = step(svc.plans, slabs, zf, zb, zb, zb)
        jax.block_until_ready(out[1])
        return (time.perf_counter() - t0) / iters * 1e3

    sharded_ms = timed(svc._step, svc.slabs)
    dev = jax.devices()[0]
    single = jax.jit(make_gcn_slab_step(svc.cfg))
    slabs1 = jax.device_put(svc.slabs, dev)
    single_ms = timed(single, slabs1)
    return max(0.0, sharded_ms - single_ms)


def run_sharded_sessions(cfg, *, mesh: int, **kwargs) -> Dict:
    """Serve a session load with the slab sharded over a ``mesh``-device
    1-D batch mesh — :func:`repro.serving.run_sessions` with the mesh
    axis set; the returned row carries ``mesh`` and
    ``collective_ms_per_tick`` for the sharded ``BENCH_sessions.json``
    axis."""
    from repro.serving import run_sessions

    return run_sessions(cfg, mesh=int(mesh), **kwargs)
