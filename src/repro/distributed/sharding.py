"""Logical-axis sharding (MaxText-style rules), used everywhere in the zoo.

Model code annotates tensors with *logical* axis names via ``constrain``;
a context (set by the launcher / dry-run) maps logical names to mesh axes.
Outside any context ``constrain`` is a no-op, so unit tests and smoke tests
run unchanged on one CPU device.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Rules = Dict[str, Union[None, str, Tuple[str, ...]]]

# Default rules for the production mesh (data, model) [+ optional pod axis].
# Design: batch over (pod, data); big weight dims + sequence-between-blocks
# over model (sequence parallelism); vocab/ffn/experts/kv-flat over model.
DEFAULT_RULES: Rules = {
    "batch": ("pod", "data"),
    "seq": None,               # activations' sequence dim inside blocks
    "seq_shard": "model",      # sequence dim *between* blocks (SP regions)
    "embed": None,             # activation d_model dim
    "vocab": "model",
    "ffn": "model",
    "heads": None,
    "qkv_flat": "model",       # flattened heads*head_dim weight dim
    "kv_flat": "model",        # flattened kv_heads*head_dim (cache + weights)
    "expert": "model",
    "embed_fsdp": "data",      # weight d_model dim (ZeRO-3 over data)
    "layers": None,
    "state": "model",          # ssm state dims (divisible for all archs)
    "kv_seq": None,            # decode KV cache sequence dim (hillclimb knob)
    "kv_hd": "model",          # KV cache head_dim (divides 16 for all archs)
}


class _Ctx(threading.local):
    mesh: Optional[Mesh] = None
    rules: Optional[Rules] = None


_CTX = _Ctx()


@contextlib.contextmanager
def axis_rules(mesh: Mesh, rules: Optional[Rules] = None):
    prev = (_CTX.mesh, _CTX.rules)
    _CTX.mesh, _CTX.rules = mesh, dict(rules or DEFAULT_RULES)
    try:
        yield
    finally:
        _CTX.mesh, _CTX.rules = prev


def _resolve(axis: Optional[str]) -> Union[None, str, Tuple[str, ...]]:
    if axis is None or _CTX.rules is None:
        return None
    spec = _CTX.rules.get(axis)
    if spec is None:
        return None
    # drop mesh axes that don't exist (e.g. "pod" on the single-pod mesh);
    # a tuple left with one member normalizes to the bare string so specs
    # compare equal to hand-written P("data", ...) forms
    names = _CTX.mesh.axis_names
    if isinstance(spec, tuple):
        kept = tuple(s for s in spec if s in names)
        if not kept:
            return None
        return kept[0] if len(kept) == 1 else kept
    return spec if spec in names else None


def logical_spec(*axes: Optional[str]) -> P:
    return P(*[_resolve(a) for a in axes])


def constrain(x: jax.Array, *axes: Optional[str]) -> jax.Array:
    """Apply a logical sharding constraint; no-op outside axis_rules()."""
    if _CTX.mesh is None:
        return x
    if len(axes) != x.ndim:
        raise ValueError(f"{len(axes)} axes for rank-{x.ndim} tensor")
    spec = logical_spec(*axes)
    return jax.lax.with_sharding_constraint(x, NamedSharding(_CTX.mesh, spec))


def named_sharding(*axes: Optional[str]) -> Optional[NamedSharding]:
    if _CTX.mesh is None:
        return None
    return NamedSharding(_CTX.mesh, logical_spec(*axes))


def current_mesh() -> Optional[Mesh]:
    return _CTX.mesh


def divisible(dim: int, axis: Optional[str]) -> bool:
    """Would sharding `dim` over logical `axis` divide evenly on this mesh?"""
    if _CTX.mesh is None:
        return True
    spec = _resolve(axis)
    if spec is None:
        return True
    axes = spec if isinstance(spec, tuple) else (spec,)
    n = 1
    for a in axes:
        n *= _CTX.mesh.shape[a]
    return dim % n == 0
