"""Multi-replica session routing over N :class:`~repro.serving.GcnService`\\ s.

One mesh-sharded slab scales slot capacity; replicas scale *dispatch*
throughput (each replica is its own service with its own compiled plans,
slab and scheduler — on real hardware, its own device set).  The router
in front of them owns three things:

* **consistent pinning** — a session opened through the router gets a
  :class:`RouterHandle`; the router remembers which replica holds it, and
  every ``submit``/``poll``/``close`` routes there.  The pin survives
  rebalancing: migration atomically re-points the handle.
* **feedback placement** — new sessions land on the replica with the
  lowest load (busy slots + queue depth; index breaks ties), read fresh
  from each replica at open time (:meth:`ReplicaRouter.feedback`).
* **drain-and-rebalance** — :meth:`ReplicaRouter.rebalance` moves
  sessions from the most- to the least-loaded replica through the
  existing ``snapshot_slots``/``restore_slots`` host round-trip
  (``GcnService.export_session`` → ``import_session``).  The locked
  parity invariant (tests/test_distributed.py): a migrated session's
  final logits match its uninterrupted single-replica run ≤1e-3, and
  bystander sessions on both replicas are bit-identical.

The router tick is lockstep: :meth:`ReplicaRouter.tick` advances every
replica's clock by exactly one tick (busy replicas run a real tick, idle
ones fast-forward), so arrival timestamps mean the same thing on every
replica.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.serving import GcnService
from repro.serving.scheduler import bursty_arrivals, poisson_arrivals


@dataclasses.dataclass(frozen=True)
class RouterHandle:
    """Opaque ticket for one routed session: stable across rebalancing
    (the router re-points ``rsid`` at the session's current replica and
    replica-local handle)."""

    rsid: int


class ReplicaRouter:
    """Route sessions across N replica :class:`GcnService` instances.

    Construct with prebuilt services (same config/backend/QoS across
    replicas) or via :meth:`build`, which compiles the plans once and
    shares them — replica 2..N skip plan building and BN calibration."""

    def __init__(self, services: Sequence[GcnService]):
        if not services:
            raise ValueError("router needs at least one replica service")
        self.services: List[GcnService] = list(services)
        ticks = {s.now for s in self.services}
        if len(ticks) != 1:
            raise ValueError(
                f"replica clocks disagree at construction: {sorted(ticks)}")
        self._tick = self.services[0].now
        self._next_rsid = 0
        # rsid -> (replica index, replica-local handle); the one mutable
        # pin rebalancing re-points
        self._where: Dict[int, tuple] = {}
        self.rebalances = 0          # sessions moved across replicas
        self.migration_failures = 0  # rebalance picks that had no mover

    @classmethod
    def build(cls, cfg, *, replicas: int, **service_kwargs) -> "ReplicaRouter":
        """Build ``replicas`` services for one router: the first compiles
        its ExecutionPlans and BN calibration, the rest share them (plans
        are immutable pytrees; slabs/schedulers stay per-replica)."""
        if replicas < 1:
            raise ValueError(f"replicas must be >= 1, got {replicas}")
        first = GcnService(cfg, **service_kwargs)
        service_kwargs.pop("plans", None)
        service_kwargs.pop("bn_stats", None)
        rest = [GcnService(cfg, plans=first.plans, bn_stats=first.bn_stats,
                           **service_kwargs)
                for _ in range(replicas - 1)]
        return cls([first] + rest)

    # -- placement ------------------------------------------------------------

    @property
    def now(self) -> int:
        """The router clock (every replica's clock agrees with it)."""
        return self._tick

    def feedback(self) -> List[Dict[str, int]]:
        """Per-replica load feedback: busy slots, queue depth, capacity —
        the placement signal (and the rebalance imbalance measure)."""
        return [{"replica": i, "busy": s.sched.busy(),
                 "queued": len(s.sched.queue), "capacity": s.capacity}
                for i, s in enumerate(self.services)]

    def _load(self, i: int) -> int:
        s = self.services[i]
        return s.sched.busy() + len(s.sched.queue)

    def _place(self) -> int:
        return min(range(len(self.services)),
                   key=lambda i: (self._load(i), i))

    def replica_of(self, h: RouterHandle) -> int:
        """The replica index currently holding ``h`` (the pin)."""
        return self._where[h.rsid][0]

    # -- the session protocol (delegated) --------------------------------------

    def _at(self, h: RouterHandle) -> tuple:
        try:
            rid, inner = self._where[h.rsid]
        except KeyError:
            raise KeyError(f"unknown router handle {h!r}") from None
        return self.services[rid], inner

    def open_session(self, *, priority: int = 0,
                     deadline: Optional[int] = None,
                     arrival: Optional[int] = None,
                     replica: Optional[int] = None) -> RouterHandle:
        """Open a session on the least-loaded replica (or pin it to an
        explicit ``replica`` — the test/manual-placement override) and
        return its router-level handle."""
        rid = self._place() if replica is None else int(replica)
        inner = self.services[rid].open_session(
            priority=priority, deadline=deadline, arrival=arrival)
        h = RouterHandle(rsid=self._next_rsid)
        self._next_rsid += 1
        self._where[h.rsid] = (rid, inner)
        return h

    def submit(self, h: RouterHandle, frame: np.ndarray) -> None:
        """Append one raw (V, C) frame to the session's pinned replica."""
        svc, inner = self._at(h)
        svc.submit(inner, frame)

    def submit_clip(self, h: RouterHandle, clip: np.ndarray) -> None:
        """Submit a whole (T, V, C) clip and close the stream."""
        svc, inner = self._at(h)
        svc.submit_clip(inner, clip)

    def close(self, h: RouterHandle) -> None:
        """End the session's stream on its pinned replica."""
        svc, inner = self._at(h)
        svc.close(inner)

    def poll(self, h: RouterHandle, *, wait: bool = False):
        """Status from the session's pinned replica (semantics of
        :meth:`GcnService.poll`, including the async-logits default)."""
        svc, inner = self._at(h)
        return svc.poll(inner, wait=wait)

    # -- lockstep ticking -------------------------------------------------------

    def tick(self) -> None:
        """Advance every replica by exactly one tick: busy replicas run a
        real scheduler tick, idle replicas fast-forward their clock — the
        lockstep keeps arrival timestamps comparable across replicas."""
        nxt = self._tick + 1
        for s in self.services:
            if s.idle():
                s.advance_clock(nxt)
            else:
                s.tick()
        self._tick = nxt

    def idle(self) -> bool:
        """True when every replica is idle."""
        return all(s.idle() for s in self.services)

    def advance_clock(self, tick: int) -> None:
        """Fast-forward every (idle) replica to ``tick`` — lulls walk each
        replica's elastic ladder down, same as the single service."""
        for s in self.services:
            s.advance_clock(tick)
        self._tick = max(self._tick, int(tick))

    def run_until_idle(self, max_ticks: int = 100_000) -> int:
        """Tick until every replica drains; returns ticks run."""
        n = 0
        while not self.idle():
            if n >= max_ticks:
                raise RuntimeError(
                    f"router did not drain within {max_ticks} ticks")
            self.tick()
            n += 1
        return n

    # -- drain and rebalance -----------------------------------------------------

    def migrate_session(self, h: RouterHandle, dst: int) -> None:
        """Move one live session to replica ``dst`` through the host
        snapshot round-trip: export on the source (slot/queue entry plus
        per-stream device snapshots), import on the destination (snapshot
        upload + re-queue), re-point the pin.  A no-op when the session
        already lives on ``dst``."""
        rid, inner = self._where[h.rsid]
        dst = int(dst)
        if dst == rid:
            return
        package = self.services[rid].export_session(inner)
        new_inner = self.services[dst].import_session(package)
        self._where[h.rsid] = (dst, new_inner)
        self.rebalances += 1

    def _movable_on(self, rid: int) -> Optional[RouterHandle]:
        """A session on ``rid`` that can migrate: prefer queued sessions
        (no slot disruption), fall back to active ones; oldest first."""
        svc = self.services[rid]
        queued = active = None
        for rsid in sorted(self._where):
            r, inner = self._where[rsid]
            if r != rid:
                continue
            state = svc.poll(inner).state
            if state == "queued" and queued is None:
                queued = RouterHandle(rsid=rsid)
            elif state in ("active", "draining") and active is None:
                active = RouterHandle(rsid=rsid)
            if queued is not None:
                break
        return queued or active

    def rebalance(self, threshold: int = 2) -> int:
        """Even out replica load: while the busiest replica carries at
        least ``threshold`` more sessions (busy + queued) than the most
        idle one, drain one session from the former into the latter.
        Returns the number of sessions moved (also accumulated into
        ``self.rebalances`` — the BENCH row's rebalance count)."""
        moved = 0
        while True:
            loads = [self._load(i) for i in range(len(self.services))]
            src = max(range(len(loads)), key=lambda i: (loads[i], -i))
            dst = min(range(len(loads)), key=lambda i: (loads[i], i))
            if loads[src] - loads[dst] < max(1, int(threshold)):
                break
            h = self._movable_on(src)
            if h is None:
                self.migration_failures += 1
                break
            self.migrate_session(h, dst)
            moved += 1
        return moved

    # -- metrics ------------------------------------------------------------------

    def metrics(self) -> Dict:
        """One merged serving row over every replica — the routed
        ``BENCH_sessions.json`` shape: lifetime totals summed, occupancy
        averaged, latency percentiles over the union of the replicas'
        record windows, plus ``replicas``/``rebalances`` and the
        per-replica rows under ``"per_replica"``."""
        per = [s.metrics(keep_records=None) for s in self.services]
        recs = [r for m in per for r in m["records"]]
        lat = np.asarray([r.wall_finished - r.wall_admitted for r in recs])
        wall = sum(m["wall_s"] for m in per)
        frames = sum(s.sched.valid_frames for s in self.services)
        missed = sum(m["deadline_missed"] for m in per)
        done = sum(m["sessions"] for m in per)
        out = {
            "backend": per[0]["backend"],
            "slots": per[0]["slots"],
            "qos": per[0]["qos"],
            "capacity": per[0]["capacity"],
            "mesh": per[0]["mesh"],
            "replicas": len(self.services),
            "rebalances": self.rebalances,
            "sessions": done,
            "ticks": self._tick,
            "wall_s": wall,
            "frames_per_s": frames / wall if wall > 0 else 0.0,
            "occupancy": float(np.mean([m["occupancy"] for m in per])),
            "occupancy_busy": float(np.mean([m["occupancy_busy"]
                                             for m in per])),
            "latency_ms_p50": (float(np.percentile(lat, 50) * 1e3)
                               if len(lat) else 0.0),
            "latency_ms_p99": (float(np.percentile(lat, 99) * 1e3)
                               if len(lat) else 0.0),
            "preemptions": sum(m["preemptions"] for m in per),
            "restores": sum(m["restores"] for m in per),
            "deadline_missed": missed,
            "deadline_miss_rate": (missed / (missed + done)
                                   if (missed + done) else 0.0),
            "migrations": sum(m["migrations"] for m in per),
            "capacity_final": [m["capacity_final"] for m in per],
            "per_replica": [{k: v for k, v in m.items() if k != "records"}
                            for m in per],
            "records": recs,
        }
        return out


def run_routed_sessions(
    cfg,
    *,
    replicas: int = 2,
    slots: int = 8,
    n_sessions: int = 16,
    mean_interarrival: float = 8.0,
    lengths: Optional[Sequence[int]] = None,
    backend: str = "reference",
    quant: bool = True,
    seed: int = 0,
    max_ticks: int = 100_000,
    qos: str = "fifo",
    preempt_ratio: float = 0.25,
    deadline_slack: int = 25,
    capacity_tiers: Optional[Sequence[int]] = None,
    load: str = "poisson",
    fused: bool = True,
    rebalance_every: int = 16,
) -> Dict:
    """Serve a generated session load through a :class:`ReplicaRouter` —
    the routed counterpart of :func:`repro.serving.run_sessions`: same
    arrival processes, clips and QoS wiring, with feedback placement at
    admission and a :meth:`ReplicaRouter.rebalance` sweep every
    ``rebalance_every`` ticks.  Returns the merged
    :meth:`ReplicaRouter.metrics` row (``replicas``/``rebalances`` are
    its distributed axes in ``BENCH_sessions.json``)."""
    from repro.data.pipeline import DataConfig, skeleton_batches

    tiers = tuple(capacity_tiers) if capacity_tiers else (slots,)
    router = ReplicaRouter.build(
        cfg, replicas=replicas, backend=backend, qos=qos,
        capacity_tiers=tiers, quant=quant, seed=seed, fused=fused)
    svc0 = router.services[0]

    if lengths is None:
        lengths = (cfg.gcn_frames, max(2, cfg.gcn_frames // 2))
    pool = np.asarray(next(skeleton_batches(
        cfg, DataConfig(global_batch=n_sessions, seq_len=cfg.gcn_frames,
                        seed=seed + 1)))["x"])

    def clip_source(sid: int, T: int) -> np.ndarray:
        return pool[sid % len(pool), :T]

    if load == "burst":
        reqs = bursty_arrivals(
            n_sessions, lengths, cfg.gcn_joints, cfg.gcn_in_channels,
            burst_gap=max(1.0, mean_interarrival / 8.0),
            lull_gap=mean_interarrival * 8.0,
            seed=seed, clip_source=clip_source,
            high_priority_ratio=preempt_ratio)
    elif load == "poisson":
        reqs = poisson_arrivals(
            n_sessions, mean_interarrival, lengths,
            cfg.gcn_joints, cfg.gcn_in_channels, seed=seed,
            clip_source=clip_source, high_priority_ratio=preempt_ratio)
    else:
        raise ValueError(f"unknown load {load!r} (poisson | burst)")
    if qos == "deadline":
        for r in reqs:
            r.deadline = (r.arrival + len(r.clip)
                          + svc0.flush_frames(len(r.clip)) + deadline_slack)

    pending = deque(reqs)
    while router.now < max_ticks:
        while pending and pending[0].arrival <= router.now:
            r = pending.popleft()
            h = router.open_session(priority=r.priority, deadline=r.deadline,
                                    arrival=r.arrival)
            router.submit_clip(h, r.clip)
        if router.idle():
            if not pending:
                break
            router.advance_clock(pending[0].arrival)
            continue
        router.tick()
        if rebalance_every and router.now % rebalance_every == 0:
            router.rebalance()

    out = router.metrics()
    out["load"] = load
    return out
