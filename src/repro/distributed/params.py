"""Parameter partition-spec derivation (2D ZeRO-3-style sharding).

Rule per weight leaf (DESIGN.md §5):
  * MoE expert tensors: the experts dim -> "model" (expert parallelism),
    the largest remaining divisible dim -> "data".
  * Everything else: of the last two dims, the larger divisible one ->
    "model" (tensor parallelism), the other -> "data" (FSDP) if divisible.
  * Dims smaller than 64, scan-stack leading dims, and 0/1-D leaves stay
    replicated.

This never shards a head axis, so odd head counts (smollm's 15H) are safe —
flattened qkv feature dims are 16-divisible for every assigned arch.
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MIN_SHARD_DIM = 128


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape.get(name, 1)


# weights whose CONTRACTION dim must live on "model" (Megatron row-parallel:
# their producer's output is already model-sharded, so the matmul is local
# and only the output needs a reduce-scatter)
ROW_PARALLEL_NAMES = ("wo", "out_proj")


def leaf_spec(path: str, shape, mesh: Mesh, expert_dim: Optional[int] = None
              ) -> P:
    ndim = len(shape)
    spec = [None] * ndim
    model_n = _axis_size(mesh, "model")
    data_n = _axis_size(mesh, "data")
    if ndim == 0:
        return P()
    leaf_name = path.rsplit("/", 1)[-1]

    is_moe = "moe" in path or "router" in path
    used_model = False
    if is_moe and expert_dim and expert_dim in shape:
        for i, d in enumerate(shape):           # experts dim -> model (EP)
            if d == expert_dim and d % model_n == 0:
                spec[i] = "model"
                used_model = True
                break

    def ok(i, n):
        return spec[i] is None and shape[i] >= MIN_SHARD_DIM and shape[i] % n == 0

    if ndim >= 2:
        if leaf_name == "embed":
            # vocab -> model (sharded logits), d_model -> data (FSDP)
            order_model, order_data = [ndim - 2], [ndim - 1]
        elif any(leaf_name.startswith(n) for n in ROW_PARALLEL_NAMES):
            # row-parallel: contraction (dim -2) on model, output on data
            order_model, order_data = [ndim - 2], [ndim - 1]
        else:
            # column-parallel (wq/wk/wv/wi/wg/router/...): output (dim -1)
            # on model, contraction on data
            order_model, order_data = [ndim - 1], [ndim - 2]
        if not used_model:
            for i in order_model:
                if ok(i, model_n):
                    spec[i] = "model"
                    used_model = True
                    break
        for i in order_data + order_model:
            if ok(i, data_n):
                spec[i] = "data"
                break
    return P(*spec)


def _drop_data(spec: P) -> P:
    return P(*[None if s == "data" else s for s in spec])


def param_specs(params, mesh: Mesh, expert_dim: Optional[int] = None,
                policy: str = "2d"):
    """PartitionSpec tree matching ``params`` (works on ShapeDtypeStructs).

    policies:
      "2d"      — model (TP) + data (FSDP) on every weight
      "zero2"   — model (TP) only on params (weights resident, no per-layer
                  gathers); pair with 2D-sharded optimizer states so the
                  resharding happens ONCE per step at the update
      "dp_only" — replicate everything (small models where TP all-reduces
                  of activations dwarf the weight footprint)"""
    def f(path, leaf):
        if policy == "dp_only":
            return P()
        pstr = "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        spec = leaf_spec(pstr, leaf.shape, mesh, expert_dim)
        if policy == "zero2":
            spec = _drop_data(spec)
        return spec
    return jax.tree_util.tree_map_with_path(f, params)


def param_shardings(params, mesh: Mesh, expert_dim: Optional[int] = None,
                    policy: str = "2d"):
    specs = param_specs(params, mesh, expert_dim, policy)
    return jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), specs,
                                  is_leaf=lambda x: isinstance(x, P))


def sharded_bytes(params, specs, mesh: Mesh) -> int:
    """Per-device parameter bytes under the given specs."""
    total = 0
    for (path, leaf), (_, spec) in zip(
        jax.tree_util.tree_leaves_with_path(params),
        jax.tree_util.tree_leaves_with_path(
            specs, is_leaf=lambda x: isinstance(x, P)
        ),
    ):
        n = int(np.prod(leaf.shape)) if leaf.shape else 1
        div = 1
        for ax in spec:
            if ax is not None:
                div *= mesh.shape[ax]
        total += n * leaf.dtype.itemsize // div
    return total
