"""2s-AGCN — the paper's own model (Shi et al. [9]): 10 TCN-GCN blocks on
NTU RGB+D skeletons, with the RFC-HyPGCN hybrid-pruning knobs exposed."""
from repro.common.config import ModelConfig

CONFIG = ModelConfig(
    name="agcn-2s", family="gcn",
    num_layers=10, d_model=0, num_heads=0, num_kv_heads=0,
    d_ff=0, vocab_size=0,
    gcn_joints=25, gcn_frames=300, gcn_persons=2, gcn_in_channels=3,
    gcn_num_classes=60,
    gcn_channels=(64, 64, 64, 64, 128, 128, 128, 256, 256, 256),
    gcn_strides=(1, 1, 1, 1, 2, 1, 1, 2, 1, 1),
    gcn_kv=3, gcn_tkernel=9,
    # paper's final accelerating target: Drop-1 + cav-70-1 + input skip 2
    # (86% param reduction, 73.2% graph-skip) — the dry-run lowers THIS
    # pruned structure; dense-baseline cells live in experiments/dryrun_baseline
    cavity_pattern="cav-70-1", input_skip=2,
    prune_channel_fracs=(1.0, 0.6, 0.6, 0.55, 0.5, 0.5, 0.45, 0.4, 0.35, 0.3),
    # engine backend for inference paths (serve/bench); --backend overrides
    gcn_backend="reference",
    # streaming (serve --stream): cumulative logit pool reproduces the
    # clip engine exactly post-drain; set W>0 for a sliding live window
    gcn_stream_pool=0,
    # perf: 3.5M params -> replicate weights, model axis = extra DP
    # (EXPERIMENTS.md §Perf, agcn hillclimb iteration 1)
    sharding="dp_only",
    train_microbatches=1,
)

REDUCED = ModelConfig(
    name="agcn-2s-smoke", family="gcn",
    num_layers=4, d_model=0, num_heads=0, num_kv_heads=0,
    d_ff=0, vocab_size=0,
    gcn_joints=25, gcn_frames=32, gcn_persons=1, gcn_in_channels=3,
    gcn_num_classes=10,
    gcn_channels=(8, 8, 16, 16), gcn_strides=(1, 1, 2, 1),
    gcn_kv=3, gcn_tkernel=9,
    cavity_pattern="cav-70-1", input_skip=2,
    gcn_backend="reference",
    gcn_stream_pool=0,          # streaming↔clip parity (test_streaming.py)
)
