"""gemma3-12b — 5:1 local:global attention, 128k context, 256k vocab.
[hf:google/gemma-3-1b-pt; unverified]"""
from repro.common.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-12b", family="dense",
    num_layers=48, d_model=3840, num_heads=16, num_kv_heads=8,
    d_ff=15360, vocab_size=262144, head_dim=256,
    local_global_ratio=5, window_size=1024,
    rope_theta=1_000_000.0,
    train_microbatches=16,
)

REDUCED = ModelConfig(
    name="gemma3-12b-smoke", family="dense",
    num_layers=6, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=128, vocab_size=512, head_dim=16,
    local_global_ratio=5, window_size=8,
)
