"""qwen3-moe-30b-a3b — 128-expert top-8 MoE, 3B active params.
[hf:Qwen/Qwen3-30B-A3B; hf]"""
from repro.common.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    num_layers=48, d_model=2048, num_heads=32, num_kv_heads=4,
    d_ff=0, vocab_size=151936, head_dim=128,
    num_experts=128, experts_per_token=8, moe_d_ff=768,
    rope_theta=1_000_000.0,
    train_microbatches=4,
)

REDUCED = ModelConfig(
    name="qwen3-moe-30b-a3b-smoke", family="moe",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=0, vocab_size=512, head_dim=16,
    num_experts=8, experts_per_token=2, moe_d_ff=32,
    moe_capacity_factor=8.0,           # no token drops at smoke scale
)
