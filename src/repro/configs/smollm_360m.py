"""smollm-360m — llama-architecture small model (15 heads, GQA kv=5).
[hf:HuggingFaceTB/SmolLM-135M; hf]"""
from repro.common.config import ModelConfig

CONFIG = ModelConfig(
    name="smollm-360m", family="dense",
    num_layers=32, d_model=960, num_heads=15, num_kv_heads=5,
    d_ff=2560, vocab_size=49152, head_dim=64,
)

REDUCED = ModelConfig(
    name="smollm-360m-smoke", family="dense",
    num_layers=2, d_model=60, num_heads=3, num_kv_heads=1,
    d_ff=128, vocab_size=512, head_dim=20,
)
