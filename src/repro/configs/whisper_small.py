"""whisper-small — encoder-decoder audio transformer; conv/mel frontend is a
STUB (input_specs provides precomputed frame embeddings).
[arXiv:2212.04356; unverified]"""
from repro.common.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small", family="audio",
    num_layers=12, d_model=768, num_heads=12, num_kv_heads=12,
    d_ff=3072, vocab_size=51865, head_dim=64,
    encoder_layers=12, encoder_frames=1500, act="gelu",
)

REDUCED = ModelConfig(
    name="whisper-small-smoke", family="audio",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
    d_ff=128, vocab_size=512, head_dim=16,
    encoder_layers=2, encoder_frames=32, act="gelu",
)
