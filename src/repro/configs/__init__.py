"""Architecture registry + per-(arch × shape) input specs.

``get_config(name)`` accepts dash or underscore ids (--arch h2o-danube-1.8b).
``input_specs(cfg, shape, ...)`` builds ShapeDtypeStruct stand-ins for every
model input of the given shape cell — weak-type-correct, shardable, no
device allocation — plus the matching logical-axis trees for in_shardings.

Shape applicability (DESIGN.md §4):
  * long_500k  — only sub-quadratic archs (SWA / local-global / SSM / hybrid)
  * decode/long — not for the paper's GCN (action recognition has no
    autoregressive decode; its inference cell is gcn_infer)
"""
from __future__ import annotations

import importlib
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.common.config import GCN_SHAPES, SHAPES, ModelConfig, ShapeConfig

_ARCH_MODULES = [
    "h2o_danube_1_8b",
    "gemma3_12b",
    "internlm2_20b",
    "smollm_360m",
    "whisper_small",
    "llava_next_mistral_7b",
    "qwen3_moe_30b_a3b",
    "granite_moe_3b_a800m",
    "xlstm_1_3b",
    "zamba2_7b",
    "agcn_2s",
]

CONFIGS: Dict[str, ModelConfig] = {}
REDUCED: Dict[str, ModelConfig] = {}
for _m in _ARCH_MODULES:
    mod = importlib.import_module(f"repro.configs.{_m}")
    CONFIGS[mod.CONFIG.name] = mod.CONFIG
    REDUCED[mod.CONFIG.name] = mod.REDUCED

ASSIGNED = [n for n in CONFIGS if n != "agcn-2s"]


def _norm(name: str) -> str:
    return name.replace("_", "-").replace(".", "-").lower()


def get_config(name: str, reduced: bool = False) -> ModelConfig:
    table = REDUCED if reduced else CONFIGS
    key = _norm(name)
    for k, v in table.items():
        if _norm(k) == key:
            return v
    raise KeyError(f"unknown arch {name!r}; have {sorted(table)}")


def sub_quadratic(cfg: ModelConfig) -> bool:
    return (
        cfg.family in ("ssm", "hybrid")
        or cfg.window_size > 0
        or cfg.local_global_ratio > 0
    )


def shape_applicable(cfg: ModelConfig, shape: str) -> Tuple[bool, str]:
    """(applicable, reason-if-not)."""
    if cfg.family == "gcn":
        if shape in GCN_SHAPES:
            return True, ""
        return False, "GCN model uses gcn_train/gcn_infer cells"
    if shape not in SHAPES:
        return False, f"unknown shape {shape}"
    if shape == "long_500k" and not sub_quadratic(cfg):
        return False, "pure full attention at 524k context (sub-quadratic required)"
    return True, ""


def applicable_shapes(cfg: ModelConfig) -> List[str]:
    pool = GCN_SHAPES if cfg.family == "gcn" else SHAPES
    return [s for s in pool if shape_applicable(cfg, s)[0]]


# ---------------------------------------------------------------------------
# input specs
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape_name: str
                ) -> Tuple[Dict[str, jax.ShapeDtypeStruct], Dict[str, tuple]]:
    """Returns (shape-struct dict, logical-axis dict) for the batch inputs.

    Train/prefill cells describe the full batch {tokens, labels, ...};
    decode cells describe the per-step inputs {tokens (B,1), pos} — the KV
    cache specs come from registry.init_cache/cache_specs.
    """
    shp = (GCN_SHAPES | SHAPES)[shape_name]
    B, S = shp.global_batch, shp.seq_len
    i32 = jnp.int32

    if cfg.family == "gcn":
        n = B * cfg.gcn_persons
        return (
            {"x": _sds((n, cfg.gcn_frames, cfg.gcn_joints, cfg.gcn_in_channels),
                       jnp.float32),
             "labels": _sds((n,), i32)},
            {"x": ("batch", None, None, None), "labels": ("batch",)},
        )

    if shp.is_decode:
        batch = {"tokens": _sds((B, 1), i32), "pos": _sds((), i32)}
        axes = {"tokens": ("batch", None), "pos": ()}
        if cfg.family == "audio":
            batch["memory"] = _sds((B, cfg.encoder_frames, cfg.d_model),
                                   jnp.bfloat16)
            axes["memory"] = ("batch", None, None)
        return batch, axes

    batch = {}
    axes = {}
    if cfg.family == "vlm":
        s_text = S - cfg.num_image_tokens
        batch["tokens"] = _sds((B, s_text), i32)
        batch["image_embeds"] = _sds((B, cfg.num_image_tokens, cfg.d_model),
                                     jnp.bfloat16)
        batch["labels"] = _sds((B, s_text), i32)
        axes = {"tokens": ("batch", None), "image_embeds": ("batch", None, None),
                "labels": ("batch", None)}
    elif cfg.family == "audio":
        batch["frames"] = _sds((B, cfg.encoder_frames, cfg.d_model), jnp.bfloat16)
        batch["tokens"] = _sds((B, S), i32)
        batch["labels"] = _sds((B, S), i32)
        axes = {"frames": ("batch", None, None), "tokens": ("batch", None),
                "labels": ("batch", None)}
    else:
        batch["tokens"] = _sds((B, S), i32)
        batch["labels"] = _sds((B, S), i32)
        axes = {"tokens": ("batch", None), "labels": ("batch", None)}
    return batch, axes
