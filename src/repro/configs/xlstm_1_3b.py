"""xlstm-1.3b — xLSTM[7:1]: 7 mLSTM blocks per 1 sLSTM block, 48 blocks.
[arXiv:2405.04517; unverified]"""
from repro.common.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b", family="ssm",
    num_layers=48, d_model=2048, num_heads=4, num_kv_heads=4,
    d_ff=0, vocab_size=50304, head_dim=64,
    slstm_every=8, ssm_expand=2,
    train_microbatches=8,
)

REDUCED = ModelConfig(
    name="xlstm-1.3b-smoke", family="ssm",
    num_layers=4, d_model=64, num_heads=2, num_kv_heads=2,
    d_ff=0, vocab_size=512, head_dim=16,
    slstm_every=2, ssm_expand=2,
)
