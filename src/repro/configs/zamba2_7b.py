"""zamba2-7b — Mamba2 backbone + weight-shared attention block every 6
mamba layers (81 = 11×(1+6) + 4 tail mamba).  [arXiv:2411.15242; unverified]"""
from repro.common.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b", family="hybrid",
    num_layers=81, d_model=3584, num_heads=32, num_kv_heads=32,
    d_ff=14336, vocab_size=32000, head_dim=112,
    ssm_state=64, ssm_expand=2, shared_attn_every=6,
    train_microbatches=8,
)

REDUCED = ModelConfig(
    name="zamba2-7b-smoke", family="hybrid",
    num_layers=9, d_model=128, num_heads=2, num_kv_heads=2,
    d_ff=256, vocab_size=512, head_dim=32,
    ssm_state=16, ssm_expand=2, shared_attn_every=3,
)
