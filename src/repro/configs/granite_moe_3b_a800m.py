"""granite-moe-3b-a800m — 40-expert top-8 MoE (padded to 48 experts so the
16-way model mesh axis divides; pads get -inf router logits — DESIGN.md §5).
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]"""
from repro.common.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m", family="moe",
    num_layers=32, d_model=1536, num_heads=24, num_kv_heads=8,
    d_ff=0, vocab_size=49155, head_dim=64,
    num_experts=40, experts_per_token=8, moe_d_ff=512,
)

REDUCED = ModelConfig(
    name="granite-moe-3b-a800m-smoke", family="moe",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=0, vocab_size=512, head_dim=16,
    num_experts=5, experts_per_token=2, moe_d_ff=32,
    moe_capacity_factor=8.0,           # no token drops at smoke scale
)
