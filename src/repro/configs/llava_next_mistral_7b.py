"""llava-next-mistral-7b — mistral-7b text backbone consuming anyres patch
embeddings; the vision tower is a STUB (input_specs provides precomputed
patch embeddings).  [hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]"""
from repro.common.config import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b", family="vlm",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=14336, vocab_size=32000, head_dim=128,
    num_image_tokens=2880,                 # anyres: 5 tiles × 576 patches
    rope_theta=1_000_000.0,
    train_microbatches=4,
)

REDUCED = ModelConfig(
    name="llava-next-mistral-7b-smoke", family="vlm",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=128, vocab_size=512, head_dim=16, num_image_tokens=8,
)
