"""h2o-danube-1.8b — llama+mistral mix with sliding-window attention.
[arXiv:2401.16818; hf]"""
from repro.common.config import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-1.8b", family="dense",
    num_layers=24, d_model=2560, num_heads=32, num_kv_heads=8,
    d_ff=6912, vocab_size=32000, head_dim=80,
    window_size=4096,                      # mistral-style SWA
    rope_theta=10_000.0,
)

REDUCED = ModelConfig(
    name="h2o-danube-1.8b-smoke", family="dense",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=128, vocab_size=512, head_dim=16, window_size=16,
)
