"""Encoder-decoder transformer (Whisper-small backbone).

The conv/mel frontend is a STUB per the assignment: ``input_specs`` provides
precomputed frame embeddings (B, encoder_frames, d).  LayerNorm + learned
absolute positions + non-gated GELU MLP, as in Whisper.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.config import ModelConfig
from repro.distributed.sharding import constrain
from repro.models.layers.attention import attention_layer, attn_init
from repro.models.layers.common import he_init, layernorm, layernorm_init


def _mlp_init(key, d, dff):
    k1, k2 = jax.random.split(key)
    return {
        "wi": he_init(k1, (d, dff), d),
        "wo": he_init(k2, (dff, d), dff),
    }


def _mlp(p, x):
    return jax.nn.gelu(x @ p["wi"]) @ p["wo"]


def _enc_layer_init(key, cfg):
    ks = jax.random.split(key, 2)
    return {
        "ln1": layernorm_init(cfg.d_model), "ln2": layernorm_init(cfg.d_model),
        "attn": attn_init(ks[0], cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                          cfg.head_dim),
        "mlp": _mlp_init(ks[1], cfg.d_model, cfg.d_ff),
    }


def _dec_layer_init(key, cfg):
    ks = jax.random.split(key, 3)
    p = _enc_layer_init(key, cfg)
    p["ln_x"] = layernorm_init(cfg.d_model)
    p["xattn"] = attn_init(ks[2], cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                           cfg.head_dim)
    return p


def init_params(cfg: ModelConfig, key: jax.Array, dtype=jnp.float32) -> Dict:
    enc_l = cfg.encoder_layers or cfg.num_layers
    keys = jax.random.split(key, enc_l + cfg.num_layers + 3)
    enc = [_enc_layer_init(keys[i], cfg) for i in range(enc_l)]
    dec = [_dec_layer_init(keys[enc_l + i], cfg) for i in range(cfg.num_layers)]
    params = {
        "embed": he_init(keys[-1], (cfg.padded_vocab, cfg.d_model), cfg.d_model),
        "enc_pos": he_init(keys[-2], (cfg.encoder_frames, cfg.d_model),
                           cfg.d_model) * 0.02,
        "dec_pos": he_init(keys[-3], (32_768, cfg.d_model), cfg.d_model) * 0.02,
        "enc_layers": jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *enc),
        "dec_layers": jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *dec),
        "enc_norm": layernorm_init(cfg.d_model),
        "dec_norm": layernorm_init(cfg.d_model),
    }
    return jax.tree_util.tree_map(lambda x: x.astype(dtype), params)


def encode(params: Dict, frames: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """frames: (B, F, d) stub embeddings -> encoder memory (B, F, d)."""
    x = frames + params["enc_pos"][None, : frames.shape[1]]
    x = constrain(x, "batch", None, None)
    positions = jnp.arange(x.shape[1])

    def body(h, lp):
        a, _ = attention_layer(
            lp["attn"], layernorm(h, lp["ln1"]), positions,
            num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
            head_dim=cfg.head_dim, rope_theta=cfg.rope_theta, causal=False,
        )
        h = h + a
        h = h + _mlp(lp["mlp"], layernorm(h, lp["ln2"]))
        return constrain(h, "batch", None, None), None

    x, _ = jax.lax.scan(jax.checkpoint(body), x, params["enc_layers"])
    return layernorm(x, params["enc_norm"])


def decode(
    params: Dict,
    tokens: jnp.ndarray,                 # (B, S)
    memory: jnp.ndarray,                 # (B, F, d)
    cfg: ModelConfig,
    caches: Optional[Any] = None,
    positions: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, Optional[Any]]:
    x = jnp.take(params["embed"], tokens, axis=0)
    if positions is None:
        positions = jnp.arange(x.shape[1])
    x = x + jnp.take(params["dec_pos"], positions, axis=0)
    x = constrain(x, "batch", None, None)

    def body(carry, inp):
        h = carry
        if caches is None:
            lp, cache = inp, None
        else:
            lp, cache = inp
        a, new_c = attention_layer(
            lp["attn"], layernorm(h, lp["ln1"]), positions,
            num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
            head_dim=cfg.head_dim, rope_theta=cfg.rope_theta, causal=True,
            cache=cache,
        )
        h = h + a
        xa, _ = attention_layer(
            lp["xattn"], layernorm(h, lp["ln_x"]), positions,
            num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
            head_dim=cfg.head_dim, rope_theta=cfg.rope_theta, causal=False,
            memory=memory,
        )
        h = h + xa
        h = h + _mlp(lp["mlp"], layernorm(h, lp["ln2"]))
        return constrain(h, "batch", None, None), new_c

    if caches is None:
        x, _ = jax.lax.scan(jax.checkpoint(body), x, params["dec_layers"])
        new_caches = None
    else:
        x, new_caches = jax.lax.scan(body, x, (params["dec_layers"], caches))
    x = layernorm(x, params["dec_norm"])
    logits = x @ params["embed"].T
    return constrain(logits, "batch", None, "vocab"), new_caches


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    L = cfg.num_layers
    return {
        "k": jnp.zeros((L, batch, max_len, cfg.num_kv_heads, cfg.head_dim), dtype),
        "v": jnp.zeros((L, batch, max_len, cfg.num_kv_heads, cfg.head_dim), dtype),
        "pos": jnp.zeros((L,), jnp.int32),
    }


def cache_specs(cfg: ModelConfig):
    return {
        "k": (None, "batch", "kv_seq", None, "kv_hd"),
        "v": (None, "batch", "kv_seq", None, "kv_hd"),
        "pos": (None,),
    }
