"""Decoder-only transformer family: dense (danube/internlm2/smollm/gemma3),
MoE (qwen3/granite), and VLM (llava — text backbone consuming stub patch
embeddings).

Layers are stacked into scan groups (cfg.scan_group layers per group) so the
HLO stays O(1) in depth; mixed attention patterns (gemma3's 5 local : 1
global) put one pattern period inside each group, unrolled in the group body.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.config import ModelConfig
from repro.distributed.sharding import constrain
from repro.models.layers.attention import attention_layer, attn_init
from repro.models.layers.common import he_init, rmsnorm, rmsnorm_init
from repro.models.layers.mlp import mlp, mlp_init
from repro.models.layers.moe import moe_ffn, moe_init


# ---------------------------------------------------------------------------
# structure helpers
# ---------------------------------------------------------------------------

def layer_kinds(cfg: ModelConfig) -> List[str]:
    """Per-layer attention kind within one scan group."""
    g = scan_group_size(cfg)
    if cfg.local_global_ratio > 0:
        r = cfg.local_global_ratio
        return ["local"] * r + ["global"] * (g - r) if g == r + 1 else (
            (["local"] * r + ["global"]) * (g // (r + 1))
        )
    if cfg.window_size > 0:
        return ["local"] * g
    return ["global"] * g


def scan_group_size(cfg: ModelConfig) -> int:
    if cfg.local_global_ratio > 0:
        return cfg.local_global_ratio + 1
    return max(1, cfg.scan_group)


def num_groups(cfg: ModelConfig) -> int:
    g = scan_group_size(cfg)
    assert cfg.num_layers % g == 0, (cfg.num_layers, g)
    return cfg.num_layers // g


def _window(cfg: ModelConfig, kind: str) -> int:
    return cfg.window_size if kind == "local" else 0


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _layer_init(key, cfg: ModelConfig) -> Dict:
    ks = jax.random.split(key, 3)
    p = {
        "ln1": rmsnorm_init(cfg.d_model),
        "ln2": rmsnorm_init(cfg.d_model),
        "attn": attn_init(ks[0], cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                          cfg.head_dim),
    }
    if cfg.family == "moe":
        p["moe"] = moe_init(ks[1], cfg.d_model, cfg.moe_d_ff, cfg.num_experts,
                            cfg.padded_experts)
    else:
        p["mlp"] = mlp_init(ks[1], cfg.d_model, cfg.d_ff)
    return p


def init_params(cfg: ModelConfig, key: jax.Array, dtype=jnp.float32) -> Dict:
    g = scan_group_size(cfg)
    ng = num_groups(cfg)
    keys = jax.random.split(key, cfg.num_layers + 2)

    def group(gi):
        layers = [_layer_init(keys[gi * g + i], cfg) for i in range(g)]
        return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *layers)

    groups = [group(gi) for gi in range(ng)]
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *groups)
    params = {
        "embed": he_init(keys[-1], (cfg.padded_vocab, cfg.d_model), cfg.d_model),
        "layers": stacked,                     # leaves: (ng, g, ...)
        "final_norm": rmsnorm_init(cfg.d_model),
    }
    if cfg.family == "vlm":
        params["img_proj"] = he_init(keys[-2], (cfg.d_model, cfg.d_model),
                                     cfg.d_model)
    return jax.tree_util.tree_map(lambda x: x.astype(dtype), params)


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _group_body(cfg: ModelConfig, kinds: List[str]):
    def body(x, gp, positions, caches):
        new_caches = [] if caches is not None else None
        aux = jnp.zeros((), jnp.float32)
        for i, kind in enumerate(kinds):
            lp = jax.tree_util.tree_map(lambda a: a[i], gp)
            cache_i = (
                jax.tree_util.tree_map(lambda a: a[i], caches)
                if caches is not None else None
            )
            # explicit SP boundary: all-gather the normed activations over
            # the model axis ONCE here, so the blocked flash internals never
            # get seq-sharded (XLA otherwise reshards them with per-layer
            # all-to-alls — perf iteration A1, EXPERIMENTS §Perf)
            attn_in = constrain(
                rmsnorm(x, lp["ln1"], cfg.norm_eps), "batch", None, None)
            h, new_c = attention_layer(
                lp["attn"], attn_in, positions,
                num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
                head_dim=cfg.head_dim, rope_theta=cfg.rope_theta,
                causal=True, window=_window(cfg, kind), cache=cache_i,
            )
            x = x + h
            h2 = rmsnorm(x, lp["ln2"], cfg.norm_eps)
            if cfg.family == "moe":
                h2, a = moe_ffn(
                    lp["moe"], h2, num_experts=cfg.num_experts,
                    top_k=cfg.experts_per_token,
                    capacity_factor=cfg.moe_capacity_factor, act=cfg.act,
                )
                aux = aux + a
            else:
                h2 = mlp(lp["mlp"], h2, cfg.act)
            x = x + h2
            x = constrain(x, "batch", "seq_shard", None)
            if new_caches is not None:
                new_caches.append(new_c)
        if new_caches is not None:
            new_caches = jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs), *new_caches
            )
        return x, new_caches, aux
    return body


def _remat(f, cfg: ModelConfig):
    if cfg.remat == "none":
        return f
    policy = (
        jax.checkpoint_policies.nothing_saveable
        if cfg.remat == "full"
        else jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
    )
    return jax.checkpoint(f, policy=policy)


def forward(
    params: Dict,
    tokens: jnp.ndarray,                    # (B, S) int32
    cfg: ModelConfig,
    image_embeds: Optional[jnp.ndarray] = None,   # vlm: (B, N_img, d)
    caches: Optional[Any] = None,           # stacked (ng, g, ...) KV caches
    positions: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, Optional[Any], jnp.ndarray]:
    """Returns (logits (B,S_total,Vp), new_caches, aux_loss)."""
    kinds = layer_kinds(cfg)
    body = _group_body(cfg, kinds)
    x = jnp.take(params["embed"], tokens, axis=0) * np.sqrt(cfg.d_model)
    if cfg.family == "vlm" and image_embeds is not None:
        img = image_embeds @ params["img_proj"]
        x = jnp.concatenate([img.astype(x.dtype), x], axis=1)
    x = constrain(x, "batch", "seq_shard", None)
    if positions is None:
        positions = jnp.arange(x.shape[1])

    if caches is None:
        def scan_fn(carry, gp):
            h, aux = carry
            h, _, a = body(h, gp, positions, None)
            return (h, aux + a), None
        scan_body = _remat(scan_fn, cfg)
        (x, aux), _ = jax.lax.scan(
            scan_body, (x, jnp.zeros((), jnp.float32)), params["layers"]
        )
        new_caches = None
    else:
        def scan_fn(carry, inp):
            h, aux = carry
            gp, cache = inp
            h, new_c, a = body(h, gp, positions, cache)
            return (h, aux + a), new_c
        (x, aux), new_caches = jax.lax.scan(
            scan_fn, (x, jnp.zeros((), jnp.float32)),
            (params["layers"], caches),
        )

    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = x @ params["embed"].T
    logits = constrain(logits, "batch", None, "vocab")
    return logits, new_caches, aux


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------

def cache_len(cfg: ModelConfig, max_len: int) -> int:
    """SWA ring buffer: a uniform sliding window only ever needs `window`
    slots (RoPE is applied before caching and softmax is order-invariant,
    so ring slots attend exactly like the true last-`window` tokens).
    Mixed local:global stacks (gemma3) keep full length — the global
    layers need it and cache groups are stacked uniformly."""
    if cfg.window_size > 0 and cfg.local_global_ratio == 0:
        return min(max_len, cfg.window_size)
    return max_len


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    ng, g = num_groups(cfg), scan_group_size(cfg)
    L = cache_len(cfg, max_len)
    kv = {
        "k": jnp.zeros((ng, g, batch, L, cfg.num_kv_heads, cfg.head_dim),
                       dtype),
        "v": jnp.zeros((ng, g, batch, L, cfg.num_kv_heads, cfg.head_dim),
                       dtype),
        "pos": jnp.zeros((ng, g), jnp.int32),
    }
    return kv


def cache_specs(cfg: ModelConfig):
    """Logical axes of each cache leaf (for dry-run shardings)."""
    return {
        "k": (None, None, "batch", "kv_seq", None, "kv_hd"),
        "v": (None, None, "batch", "kv_seq", None, "kv_hd"),
        "pos": (None, None),
    }
