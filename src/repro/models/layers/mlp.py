"""Gated MLP with optional structured channel pruning (paper C1 applied to
LM FFNs: pruning the shared d_ff dimension shrinks *both* the up/gate and the
down matmuls — the dataflow-reorganization insight; DESIGN.md §4)."""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models.layers.common import activation, he_init


def mlp_init(key, d_model: int, d_ff: int) -> Dict:
    ks = jax.random.split(key, 3)
    return {
        "wi": he_init(ks[0], (d_model, d_ff), d_model),
        "wg": he_init(ks[1], (d_model, d_ff), d_model),
        "wo": he_init(ks[2], (d_ff, d_model), d_ff),
    }


def mlp(p: Dict, x: jnp.ndarray, act: str = "silu",
        kept_ff: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """x: (B, S, d).  kept_ff: optional kept-channel indices (C1 pruning)."""
    wi, wg, wo = p["wi"], p["wg"], p["wo"]
    if kept_ff is not None:
        wi = jnp.take(wi, kept_ff, axis=1)
        wg = jnp.take(wg, kept_ff, axis=1)
        wo = jnp.take(wo, kept_ff, axis=0)
    # no sharding constraint on h: with x sequence-sharded and wg/wi
    # column-sharded, h is doubly (seq × ffn) sharded with zero comms and
    # the down-proj needs only an all-reduce of the seq-sharded output
    # (perf iteration A1, EXPERIMENTS §Perf)
    h = activation(act)(x @ wg) * (x @ wi)
    return h @ wo


def prune_mlp_channels(p: Dict, keep_frac: float) -> jnp.ndarray:
    """Magnitude-based kept d_ff channels (paper C1 selection rule: keep the
    channels with largest mean |W| across producer+consumer)."""
    score = (
        jnp.abs(p["wi"]).mean(0) + jnp.abs(p["wg"]).mean(0) + jnp.abs(p["wo"]).mean(1)
    )
    keep = max(1, int(round(score.shape[0] * keep_frac)))
    idx = jnp.argsort(-score)[:keep]
    return jnp.sort(idx)
