"""Shared layer primitives: norms, RoPE, inits, activations."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def he_init(key, shape, fan_in, dtype=jnp.float32):
    return jax.random.normal(key, shape, dtype) * np.sqrt(2.0 / max(1, fan_in))


def lecun_init(key, shape, fan_in, dtype=jnp.float32):
    return jax.random.normal(key, shape, dtype) * np.sqrt(1.0 / max(1, fan_in))


def rmsnorm_init(d: int):
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(x: jnp.ndarray, p, eps: float = 1e-6) -> jnp.ndarray:
    """RMSNorm with f32 *statistics* but dtype-preserving elementwise math:
    no full-width f32 activation tensor ever exists, so sharding boundaries
    next to norms move bf16, not f32 (perf iteration A4, EXPERIMENTS §Perf)."""
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return x * inv * p["scale"].astype(x.dtype)


def layernorm_init(d: int):
    return {"scale": jnp.ones((d,), jnp.float32), "bias": jnp.zeros((d,), jnp.float32)}


def layernorm(x: jnp.ndarray, p, eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mean = jnp.mean(x32, -1, keepdims=True)
    var = jnp.var(x32, -1, keepdims=True)
    return ((x32 - mean) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]).astype(dt)


def activation(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return jax.nn.gelu
    if name == "relu2":                  # squared ReLU — sparse activations,
        return lambda x: jnp.square(jax.nn.relu(x))   # RFC-compressible
    raise ValueError(name)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (B, S, H, D); positions: (B, S) or (S,)."""
    D = x.shape[-1]
    freqs = rope_freqs(D, theta)                       # (D/2,)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freqs       # (B, S, D/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)
