"""Mixture-of-Experts FFN with capacity-based, per-example scatter dispatch.

The dispatch is *sort-free*: each (token, choice) computes its slot inside
its expert's capacity buffer with a cumulative sum over the one-hot routing
mask — the same cumsum-compaction primitive as the paper's RFC encoder
(position-of-nth-nonzero), applied to token→expert routing instead of
channel banks (DESIGN.md §4).

Dispatch is vmapped over the batch dim so every scatter/gather is LOCAL to
the data shard that owns the example; the only cross-device movement is the
(B-sharded → E-sharded) buffer reshard, which GSPMD lowers as an all-to-all
— the standard expert-parallel exchange (perf iteration M1, EXPERIMENTS
§Perf; the previous global-cumsum formulation lowered as per-layer
all-reduces of the whole expert buffer).

Experts are sharded over the mesh "model" axis; ``num_experts`` is padded so
16 divides it (pad experts get −inf router logits and zero weights).
Tokens over capacity are dropped (residual passes through).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models.layers.common import activation, he_init


def moe_init(key, d_model: int, moe_d_ff: int, num_experts: int,
             padded_experts: int) -> Dict:
    ks = jax.random.split(key, 4)
    E = padded_experts
    wi = he_init(ks[0], (E, d_model, moe_d_ff), d_model)
    wg = he_init(ks[1], (E, d_model, moe_d_ff), d_model)
    wo = he_init(ks[2], (E, moe_d_ff, d_model), moe_d_ff)
    if E > num_experts:
        mask = (jnp.arange(E) < num_experts).astype(wi.dtype)[:, None, None]
        wi, wg, wo = wi * mask, wg * mask, wo * mask
    return {
        "router": he_init(ks[3], (d_model, E), d_model),
        "wi": wi, "wg": wg, "wo": wo,
    }


def _dispatch_one(xt, expert_idx, keep, slot, E: int, cap: int):
    """Per-example scatter: xt (T, d) -> buf (E, cap+1, d).

    One scatter per routing choice (k is small and static) instead of a
    single scatter of the 8×-repeated token tensor: the backward pass then
    sums the k gather-cotangents locally BEFORE any cross-shard reduction
    (perf iteration M2, EXPERIMENTS §Perf)."""
    k = expert_idx.shape[-1]
    sidx = jnp.where(keep, slot, cap)
    buf = jnp.zeros((E, cap + 1, xt.shape[-1]), xt.dtype)
    for j in range(k):
        buf = buf.at[expert_idx[:, j], sidx[:, j]].add(xt)
    return buf


def _combine_one(out_buf, expert_idx, keep, slot, gates, cap: int):
    """Per-example gather: out_buf (E, cap+1, d) -> (T, d)."""
    T, k = expert_idx.shape
    sidx = jnp.where(keep, slot, cap)
    w = (gates * keep).astype(out_buf.dtype)
    out = jnp.zeros((T, out_buf.shape[-1]), out_buf.dtype)
    for j in range(k):
        out = out + out_buf[expert_idx[:, j], sidx[:, j]] * w[:, j : j + 1]
    return out


def moe_ffn(
    p: Dict,
    x: jnp.ndarray,                  # (B, S, d)
    *,
    num_experts: int,                # real experts (pads masked out)
    top_k: int,
    capacity_factor: float = 1.25,
    act: str = "silu",
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (output (B,S,d), aux load-balancing loss)."""
    B, S, d = x.shape
    E = p["router"].shape[-1]
    cap = max(1, int(top_k * S * capacity_factor / E))

    logits = (x @ p["router"]).astype(jnp.float32)             # (B, S, E)
    logits = jnp.where(jnp.arange(E) < num_experts, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, top_k)        # (B, S, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # RFC-style cumsum compaction, per example: slot of each (token, choice)
    # = number of earlier assignments to the same expert within the example
    onehot = jax.nn.one_hot(expert_idx, E, dtype=jnp.int32)    # (B, S, k, E)
    flat = onehot.reshape(B, S * top_k, E)
    slot = jnp.cumsum(flat, axis=1) - flat
    slot = (slot * flat).sum(-1).reshape(B, S, top_k)          # (B, S, k)
    keep = slot < cap

    buf = jax.vmap(
        lambda xt, ei, ke, sl: _dispatch_one(xt, ei, ke, sl, E, cap)
    )(x, expert_idx, keep, slot)                               # (B, E, cap+1, d)
    # B-sharded -> E-sharded exchange (the EP all-to-all)
    buf = constrain(buf, "batch", "expert", None, None)

    h = activation(act)(jnp.einsum("becd,edf->becf", buf, p["wg"])) * \
        jnp.einsum("becd,edf->becf", buf, p["wi"])
    out_buf = jnp.einsum("becf,efd->becd", h, p["wo"])
    out_buf = constrain(out_buf, "batch", "expert", None, None)

    out = jax.vmap(
        lambda ob, ei, ke, sl, gv: _combine_one(ob, ei, ke, sl, gv, cap)
    )(out_buf, expert_idx, keep, slot, gate_vals)              # (B, S, d)
    out = constrain(out, "batch", None, None)

    # load-balance aux loss (Switch-style)
    pe = probs.reshape(-1, E)
    me = pe.mean(0)
    ce = onehot.reshape(-1, top_k, E).sum(1).astype(jnp.float32).mean(0) \
        * E / top_k
    aux = (me * ce).sum() * num_experts / E
    return out, aux
