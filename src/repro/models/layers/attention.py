"""GQA attention with blockwise (flash-style) softmax, SWA / local-global
masks, cross-attention, and a KV-cache decode path.

The blockwise formulation (online softmax over KV blocks, fp32 running
max/sum) never materialises the full (Sq × Skv) score matrix, which is what
lets the prefill_32k shapes fit HBM.  Causal block *skipping* (not just
masking) is left to the perf pass — see EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models.layers.common import apply_rope, he_init

NEG_INF = -1e30


def attn_init(key, d_model: int, num_heads: int, num_kv_heads: int,
              head_dim: int) -> Dict:
    ks = jax.random.split(key, 3)
    # q and fused kv: two column-parallel matmuls -> two (not three)
    # boundary cotangents (perf iteration A3).  kv stays fused because its
    # k/v midpoint split is ALWAYS shard-aligned (2·kv_dim/16 divides
    # kv_dim); fusing q in as well puts the q/k boundary at q_dim, which is
    # NOT shard-aligned for most archs and forces GSPMD to gather the whole
    # projection (perf iteration A8, EXPERIMENTS §Perf).
    return {
        "wq": he_init(ks[0], (d_model, num_heads * head_dim), d_model),
        "wkv": he_init(ks[1], (d_model, 2 * num_kv_heads * head_dim), d_model),
        "wo": he_init(ks[2], (num_heads * head_dim, d_model), num_heads * head_dim),
    }


def _mask(qi, kj, causal: bool, window: int, kv_valid: Optional[jnp.ndarray]):
    """qi: (qb,), kj: (kb,) global indices -> (qb, kb) additive mask."""
    m = jnp.zeros((qi.shape[0], kj.shape[0]), jnp.float32)
    if causal:
        m = jnp.where(kj[None, :] > qi[:, None], NEG_INF, m)
    if window > 0:
        m = jnp.where(qi[:, None] - kj[None, :] >= window, NEG_INF, m)
    if kv_valid is not None:
        m = jnp.where(kj[None, :] >= kv_valid, NEG_INF, m)
    return m


def flash_attention(
    q: jnp.ndarray,              # (B, Sq, H, D)
    k: jnp.ndarray,              # (B, Skv, Hkv, D)
    v: jnp.ndarray,              # (B, Skv, Hkv, D)
    causal: bool = True,
    window: int = 0,
    q_offset: int | jnp.ndarray = 0,
    kv_valid: Optional[jnp.ndarray] = None,   # scalar: #valid cache slots
    q_block: int = 512,
    kv_block: int = 1024,
) -> jnp.ndarray:
    B, Sq, H, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    scale = D ** -0.5

    qb = min(q_block, Sq)
    kb = min(kv_block, Skv)
    # pad to block multiples
    Sq_p, Skv_p = -(-Sq // qb) * qb, -(-Skv // kb) * kb
    if Skv_p != Skv:
        k = jnp.pad(k, ((0, 0), (0, Skv_p - Skv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, Skv_p - Skv), (0, 0), (0, 0)))
        kv_valid = jnp.asarray(Skv if kv_valid is None else kv_valid)
    if Sq_p != Sq:
        q = jnp.pad(q, ((0, 0), (0, Sq_p - Sq), (0, 0), (0, 0)))
    nq, nk = Sq_p // qb, Skv_p // kb

    qg = q.reshape(B, nq, qb, Hkv, G, D)
    kg = k.reshape(B, nk, kb, Hkv, D)
    vg = v.reshape(B, nk, kb, Hkv, D)

    def q_step(_, qi_blk):
        qblk, qidx = qi_blk                       # (B, qb, Hkv, G, D), scalar
        qi = q_offset + qidx * qb + jnp.arange(qb)

        def kv_step(carry, kv_blk):
            m_run, l_run, acc = carry
            kblk, vblk, kidx = kv_blk
            kj = kidx * kb + jnp.arange(kb)
            s = jnp.einsum(
                "bqhgd,bkhd->bhgqk", qblk, kblk,
                preferred_element_type=jnp.float32,
            ) * scale
            s = s + _mask(qi, kj, causal, window, kv_valid)
            m_new = jnp.maximum(m_run, s.max(-1))
            # probabilities in bf16 after the stabilised subtraction: halves
            # the dominant S²-proportional HBM traffic of unfused attention
            # (perf iteration A3, EXPERIMENTS §Perf); the running max/sum
            # stay f32 so the softmax remains numerically exact
            p = jnp.exp((s - m_new[..., None]).astype(vblk.dtype))
            corr = jnp.exp(m_run - m_new)
            l_new = l_run * corr + p.astype(jnp.float32).sum(-1)
            pv = jnp.einsum(
                "bhgqk,bkhd->bhgqd", p, vblk,
                preferred_element_type=jnp.float32,
            )
            return (m_new, l_new, acc * corr[..., None] + pv), None

        init = (
            jnp.full((B, Hkv, G, qb), NEG_INF, jnp.float32),
            jnp.zeros((B, Hkv, G, qb), jnp.float32),
            jnp.zeros((B, Hkv, G, qb, D), jnp.float32),
        )
        (m_run, l_run, acc), _ = jax.lax.scan(
            kv_step, init,
            (jnp.moveaxis(kg, 1, 0), jnp.moveaxis(vg, 1, 0), jnp.arange(nk)),
        )
        out = acc / jnp.maximum(l_run[..., None], 1e-20)
        return None, jnp.transpose(out, (0, 3, 1, 2, 4))   # (B, qb, Hkv, G, D)

    _, outs = jax.lax.scan(
        q_step, None, (jnp.moveaxis(qg, 1, 0), jnp.arange(nq))
    )                                               # (nq, B, qb, Hkv, G, D)
    out = jnp.moveaxis(outs, 0, 1).reshape(B, Sq_p, H, D)[:, :Sq]
    return out.astype(q.dtype)


def attention_layer(
    p: Dict,
    x: jnp.ndarray,                       # (B, S, d)
    positions: jnp.ndarray,
    *,
    num_heads: int,
    num_kv_heads: int,
    head_dim: int,
    rope_theta: float,
    causal: bool = True,
    window: int = 0,
    cache: Optional[Dict] = None,         # {"k","v": (B, Smax, Hkv, D), "pos"}
    memory: Optional[jnp.ndarray] = None, # cross-attention memory (B, Sm, d)
) -> Tuple[jnp.ndarray, Optional[Dict]]:
    B, S, d = x.shape
    kv_src = memory if memory is not None else x
    Skv = kv_src.shape[1]
    q = x @ p["wq"]
    k, v = jnp.split(kv_src @ p["wkv"], 2, axis=-1)
    q = q.reshape(B, S, num_heads, head_dim)
    k = k.reshape(B, Skv, num_kv_heads, head_dim)
    v = v.reshape(B, Skv, num_kv_heads, head_dim)

    if memory is None:
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)

    kv_valid = None
    q_offset = 0
    new_cache = None
    ring = False
    if cache is not None:
        pos = cache["pos"]                                   # scalar int32
        cdt = cache["k"].dtype
        L = cache["k"].shape[1]
        # SWA ring buffer (decoder.cache_len): cache shorter than the
        # context -> write at pos % L; every live slot is inside the
        # window by construction, so no positional masking is needed
        ring = window > 0 and L <= window and S == 1
        slot = pos % L if ring else pos
        ck = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k.astype(cdt), slot, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v.astype(cdt), slot, axis=1)
        k, v = ck, cv
        kv_valid = jnp.minimum(pos + S, L) if ring else pos + S
        q_offset = pos
        new_cache = {"k": ck, "v": cv, "pos": pos + S}

    out = flash_attention(
        q, k, v,
        causal=causal and memory is None and not ring,
        window=0 if ring else window,
        q_offset=0 if ring else q_offset,
        kv_valid=kv_valid,
    )
    out = out.reshape(B, S, num_heads * head_dim) @ p["wo"]
    # reduce-scatter back to the sequence-sharded boundary (Megatron-SP)
    out = constrain(out, "batch", "seq_shard", None)
    return out, new_cache
