"""Chunked SSD (state-space duality) core — shared by Mamba2 (zamba2) and
mLSTM (xlstm), which are both "gated linear attention with decay":

    S_t = a_t · S_{t-1} + dt_t · B_t ⊗ x_t          (state: H × N × P)
    y_t = C_t · S_t

The chunkwise algorithm computes intra-chunk interactions as a masked
attention-like matmul (MXU-friendly) and carries inter-chunk state with a
short lax.scan — O(S·L) work instead of O(S²), which is what makes the
long_500k shapes lowerable for the SSM/hybrid archs.

Heads are independent, so for wide models the scan runs over head *groups*
(lax.map) to bound the (L×L) decay-mask working set — the VMEM-tiling
argument of the paper's bank storage applied to the sequence dimension.

mLSTM is realised by mapping (v, k, q, i, f) -> (x, B, C, dt, a) and
augmenting x with a ones-column so the same kernel also produces the
normalizer n·q (see repro.models.layers.xlstm).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

DEFAULT_CHUNK = 128
# Head-group batching (lax.map over groups) is DISABLED by default: the
# heads axis is model-sharded in production, which already bounds the
# (L×L×H_local) intra-chunk working set, and a group size that does not
# equal the per-shard head count forces GSPMD to gather all heads and
# replicate the scan (perf iteration H1, EXPERIMENTS §Perf).
HEAD_GROUP = 0


def _ssd_core(x, log_a, dt, Bm, Cm, init_state, chunk):
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    L = min(chunk, S)
    pad = (-S) % L
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        log_a = jnp.pad(log_a, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    Sp = S + pad
    nc = Sp // L

    xc = x.reshape(Bsz, nc, L, H, P)
    lac = log_a.reshape(Bsz, nc, L, H).astype(jnp.float32)
    dtc = dt.reshape(Bsz, nc, L, H).astype(jnp.float32)
    Bc = Bm.reshape(Bsz, nc, L, N)
    Cc = Cm.reshape(Bsz, nc, L, N)

    cum = jnp.cumsum(lac, axis=2)                       # (B, nc, L, H)
    total = cum[:, :, -1]                               # (B, nc, H)

    # --- intra-chunk (attention-like, causal with decay mask) ---
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]        # (B,nc,i,j,H)
    causal = jnp.tril(jnp.ones((L, L), bool))
    # mask BEFORE exp: exp of the (positive) upper triangle overflows and
    # 0*inf = NaN poisons the cotangent of the where
    diff = jnp.where(causal[None, None, :, :, None], diff, -jnp.inf)
    decay = jnp.exp(diff)
    cb = jnp.einsum("bnie,bnje->bnij", Cc, Bc)          # (B,nc,L,L)
    w = cb[..., None] * decay * dtc[:, :, None, :, :]   # (B,nc,i,j,H)
    y_intra = jnp.einsum("bnijh,bnjhp->bnihp", w.astype(x.dtype), xc)

    # --- chunk summary states ---
    sdec = jnp.exp(total[:, :, None, :] - cum) * dtc    # (B,nc,L,H)
    states = jnp.einsum("bnlh,bnle,bnlhp->bnhep", sdec.astype(x.dtype), Bc, xc)

    # --- inter-chunk scan ---
    s0 = (
        jnp.zeros((Bsz, H, N, P), jnp.float32)
        if init_state is None else init_state.astype(jnp.float32)
    )

    def step(s, inp):
        st, tot = inp                                   # (B,H,N,P), (B,H)
        new = s * jnp.exp(tot)[:, :, None, None] + st.astype(jnp.float32)
        return new, s

    final, prevs = jax.lax.scan(
        step, s0,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(total, 1, 0)),
    )
    prevs = jnp.moveaxis(prevs, 0, 1)                   # (B,nc,H,N,P)

    y_inter = jnp.einsum(
        "bnle,bnlh,bnhep->bnlhp",
        Cc, jnp.exp(cum).astype(x.dtype), prevs.astype(x.dtype),
    )
    y = (y_intra + y_inter).reshape(Bsz, Sp, H, P)[:, :S]
    return y, final


def ssd_scan(
    x: jnp.ndarray,        # (B, S, H, P)
    log_a: jnp.ndarray,    # (B, S, H)   per-step log decay (<= 0)
    dt: jnp.ndarray,       # (B, S, H)   input scale (>= 0)
    Bm: jnp.ndarray,       # (B, S, N)   input proj (shared across heads)
    Cm: jnp.ndarray,       # (B, S, N)   output proj
    init_state: Optional[jnp.ndarray] = None,   # (B, H, N, P)
    chunk: int = DEFAULT_CHUNK,
    head_group: int = HEAD_GROUP,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (y (B,S,H,P), final_state (B,H,N,P))."""
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    if not head_group or H <= head_group or H % head_group:
        return _ssd_core(x, log_a, dt, Bm, Cm, init_state, chunk)

    ng = H // head_group
    xg = jnp.moveaxis(x.reshape(Bsz, S, ng, head_group, P), 2, 0)
    lag = jnp.moveaxis(log_a.reshape(Bsz, S, ng, head_group), 2, 0)
    dtg = jnp.moveaxis(dt.reshape(Bsz, S, ng, head_group), 2, 0)
    if init_state is None:
        init_state = jnp.zeros((Bsz, H, N, P), jnp.float32)
    sg = jnp.moveaxis(init_state.reshape(Bsz, ng, head_group, N, P), 1, 0)

    def f(args):
        xi, lai, dti, si = args
        return _ssd_core(xi, lai, dti, Bm, Cm, si, chunk)

    ys, finals = jax.lax.map(f, (xg, lag, dtg, sg))
    y = jnp.moveaxis(ys, 0, 2).reshape(Bsz, S, H, P)
    final = jnp.moveaxis(finals, 0, 1).reshape(Bsz, H, N, P)
    return y, final


def ssd_step(
    state: jnp.ndarray,    # (B, H, N, P)
    x: jnp.ndarray,        # (B, H, P)
    log_a: jnp.ndarray,    # (B, H)
    dt: jnp.ndarray,       # (B, H)
    Bm: jnp.ndarray,       # (B, N)
    Cm: jnp.ndarray,       # (B, N)
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Single decode step.  Returns (y (B,H,P), new_state (B,H,N,P))."""
    s = state * jnp.exp(log_a.astype(jnp.float32))[:, :, None, None]
    s = s + jnp.einsum(
        "bh,be,bhp->bhep", dt.astype(jnp.float32), Bm.astype(jnp.float32),
        x.astype(jnp.float32),
    )
    y = jnp.einsum("be,bhep->bhp", Cm.astype(jnp.float32), s)
    return y.astype(x.dtype), s
