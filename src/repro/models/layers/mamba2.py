"""Mamba2 block (used by zamba2) built on the shared SSD core.

Simplifications vs. the CUDA reference (noted in DESIGN.md): one B/C group
(ngroups=1), no internal RMSNorm-gating variant (we use post-SSD gated norm),
depthwise short conv width 4.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers.common import he_init, rmsnorm, rmsnorm_init
from repro.models.layers.ssd import ssd_scan, ssd_step

HEAD_P = 64    # mamba2 head channel dim


def mamba2_init(key, d_model: int, d_state: int, expand: int = 2,
                conv_width: int = 4) -> Dict:
    d_inner = expand * d_model
    H = d_inner // HEAD_P
    ks = jax.random.split(key, 6)
    # separate projections per component (z / x / B / C / dt) so each output
    # is shard-aligned on its own — a fused in_proj's split boundaries cut
    # across model-axis shards and force GSPMD to replicate the SSD scan
    # (perf iteration H1, EXPERIMENTS §Perf); B/C/dt are small and stay
    # replicated (below MIN_SHARD_DIM)
    return {
        "wz": he_init(ks[0], (d_model, d_inner), d_model),
        "wx": he_init(ks[1], (d_model, d_inner), d_model),
        "wb": he_init(ks[3], (d_model, d_state), d_model),
        "wc": he_init(ks[4], (d_model, d_state), d_model),
        "wdt": he_init(ks[5], (d_model, H), d_model) * 0.1,
        "conv_w": he_init(ks[1], (conv_width, d_inner), conv_width) * 0.1,
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)),       # (H,)
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "out_proj": he_init(ks[2], (d_inner, d_model), d_inner),
        "norm": rmsnorm_init(d_inner),
    }


def _short_conv(x: jnp.ndarray, w: jnp.ndarray,
                cache: Optional[jnp.ndarray]) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Causal depthwise conv over S.  x: (B,S,C), w: (K,C).
    cache: (B, K-1, C) trailing context for decode."""
    K = w.shape[0]
    if cache is not None:
        x_ext = jnp.concatenate([cache, x], axis=1)
    else:
        x_ext = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(x_ext[:, i : i + x.shape[1]] * w[i] for i in range(K))
    new_cache = x_ext[:, -(K - 1):]
    return jax.nn.silu(out), new_cache


def mamba2_layer(
    p: Dict,
    x: jnp.ndarray,                 # (B, S, d)
    d_state: int,
    expand: int = 2,
    cache: Optional[Dict] = None,   # {"conv": (B,K-1,C), "ssm": (B,H,N,P)}
) -> Tuple[jnp.ndarray, Optional[Dict]]:
    B, S, d = x.shape
    d_inner = expand * d
    H = d_inner // HEAD_P

    z = x @ p["wz"]
    xs = x @ p["wx"]
    Bm = x @ p["wb"]
    Cm = x @ p["wc"]
    dt = x @ p["wdt"]
    conv_cache = cache["conv"] if cache is not None else None
    xs, new_conv = _short_conv(xs, p["conv_w"], conv_cache)

    dt = jax.nn.softplus(dt + p["dt_bias"])             # (B,S,H)
    A = -jnp.exp(p["A_log"])                            # (H,) negative
    log_a = dt * A

    xh = xs.reshape(B, S, H, HEAD_P)
    if cache is not None:
        y, new_ssm = ssd_step(
            cache["ssm"], xh[:, 0], log_a[:, 0], dt[:, 0], Bm[:, 0], Cm[:, 0]
        )
        y = y[:, None]
    else:
        y, new_ssm = ssd_scan(xh, log_a, dt, Bm, Cm)
    y = y + xh * p["D"][:, None]
    y = y.reshape(B, S, d_inner)
    y = rmsnorm(y * jax.nn.silu(z), p["norm"])
    out = y @ p["out_proj"]
    new_cache = {"conv": new_conv, "ssm": new_ssm} if cache is not None else None
    return out, new_cache
