"""xLSTM blocks: mLSTM (matrix memory, parallelizable — realised on the
shared SSD core with a ones-column normalizer trick) and sLSTM (scalar
memory with true hidden-to-hidden recurrence and exponential-gate
stabilisation, lax.scan over time).

mLSTM mapping onto SSD (DESIGN.md):  x=v, B=k/√d, C=q, dt=exp(i−m̃),
log_a=logsigmoid(f).  Augmenting v with a ones column makes the same scan
emit the normalizer n·q, so y = num / max(|den|, 1).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers.common import he_init, rmsnorm, rmsnorm_init
from repro.models.layers.ssd import ssd_scan, ssd_step


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def mlstm_init(key, d_model: int, num_heads: int, expand: int = 2) -> Dict:
    d_inner = expand * d_model
    dh = d_inner // num_heads
    ks = jax.random.split(key, 6)
    return {
        "wqkv": he_init(ks[0], (d_model, 3 * d_inner), d_model),
        "wif": he_init(ks[1], (d_model, 2 * num_heads), d_model) * 0.1,
        "if_bias": jnp.concatenate(
            [jnp.zeros((num_heads,)), 3.0 + jnp.arange(num_heads, dtype=jnp.float32) * 0.5]
        ),
        "wz": he_init(ks[2], (d_model, d_inner), d_model),
        "out_proj": he_init(ks[3], (d_inner, d_model), d_inner),
        "norm": rmsnorm_init(d_inner),
    }


def mlstm_layer(
    p: Dict,
    x: jnp.ndarray,                 # (B, S, d)
    num_heads: int,
    expand: int = 2,
    cache: Optional[Dict] = None,   # {"ssm": (B,H,dh,dh+1)}
) -> Tuple[jnp.ndarray, Optional[Dict]]:
    B, S, d = x.shape
    d_inner = expand * d
    dh = d_inner // num_heads

    qkv = x @ p["wqkv"]
    q, k, v = jnp.split(qkv, 3, axis=-1)
    q = q.reshape(B, S, num_heads, dh)
    k = k.reshape(B, S, num_heads, dh) * dh ** -0.5
    v = v.reshape(B, S, num_heads, dh)

    gates = (x @ p["wif"] + p["if_bias"]).astype(jnp.float32)
    i_pre, f_pre = jnp.split(gates, 2, axis=-1)          # (B,S,H)
    log_f = jax.nn.log_sigmoid(f_pre)
    dt = jnp.exp(jnp.minimum(i_pre, 10.0))               # stabilised exp gate

    # normalizer trick: append ones column to v
    v_aug = jnp.concatenate([v, jnp.ones((B, S, num_heads, 1), v.dtype)], -1)

    # per-head B/C: SSD uses head-shared B/C, so fold heads into batch
    def fold(t):          # (B,S,H,X) -> (B*H? ) — instead move H into batch
        return t

    # SSD core is head-batched already via its H axis; but B/C are shared
    # across heads there.  For mLSTM, k/q are per-head -> run SSD with H=1
    # folding heads into the batch axis.
    q_f = q.transpose(0, 2, 1, 3).reshape(B * num_heads, S, dh)
    k_f = k.transpose(0, 2, 1, 3).reshape(B * num_heads, S, dh)
    v_f = v_aug.transpose(0, 2, 1, 3).reshape(B * num_heads, S, 1, dh + 1)
    la_f = log_f.transpose(0, 2, 1).reshape(B * num_heads, S, 1)
    dt_f = dt.transpose(0, 2, 1).reshape(B * num_heads, S, 1)

    state0 = None
    if cache is not None:
        state0 = cache["ssm"].reshape(B * num_heads, 1, dh, dh + 1)
        y, new_state = ssd_step(
            state0, v_f[:, 0], la_f[:, 0], dt_f[:, 0], k_f[:, 0], q_f[:, 0]
        )
        y = y[:, None]
    else:
        y, new_state = ssd_scan(v_f, la_f, dt_f, k_f, q_f)

    y = y.reshape(B, num_heads, S, dh + 1).transpose(0, 2, 1, 3)
    num, den = y[..., :dh], y[..., dh:]
    h = num / jnp.maximum(jnp.abs(den), 1.0)
    h = h.reshape(B, S, d_inner)
    h = rmsnorm(h, p["norm"]) * jax.nn.silu(x @ p["wz"])
    out = h @ p["out_proj"]
    new_cache = (
        {"ssm": new_state.reshape(B, num_heads, dh, dh + 1)}
        if cache is not None else None
    )
    return out, new_cache


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_init(key, d_model: int, num_heads: int) -> Dict:
    dh = d_model // num_heads
    ks = jax.random.split(key, 3)
    return {
        "wx": he_init(ks[0], (d_model, 4 * d_model), d_model),
        "r": he_init(ks[1], (num_heads, dh, 4 * dh), dh) * 0.5,
        "bias": jnp.zeros((4 * d_model,)),
        "norm": rmsnorm_init(d_model),
        "out_proj": he_init(ks[2], (d_model, d_model), d_model),
    }


def slstm_layer(
    p: Dict,
    x: jnp.ndarray,                 # (B, S, d)
    num_heads: int,
    cache: Optional[Dict] = None,   # {"c","n","h","m": (B,H,dh)}
) -> Tuple[jnp.ndarray, Optional[Dict]]:
    B, S, d = x.shape
    dh = d // num_heads
    xg = (x @ p["wx"] + p["bias"]).reshape(B, S, num_heads, 4 * dh)

    def step(carry, xt):
        c, n, h, m = carry                               # (B,H,dh) each, f32
        rec = jnp.einsum("bhd,hde->bhe", h, p["r"].astype(jnp.float32))
        g = xt.astype(jnp.float32) + rec                 # (B,H,4dh)
        zi, ii, fi, oi = jnp.split(g, 4, axis=-1)
        z = jnp.tanh(zi)
        o = jax.nn.sigmoid(oi)
        m_new = jnp.maximum(fi + m, ii)
        i = jnp.exp(ii - m_new)
        f = jnp.exp(fi + m - m_new)
        c = f * c + i * z
        n = f * n + i
        h_new = o * c / jnp.maximum(n, 1.0)
        return (c, n, h_new, m_new), h_new

    if cache is not None:
        carry0 = (cache["c"], cache["n"], cache["h"], cache["m"])
    else:
        zeros = jnp.zeros((B, num_heads, dh), jnp.float32)
        carry0 = (zeros, zeros, zeros, zeros - 1e30 * 0.0)
    carry, hs = jax.lax.scan(step, carry0, jnp.moveaxis(xg, 1, 0))
    h = jnp.moveaxis(hs, 0, 1).reshape(B, S, d).astype(x.dtype)
    out = rmsnorm(h, p["norm"]) @ p["out_proj"]
    new_cache = (
        {"c": carry[0], "n": carry[1], "h": carry[2], "m": carry[3]}
        if cache is not None else None
    )
    return out, new_cache
