"""Family dispatch: one uniform API over every architecture family.

    init_params(cfg, key, dtype)        -> params pytree
    loss_fn(params, batch, cfg)         -> (loss, metrics) — training forward
    serve_fn(params, batch, cache, cfg) -> (logits, new_cache) — decode step
    init_cache(cfg, batch, max_len)     -> cache pytree
    cache_specs(cfg)                    -> logical-axis tree matching cache

Batch dicts by family:
    dense/moe:  {tokens (B,S), labels (B,S)}
    vlm:        {tokens (B,S_text), image_embeds (B,N_img,d), labels (B,S_text)}
    audio:      {frames (B,F,d), tokens (B,S), labels (B,S)}
    ssm/hybrid: {tokens, labels}
    gcn:        {x (N,T,V,C), labels (N,)}
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.common.config import ModelConfig
from repro.core.agcn import model as agcn
from repro.models import decoder, encdec, hybrid, ssm_model

LM_FAMILIES = ("dense", "moe", "vlm", "ssm", "hybrid", "audio")


def init_params(cfg: ModelConfig, key: jax.Array, dtype=jnp.float32):
    if cfg.family in ("dense", "moe", "vlm"):
        return decoder.init_params(cfg, key, dtype)
    if cfg.family == "audio":
        return encdec.init_params(cfg, key, dtype)
    if cfg.family == "ssm":
        return ssm_model.init_params(cfg, key, dtype)
    if cfg.family == "hybrid":
        return hybrid.init_params(cfg, key, dtype)
    if cfg.family == "gcn":
        p = agcn.init_params(cfg, key)
        return jax.tree_util.tree_map(lambda x: x.astype(dtype), p)
    raise ValueError(cfg.family)


def _xent(logits: jnp.ndarray, labels: jnp.ndarray, vocab: int) -> jnp.ndarray:
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    loss = (logz - gold).mean()
    zloss = 1e-4 * jnp.square(logz).mean()          # logit drift regulariser
    return loss + zloss


def loss_fn(params, batch: Dict[str, jnp.ndarray], cfg: ModelConfig,
            inference: bool = False
            ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    if cfg.family == "gcn":
        plan = None
        if inference:                      # paper prunes the deployed model;
            from repro.core.pruning.plan import plan_from_config
            plan = plan_from_config(cfg)   # training runs the dense graph
        # always the reference backend here: loss_fn is jitted by its
        # callers, and pallas ExecutionPlans must be compiled outside the
        # trace — pallas inference goes through prebuilt plans instead
        # (steps.make_gcn_infer_step / launch.serve.serve_gcn)
        logits = agcn.forward(params, batch["x"], cfg, plan=plan,
                              backend="reference")
        loss = _xent(logits, batch["labels"], cfg.gcn_num_classes)
        acc = (logits.argmax(-1) == batch["labels"]).mean()
        return loss, {"loss": loss, "acc": acc}

    if cfg.family == "audio":
        memory = encdec.encode(params, batch["frames"], cfg)
        logits, _ = encdec.decode(params, batch["tokens"], memory, cfg)
        aux = jnp.zeros(())
    elif cfg.family in ("dense", "moe", "vlm"):
        logits, _, aux = decoder.forward(
            params, batch["tokens"], cfg,
            image_embeds=batch.get("image_embeds"),
        )
        if cfg.family == "vlm":
            logits = logits[:, -batch["tokens"].shape[1]:]   # text positions
    elif cfg.family == "ssm":
        logits, _ = ssm_model.forward(params, batch["tokens"], cfg)
        aux = jnp.zeros(())
    elif cfg.family == "hybrid":
        logits, _ = hybrid.forward(params, batch["tokens"], cfg)
        aux = jnp.zeros(())
    else:
        raise ValueError(cfg.family)

    loss = _xent(logits[:, :-1], batch["labels"][:, 1:], cfg.vocab_size)
    total = loss + 0.01 * aux
    return total, {"loss": loss, "aux": aux}


def serve_fn(params, batch: Dict[str, jnp.ndarray], cache, cfg: ModelConfig
             ) -> Tuple[jnp.ndarray, Any]:
    """One decode step: batch = {tokens (B,1), pos scalar int32, [memory]}."""
    pos = batch["pos"]
    positions = pos + jnp.arange(batch["tokens"].shape[1])
    if cfg.family in ("dense", "moe", "vlm"):
        logits, new_cache, _ = decoder.forward(
            params, batch["tokens"], cfg, caches=cache, positions=positions,
        )
        return logits, new_cache
    if cfg.family == "audio":
        return encdec.decode(
            params, batch["tokens"], batch["memory"], cfg, caches=cache,
            positions=positions,
        )
    if cfg.family == "ssm":
        return ssm_model.forward(params, batch["tokens"], cfg, caches=cache,
                                 positions=positions)
    if cfg.family == "hybrid":
        return hybrid.forward(params, batch["tokens"], cfg, caches=cache,
                              positions=positions)
    raise ValueError(f"no serve path for family {cfg.family}")


def prefill_fn(params, batch: Dict[str, jnp.ndarray], cfg: ModelConfig):
    """Prefill forward (logits only — cache writing exercised by serve_fn)."""
    return loss_fn(params, batch, cfg)[0] if "labels" in batch else None


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    if cfg.family in ("dense", "moe", "vlm"):
        return decoder.init_cache(cfg, batch, max_len, dtype)
    if cfg.family == "audio":
        return encdec.init_cache(cfg, batch, max_len, dtype)
    if cfg.family == "ssm":
        return ssm_model.init_cache(cfg, batch, max_len)
    if cfg.family == "hybrid":
        return hybrid.init_cache(cfg, batch, max_len, dtype)
    raise ValueError(cfg.family)


def cache_specs(cfg: ModelConfig):
    if cfg.family in ("dense", "moe", "vlm"):
        return decoder.cache_specs(cfg)
    if cfg.family == "audio":
        return encdec.cache_specs(cfg)
    if cfg.family == "ssm":
        return ssm_model.cache_specs(cfg)
    if cfg.family == "hybrid":
        return hybrid.cache_specs(cfg)
    raise ValueError(cfg.family)
