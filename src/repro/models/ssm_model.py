"""xLSTM language model (xlstm-1.3b): mLSTM blocks with periodic sLSTM
blocks (ratio cfg.slstm_every, xLSTM[7:1] for the 1.3B config), each
followed by a gated MLP.  Scan groups hold one pattern period.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.config import ModelConfig
from repro.distributed.sharding import constrain
from repro.models.layers.common import he_init, rmsnorm, rmsnorm_init
from repro.models.layers.mlp import mlp, mlp_init
from repro.models.layers.xlstm import (
    mlstm_init, mlstm_layer, slstm_init, slstm_layer,
)


def group_size(cfg: ModelConfig) -> int:
    return cfg.slstm_every if cfg.slstm_every > 0 else cfg.scan_group


def num_groups(cfg: ModelConfig) -> int:
    g = group_size(cfg)
    assert cfg.num_layers % g == 0
    return cfg.num_layers // g


def _mlstm_block_init(key, cfg):
    k1, k2 = jax.random.split(key)
    p = {
        "ln": rmsnorm_init(cfg.d_model),
        "cell": mlstm_init(k1, cfg.d_model, cfg.num_heads, cfg.ssm_expand),
    }
    if cfg.d_ff:
        p["ln2"] = rmsnorm_init(cfg.d_model)
        p["mlp"] = mlp_init(k2, cfg.d_model, cfg.d_ff)
    return p


def _slstm_block_init(key, cfg):
    k1, k2 = jax.random.split(key)
    p = {
        "ln": rmsnorm_init(cfg.d_model),
        "cell": slstm_init(k1, cfg.d_model, cfg.num_heads),
    }
    if cfg.d_ff:
        p["ln2"] = rmsnorm_init(cfg.d_model)
        p["mlp"] = mlp_init(k2, cfg.d_model, cfg.d_ff)
    return p


def init_params(cfg: ModelConfig, key: jax.Array, dtype=jnp.float32) -> Dict:
    g, ng = group_size(cfg), num_groups(cfg)
    keys = jax.random.split(key, cfg.num_layers + 1)

    def group(gi):
        # layers 0..g-2 are mLSTM, layer g-1 is sLSTM (xLSTM[g-1 : 1])
        m = [_mlstm_block_init(keys[gi * g + i], cfg) for i in range(g - 1)]
        s = _slstm_block_init(keys[gi * g + g - 1], cfg)
        return {
            "mlstm": jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *m),
            "slstm": s,
        }

    groups = [group(gi) for gi in range(ng)]
    params = {
        "embed": he_init(keys[-1], (cfg.padded_vocab, cfg.d_model), cfg.d_model),
        "layers": jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *groups),
        "final_norm": rmsnorm_init(cfg.d_model),
    }
    return jax.tree_util.tree_map(lambda x: x.astype(dtype), params)


def _apply_mlstm(cfg, lp, x, cache):
    h, new_c = mlstm_layer(
        lp["cell"], rmsnorm(x, lp["ln"], cfg.norm_eps), cfg.num_heads,
        cfg.ssm_expand, cache,
    )
    x = x + h
    if "mlp" in lp:
        x = x + mlp(lp["mlp"], rmsnorm(x, lp["ln2"], cfg.norm_eps), cfg.act)
    return x, new_c


def _apply_slstm(cfg, lp, x, cache):
    h, new_c = slstm_layer(
        lp["cell"], rmsnorm(x, lp["ln"], cfg.norm_eps), cfg.num_heads, cache,
    )
    x = x + h
    if "mlp" in lp:
        x = x + mlp(lp["mlp"], rmsnorm(x, lp["ln2"], cfg.norm_eps), cfg.act)
    return x, new_c


def forward(
    params: Dict,
    tokens: jnp.ndarray,
    cfg: ModelConfig,
    caches: Optional[Any] = None,
    positions: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, Optional[Any]]:
    g = group_size(cfg)
    x = jnp.take(params["embed"], tokens, axis=0) * np.sqrt(cfg.d_model)
    x = constrain(x, "batch", "seq_shard", None)

    def body(h, inp):
        if caches is None:
            gp, cache = inp, None
        else:
            gp, cache = inp
        new_m, new_s = [], None
        for i in range(g - 1):
            lp = jax.tree_util.tree_map(lambda a: a[i], gp["mlstm"])
            c_i = (
                jax.tree_util.tree_map(lambda a: a[i], cache["mlstm"])
                if cache is not None else None
            )
            h, nc = _apply_mlstm(cfg, lp, h, c_i)
            new_m.append(nc)
        c_s = cache["slstm"] if cache is not None else None
        h, new_s = _apply_slstm(cfg, gp["slstm"], h, c_s)
        h = constrain(h, "batch", "seq_shard", None)
        if cache is None:
            return h, None
        return h, {
            "mlstm": jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *new_m),
            "slstm": new_s,
        }

    if caches is None:
        x, _ = jax.lax.scan(jax.checkpoint(body), x, params["layers"])
        new_caches = None
    else:
        x, new_caches = jax.lax.scan(body, x, (params["layers"], caches))

    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = x @ params["embed"].T
    return constrain(logits, "batch", None, "vocab"), new_caches


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.float32):
    g, ng = group_size(cfg), num_groups(cfg)
    d_inner = cfg.ssm_expand * cfg.d_model
    dh = d_inner // cfg.num_heads
    dh_s = cfg.d_model // cfg.num_heads
    def zeros():
        return jnp.zeros((ng, batch, cfg.num_heads, dh_s), jnp.float32)
    return {
        "mlstm": {
            "ssm": jnp.zeros((ng, g - 1, batch, cfg.num_heads, dh, dh + 1),
                             jnp.float32),
        },
        "slstm": {"c": zeros(), "n": zeros(), "h": zeros(), "m": zeros()},
    }


def cache_specs(cfg: ModelConfig):
    return {
        "mlstm": {"ssm": (None, None, "batch", None, "state", None)},
        "slstm": {k: (None, "batch", None, None) for k in ("c", "n", "h", "m")},
    }
