"""Zamba2-style hybrid: Mamba2 backbone with a *shared* (weight-tied)
attention+MLP block applied periodically.

Structure (cfg.num_layers total applications): scan over ``ng`` groups of
[1 shared attention block + (shared_attn_every) mamba layers], plus a tail
of unrolled mamba layers so the counts match exactly
(81 = 11 × (1 + 6) + 4 for zamba2-7b).  The shared block's weights are
closed over (NOT scanned), reproducing Zamba's parameter sharing; each
application gets its own input LayerNorm (a simplification of Zamba's
per-use LoRA, noted in DESIGN.md).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.config import ModelConfig
from repro.distributed.sharding import constrain
from repro.models.layers.attention import attention_layer, attn_init
from repro.models.layers.common import he_init, rmsnorm, rmsnorm_init
from repro.models.layers.mamba2 import HEAD_P, mamba2_init, mamba2_layer
from repro.models.layers.mlp import mlp, mlp_init


def structure(cfg: ModelConfig) -> Tuple[int, int, int]:
    """(num_groups, mamba_per_group, tail_mamba)."""
    per = cfg.shared_attn_every
    ng = cfg.num_layers // (per + 1)
    tail = cfg.num_layers - ng * (per + 1)
    return ng, per, tail


def init_params(cfg: ModelConfig, key: jax.Array, dtype=jnp.float32) -> Dict:
    ng, per, tail = structure(cfg)
    n_mamba = ng * per + tail
    keys = jax.random.split(key, n_mamba + 4)

    mamba = [
        {"ln": rmsnorm_init(cfg.d_model),
         "cell": mamba2_init(keys[i], cfg.d_model, cfg.ssm_state, cfg.ssm_expand)}
        for i in range(n_mamba)
    ]
    grouped = [
        jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *mamba[g * per : (g + 1) * per]
        )
        for g in range(ng)
    ]
    k_attn, k_mlp, k_emb = keys[-3], keys[-2], keys[-1]
    params = {
        "embed": he_init(k_emb, (cfg.padded_vocab, cfg.d_model), cfg.d_model),
        "mamba_groups": jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *grouped
        ),
        "mamba_tail": (
            jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *mamba[ng * per:])
            if tail else None
        ),
        # one shared block, used at every group boundary (weight tying)
        "shared": {
            "attn": attn_init(k_attn, cfg.d_model, cfg.num_heads,
                              cfg.num_kv_heads, cfg.head_dim),
            "mlp": mlp_init(k_mlp, cfg.d_model, cfg.d_ff),
            "ln2": rmsnorm_init(cfg.d_model),
        },
        # per-use input norms for the shared block
        "use_ln": {"scale": jnp.ones((ng, cfg.d_model), jnp.float32)},
        "final_norm": rmsnorm_init(cfg.d_model),
    }
    return jax.tree_util.tree_map(
        lambda x: x.astype(dtype) if x is not None else None, params,
        is_leaf=lambda x: x is None,
    )


def _shared_block(cfg, shared, ln_scale, x, positions, cache):
    h = rmsnorm(x, {"scale": ln_scale}, cfg.norm_eps)
    a, new_c = attention_layer(
        shared["attn"], h, positions,
        num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
        head_dim=cfg.head_dim, rope_theta=cfg.rope_theta, causal=True,
        cache=cache,
    )
    x = x + a
    x = x + mlp(shared["mlp"], rmsnorm(x, shared["ln2"], cfg.norm_eps), cfg.act)
    return x, new_c


def _mamba_block(cfg, lp, x, cache):
    h, new_c = mamba2_layer(
        lp["cell"], rmsnorm(x, lp["ln"], cfg.norm_eps), cfg.ssm_state,
        cfg.ssm_expand, cache,
    )
    return x + h, new_c


def forward(
    params: Dict,
    tokens: jnp.ndarray,
    cfg: ModelConfig,
    caches: Optional[Any] = None,
    positions: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, Optional[Any]]:
    ng, per, tail = structure(cfg)
    x = jnp.take(params["embed"], tokens, axis=0) * np.sqrt(cfg.d_model)
    x = constrain(x, "batch", "seq_shard", None)
    if positions is None:
        positions = jnp.arange(x.shape[1])
    shared = params["shared"]

    def body(h, inp):
        if caches is None:
            (gp, ln_scale), cache = inp, None
        else:
            gp, ln_scale, cache = inp
        c_attn = cache["attn"] if cache is not None else None
        h, new_attn = _shared_block(cfg, shared, ln_scale, h, positions, c_attn)
        new_m = []
        for i in range(per):
            lp = jax.tree_util.tree_map(lambda a: a[i], gp)
            c_i = (
                jax.tree_util.tree_map(lambda a: a[i], cache["mamba"])
                if cache is not None else None
            )
            h, nc = _mamba_block(cfg, lp, h, c_i)
            new_m.append(nc)
        h = constrain(h, "batch", "seq_shard", None)
        if cache is None:
            return h, None
        return h, {
            "attn": new_attn,
            "mamba": jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *new_m),
        }

    if caches is None:
        x, _ = jax.lax.scan(
            jax.checkpoint(body), x,
            (params["mamba_groups"], params["use_ln"]["scale"]),
        )
        new_caches: Optional[Dict] = None
        tail_caches = None
    else:
        x, group_caches = jax.lax.scan(
            body, x,
            (params["mamba_groups"], params["use_ln"]["scale"], caches["groups"]),
        )
        new_caches = {"groups": group_caches}
        tail_caches = caches.get("tail")

    if tail:
        new_tail = []
        for i in range(tail):
            lp = jax.tree_util.tree_map(lambda a: a[i], params["mamba_tail"])
            c_i = (
                jax.tree_util.tree_map(lambda a: a[i], tail_caches)
                if tail_caches is not None else None
            )
            x, nc = _mamba_block(cfg, lp, x, c_i)
            new_tail.append(nc)
        if new_caches is not None:
            new_caches["tail"] = jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs), *new_tail
            )

    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = x @ params["embed"].T
    return constrain(logits, "batch", None, "vocab"), new_caches


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    ng, per, tail = structure(cfg)
    d_inner = cfg.ssm_expand * cfg.d_model
    H = d_inner // HEAD_P
    K = cfg.ssm_conv
    conv_c = d_inner

    def mamba_cache(n):
        return {
            "conv": jnp.zeros((n, batch, K - 1, conv_c), dtype),
            "ssm": jnp.zeros((n, batch, H, cfg.ssm_state, HEAD_P), jnp.float32),
        }

    cache = {
        "groups": {
            "attn": {
                "k": jnp.zeros((ng, batch, max_len, cfg.num_kv_heads,
                                cfg.head_dim), dtype),
                "v": jnp.zeros((ng, batch, max_len, cfg.num_kv_heads,
                                cfg.head_dim), dtype),
                "pos": jnp.zeros((ng,), jnp.int32),
            },
            "mamba": jax.tree_util.tree_map(
                lambda x: x.reshape(ng, per, *x.shape[1:]), mamba_cache(ng * per)
            ),
        },
    }
    if tail:
        cache["tail"] = mamba_cache(tail)
    return cache


def cache_specs(cfg: ModelConfig):
    ng, per, tail = structure(cfg)
    mamba_spec = {
        "conv": (None, None, "batch", None, None),
        "ssm": (None, None, "batch", None, "state", None),
    }
    spec = {
        "groups": {
            "attn": {
                "k": (None, "batch", "kv_seq", None, "kv_hd"),
                "v": (None, "batch", "kv_seq", None, "kv_hd"),
                "pos": (None,),
            },
            "mamba": mamba_spec,
        },
    }
    if tail:
        spec["tail"] = {
            "conv": (None, "batch", None, None),
            "ssm": (None, "batch", None, "state", None),
        }
    return spec
