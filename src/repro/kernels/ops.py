"""Jit'd public wrappers around the Pallas kernels.

These handle layout adaptation (padding, filter-group permutation, kept-tap
packing) so callers use natural shapes; the kernels see hardware-aligned
tiles.  ``interpret`` defaults to True because this container is CPU-only —
on TPU pass interpret=False and the same BlockSpecs compile.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.cavity_tconv import (cavity_tconv_pallas,
                                        cavity_tconv_step_pallas)
from repro.kernels.graph_sconv import (graph_sconv_csr_pallas,
                                       graph_sconv_pallas)
from repro.kernels.rfc_pack import rfc_decode_pallas, rfc_encode_pallas
from repro.kernels.window_sim import windowed_similarity_pallas


def _pad_to(x: jnp.ndarray, axis: int, mult: int) -> jnp.ndarray:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


# ---------------------------------------------------------------------------
# RFC
# ---------------------------------------------------------------------------

def rfc_encode(x: jnp.ndarray, bank: int = 16, interpret: bool = True):
    """Encode activations of any (..., C) shape; returns (values, hot)."""
    shape = x.shape
    flat = x.reshape(-1, shape[-1])
    rows = flat.shape[0]
    flat = _pad_to(_pad_to(flat, 1, bank), 0, 8)
    vals, hot = rfc_encode_pallas(flat, bank=bank, interpret=interpret)
    vals = vals[:rows, : shape[-1]].reshape(shape)
    hot = hot[:rows, : shape[-1]].reshape(shape)
    return vals, hot


def rfc_decode(values: jnp.ndarray, hot: jnp.ndarray, bank: int = 16,
               interpret: bool = True) -> jnp.ndarray:
    """Inverse of :func:`rfc_encode`: scatter each bank's front-packed
    values back to their hot positions.  Any (..., C) shape; lossless on
    post-ReLU activations (the roundtrip contract in test_rfc_format)."""
    shape = values.shape
    v = _pad_to(_pad_to(values.reshape(-1, shape[-1]), 1, bank), 0, 8)
    h = _pad_to(_pad_to(hot.reshape(-1, shape[-1]), 1, bank), 0, 8)
    out = rfc_decode_pallas(v, h, bank=bank, interpret=interpret)
    return out[: int(np.prod(shape[:-1])), : shape[-1]].reshape(shape)


# ---------------------------------------------------------------------------
# Cavity temporal conv
# ---------------------------------------------------------------------------

def pack_cavity_weights(
    w: np.ndarray,           # (F, C, K) dense weights of the *kept* filters
    tap_mask: np.ndarray,    # (F, K) bool — cavity pattern tiled to F
    loop: int = 8,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Group filters by recurring pattern row (f % loop) and pack kept taps.

    Returns (wp (L, n_keep, C, Fg), taps (L, n_keep) int32, perm (F,) int32)
    where out_dense[..., perm] reassembles the natural filter order from the
    (L, Fg) kernel output.  Filters are zero-padded to a multiple of loop.
    """
    F, C, K = w.shape
    Fp = ((F + loop - 1) // loop) * loop
    if Fp != F:
        w = np.concatenate([w, np.zeros((Fp - F, C, K), w.dtype)], 0)
        tap_mask = np.concatenate(
            [tap_mask, np.tile(tap_mask[:1], (Fp - F, 1))], 0
        )
    Fg = Fp // loop
    n_keep = int(tap_mask[:loop].sum(axis=1).max())
    wp = np.zeros((loop, n_keep, C, Fg), w.dtype)
    taps = np.zeros((loop, n_keep), np.int32)
    for g in range(loop):
        kept = np.flatnonzero(tap_mask[g])
        taps[g, : len(kept)] = kept
        for j, k in enumerate(kept):
            # filters g, g+loop, g+2*loop, ... share this tap set
            wp[g, j] = w[g::loop, :, k].T          # (C, Fg)
    # kernel output flattens (L, Fg): slot g*Fg+i holds filter g + loop*i
    inv = np.empty(Fp, np.int32)
    order = np.arange(Fp).reshape(Fg, loop).T.reshape(-1)  # (L, Fg) flat -> f
    inv[order] = np.arange(Fp)
    return wp, taps, inv[:Fp]


def cavity_tconv(
    x: jnp.ndarray,          # (B, T, C)
    wp: jnp.ndarray,
    taps: jnp.ndarray,
    inv_perm: np.ndarray,
    num_filters: int,
    kernel_size: int = 9,
    stride: int = 1,
    interpret: bool = True,
) -> jnp.ndarray:
    """Cavity-pruned temporal conv, 'same' padding.  Returns (B, T_out, F).

    T_out follows conv semantics, ``(T + 2·pad − K)//stride + 1`` — for a
    stride that doesn't divide the window count (odd T into a stride-2
    block) the right pad is extended with zeros so the kernel's in-bounds
    floor count equals it; otherwise reference and pallas would disagree
    by one trailing output (and streaming parity with them)."""
    pad = kernel_size // 2
    T = x.shape[1]
    t_out = (T + 2 * pad - kernel_size) // stride + 1
    # kernel needs K-1 + t_out·stride rows; ≥ T + 2·pad, equal iff divisible
    t_pad = kernel_size - 1 + t_out * stride
    xp = jnp.pad(x, ((0, 0), (pad, t_pad - T - pad), (0, 0)))
    out = cavity_tconv_pallas(
        xp, wp, taps, kernel_size=kernel_size, stride=stride,
        interpret=interpret,
    )                                                 # (B, T_out, L, Fg)
    B, T_out, L, Fg = out.shape
    flat = out.reshape(B, T_out, L * Fg)
    flat = jnp.take(flat, jnp.asarray(inv_perm), axis=-1)
    return flat[..., :num_filters]


def cavity_tconv_step(
    x: jnp.ndarray,          # (B, K, C) chronological window (oldest first)
    wp: jnp.ndarray,
    taps: jnp.ndarray,
    inv_perm: np.ndarray,
    num_filters: int,
    interpret: bool = True,
) -> jnp.ndarray:
    """Single-timestep cavity tconv over a full window.  Returns (B, F).

    The streaming engine's per-frame path: no padding (the window already
    holds K frames — ring-buffer zeros stand in for the clip's 'same'
    padding) and no stride (emission gating lives in the engine).  Same
    packed weights / tap sets / filter permutation as :func:`cavity_tconv`."""
    out = cavity_tconv_step_pallas(x, wp, taps, interpret=interpret)
    B, L, Fg = out.shape
    flat = out.reshape(B, L * Fg)
    flat = jnp.take(flat, jnp.asarray(inv_perm), axis=-1)
    return flat[:, :num_filters]


# ---------------------------------------------------------------------------
# Windowed similarity (streaming C_k)
# ---------------------------------------------------------------------------

def windowed_similarity(
    ring_th: jnp.ndarray,    # (S, K, V, Ce) per-slot θ-embedding ring
    ring_ph: jnp.ndarray,    # (S, K, V, Ce) per-slot φ-embedding ring
    valid_joints: int = 0,
    interpret: bool = True,
) -> jnp.ndarray:
    """Streaming windowed C_k from the embedding rings.  Returns (S, V, V).

    One fused pass per slab slot: ring window sum → Θ·Φᵀ/√Ce → masked
    row softmax (input-joint columns ≥ ``valid_joints`` excluded; 0 = all
    of V live).  The joint axis is sublane-padded here and the padded
    columns are always masked, so the sliced result equals the reference
    ``adaptive.windowed_ck(ring.sum(1), ...)`` twin ≤1e-3."""
    S, K, V, Ce = ring_th.shape
    th = _pad_to(ring_th, 2, 8)
    ph = _pad_to(ring_ph, 2, 8)
    valid = valid_joints if 0 < valid_joints < V else V
    out = windowed_similarity_pallas(th, ph, valid=int(valid),
                                     interpret=interpret)
    return out[:, :V, :V]


# ---------------------------------------------------------------------------
# Fused graph + spatial conv
# ---------------------------------------------------------------------------

def _pad_rows(x: jnp.ndarray):
    """Flatten (N, T, V, Cin) to kernel rows: joints sublane-aligned, N*T
    padded to whole row tiles.  Returns (xr, R, Vp)."""
    from repro.kernels.graph_sconv import R_TILE

    N, T, V, Cin = x.shape
    Vp = ((V + 7) // 8) * 8                          # sublane-align joints
    R = N * T
    xr = _pad_to(x.reshape(R, V, Cin), 1, 8)
    # row axis: whole tiles when more than one, else one 8-aligned tile
    xr = _pad_to(xr, 0, R_TILE if R > R_TILE else 8)
    return xr, R, Vp


def graph_sconv(
    x: jnp.ndarray,          # (N, T, V, Cin) — kept channels already gathered
    g: jnp.ndarray,          # (K, V, V) or prepadded (K, Vp, Vp) from a plan
    w: jnp.ndarray,          # (K, Cin, Cout)
    interpret: bool = True,
    topology: str = "",
) -> jnp.ndarray:
    """Fused Σ_k (G_k·x)·W_k.  Returns (N, T, V, Cout).

    Both blocked axes are padded here: joints to the 8-sublane multiple and
    the flattened N*T row axis to a whole number of row tiles — an odd
    batch×time product must never reach the kernel as one giant tile (or a
    non-dividing grid).  ``g`` may arrive already padded to (K, Vp, Vp) from
    an ExecutionPlan, or wider still when the plan is padded to a slab Vmax
    and ``x`` runs at the topology's own joint count (the wider graph is
    zero outside its valid joints, so slicing to Vp is exact); raw (K, V, V)
    graphs are padded on the fly.  ``topology`` only decorates the
    mismatched-shape errors so mixed-slab bugs name the offending skeleton.
    """
    N, T, V, Cin = x.shape
    xr, R, Vp = _pad_rows(x)
    note = f" for topology {topology!r}" if topology else ""
    if g.shape[0] != w.shape[0]:
        raise ValueError(
            f"graph has K={g.shape[0]} subsets but w has K={w.shape[0]}"
            f"{note}; the plan packed weights against a different topology")
    if g.shape[-1] == V:
        gp = jnp.zeros((g.shape[0], Vp, Vp), g.dtype).at[:, :V, :V].set(g)
    elif g.shape[-1] == Vp:
        gp = g
    elif g.shape[-1] > Vp:
        gp = g[:, :Vp, :Vp]              # plan padded to a wider slab Vmax
    else:
        raise ValueError(
            f"graph{note} padded to {g.shape[-1]}, expected >= {V} "
            f"(x runs {V} joints, sublane-aligned to {Vp})")
    out = graph_sconv_pallas(xr, gp, w.astype(x.dtype), interpret=interpret)
    return out[:R, :V, :].reshape(N, T, V, -1)


def pack_csr_ell(
    indptr: np.ndarray,      # (K, V+1) int32
    indices: np.ndarray,     # (K, E) int32
    values: np.ndarray,      # (K, E) f32, zero-padded
    vp: int,                 # padded joint count (multiple of 8)
) -> Tuple[np.ndarray, np.ndarray]:
    """Host-side CSR → ELL repack for :func:`graph_sconv_csr_pallas`.

    Each output row gets its neighbor list padded to the max row degree D
    (idx 0 / val 0 — a harmless gather of joint 0 scaled by zero), and rows
    are padded to ``vp``.  Returns (idx (K, vp, D) int32, val (K, vp, D)
    f32)."""
    indptr = np.asarray(indptr)
    indices = np.asarray(indices)
    values = np.asarray(values)
    K, V1 = indptr.shape
    V = V1 - 1
    deg = int(max(1, (indptr[:, 1:] - indptr[:, :-1]).max()))
    idx = np.zeros((K, vp, deg), np.int32)
    val = np.zeros((K, vp, deg), np.float32)
    for k in range(K):
        for r in range(V):
            lo, hi = int(indptr[k, r]), int(indptr[k, r + 1])
            idx[k, r, : hi - lo] = indices[k, lo:hi]
            val[k, r, : hi - lo] = values[k, lo:hi]
    return idx, val


def graph_sconv_csr(
    x: jnp.ndarray,          # (N, T, V, Cin) — kept channels already gathered
    idx: jnp.ndarray,        # (K, Vp', D) ELL indices, Vp' >= roundup8(V)
    val: jnp.ndarray,        # (K, Vp', D) ELL values
    w: jnp.ndarray,          # (K, Cin, Cout)
    interpret: bool = True,
    topology: str = "",
) -> jnp.ndarray:
    """Sparse Σ_k (G_k·x)·W_k over an ELL-packed graph.  Returns
    (N, T, V, Cout).

    Row/joint padding mirrors :func:`graph_sconv`; an ELL pack wider than
    x's padded joint count (a plan padded to slab Vmax) is sliced down —
    exact because padded rows are all-zero and indices only reference valid
    joints."""
    N, T, V, Cin = x.shape
    xr, R, Vp = _pad_rows(x)
    note = f" for topology {topology!r}" if topology else ""
    if idx.shape[0] != w.shape[0]:
        raise ValueError(
            f"ELL graph has K={idx.shape[0]} subsets but w has "
            f"K={w.shape[0]}{note}")
    if idx.shape[1] < Vp:
        raise ValueError(
            f"ELL graph{note} packed to {idx.shape[1]} joints, expected "
            f">= {Vp} (x runs {V} joints, sublane-aligned to {Vp})")
    out = graph_sconv_csr_pallas(
        xr, idx[:, :Vp], val[:, :Vp].astype(x.dtype), w.astype(x.dtype),
        interpret=interpret)
    return out[:R, :V, :].reshape(N, T, V, -1)
