"""Pallas TPU kernel for the cavity-pruned temporal convolution (paper C2).

The cavity pattern is a recurring loop of ``L`` (=8) tap masks, so filters
fall into L *groups with identical tap sets* (filter f -> group f % L after
the ops-layer permutation).  Within a group the conv is a dense
gather-over-kept-taps + matmul — exactly the FLOP skip of the paper with
full MXU utilisation and static, balanced per-group work (the paper's
"balanced pruning" requirement becomes tile balance here; DESIGN.md §2).

Layouts (after the ops.py re-pack):
  x:    (B, T_pad, C)            input, already zero-padded by K//2 on T
  wp:   (L, n_keep, C, Fg)       packed kept-tap weights per group (taps with
                                 zero weight pad groups that keep fewer taps)
  taps: (L, n_keep) int32        kept tap offsets per group
  out:  (B, T_out, L, Fg)        per-group outputs (ops.py un-permutes)

Grid: (B tiles, L groups).  Each grid step reads the taps row of its group
(block-indexed, so the tap offsets are *per-block constants*) and issues
``n_keep`` shifted (C×Fg) matmuls instead of K=9 — the paper's skip ratio.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

B_TILE = 16


def _kernel(x_ref, w_ref, taps_ref, out_ref, *, n_keep: int, t_out: int,
            stride: int):
    acc = jnp.zeros((x_ref.shape[0], t_out, w_ref.shape[-1]), jnp.float32)
    for j in range(n_keep):                        # static loop over kept taps
        off = taps_ref[0, j]
        xs = pl.load(
            x_ref,
            (slice(None), pl.dslice(off, t_out * stride), slice(None)),
        )
        if stride > 1:
            xs = xs[:, ::stride, :]
        w = w_ref[0, j]                            # (C, Fg)
        acc += jax.lax.dot_general(
            xs, w, (((2,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
    out_ref[...] = acc[:, :, None, :].astype(out_ref.dtype)


def _step_kernel(x_ref, w_ref, taps_ref, out_ref, *, n_keep: int):
    """Single-timestep variant: the window (b_tile, K, C) IS the receptive
    field, so each group is just ``n_keep`` gathered (C×Fg) matmuls — no
    temporal slide, no stride (the streaming engine gates emission)."""
    acc = jnp.zeros((x_ref.shape[0], w_ref.shape[-1]), jnp.float32)
    for j in range(n_keep):                        # static loop over kept taps
        off = taps_ref[0, j]
        xs = pl.load(x_ref, (slice(None), pl.dslice(off, 1), slice(None)))
        acc += jax.lax.dot_general(
            xs[:, 0, :], w_ref[0, j], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
    out_ref[...] = acc[:, None, :].astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def cavity_tconv_step_pallas(
    x: jnp.ndarray,        # (B, K, C) chronological window, oldest first
    wp: jnp.ndarray,       # (L, n_keep, C, Fg) — same packing as the clip path
    taps: jnp.ndarray,     # (L, n_keep) int32
    interpret: bool = True,
) -> jnp.ndarray:
    """One output timestep per row from a full K-frame window: (B, L, Fg).

    This is the streaming engine's per-frame temporal conv: the packed
    cavity weights and tap sets are byte-identical to the clip kernel's, so
    a plan compiled once serves both dataflows."""
    B, K, C = x.shape
    L, n_keep, _, Fg = wp.shape
    b_tile = B_TILE if B % B_TILE == 0 else B
    grid = (B // b_tile, L)

    in_spec = pl.BlockSpec((b_tile, K, C), lambda b, g: (b, 0, 0))
    w_spec = pl.BlockSpec((1, n_keep, C, Fg), lambda b, g: (g, 0, 0, 0))
    taps_spec = pl.BlockSpec((1, n_keep), lambda b, g: (g, 0))
    out_spec = pl.BlockSpec((b_tile, 1, Fg), lambda b, g: (b, g, 0))

    return pl.pallas_call(
        functools.partial(_step_kernel, n_keep=n_keep),
        grid=grid,
        in_specs=[in_spec, w_spec, taps_spec],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((B, L, Fg), x.dtype),
        interpret=interpret,
    )(x, wp, taps)


@functools.partial(jax.jit, static_argnames=("kernel_size", "stride", "interpret"))
def cavity_tconv_pallas(
    x: jnp.ndarray,        # (B, T_pad, C)
    wp: jnp.ndarray,       # (L, n_keep, C, Fg)
    taps: jnp.ndarray,     # (L, n_keep) int32
    kernel_size: int = 9,
    stride: int = 1,
    interpret: bool = True,
) -> jnp.ndarray:
    """Clip-mode packed cavity tconv: (B, T_pad, C) -> (B, T_out, L, Fg).

    ``wp``/``taps`` are the ops.pack_cavity_weights layout — group g holds
    filters g, g+L, g+2L… sharing one kept-tap set; each grid step issues
    only those ``n_keep`` shifted (C×Fg) matmuls (the C2 FLOP skip).  The
    caller (ops.cavity_tconv) provides 'same'+stride zero padding on T and
    un-permutes the flattened (L, Fg) filter axis."""
    B, T_pad, C = x.shape
    L, n_keep, _, Fg = wp.shape
    T_out = (T_pad - kernel_size + 1) // stride
    b_tile = B_TILE if B % B_TILE == 0 else B
    grid = (B // b_tile, L)

    in_spec = pl.BlockSpec((b_tile, T_pad, C), lambda b, g: (b, 0, 0))
    w_spec = pl.BlockSpec((1, n_keep, C, Fg), lambda b, g: (g, 0, 0, 0))
    taps_spec = pl.BlockSpec((1, n_keep), lambda b, g: (g, 0))
    out_spec = pl.BlockSpec((b_tile, T_out, 1, Fg), lambda b, g: (b, 0, g, 0))

    kern = functools.partial(_kernel, n_keep=n_keep, t_out=T_out, stride=stride)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[in_spec, w_spec, taps_spec],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((B, T_out, L, Fg), x.dtype),
        interpret=interpret,
    )(x, wp, taps)
