"""Pallas TPU kernels for the paper's compute hot-spots — fused graph+1×1
spatial conv (``graph_sconv``), cavity-pruned temporal conv clip/step
(``cavity_tconv``), RFC encode/decode (``rfc_pack``), flash decode
attention (``flash_decode``) — plus the layout-adapting public wrappers
(``ops``) and the pure-jnp oracles (``ref``) the parity tests sweep."""
