"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth for the per-kernel allclose tests
(tests/test_kernels.py sweeps shapes/dtypes against them).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def rfc_encode_ref(x: jnp.ndarray, bank: int = 16):
    """ReLU + stable in-bank compaction.  x: (rows, C)."""
    x = jnp.maximum(x, 0.0)
    rows, cols = x.shape
    b = x.reshape(rows, cols // bank, bank)
    hot = b > 0
    order = jnp.argsort(~hot, axis=-1, stable=True)
    vals = jnp.take_along_axis(b, order, axis=-1)
    return vals.reshape(rows, cols), hot.astype(x.dtype).reshape(rows, cols)


def rfc_decode_ref(values: jnp.ndarray, hot: jnp.ndarray, bank: int = 16):
    """Scatter front-packed bank values back to their hot positions —
    the decode oracle; (rows, C) in, (rows, C) out."""
    rows, cols = values.shape
    v = values.reshape(rows, cols // bank, bank)
    h = hot.reshape(rows, cols // bank, bank) > 0
    pos = jnp.cumsum(h.astype(jnp.int32), axis=-1) - 1
    out = jnp.where(h, jnp.take_along_axis(v, jnp.maximum(pos, 0), axis=-1), 0)
    return out.reshape(rows, cols)


def cavity_tconv_ref(
    x: jnp.ndarray,        # (B, T, C) — *unpadded*
    w: jnp.ndarray,        # (F, C, K) masked weights (zeros at pruned taps)
    stride: int = 1,
) -> jnp.ndarray:
    """Dense masked temporal conv, 'same' padding — (B, T_out, F)."""
    K = w.shape[-1]
    pad = K // 2
    rhs = jnp.transpose(w, (2, 1, 0))[:, None, :, :]  # (K, 1, C, F)
    out = jax.lax.conv_general_dilated(
        x[:, :, None, :], rhs,
        window_strides=(stride, 1),
        padding=((pad, pad), (0, 0)),
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return out[:, :, 0, :]


def graph_sconv_ref(x: jnp.ndarray, g: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """out = sum_k (G_k·x)·W_k.  x: (R, V, Cin), g: (K, V, V), w: (K, Cin, Co)."""
    y = jnp.einsum("rvc,kwv->krwc", x, g)
    return jnp.einsum("krwc,kco->rwo", y, w)


def graph_sconv_csr_ref(x, indptr, indices, values, w):
    """CSR spatial conv: gather-accumulate over indptr/indices per subset.

    x: (R, Vx, Cin) with Vx >= V (extra rows are padding the graph never
    references), indptr: (K, V+1), indices/values: (K, E) zero-padded,
    w: (K, Cin, Co).  Returns (R, V, Co).
    """
    K, E = indices.shape
    V = indptr.shape[1] - 1
    R, _, C = x.shape
    out = jnp.zeros((R, V, w.shape[-1]), jnp.float32)
    for k in range(K):
        # entry e lives on output row w iff indptr[k,w] <= e < indptr[k,w+1];
        # zero-padded entries map past the last row and are dropped.
        rows = jnp.searchsorted(indptr[k], jnp.arange(E), side="right") - 1
        gathered = jnp.take(x, indices[k], axis=1) * values[k][None, :, None]
        agg = jnp.zeros((R, V, C), x.dtype).at[:, rows, :].add(
            gathered, mode="drop")
        out = out + jnp.einsum("rvc,co->rvo", agg, w[k],
                               preferred_element_type=jnp.float32)
    return out.astype(x.dtype)


def flash_decode_ref(q, k, v, valid):
    """GQA decode attention oracle.  q: (B,Hkv,G,D), k/v: (B,S,Hkv,D)."""
    D = q.shape[-1]
    S = k.shape[1]
    s = jnp.einsum("bhgd,bshd->bhgs", q, k) / np.sqrt(D)
    s = jnp.where(jnp.arange(S) < valid, s, -1e30)
    return jnp.einsum("bhgs,bshd->bhgd", jax.nn.softmax(s, -1), v)
