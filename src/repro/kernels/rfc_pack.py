"""Pallas TPU kernels for RFC encode/decode (paper §V-C → DESIGN.md §2).

TPU-native formulation: in-bank compaction is a *permutation*, and a 16×16
permutation is a tiny matmul — so instead of a sort (which lowers poorly to
the VPU) we build the one-hot compaction matrix from a cumulative sum of the
hot mask and contract with it.  All lane accesses stay aligned; the bank
width 16 maps onto the VREG lane dimension, mirroring the paper's
"one-cycle aligned access" property.

Layouts:
  x:       (rows, C)            activations, C % bank == 0
  values:  (rows, C)            compacted banks (front-packed, zero padded)
  hot:     (rows, C) float mask (1.0 where the ReLU output was non-zero)

``interpret=True`` is used on CPU (this container); on TPU the same kernels
compile with the BlockSpecs below.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BANK = 16
ROW_TILE = 256
COL_TILE = 256


def _encode_kernel(x_ref, vals_ref, hot_ref, *, bank: int):
    x = x_ref[...]
    rows, cols = x.shape
    x = jnp.maximum(x, 0.0)                       # fused ReLU (paper: encode
    b = x.reshape(rows, cols // bank, bank)       #  is combined with ReLU)
    hot = (b > 0.0).astype(x.dtype)
    # position of each non-zero inside the compacted stream
    pos = jnp.cumsum(hot, axis=-1) - 1.0
    tgt = jax.lax.broadcasted_iota(x.dtype, (rows, cols // bank, bank, bank), 3)
    # perm[i, j] = 1 iff element i is the j-th non-zero of its bank
    perm = (pos[..., None] == tgt) * hot[..., None]
    vals = jnp.einsum("rbi,rbij->rbj", b, perm, preferred_element_type=x.dtype)
    vals_ref[...] = vals.reshape(rows, cols)
    hot_ref[...] = hot.reshape(rows, cols)


def _decode_kernel(vals_ref, hot_ref, out_ref, *, bank: int):
    vals = vals_ref[...]
    hot = hot_ref[...]
    rows, cols = vals.shape
    v = vals.reshape(rows, cols // bank, bank)
    h = hot.reshape(rows, cols // bank, bank)
    pos = jnp.cumsum(h, axis=-1) - 1.0
    tgt = jax.lax.broadcasted_iota(vals.dtype, (rows, cols // bank, bank, bank), 3)
    perm = (pos[..., None] == tgt) * h[..., None]          # (r, b, i, j)
    out = jnp.einsum("rbj,rbij->rbi", v, perm, preferred_element_type=vals.dtype)
    out_ref[...] = out.reshape(rows, cols)


def _grid_specs(rows: int, cols: int, n_out: int):
    grid = (pl.cdiv(rows, ROW_TILE), pl.cdiv(cols, COL_TILE))
    spec = pl.BlockSpec((ROW_TILE, COL_TILE), lambda r, c: (r, c))
    return grid, spec


@functools.partial(jax.jit, static_argnames=("bank", "interpret"))
def rfc_encode_pallas(x: jnp.ndarray, bank: int = BANK, interpret: bool = True):
    """ReLU + bank-compact.  x: (rows, C) -> (values, hot) both (rows, C)."""
    rows, cols = x.shape
    if cols % bank:
        raise ValueError(f"C={cols} not divisible by bank={bank}")
    grid, spec = _grid_specs(rows, cols, 2)
    return pl.pallas_call(
        functools.partial(_encode_kernel, bank=bank),
        grid=grid,
        in_specs=[spec],
        out_specs=[spec, spec],
        out_shape=[
            jax.ShapeDtypeStruct((rows, cols), x.dtype),
            jax.ShapeDtypeStruct((rows, cols), x.dtype),
        ],
        interpret=interpret,
    )(x)


@functools.partial(jax.jit, static_argnames=("bank", "interpret"))
def rfc_decode_pallas(values: jnp.ndarray, hot: jnp.ndarray, bank: int = BANK,
                      interpret: bool = True) -> jnp.ndarray:
    """Bank-decompact via the transposed one-hot permutation matmul:
    (values, hot) (rows, C) -> dense (rows, C).  Exact inverse of
    :func:`rfc_encode_pallas` on post-ReLU data."""
    rows, cols = values.shape
    grid, spec = _grid_specs(rows, cols, 1)
    return pl.pallas_call(
        functools.partial(_decode_kernel, bank=bank),
        grid=grid,
        in_specs=[spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((rows, cols), values.dtype),
        interpret=interpret,
    )(values, hot)
