"""Pallas TPU kernel for the streaming windowed-similarity graph C(t).

Fuses the adaptive-streaming C_k evaluation (repro.core.agcn.adaptive)
over the per-slot embedding rings in one VMEM pass: the K-deep window
reduction, the Θ·Φᵀ similarity matmul, the padded-joint column mask and
the row softmax never round-trip the (V, Ce) intermediates to HBM —
per slot the kernel reads two (K, Vp, Ce) rings and writes one (Vp, Vp)
normalized graph.

Layouts:
  ring_th: (S, K, Vp, Ce)   per-slot θ-embedding ring (any ring phase —
  ring_ph: (S, K, Vp, Ce)    the window sum is phase-invariant)
  out:     (S, Vp, Vp)
Grid: (S,) — one program per slab slot; K is a static in-kernel loop.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(th_ref, ph_ref, out_ref, *, kwin: int, valid: int):
    # window reduction: the ring rows sum to Θ(t)/Φ(t) regardless of phase
    th = th_ref[0, 0].astype(jnp.float32)              # (Vp, Ce)
    ph = ph_ref[0, 0].astype(jnp.float32)
    for k in range(1, kwin):                           # K static
        th = th + th_ref[0, k].astype(jnp.float32)
        ph = ph + ph_ref[0, k].astype(jnp.float32)
    ce = th_ref.shape[-1]
    logits = jax.lax.dot_general(
        th, ph, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * jax.lax.rsqrt(jnp.float32(ce))                 # (Vp, Vp)
    # mask dead input-joint columns (slab padding + the 8-sublane pad)
    vp = logits.shape[-1]
    col = jax.lax.broadcasted_iota(jnp.int32, (vp, vp), 1)
    logits = jnp.where(col < valid, logits, jnp.float32(-1e30))
    m = jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(logits - m)
    out = e / jnp.sum(e, axis=-1, keepdims=True)
    out_ref[0] = out.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("valid", "interpret"))
def windowed_similarity_pallas(
    ring_th: jnp.ndarray,    # (S, K, Vp, Ce)
    ring_ph: jnp.ndarray,    # (S, K, Vp, Ce)
    valid: int,              # live input-joint count (columns >= it masked)
    interpret: bool = True,
) -> jnp.ndarray:
    """Fused window-sum → similarity → masked softmax per slab slot:
    (S, K, Vp, Ce) rings -> (S, Vp, Vp) normalized graphs.

    The reference twin is ``adaptive.windowed_ck(ring.sum(1), ...)``;
    parity ≤1e-3 is locked by tests/test_kernels.py.  Callers pad the
    joint axis (ops.windowed_similarity does this) so Vp is sublane-
    aligned."""
    S, K, Vp, Ce = ring_th.shape
    spec = pl.BlockSpec((1, K, Vp, Ce), lambda s: (s, 0, 0, 0))
    out_spec = pl.BlockSpec((1, Vp, Vp), lambda s: (s, 0, 0))
    return pl.pallas_call(
        functools.partial(_kernel, kwin=K, valid=valid),
        grid=(S,),
        in_specs=[spec, spec],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((S, Vp, Vp), ring_th.dtype),
        interpret=interpret,
    )(ring_th, ring_ph)
