"""Pallas TPU kernel: fused GQA decode attention (one query token against a
long KV cache) with online softmax — the serving hot spot of the decode_32k
/ long_500k cells.

Unfused decode attention materialises the (H × S) score row in HBM; this
kernel streams KV blocks through VMEM and keeps the running max/sum/acc in
scratch, so HBM traffic is exactly one read of the KV cache — the roofline
floor for decode.

Layouts:
  q:     (B, Hkv, G, D)    grouped query heads (G = H // Hkv)
  k, v:  (B, S, Hkv, D)    cache
  valid: (1, 1) int32      number of valid cache slots
  out:   (B, Hkv, G, D)

Grid: (B, Hkv, S_blocks) — the S axis is the innermost (sequential) axis so
the scratch accumulator carries across KV blocks of one (batch, kv-head).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

S_BLOCK = 512
NEG_INF = -1e30


def _kernel(valid_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
            *, s_block: int, scale: float):
    s_idx = pl.program_id(2)

    @pl.when(s_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0]                                # (G, D)
    k = k_ref[0, :, 0, :]                          # (Sblk, D)
    v = v_ref[0, :, 0, :]                          # (Sblk, D)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * scale                                      # (G, Sblk)
    pos = s_idx * s_block + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(pos < valid_ref[0, 0], s, NEG_INF)

    m_prev, l_prev = m_ref[...], l_ref[...]        # (G, 1)
    m_new = jnp.maximum(m_prev, s.max(-1, keepdims=True))
    p = jnp.exp(s - m_new)                         # (G, Sblk)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + p.sum(-1, keepdims=True)
    pv = jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                              # (G, D)
    acc_ref[...] = acc_ref[...] * corr + pv
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(s_idx == pl.num_programs(2) - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-20)).astype(
            o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def flash_decode_pallas(
    q: jnp.ndarray,        # (B, Hkv, G, D)
    k: jnp.ndarray,        # (B, S, Hkv, D)
    v: jnp.ndarray,        # (B, S, Hkv, D)
    valid: jnp.ndarray,    # scalar int32
    interpret: bool = True,
) -> jnp.ndarray:
    """Fused GQA decode attention with online softmax: one query token per
    (batch, kv-head, group) against a ``valid``-masked KV cache — q
    (B, Hkv, G, D), k/v (B, S, Hkv, D) -> (B, Hkv, G, D), with exactly one
    HBM read of the cache (running max/sum/acc live in VMEM scratch)."""
    B, Hkv, G, D = q.shape
    S = k.shape[1]
    s_block = min(S_BLOCK, S)
    if S % s_block:
        raise ValueError(f"S={S} not divisible by block {s_block}")
    grid = (B, Hkv, S // s_block)
    scale = D ** -0.5

    return pl.pallas_call(
        functools.partial(_kernel, s_block=s_block, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, h, s: (0, 0)),
            pl.BlockSpec((1, 1, G, D), lambda b, h, s: (b, h, 0, 0)),
            pl.BlockSpec((1, s_block, 1, D), lambda b, h, s: (b, s, h, 0)),
            pl.BlockSpec((1, s_block, 1, D), lambda b, h, s: (b, s, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, s: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),       # running max
            pltpu.VMEM((G, 1), jnp.float32),       # running sum
            pltpu.VMEM((G, D), jnp.float32),       # output accumulator
        ],
        interpret=interpret,
    )(valid.reshape(1, 1), q, k, v)
