"""Pallas TPU kernel for the fused, reorganized graph + 1×1 spatial conv
(paper C1, eq. (5)).

Computes   out = Σ_k (G_k · x) · W_k   in one VMEM pass: the graph matmul
(V×V, V=25 padded to 32 lanes) and the pruned 1×1 conv share the x tile, so
the intermediate (G·x) never round-trips to HBM — the TPU analogue of the
paper's on-chip dataflow where graph results feed Mult-PEs directly.

Channel compaction happens in ops.py (kept channels gathered before the
call), so Cin here is the *kept* channel count — the graph-skip is already
realised in the shapes.

Layouts:
  x:   (R, V, Cin)    rows = N*T (flattened batch×time)
  g:   (K, V, V)      static + learned graph, padded to Vp
  w:   (K, Cin, Cout)
  out: (R, V, Cout)
Grid: (R tiles, Cout tiles); K is a static in-kernel loop.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

R_TILE = 128
CO_TILE = 128


def _kernel(x_ref, g_ref, w_ref, out_ref, *, kv: int):
    x = x_ref[...]                                  # (r, Vp, Cin)
    acc = jnp.zeros(out_ref.shape, jnp.float32)
    for k in range(kv):                             # K_v = 3, static
        gk = g_ref[k]                               # (Vp, Vp)
        # graph matmul: y[r, w, c] = sum_v gk[w, v] * x[r, v, c]
        y = jax.lax.dot_general(
            gk, x, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                           # (Vp, r, Cin)
        y = jnp.transpose(y, (1, 0, 2))             # (r, Vp, Cin)
        wk = w_ref[k]                               # (Cin, co)
        acc += jax.lax.dot_general(
            y, wk, (((2,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
    out_ref[...] = acc.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def graph_sconv_pallas(
    x: jnp.ndarray,      # (R, Vp, Cin)
    g: jnp.ndarray,      # (K, Vp, Vp)
    w: jnp.ndarray,      # (K, Cin, Cout)
    interpret: bool = True,
) -> jnp.ndarray:
    """Fused Σ_k (G_k·x)·W_k in one VMEM pass: (R, Vp, Cin) -> (R, Vp, Cout).

    The graph matmul and the 1×1 conv share each x tile, so the (G·x)
    intermediate never leaves VMEM; callers pad R/V (ops.graph_sconv) so
    the (R tiles, Cout tiles) grid divides exactly."""
    R, Vp, Cin = x.shape
    K, _, Cout = w.shape
    if R % R_TILE == 0:
        r_tile = R_TILE
    elif R <= R_TILE:
        r_tile = R                      # single row tile (small batches)
    else:
        raise ValueError(
            f"row axis R={R} exceeds one tile but is not a multiple of "
            f"R_TILE={R_TILE}; pad the flattened N*T axis (ops.graph_sconv "
            f"does this) so the grid divides")
    co_tile = CO_TILE if Cout % CO_TILE == 0 else Cout
    grid = (R // r_tile, Cout // co_tile)

    in_spec = pl.BlockSpec((r_tile, Vp, Cin), lambda r, c: (r, 0, 0))
    g_spec = pl.BlockSpec((K, Vp, Vp), lambda r, c: (0, 0, 0))
    w_spec = pl.BlockSpec((K, Cin, co_tile), lambda r, c: (0, 0, c))
    out_spec = pl.BlockSpec((r_tile, Vp, co_tile), lambda r, c: (r, 0, c))

    return pl.pallas_call(
        functools.partial(_kernel, kv=K),
        grid=grid,
        in_specs=[in_spec, g_spec, w_spec],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((R, Vp, Cout), x.dtype),
        interpret=interpret,
    )(x, g, w)


def _csr_kernel(x_ref, idx_ref, val_ref, w_ref, out_ref, *, kv: int, deg: int):
    x = x_ref[...]                                  # (r, Vp, Cin)
    acc = jnp.zeros(out_ref.shape, jnp.float32)
    for k in range(kv):                             # static subset loop
        agg = jnp.zeros(x.shape, jnp.float32)
        for d in range(deg):                        # static ELL-slot loop
            ids = idx_ref[k, :, d]                  # (Vp,) neighbor of row w
            vals = val_ref[k, :, d]                 # (Vp,) edge weight (0=pad)
            agg = agg + jnp.take(x, ids, axis=1) * vals[None, :, None]
        wk = w_ref[k]                               # (Cin, co)
        acc += jax.lax.dot_general(
            agg, wk, (((2,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
    out_ref[...] = acc.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def graph_sconv_csr_pallas(
    x: jnp.ndarray,        # (R, Vp, Cin)
    idx: jnp.ndarray,      # (K, Vp, D) int32 ELL neighbor indices
    val: jnp.ndarray,      # (K, Vp, D) f32 edge weights, zero-padded
    w: jnp.ndarray,        # (K, Cin, Cout)
    interpret: bool = True,
) -> jnp.ndarray:
    """Sparse Σ_k (G_k·x)·W_k over an ELL-packed graph.

    The graph matmul is replaced by D gather-accumulate sweeps (D = max row
    degree, from ops.pack_csr_ell): each sweep pulls one neighbor per output
    joint and scales by its edge weight, so compute follows nnz instead of
    Vp² — the win for the near-empty two-person / hand graphs.  Grid and
    tiling mirror :func:`graph_sconv_pallas`; idx/val ride whole in VMEM.
    """
    R, Vp, Cin = x.shape
    K, _, Cout = w.shape
    D = idx.shape[-1]
    if R % R_TILE == 0:
        r_tile = R_TILE
    elif R <= R_TILE:
        r_tile = R
    else:
        raise ValueError(
            f"row axis R={R} exceeds one tile but is not a multiple of "
            f"R_TILE={R_TILE}; pad the flattened N*T axis (ops.graph_sconv_csr "
            f"does this) so the grid divides")
    co_tile = CO_TILE if Cout % CO_TILE == 0 else Cout
    grid = (R // r_tile, Cout // co_tile)

    in_spec = pl.BlockSpec((r_tile, Vp, Cin), lambda r, c: (r, 0, 0))
    idx_spec = pl.BlockSpec((K, Vp, D), lambda r, c: (0, 0, 0))
    val_spec = pl.BlockSpec((K, Vp, D), lambda r, c: (0, 0, 0))
    w_spec = pl.BlockSpec((K, Cin, co_tile), lambda r, c: (0, 0, c))
    out_spec = pl.BlockSpec((r_tile, Vp, co_tile), lambda r, c: (r, 0, c))

    return pl.pallas_call(
        functools.partial(_csr_kernel, kv=K, deg=D),
        grid=grid,
        in_specs=[in_spec, idx_spec, val_spec, w_spec],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((R, Vp, Cout), x.dtype),
        interpret=interpret,
    )(x, idx, val, w)
