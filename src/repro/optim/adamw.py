"""AdamW with global-norm clipping and warmup-cosine schedule.

Optimizer moments are stored fp32 and inherit the parameters' 2D (model ×
data) sharding, i.e. ZeRO-style fully sharded states.  The update is pure
(params, state, grads) -> (params, state) so jit donation works.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.common.config import TrainConfig


class OptState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


def init(params) -> OptState:
    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params
    )
    zeros2 = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params
    )
    return OptState(step=jnp.zeros((), jnp.int32), m=zeros, v=zeros2)


def schedule(step: jnp.ndarray, tcfg: TrainConfig) -> jnp.ndarray:
    warm = tcfg.learning_rate * (step + 1) / max(1, tcfg.warmup_steps)
    t = jnp.clip(
        (step - tcfg.warmup_steps)
        / max(1, tcfg.total_steps - tcfg.warmup_steps),
        0.0, 1.0,
    )
    cos = tcfg.learning_rate * 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    return jnp.where(step < tcfg.warmup_steps, warm, cos)


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves)
    )


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads
    ), norm


def update(
    params, grads, state: OptState, tcfg: TrainConfig
) -> Tuple[Any, OptState, Dict[str, jnp.ndarray]]:
    grads, gnorm = clip_by_global_norm(grads, tcfg.grad_clip)
    lr = schedule(state.step, tcfg)
    b1, b2 = tcfg.beta1, tcfg.beta2
    t = state.step + 1

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g32
        v = b2 * v + (1 - b2) * jnp.square(g32)
        mhat = m / (1 - b1 ** t.astype(jnp.float32))
        vhat = v / (1 - b2 ** t.astype(jnp.float32))
        step_ = mhat / (jnp.sqrt(vhat) + 1e-8)
        if p.ndim >= 2:                                  # decoupled decay
            step_ = step_ + tcfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step_).astype(p.dtype), m, v

    out = jax.tree_util.tree_map(upd, params, grads, state.m, state.v)
    new_params = jax.tree_util.tree_map(
        lambda x: x[0], out, is_leaf=lambda x: isinstance(x, tuple)
    )
    new_m = jax.tree_util.tree_map(
        lambda x: x[1], out, is_leaf=lambda x: isinstance(x, tuple)
    )
    new_v = jax.tree_util.tree_map(
        lambda x: x[2], out, is_leaf=lambda x: isinstance(x, tuple)
    )
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, OptState(step=t, m=new_m, v=new_v), metrics
