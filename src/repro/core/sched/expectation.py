"""Dynamic data scheduling expectation model (paper §V-B, eq. 6, Table II).

On the FPGA, a Dyn-Mult-PE holds ``w`` kept weights (waiting queues) and a
*smaller* number of multipliers (DSPs); valid work per cycle is the number of
queues whose feature operand is non-zero, d ~ Binomial(w, 1-s) for feature
sparsity ``s``.  The expectation E(D) = w·(1-s) sizes the DSP pool; dynamic
scheduling dispatches the d valid MACs onto E(D)-ish DSPs, trading a small
queueing delay for hardware savings.

There is no per-multiplier queue on a TPU (the MXU is statically scheduled),
so the *mechanism* does not transfer — but the *statistical sizing* does: we
reuse E(D) as the capacity factor that sizes compacted tiles (e.g. RFC
mini-bank depths and MoE expert capacity).  Documented in DESIGN.md §2.
"""
from __future__ import annotations

import math
from typing import Dict

import numpy as np


def valid_work_pmf(w: int, sparsity: float) -> np.ndarray:
    """P(d valid MACs) for d=0..w with feature sparsity ``sparsity``."""
    p = 1.0 - sparsity
    return np.array(
        [math.comb(w, d) * p**d * (1 - p) ** (w - d) for d in range(w + 1)]
    )


def expected_valid(w: int, sparsity: float) -> float:
    """E(D) = sum_d d·p(d) = w·(1-s).  (The paper's printed eq. (6) is the
    w=6 case with grouped terms.)"""
    pmf = valid_work_pmf(w, sparsity)
    return float(sum(d * pmf[d] for d in range(w + 1)))


def dsp_allocation(w: int, sparsity: float, guard: float = 0.15) -> int:
    """Number of multipliers to provision: ceil(E(D)·(1+guard)), ≥1, ≤w."""
    return max(1, min(w, math.ceil(expected_valid(w, sparsity) * (1.0 + guard))))


def delay_probability(w: int, sparsity: float, dsps: int) -> float:
    """P(valid work exceeds provisioned multipliers in a cycle) — the
    paper's 'max delay' proxy (Table II)."""
    pmf = valid_work_pmf(w, sparsity)
    return float(pmf[dsps + 1:].sum())


def scheduling_report(w: int, sparsity: float, guard: float = 0.15) -> Dict[str, float]:
    """Full Table-II row for one (queue width, sparsity) point: E(D), the
    provisioned multiplier count, its saving/efficiency, and delay prob."""
    d = dsp_allocation(w, sparsity, guard)
    return {
        "kept_weights": w,
        "sparsity": sparsity,
        "expected_valid": expected_valid(w, sparsity),
        "dsps": d,
        "dsp_saving": 1.0 - d / w,
        "delay_prob": delay_probability(w, sparsity, d),
        "efficiency": expected_valid(w, sparsity) / d,
    }
