"""Paper-mechanism core: the 2s-AGCN model + backend-dispatched execution
engine (``agcn``), the hybrid pruning plans C1/C2 (``pruning``), the RFC
sparse-feature format C3 (``rfc``), Q8.8/int8 quantization C5 (``quant``)
and the Dyn-Mult-PE expectation model (``sched``).  Substrate-specific
kernels live in ``repro.kernels``; serving/scheduling in ``repro.launch``."""
