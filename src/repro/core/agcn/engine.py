"""Backend-dispatched AGCN execution engine (plan-compile-then-execute).

The paper's accelerator runs the reorganized graph+spatial dataflow, the
cavity-pruned temporal conv and the runtime RFC compress as one fused
on-chip pipeline.  This module is the software analogue: instead of the
model re-deriving gathers / packings / padded graphs on every step, an
``ExecutionPlan`` is compiled **once** from ``(params, PrunePlan,
ModelConfig)`` and the hot loop only executes it.

Two backends implement the per-block ops:

  reference — the pure-jnp einsum path (extracted from ``model.py``); fully
              traceable, so it also serves the differentiable train path.
  pallas    — the fused Pallas kernels in ``repro.kernels.ops``:
              ``graph_sconv`` (graph matmul + 1×1 conv in one VMEM pass),
              packed ``cavity_tconv`` (kept-tap matmuls only), and RFC
              encode/decode between blocks as the inter-layer activation
              format.  ``interpret=True`` runs the same BlockSpecs on CPU;
              on TPU pass ``interpret=False`` and they compile.

The plan is a registered pytree: its arrays are jit arguments (so two
plans built from the same config hit the same jit cache entry — no
re-tracing, and *no re-packing inside the jitted step*), while shapes,
strides and flags live in the hashable static aux.

Pallas plans must be built **outside** jit: cavity weight packing
(``ops.pack_cavity_weights``) is host-side numpy by design — that is the
"compile" in plan-compile-then-execute.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Protocol, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.config import ModelConfig
from repro.core.agcn.graph import build_ntu_subsets, similarity_graph
from repro.core.pruning.plan import PrunePlan
from repro.core.quant import quantize_q88
from repro.kernels import ops

BACKENDS = ("reference", "pallas")


# ---------------------------------------------------------------------------
# plan containers
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BlockStatic:
    """Hashable per-block metadata (shapes and flags the tracer must see
    as python constants)."""

    stride: int
    cout: int
    n_kept_filters: int
    tkernel: int
    use_ck: bool
    pruned_in: bool          # kept_in gather present
    pruned_filters: bool     # kept_filters scatter present


@dataclasses.dataclass(frozen=True)
class PlanStatic:
    backend: str
    interpret: bool
    input_skip: int
    use_rfc: bool            # RFC roundtrip between blocks (pallas format)
    rfc_bank: int
    tkernel: int
    blocks: Tuple[BlockStatic, ...]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class ExecutionPlan:
    """Compiled, engine-ready form of one AGCN stream.

    ``arrays`` is the pytree the jitted step consumes (pre-gathered /
    pre-quantized / pre-packed weights, precomputed graphs ``A + B_k``,
    kept-index vectors); ``static`` is the hashable aux.
    """

    arrays: Dict[str, Any]
    static: PlanStatic

    def tree_flatten(self):
        return (self.arrays,), self.static

    @classmethod
    def tree_unflatten(cls, static, children):
        return cls(arrays=children[0], static=static)


# ---------------------------------------------------------------------------
# shared math (used by both backends and by the legacy-compatible paths)
# ---------------------------------------------------------------------------

def batch_norm(x: jnp.ndarray, p: Dict[str, jnp.ndarray],
               eps: float = 1e-5) -> jnp.ndarray:
    """Stateless batch norm: f32-accumulated stats, elementwise math in the
    activation dtype (see model.py docstring / EXPERIMENTS §Perf)."""
    axes = tuple(range(x.ndim - 1))
    mean = jnp.mean(x, axes, keepdims=True)
    var = jnp.var(x, axes, keepdims=True)
    inv = jax.lax.rsqrt(var.astype(jnp.float32) + eps).astype(x.dtype)
    return (x - mean) * inv * p["scale"] + p["bias"]


def _proj(x, w, bn, stride):
    if stride != 1:
        x = x[:, ::stride]
    return batch_norm(jnp.einsum("ntvc,co->ntvo", x, w), bn)


def _scatter_filters(out: jnp.ndarray, fidx: jnp.ndarray, cout: int):
    """Scatter compacted filter outputs back to full width (pruned filters
    stay zero so the residual path sees the accelerator's shortcut layout)."""
    full = jnp.zeros((*out.shape[:-1], cout), out.dtype)
    return full.at[..., fidx].set(out)


# ---------------------------------------------------------------------------
# backends
# ---------------------------------------------------------------------------

class Backend(Protocol):
    """Per-block op provider.  ``ba`` are the block's plan arrays, ``bs``
    its static metadata; activations are (N, T, V, C)."""

    name: str

    def spatial(self, x: jnp.ndarray, ba: Dict[str, Any],
                bs: BlockStatic) -> jnp.ndarray: ...

    def temporal(self, x: jnp.ndarray, ba: Dict[str, Any],
                 bs: BlockStatic) -> jnp.ndarray: ...

    def transfer(self, h: jnp.ndarray, ps: PlanStatic) -> jnp.ndarray: ...


def _gather_in(x: jnp.ndarray, ba: Dict[str, Any]) -> jnp.ndarray:
    if ba["kept_in"] is not None:
        return jnp.take(x, ba["kept_in"], axis=-1)
    return x


def _spatial_einsum(x: jnp.ndarray, ba: Dict[str, Any],
                    bs: BlockStatic) -> jnp.ndarray:
    """Reference math for Σ_k (G_k·x)·W_k (+ optional data-dependent C_k)."""
    G = ba["G"].astype(x.dtype)
    Wk = ba["Wk"].astype(x.dtype)
    if bs.use_ck:
        Ck = similarity_graph(x, ba["theta"], ba["phi"])
        Gn = G[None] + Ck[:, None]                    # (N, K, V, V)
        y = jnp.einsum("ntvc,nkwv->nktwc", x, Gn)
        return jnp.einsum("nktwc,kco->ntwo", y, Wk)
    return jnp.einsum("ntvc,kwv,kco->ntwo", x, G, Wk)


class ReferenceBackend:
    """Pure-jnp path — today's model math, executed from the plan."""

    name = "reference"

    def spatial(self, x, ba, bs):
        return _spatial_einsum(_gather_in(x, ba), ba, bs)

    def temporal(self, x, ba, bs):
        w = ba["tw"].astype(x.dtype)                  # (F_kept, C, K) masked
        K = w.shape[-1]
        pad = K // 2
        rhs = jnp.transpose(w, (2, 1, 0))[:, None, :, :]   # (K, 1, C, F)
        out = jax.lax.conv_general_dilated(
            x, rhs,
            window_strides=(bs.stride, 1),
            padding=((pad, pad), (0, 0)),
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
        )
        out = out + ba["tb"]
        if bs.pruned_filters:
            out = _scatter_filters(out, ba["kept_filters"], bs.cout)
        return out

    def transfer(self, h, ps):
        return h


class PallasBackend:
    """Fused Pallas kernels; RFC roundtrip is the inter-layer format.

    The data-dependent C_k graph cannot be precompiled (it is a function of
    the activations), so blocks with ``use_ck`` fall back to the reference
    einsum — matching the paper, which drops C_k at deployment (Table I).
    """

    name = "pallas"

    def __init__(self, interpret: bool = True):
        self.interpret = interpret

    def spatial(self, x, ba, bs):
        xg = _gather_in(x, ba)
        if bs.use_ck:
            return _spatial_einsum(xg, ba, bs)
        return ops.graph_sconv(xg, ba["Gp"], ba["Wk"],
                               interpret=self.interpret)

    def temporal(self, x, ba, bs):
        N, T, V, C = x.shape
        xb = jnp.transpose(x, (0, 2, 1, 3)).reshape(N * V, T, C)
        out = ops.cavity_tconv(
            xb, ba["wp"], ba["taps"], ba["inv_perm"],
            num_filters=bs.n_kept_filters, kernel_size=bs.tkernel,
            stride=bs.stride, interpret=self.interpret,
        )                                            # (N*V, T_out, F_kept)
        T_out = out.shape[1]
        out = jnp.transpose(
            out.reshape(N, V, T_out, -1), (0, 2, 1, 3))
        out = out + ba["tb"]
        if bs.pruned_filters:
            out = _scatter_filters(out, ba["kept_filters"], bs.cout)
        return out

    def transfer(self, h, ps):
        if not ps.use_rfc:
            return h
        vals, hot = ops.rfc_encode(h, bank=ps.rfc_bank,
                                   interpret=self.interpret)
        return ops.rfc_decode(vals, hot, bank=ps.rfc_bank,
                              interpret=self.interpret)


def get_backend(name: str, interpret: bool = True) -> Backend:
    if name == "reference":
        return ReferenceBackend()
    if name == "pallas":
        return PallasBackend(interpret=interpret)
    raise ValueError(f"unknown backend {name!r} (expected one of {BACKENDS})")


# ---------------------------------------------------------------------------
# plan compilation
# ---------------------------------------------------------------------------

def _to_numpy(x) -> np.ndarray:
    """Concretize for host-side packing — raises a clear error if a pallas
    plan is being built inside jit (packing must happen outside the step)."""
    try:
        return np.asarray(x)
    except jax.errors.TracerArrayConversionError as e:
        raise ValueError(
            "pallas ExecutionPlans must be built outside jit: cavity weight "
            "packing is host-side (plan-compile-then-execute)") from e


def build_execution_plan(
    params: Dict[str, Any],
    cfg: ModelConfig,
    prune_plan: Optional[PrunePlan] = None,
    *,
    quant: bool = False,
    backend: str = "reference",
    interpret: bool = True,
    use_rfc: Optional[bool] = None,
) -> ExecutionPlan:
    """Compile ``(params, PrunePlan, ModelConfig)`` into an ExecutionPlan.

    Everything the hot loop should not redo per step happens here: kept-
    channel index gathers, graph precompute ``A + B_k`` (padded to
    ``(K, Vp, Vp)`` for the pallas kernel), temporal filter gather + cavity
    tap masking, cavity weight packing, Q8.8 weight quantization, and the
    per-block shape bookkeeping.  Building is pure: same inputs produce an
    identical plan (leaf-for-leaf), so jitted steps taking the plan as an
    argument never retrace across rebuilds.
    """
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}")
    from repro.core.agcn.model import AGCN_STRIDES  # no import cycle: model
    strides = cfg.gcn_strides or AGCN_STRIDES       # lazily imports engine
    V = cfg.gcn_joints
    Vp = ((V + 7) // 8) * 8
    # host-side numpy graph build — stays concrete even under a jit trace
    # (the reference backend's plan build is traced by the train path)
    A = build_ntu_subsets(cfg.gcn_kv).astype(np.float32)

    blocks_a: List[Dict[str, Any]] = []
    blocks_s: List[BlockStatic] = []
    for b, blk in enumerate(params["blocks"]):
        pb = prune_plan.blocks[b] if prune_plan is not None else None
        cout = int(blk["tconv_w"].shape[0])
        use_ck = bool(cfg.use_ck and "theta" in blk)

        # --- spatial: graph precompute + kept-channel gather + quant ------
        G = jnp.asarray(A, jnp.float32) + blk["Bk"].astype(jnp.float32)
        Wk = blk["Wk"]
        if quant:
            Wk = quantize_q88(Wk)
        theta, phi = blk.get("theta"), blk.get("phi")
        kept_in = None
        if pb is not None:
            kept_in = jnp.asarray(pb.kept_in, jnp.int32)
            Wk = jnp.take(Wk, kept_in, axis=1)
            if use_ck:
                theta = jnp.take(theta, kept_in, axis=0)
                phi = jnp.take(phi, kept_in, axis=0)

        # --- temporal: filter gather + cavity mask + quant ----------------
        tw = blk["tconv_w"]                           # (F, C, K)
        if quant:
            tw = quantize_q88(tw)
        tb = blk["tconv_b"]
        kept_filters = None
        tap_mask = np.ones((cout, cfg.gcn_tkernel), bool)
        if pb is not None:
            kept_filters = jnp.asarray(pb.kept_filters, jnp.int32)
            tw = jnp.take(tw, kept_filters, axis=0)
            tb = jnp.take(tb, kept_filters)
            tap_mask = np.asarray(pb.tap_mask, bool)
            tw = tw * jnp.asarray(tap_mask, tw.dtype)[:, None, :]
        n_kept = int(tw.shape[0])

        ba: Dict[str, Any] = {
            "G": G, "Wk": Wk, "kept_in": kept_in,
            "theta": theta, "phi": phi,
            "bn_s": blk["bn_s"], "bn_t": blk["bn_t"],
            "tw": tw, "tb": tb, "kept_filters": kept_filters,
            "down_w": blk.get("down_w"), "bn_down": blk.get("bn_down"),
            "short_w": blk.get("short_w"), "bn_short": blk.get("bn_short"),
            "Gp": None, "wp": None, "taps": None, "inv_perm": None,
        }

        if backend == "pallas":
            # padded graph (K, Vp, Vp): the kernel's sublane-aligned layout
            Gp = jnp.zeros((G.shape[0], Vp, Vp), G.dtype)
            ba["Gp"] = Gp.at[:, :V, :V].set(G)
            # host-side cavity packing — dense blocks pack the full 9 taps
            wp, taps, inv = ops.pack_cavity_weights(
                _to_numpy(tw), tap_mask[:n_kept] if pb is not None
                else np.ones((n_kept, cfg.gcn_tkernel), bool))
            ba["wp"] = jnp.asarray(wp)
            ba["taps"] = jnp.asarray(taps)
            ba["inv_perm"] = jnp.asarray(inv, jnp.int32)
            # drop the dense forms the pallas path never reads — they'd ride
            # every jit call as dead payload (G stays only for the C_k
            # fallback, which runs the reference einsum)
            ba["tw"] = None
            if not use_ck:
                ba["G"] = None

        blocks_a.append(ba)
        blocks_s.append(BlockStatic(
            stride=int(strides[b]), cout=cout, n_kept_filters=n_kept,
            tkernel=int(cfg.gcn_tkernel), use_ck=use_ck,
            pruned_in=kept_in is not None,
            pruned_filters=kept_filters is not None,
        ))

    input_skip = (prune_plan.input_skip if prune_plan is not None
                  else cfg.input_skip)
    if use_rfc is None:
        use_rfc = backend == "pallas"
    static = PlanStatic(
        backend=backend, interpret=bool(interpret),
        input_skip=int(input_skip), use_rfc=bool(use_rfc),
        rfc_bank=int(cfg.rfc_bank), tkernel=int(cfg.gcn_tkernel),
        blocks=tuple(blocks_s),
    )
    arrays = {
        "data_bn": params["data_bn"],
        "blocks": blocks_a,
        "fc_w": params["fc_w"], "fc_b": params["fc_b"],
    }
    return ExecutionPlan(arrays=arrays, static=static)


# ---------------------------------------------------------------------------
# execution
# ---------------------------------------------------------------------------

def _stem(arrays, x, input_skip: int) -> jnp.ndarray:
    x = x.astype(arrays["data_bn"]["scale"].dtype)
    if input_skip > 1:
        x = x[:, ::input_skip]            # C5 input-skipping (frame sampling)
    N, T, V, C = x.shape
    h = x.reshape(N, T, V * C)
    return batch_norm(h, arrays["data_bn"]).reshape(N, T, V, C)


def _run_block(h, ba, bs, backend: Backend):
    s = backend.spatial(h, ba, bs)
    s = batch_norm(s, ba["bn_s"])
    down = (_proj(h, ba["down_w"], ba["bn_down"], 1)
            if ba["down_w"] is not None else h)
    s = jax.nn.relu(s + down)
    t = backend.temporal(s, ba, bs)
    t = batch_norm(t, ba["bn_t"])
    if ba["short_w"] is not None:
        res = _proj(h, ba["short_w"], ba["bn_short"], bs.stride)
    else:
        res = h if bs.stride == 1 else h[:, ::bs.stride]
    return jax.nn.relu(t + res)


def block_outputs(plan: ExecutionPlan, x: jnp.ndarray) -> List[jnp.ndarray]:
    """Per-block post-ReLU activations (drives the sparsity probe)."""
    backend = get_backend(plan.static.backend, plan.static.interpret)
    h = _stem(plan.arrays, x, plan.static.input_skip)
    outs = []
    nblocks = len(plan.static.blocks)
    for b, (ba, bs) in enumerate(zip(plan.arrays["blocks"],
                                     plan.static.blocks)):
        h = _run_block(h, ba, bs, backend)
        outs.append(h)
        if b < nblocks - 1:
            h = backend.transfer(h, plan.static)
    return outs


def execute(plan: ExecutionPlan, x: jnp.ndarray) -> jnp.ndarray:
    """Run the compiled plan on a clip batch (N, T, V, C) -> logits."""
    backend = get_backend(plan.static.backend, plan.static.interpret)
    h = _stem(plan.arrays, x, plan.static.input_skip)
    nblocks = len(plan.static.blocks)
    for b, (ba, bs) in enumerate(zip(plan.arrays["blocks"],
                                     plan.static.blocks)):
        h = _run_block(h, ba, bs, backend)
        if b < nblocks - 1:
            h = backend.transfer(h, plan.static)
    pooled = h.mean(axis=(1, 2))                       # (N, C_last)
    return pooled @ plan.arrays["fc_w"] + plan.arrays["fc_b"]
