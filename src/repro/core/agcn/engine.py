"""Backend-dispatched AGCN execution engine (plan-compile-then-execute).

The paper's accelerator runs the reorganized graph+spatial dataflow, the
cavity-pruned temporal conv and the runtime RFC compress as one fused
on-chip pipeline.  This module is the software analogue: instead of the
model re-deriving gathers / packings / padded graphs on every step, an
``ExecutionPlan`` is compiled **once** from ``(params, PrunePlan,
ModelConfig)`` and the hot loop only executes it.

Two backends implement the per-block ops:

  reference — the pure-jnp einsum path (extracted from ``model.py``); fully
              traceable, so it also serves the differentiable train path.
  pallas    — the fused Pallas kernels in ``repro.kernels.ops``:
              ``graph_sconv`` (graph matmul + 1×1 conv in one VMEM pass),
              packed ``cavity_tconv`` (kept-tap matmuls only), and RFC
              encode/decode between blocks as the inter-layer activation
              format.  ``interpret=True`` runs the same BlockSpecs on CPU;
              on TPU pass ``interpret=False`` and they compile.

The plan is a registered pytree: its arrays are jit arguments (so two
plans built from the same config hit the same jit cache entry — no
re-tracing, and *no re-packing inside the jitted step*), while shapes,
strides and flags live in the hashable static aux.

Pallas plans must be built **outside** jit: cavity weight packing
(``ops.pack_cavity_weights``) is host-side numpy by design — that is the
"compile" in plan-compile-then-execute.

Besides clip mode (``execute``), every plan also runs **streaming**: per-
frame continual inference through ``step_frame`` against a ``StreamState``
of per-block temporal ring buffers — see the streaming section below and
tests/test_streaming.py for the clip-parity contract.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Protocol, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.config import ModelConfig
from repro.core.agcn import adaptive
from repro.core.agcn.graph import GraphTopology, dense_to_csr, get_topology
from repro.core.pruning.plan import PrunePlan
from repro.core.quant import quantize_q88
from repro.kernels import ops

BACKENDS = ("reference", "pallas")


# ---------------------------------------------------------------------------
# plan containers
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BlockStatic:
    """Hashable per-block metadata (shapes and flags the tracer must see
    as python constants)."""

    stride: int
    cin: int                 # full block-input width (pre kept_in gather)
    cout: int
    n_kept_filters: int
    tkernel: int
    use_ck: bool
    pruned_in: bool          # kept_in gather present
    pruned_filters: bool     # kept_filters scatter present
    sconv: str = "dense"     # spatial-conv path: "dense" | "csr"


@dataclasses.dataclass(frozen=True)
class PlanStatic:
    """Hashable whole-plan metadata — the jit-cache key of a compiled
    ExecutionPlan (backend/interpret selection, C5 input skip, RFC
    inter-layer format flags, streaming shape constants, and the per-block
    ``BlockStatic`` tuple)."""

    backend: str
    interpret: bool
    input_skip: int
    use_rfc: bool            # RFC roundtrip between blocks (pallas format)
    rfc_bank: int
    tkernel: int
    joints: int
    in_channels: int
    stream_pool: int         # streaming logit pool: 0 = cumulative (clip
                             # parity), W > 0 = sliding window of W frames
    blocks: Tuple[BlockStatic, ...]
    topology: str = "ntu25"  # skeleton this plan was compiled for
    valid_joints: int = 0    # topology's own V (<= joints when slab-padded;
                             # 0 = legacy plan, treated as == joints)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class ExecutionPlan:
    """Compiled, engine-ready form of one AGCN stream.

    ``arrays`` is the pytree the jitted step consumes (pre-gathered /
    pre-quantized / pre-packed weights, precomputed graphs ``A + B_k``,
    kept-index vectors); ``static`` is the hashable aux.
    """

    arrays: Dict[str, Any]
    static: PlanStatic

    def tree_flatten(self):
        """Pytree split: arrays are jit leaves, PlanStatic is hashable aux."""
        return (self.arrays,), self.static

    @classmethod
    def tree_unflatten(cls, static, children):
        """Rebuild from (aux, leaves) — the jax pytree protocol inverse."""
        return cls(arrays=children[0], static=static)


# ---------------------------------------------------------------------------
# shared math (used by both backends and by the legacy-compatible paths)
# ---------------------------------------------------------------------------

def _bn_stats(x: jnp.ndarray, eps: float = 1e-5):
    """(mean, inv) over all-but-channel axes — the clip-mode batch stats."""
    axes = tuple(range(x.ndim - 1))
    mean = jnp.mean(x, axes, keepdims=True)
    var = jnp.var(x, axes, keepdims=True)
    inv = jax.lax.rsqrt(var.astype(jnp.float32) + eps).astype(x.dtype)
    return mean, inv


def _bn_norm(x, p, mean, inv):
    return (x - mean) * inv * p["scale"] + p["bias"]


def batch_norm(x: jnp.ndarray, p: Dict[str, jnp.ndarray],
               eps: float = 1e-5) -> jnp.ndarray:
    """Stateless batch norm: f32-accumulated stats, elementwise math in the
    activation dtype (see model.py docstring / EXPERIMENTS §Perf)."""
    mean, inv = _bn_stats(x, eps)
    return _bn_norm(x, p, mean, inv)


def _bn_live(site: str, x, p):
    """Default BN tap: clip-mode batch statistics, site ignored."""
    return batch_norm(x, p)


class _BNRecorder:
    """BN tap that captures each site's (mean, inv) while behaving exactly
    like the live tap — the calibration pass behind streaming's frozen
    statistics (per-frame BN cannot see clip-wide stats)."""

    def __init__(self):
        self.stats: Dict[str, Dict[str, jnp.ndarray]] = {}

    def __call__(self, site, x, p):
        mean, inv = _bn_stats(x)
        self.stats[site] = {"mean": mean.reshape(-1), "inv": inv.reshape(-1)}
        return _bn_norm(x, p, mean, inv)


class _BNFrozen:
    """BN tap applying previously recorded statistics (streaming hot path).
    Flat (C,) stats broadcast over any leading layout, so the same stats
    serve clip (N,T,V,C) and frame (N,V,C) shapes."""

    def __init__(self, stats: Dict[str, Dict[str, jnp.ndarray]]):
        self.stats = stats

    def __call__(self, site, x, p):
        s = self.stats[site]
        return _bn_norm(x, p, s["mean"], s["inv"])


def _proj(x, w, bnp, stride, bn=_bn_live, site=""):
    if stride != 1:
        x = x[:, ::stride]
    return bn(site, jnp.einsum("ntvc,co->ntvo", x, w), bnp)


def _scatter_filters(out: jnp.ndarray, fidx: jnp.ndarray, cout: int):
    """Scatter compacted filter outputs back to full width (pruned filters
    stay zero so the residual path sees the accelerator's shortcut layout)."""
    full = jnp.zeros((*out.shape[:-1], cout), out.dtype)
    return full.at[..., fidx].set(out)


# ---------------------------------------------------------------------------
# backends
# ---------------------------------------------------------------------------

class Backend(Protocol):
    """Per-block op provider.  ``ba`` are the block's plan arrays, ``bs``
    its static metadata; activations are (N, T, V, C)."""

    name: str

    def spatial(self, x: jnp.ndarray, ba: Dict[str, Any],
                bs: BlockStatic,
                ck: Optional[jnp.ndarray] = None) -> jnp.ndarray:
        """Graph spatial conv Σ_k (G_k·x)·W_k: (N,T,V,Cin) -> (N,T,V,Cout).
        ``ck`` optionally adds a precomputed per-frame data-dependent
        graph (N,T,V,V) to every subset's G_k (the windowed C_k path —
        repro.core.agcn.adaptive)."""
        ...

    def temporal(self, x: jnp.ndarray, ba: Dict[str, Any],
                 bs: BlockStatic) -> jnp.ndarray:
        """Clip-mode temporal conv over T: (N,T,V,C) -> (N,T_out,V,Cout)."""
        ...

    def temporal_step(self, win: jnp.ndarray, ba: Dict[str, Any],
                      bs: BlockStatic) -> jnp.ndarray:
        """One output frame from a K-frame window: (N,K,V,C) -> (N,V,Cout)."""
        ...

    def transfer(self, h: jnp.ndarray, ps: PlanStatic) -> jnp.ndarray:
        """Inter-block activation transfer (identity / RFC roundtrip)."""
        ...


def _gather_in(x: jnp.ndarray, ba: Dict[str, Any]) -> jnp.ndarray:
    if ba["kept_in"] is not None:
        return jnp.take(x, ba["kept_in"], axis=-1)
    return x


def _spatial_einsum(x: jnp.ndarray, ba: Dict[str, Any],
                    bs: BlockStatic,
                    ck: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Reference math for Σ_k (G_k·x)·W_k (+ optional data-dependent C_k).

    ``ck`` is a precomputed per-frame (N, T, V, V) windowed similarity
    graph (repro.core.agcn.adaptive) added to every subset's static
    ``A_k + B_k`` — the engine computes it (clip: per frame index;
    streaming: from the embedding rings) because the window state and the
    padded-joint masking live above the backend.  A plan padded to a slab
    Vmax may be run on a clip at the topology's own joint count (BN
    calibration); the padded graph is zero outside its valid joints, so
    slicing it down to x's V is exact."""
    G = ba["G"].astype(x.dtype)
    if G.shape[-1] != x.shape[2]:
        G = G[:, : x.shape[2], : x.shape[2]]
    Wk = ba["Wk"].astype(x.dtype)
    if ck is not None:
        Gn = G[None, None] + ck.astype(x.dtype)[:, :, None]  # (N,T,K,V,V)
        y = jnp.einsum("ntvc,ntkwv->ntkwc", x, Gn)
        return jnp.einsum("ntkwc,kco->ntwo", y, Wk)
    return jnp.einsum("ntvc,kwv,kco->ntwo", x, G, Wk)


def _spatial_csr_ref(x: jnp.ndarray, ba: Dict[str, Any],
                     bs: BlockStatic) -> jnp.ndarray:
    """Reference CSR spatial conv: gather-accumulate over the plan's
    indptr/indices.  The CSR is built at the topology's own V; when x runs
    wider (slab-padded frames) the extra output rows are zero-padded back —
    exact, because the graph never references padded joints."""
    from repro.kernels import ref as _ref

    N, T, V, C = x.shape
    Wk = ba["Wk"].astype(x.dtype)
    out = _ref.graph_sconv_csr_ref(
        x.reshape(N * T, V, C), ba["csr_indptr"], ba["csr_indices"],
        ba["csr_values"].astype(x.dtype), Wk)
    if out.shape[1] < V:
        out = jnp.pad(out, ((0, 0), (0, V - out.shape[1]), (0, 0)))
    return out.reshape(N, T, V, -1)


class ReferenceBackend:
    """Pure-jnp path — today's model math, executed from the plan."""

    name = "reference"

    def spatial(self, x, ba, bs, ck=None):
        """Kept-channel gather + the Σ_k (G_k·x)·W_k einsum (optional
        windowed C_k via ``ck``), or the CSR gather-accumulate when the
        plan chose ``sconv="csr"``."""
        xg = _gather_in(x, ba)
        if bs.sconv == "csr" and not bs.use_ck:
            return _spatial_csr_ref(xg, ba, bs)
        return _spatial_einsum(xg, ba, bs, ck=ck)

    def temporal(self, x, ba, bs):
        """Dense masked temporal conv, 'same' padding, stride on T; pruned
        filters are scattered back to full width for the residual path."""
        w = ba["tw"].astype(x.dtype)                  # (F_kept, C, K) masked
        K = w.shape[-1]
        pad = K // 2
        rhs = jnp.transpose(w, (2, 1, 0))[:, None, :, :]   # (K, 1, C, F)
        out = jax.lax.conv_general_dilated(
            x, rhs,
            window_strides=(bs.stride, 1),
            padding=((pad, pad), (0, 0)),
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
        )
        out = out + ba["tb"]
        if bs.pruned_filters:
            out = _scatter_filters(out, ba["kept_filters"], bs.cout)
        return out

    def temporal_step(self, win, ba, bs):
        """One output frame from a chronological window (N, K, V, C) —
        the streaming form of ``temporal`` (stride is emission gating,
        handled by the engine; the window always yields one output)."""
        w = ba["tw"].astype(win.dtype)                # (F_kept, C, K)
        out = jnp.einsum("nkvc,fck->nvf", win, w) + ba["tb"]
        if bs.pruned_filters:
            out = _scatter_filters(out, ba["kept_filters"], bs.cout)
        return out

    def transfer(self, h, ps):
        """Identity — reference activations cross blocks uncompressed."""
        return h


class PallasBackend:
    """Fused Pallas kernels; RFC roundtrip is the inter-layer format.

    The data-dependent C_k graph cannot be precompiled (it is a function
    of the activations), so blocks with ``use_ck`` apply it through the
    reference einsum — the graph itself comes precomputed via ``ck``
    (streaming builds it with the fused ``ops.windowed_similarity``
    kernel over the embedding rings; with C_k off the paper's
    deployment path, Table I, is unchanged).
    """

    name = "pallas"

    def __init__(self, interpret: bool = True):
        self.interpret = interpret

    def spatial(self, x, ba, bs, ck=None):
        """Fused graph+1×1 kernel (``ops.graph_sconv``) on the padded
        (K, Vp, Vp) plan graph, or the ELL gather kernel when the plan
        chose ``sconv="csr"``; C_k blocks apply the precomputed ``ck``
        through the einsum."""
        xg = _gather_in(x, ba)
        if bs.use_ck:
            return _spatial_einsum(xg, ba, bs, ck=ck)
        if bs.sconv == "csr":
            return ops.graph_sconv_csr(xg, ba["ell_idx"], ba["ell_val"],
                                       ba["Wk"], interpret=self.interpret)
        return ops.graph_sconv(xg, ba["Gp"], ba["Wk"],
                               interpret=self.interpret)

    def temporal(self, x, ba, bs):
        """Packed cavity tconv kernel over the flattened (N·V, T, C) rows —
        only the kept taps are issued (the paper's C2 FLOP skip)."""
        N, T, V, C = x.shape
        xb = jnp.transpose(x, (0, 2, 1, 3)).reshape(N * V, T, C)
        out = ops.cavity_tconv(
            xb, ba["wp"], ba["taps"], ba["inv_perm"],
            num_filters=bs.n_kept_filters, kernel_size=bs.tkernel,
            stride=bs.stride, interpret=self.interpret,
        )                                            # (N*V, T_out, F_kept)
        T_out = out.shape[1]
        out = jnp.transpose(
            out.reshape(N, V, T_out, -1), (0, 2, 1, 3))
        out = out + ba["tb"]
        if bs.pruned_filters:
            out = _scatter_filters(out, ba["kept_filters"], bs.cout)
        return out

    def temporal_step(self, win, ba, bs):
        """Single-timestep packed cavity tconv on a chronological window
        (N, K, V, C) — the same packed weights/taps, T_pad == K."""
        N, K, V, C = win.shape
        xb = jnp.transpose(win, (0, 2, 1, 3)).reshape(N * V, K, C)
        out = ops.cavity_tconv_step(
            xb, ba["wp"], ba["taps"], ba["inv_perm"],
            num_filters=bs.n_kept_filters, interpret=self.interpret,
        )                                             # (N*V, F_kept)
        out = out.reshape(N, V, -1) + ba["tb"]
        if bs.pruned_filters:
            out = _scatter_filters(out, ba["kept_filters"], bs.cout)
        return out

    def transfer(self, h, ps):
        """RFC encode/decode roundtrip — the compressed inter-layer
        activation format (lossless on post-ReLU values)."""
        if not ps.use_rfc:
            return h
        vals, hot = ops.rfc_encode(h, bank=ps.rfc_bank,
                                   interpret=self.interpret)
        return ops.rfc_decode(vals, hot, bank=ps.rfc_bank,
                              interpret=self.interpret)


def get_backend(name: str, interpret: bool = True) -> Backend:
    """Backend registry lookup: ``reference`` | ``pallas`` (cheap to call
    inside traced code — backends are stateless op providers)."""
    if name == "reference":
        return ReferenceBackend()
    if name == "pallas":
        return PallasBackend(interpret=interpret)
    raise ValueError(f"unknown backend {name!r} (expected one of {BACKENDS})")


# ---------------------------------------------------------------------------
# plan compilation
# ---------------------------------------------------------------------------

def _to_numpy(x) -> np.ndarray:
    """Concretize for host-side packing — raises a clear error if a pallas
    plan is being built inside jit (packing must happen outside the step)."""
    try:
        return np.asarray(x)
    except jax.errors.TracerArrayConversionError as e:
        raise ValueError(
            "pallas ExecutionPlans must be built outside jit: cavity weight "
            "packing is host-side (plan-compile-then-execute)") from e


def _graph_density(g, eps: float) -> Optional[float]:
    """Fraction of |entries| > eps, or None when ``g`` is a tracer (plan
    build inside jit — the train path — cannot measure density)."""
    try:
        gn = np.asarray(g)
    except jax.errors.TracerArrayConversionError:
        return None
    return float((np.abs(gn) > eps).mean())


def build_execution_plan(
    params: Dict[str, Any],
    cfg: ModelConfig,
    prune_plan: Optional[PrunePlan] = None,
    *,
    quant: bool = False,
    backend: str = "reference",
    interpret: bool = True,
    use_rfc: Optional[bool] = None,
    topology: Optional[Any] = None,
    pad_joints: Optional[int] = None,
    sconv: str = "auto",
    csr_eps: float = 0.0,
    csr_density: float = 0.5,
) -> ExecutionPlan:
    """Compile ``(params, PrunePlan, ModelConfig)`` into an ExecutionPlan.

    Everything the hot loop should not redo per step happens here: kept-
    channel index gathers, graph precompute ``A + B_k`` (padded to
    ``(K, Vp, Vp)`` for the pallas kernel), temporal filter gather + cavity
    tap masking, cavity weight packing, Q8.8 weight quantization, and the
    per-block shape bookkeeping.  Building is pure: same inputs produce an
    identical plan (leaf-for-leaf), so jitted steps taking the plan as an
    argument never retrace across rebuilds.

    Variable topology: ``topology`` names a registry skeleton (or passes a
    :class:`~repro.core.agcn.graph.GraphTopology` directly; default
    ``ntu25``) and ``pad_joints`` pads every joint-indexed plan array to a
    wider slab width Vmax so plans for different skeletons share one slab —
    padded rows/cols are zero, so the math at the topology's own joints is
    unchanged.  ``sconv`` picks the per-block spatial-conv path: ``dense``
    (padded matmul), ``csr`` (gather-accumulate over the measured nonzero
    entries of ``A + B_k``), or ``auto`` — CSR when the fraction of
    ``|G| > csr_eps`` entries is at most ``csr_density``, dense otherwise
    (with zero ``csr_eps`` the learned dense B_k keeps every graph at
    density 1.0, so auto picks dense — today's path — until B_k is
    thresholded).
    """
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}")
    if sconv not in ("auto", "dense", "csr"):
        raise ValueError(f"unknown sconv mode {sconv!r}")
    from repro.core.agcn.model import AGCN_STRIDES  # no import cycle: model
    strides = cfg.gcn_strides or AGCN_STRIDES       # lazily imports engine
    if isinstance(topology, GraphTopology):
        topo = topology
    else:
        topo = get_topology(topology or "ntu25", cfg.gcn_kv)
    vj = topo.num_joints                            # topology's own V
    V = int(pad_joints) if pad_joints is not None else vj
    if V < vj:
        raise ValueError(
            f"pad_joints={V} is narrower than topology {topo.name!r} "
            f"(V={vj})")
    Vp = ((V + 7) // 8) * 8
    # host-side numpy graph build — stays concrete even under a jit trace
    # (the reference backend's plan build is traced by the train path)
    A = topo.adjacency.astype(np.float32)

    blocks_a: List[Dict[str, Any]] = []
    blocks_s: List[BlockStatic] = []
    for b, blk in enumerate(params["blocks"]):
        pb = prune_plan.blocks[b] if prune_plan is not None else None
        cout = int(blk["tconv_w"].shape[0])
        cin_full = int(blk["Wk"].shape[1])            # pre-gather block input
        use_ck = bool(cfg.use_ck and "theta" in blk)

        # --- spatial: graph precompute + kept-channel gather + quant ------
        if int(blk["Bk"].shape[-1]) != vj:
            raise ValueError(
                f"block {b}: learned graph B_k is "
                f"{tuple(blk['Bk'].shape)} but topology {topo.name!r} has "
                f"V={vj} joints — params were built for a different "
                f"topology")
        Gv = jnp.asarray(A, jnp.float32) + blk["Bk"].astype(jnp.float32)
        if V != vj:     # pad to the slab width; padded joints stay isolated
            G = jnp.zeros((Gv.shape[0], V, V),
                          jnp.float32).at[:, :vj, :vj].set(Gv)
        else:
            G = Gv
        Wk = blk["Wk"]
        if quant:
            Wk = quantize_q88(Wk)
        theta, phi = blk.get("theta"), blk.get("phi")
        kept_in = None
        if pb is not None:
            kept_in = jnp.asarray(pb.kept_in, jnp.int32)
            Wk = jnp.take(Wk, kept_in, axis=1)
            if use_ck:
                theta = jnp.take(theta, kept_in, axis=0)
                phi = jnp.take(phi, kept_in, axis=0)

        # --- temporal: filter gather + cavity mask + quant ----------------
        tw = blk["tconv_w"]                           # (F, C, K)
        if quant:
            tw = quantize_q88(tw)
        tb = blk["tconv_b"]
        kept_filters = None
        tap_mask = np.ones((cout, cfg.gcn_tkernel), bool)
        if pb is not None:
            kept_filters = jnp.asarray(pb.kept_filters, jnp.int32)
            tw = jnp.take(tw, kept_filters, axis=0)
            tb = jnp.take(tb, kept_filters)
            tap_mask = np.asarray(pb.tap_mask, bool)
            tw = tw * jnp.asarray(tap_mask, tw.dtype)[:, None, :]
        n_kept = int(tw.shape[0])

        # --- spatial path selection: dense padded vs CSR ------------------
        block_sconv = "dense"
        if sconv != "dense" and not use_ck:
            density = _graph_density(Gv, csr_eps)
            if sconv == "csr":
                if density is None:
                    raise ValueError(
                        "sconv='csr' plans must be built outside jit: CSR "
                        "packing is host-side (plan-compile-then-execute)")
                block_sconv = "csr"
            elif density is not None and density <= csr_density:
                block_sconv = "csr"

        ba: Dict[str, Any] = {
            "G": G, "Wk": Wk, "kept_in": kept_in,
            "theta": theta, "phi": phi,
            "bn_s": blk["bn_s"], "bn_t": blk["bn_t"],
            "tw": tw, "tb": tb, "kept_filters": kept_filters,
            "down_w": blk.get("down_w"), "bn_down": blk.get("bn_down"),
            "short_w": blk.get("short_w"), "bn_short": blk.get("bn_short"),
            "Gp": None, "wp": None, "taps": None, "inv_perm": None,
            "csr_indptr": None, "csr_indices": None, "csr_values": None,
            "ell_idx": None, "ell_val": None,
        }

        if block_sconv == "csr":
            # entries with |G| <= csr_eps (the dense B_k noise floor when
            # eps > 0) are dropped — that is the CSR/dense parity budget
            indptr, indices, values = dense_to_csr(np.asarray(Gv), csr_eps)
            if backend == "pallas":
                ei, ev = ops.pack_csr_ell(indptr, indices, values, Vp)
                ba["ell_idx"] = jnp.asarray(ei)
                ba["ell_val"] = jnp.asarray(ev)
            else:
                ba["csr_indptr"] = jnp.asarray(indptr)
                ba["csr_indices"] = jnp.asarray(indices)
                ba["csr_values"] = jnp.asarray(values)
            ba["G"] = None          # the CSR paths never read the dense form

        if backend == "pallas":
            if block_sconv == "dense":
                # padded graph (K, Vp, Vp): the kernel's sublane-aligned
                # layout
                Gp = jnp.zeros((G.shape[0], Vp, Vp), G.dtype)
                ba["Gp"] = Gp.at[:, :V, :V].set(G)
            # host-side cavity packing — dense blocks pack the full 9 taps
            wp, taps, inv = ops.pack_cavity_weights(
                _to_numpy(tw), tap_mask[:n_kept] if pb is not None
                else np.ones((n_kept, cfg.gcn_tkernel), bool))
            ba["wp"] = jnp.asarray(wp)
            ba["taps"] = jnp.asarray(taps)
            ba["inv_perm"] = jnp.asarray(inv, jnp.int32)
            # drop the dense forms the pallas path never reads — they'd ride
            # every jit call as dead payload (G stays only for the C_k
            # fallback, which runs the reference einsum)
            ba["tw"] = None
            if not use_ck:
                ba["G"] = None

        blocks_a.append(ba)
        blocks_s.append(BlockStatic(
            stride=int(strides[b]), cin=cin_full, cout=cout,
            n_kept_filters=n_kept,
            tkernel=int(cfg.gcn_tkernel), use_ck=use_ck,
            pruned_in=kept_in is not None,
            pruned_filters=kept_filters is not None,
            sconv=block_sconv,
        ))

    input_skip = (prune_plan.input_skip if prune_plan is not None
                  else cfg.input_skip)
    if use_rfc is None:
        use_rfc = backend == "pallas"
    static = PlanStatic(
        backend=backend, interpret=bool(interpret),
        input_skip=int(input_skip), use_rfc=bool(use_rfc),
        rfc_bank=int(cfg.rfc_bank), tkernel=int(cfg.gcn_tkernel),
        joints=int(V), in_channels=int(cfg.gcn_in_channels),
        stream_pool=int(cfg.gcn_stream_pool),
        blocks=tuple(blocks_s),
        topology=topo.name, valid_joints=int(vj),
    )
    data_bn = params["data_bn"]
    C = int(cfg.gcn_in_channels)
    if V != vj:
        # joint-major (V*C) flattened stem BN: pad scale->1 / bias->0 so the
        # padded joints pass through as identity (they are masked anyway)
        pad = (V - vj) * C
        data_bn = {
            "scale": jnp.concatenate(
                [data_bn["scale"], jnp.ones((pad,), data_bn["scale"].dtype)]),
            "bias": jnp.concatenate(
                [data_bn["bias"], jnp.zeros((pad,), data_bn["bias"].dtype)]),
        }
    # parent map (slab width, pad rows self-parent) — the bone-stream gather
    parents = np.arange(V, dtype=np.int32)
    parents[:vj] = topo.parents
    arrays = {
        "data_bn": data_bn,
        "blocks": blocks_a,
        "fc_w": params["fc_w"], "fc_b": params["fc_b"],
        "parents": jnp.asarray(parents),
    }
    return ExecutionPlan(arrays=arrays, static=static)


# ---------------------------------------------------------------------------
# execution (clip mode)
# ---------------------------------------------------------------------------

def _slice_data_bn(p: Dict[str, jnp.ndarray], width: int):
    """Match the joint-major (V*C) stem BN params to a narrower clip: a
    slab-padded plan calibrates at the topology's own V, and the padding
    tail (scale 1 / bias 0) carries no information."""
    if p["scale"].shape[0] == width:
        return p
    return {k: v[:width] for k, v in p.items()}


def _stem(arrays, x, input_skip: int, bn=_bn_live) -> jnp.ndarray:
    x = x.astype(arrays["data_bn"]["scale"].dtype)
    if input_skip > 1:
        x = x[:, ::input_skip]            # C5 input-skipping (frame sampling)
    N, T, V, C = x.shape
    h = x.reshape(N, T, V * C)
    p = _slice_data_bn(arrays["data_bn"], V * C)
    return bn("data_bn", h, p).reshape(N, T, V, C)


def _run_block(h, ba, bs, backend: Backend, bn=_bn_live, tag: str = "",
               vj: int = 0):
    ck = None
    if bs.use_ck:
        # clip-mode windowed C_k: the same trailing-K recurrence the
        # streaming embedding rings evaluate, per frame index — which is
        # what makes streaming-vs-clip C_k parity a testable invariant
        ck = adaptive.clip_windowed_ck(
            _gather_in(h, ba), ba["theta"], ba["phi"], bs.tkernel,
            valid_joints=vj if 0 < vj < h.shape[2] else 0)
    s = backend.spatial(h, ba, bs, ck=ck)
    s = bn(tag + "bn_s", s, ba["bn_s"])
    down = (_proj(h, ba["down_w"], ba["bn_down"], 1, bn, tag + "bn_down")
            if ba["down_w"] is not None else h)
    s = jax.nn.relu(s + down)
    t = backend.temporal(s, ba, bs)
    t = bn(tag + "bn_t", t, ba["bn_t"])
    if ba["short_w"] is not None:
        res = _proj(h, ba["short_w"], ba["bn_short"], bs.stride, bn,
                    tag + "bn_short")
    else:
        res = h if bs.stride == 1 else h[:, ::bs.stride]
    return jax.nn.relu(t + res)


def block_outputs(plan: ExecutionPlan, x: jnp.ndarray) -> List[jnp.ndarray]:
    """Per-block post-ReLU activations (drives the sparsity probe)."""
    backend = get_backend(plan.static.backend, plan.static.interpret)
    h = _stem(plan.arrays, x, plan.static.input_skip)
    outs = []
    nblocks = len(plan.static.blocks)
    for b, (ba, bs) in enumerate(zip(plan.arrays["blocks"],
                                     plan.static.blocks)):
        h = _run_block(h, ba, bs, backend, vj=plan.static.valid_joints)
        outs.append(h)
        if b < nblocks - 1:
            h = backend.transfer(h, plan.static)
    return outs


def _forward(plan: ExecutionPlan, x: jnp.ndarray, bn) -> jnp.ndarray:
    backend = get_backend(plan.static.backend, plan.static.interpret)
    h = _stem(plan.arrays, x, plan.static.input_skip, bn)
    nblocks = len(plan.static.blocks)
    for b, (ba, bs) in enumerate(zip(plan.arrays["blocks"],
                                     plan.static.blocks)):
        h = _run_block(h, ba, bs, backend, bn, tag=f"b{b}/",
                       vj=plan.static.valid_joints)
        if b < nblocks - 1:
            h = backend.transfer(h, plan.static)
    pooled = h.mean(axis=(1, 2))                       # (N, C_last)
    return pooled @ plan.arrays["fc_w"] + plan.arrays["fc_b"]


def execute(plan: ExecutionPlan, x: jnp.ndarray) -> jnp.ndarray:
    """Run the compiled plan on a clip batch (N, T, V, C) -> logits."""
    return _forward(plan, x, _bn_live)


def collect_bn_stats(plan: ExecutionPlan, x: jnp.ndarray
                     ) -> Dict[str, Dict[str, jnp.ndarray]]:
    """Run one clip batch through the plan's own backend, recording every
    batch-norm site's (mean, inv) — the frozen statistics that let the
    streaming path reproduce clip logits (per-frame BN cannot see clip-wide
    stats).  Call outside jit: the recorder mutates a host-side dict."""
    rec = _BNRecorder()
    _forward(plan, x, rec)
    return rec.stats


# ---------------------------------------------------------------------------
# execution (streaming mode) — per-frame continual inference
# ---------------------------------------------------------------------------
#
# The same compiled plan runs frame-by-frame with stateful temporal rings:
# each block holds the last K(=tkernel) spatial outputs (its tconv input)
# plus the last K block inputs (residual source), and emits one output
# whenever the just-arrived frame completes a clip-mode window — every
# ``stride``-th input, ``pad = K//2`` frames behind real time (the clip
# conv's 'same' padding becomes a per-block latency).  Invalid frames
# (input-skip gaps, post-clip flush) write *zeros* into the tconv ring,
# which is exactly the clip conv's zero padding, so post-drain streaming
# logits equal clip logits (tests/test_streaming.py).  RFC encode/decode is
# applied to every emitted inter-block frame (pallas), and the running
# encoded activations live in the state.
#
# All per-stream clocks are tracked **per slot** (leading axis of every
# state leaf): slot s has its own raw-frame counter, per-block input
# counters, validity rings and logit pool.  A StreamState is therefore
# simultaneously one lockstep batch (every slot fed the same clip — the
# PR-2 streaming mode) and a **session slab**: independent live sessions
# occupying slots, admitted/evicted at different times by a host-side
# scheduler (repro.serving) through :func:`reset_slots`,
# :func:`step_frames` and the preemption pair :func:`snapshot_slots` /
# :func:`restore_slots`.  Free/dead slots are masked with ``valid=False``
# frames through the existing clip-validity machinery, so one compiled
# step serves any slot occupancy without retracing.

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class StreamState:
    """Pytree state of S concurrent AGCN stream slots (the session slab).

    ``blocks[b]``: ring_s (S, K, V, cout) tconv-input ring, ring_h
    (S, K, V, cin) residual-source ring, valid (S, K) clip-validity bits,
    t (S,) int32 inputs seen at this block's time scale (per slot — slots
    admitted at different times run at different ring phases); ``use_ck``
    blocks additionally carry ck_th / ck_ph (S, K, V, Ce) windowed-C_k
    embedding rings (repro.core.agcn.adaptive) — per-slot leaves like any
    other, so snapshots, the fused tick's ring, and elastic/cross-replica
    migration carry them for free.  ``t_raw``
    (S,) counts raw frames per slot; ``pool_*`` hold the per-slot running
    temporal logit pool; ``bn_stats`` the frozen calibration (shared by all
    slots — calibrated once per plan, untouched by slot resets); ``rfc``
    the per-slot running RFC-encoded inter-block activations (pallas)."""

    t_raw: Any
    blocks: List[Dict[str, Any]]
    pool_ring: Any
    pool_sum: Any
    pool_t: Any
    bn_stats: Dict[str, Dict[str, Any]]
    rfc: Optional[List[Dict[str, Any]]]

    def tree_flatten(self):
        """Pytree split: every field is a leaf subtree (no static aux), so
        states ride jit boundaries and rebuilt states never retrace."""
        return ((self.t_raw, self.blocks, self.pool_ring, self.pool_sum,
                 self.pool_t, self.bn_stats, self.rfc), None)

    @classmethod
    def tree_unflatten(cls, aux, children):
        """Rebuild from pytree children (field order of the dataclass)."""
        return cls(*children)


def _pad_data_bn_stats(bn_stats: Dict[str, Dict[str, Any]],
                       ps: PlanStatic) -> Dict[str, Dict[str, Any]]:
    """Pad the stem BN statistics of a topology-V calibration to the slab
    width (mean 0 / inv 1 — identity on the masked padded joints).  All
    other sites are per-channel (C,) and joint-count independent."""
    want = ps.joints * ps.in_channels
    db = bn_stats.get("data_bn")
    if db is None or db["mean"].shape[0] == want:
        return bn_stats
    pad = want - db["mean"].shape[0]
    out = dict(bn_stats)
    out["data_bn"] = {
        "mean": jnp.concatenate(
            [db["mean"], jnp.zeros((pad,), db["mean"].dtype)]),
        "inv": jnp.concatenate(
            [db["inv"], jnp.ones((pad,), db["inv"].dtype)]),
    }
    return out


def init_stream_state(
    plan: ExecutionPlan,
    batch: int,
    *,
    x_calib: Optional[jnp.ndarray] = None,
    bn_stats: Optional[Dict[str, Dict[str, jnp.ndarray]]] = None,
    dtype=jnp.float32,
) -> StreamState:
    """Fresh zeroed StreamState for ``batch`` concurrent stream slots.

    Streaming needs frozen batch-norm statistics: pass ``x_calib`` (a
    representative clip batch — the stats are recorded from one clip-mode
    pass of this plan's own backend) or precomputed ``bn_stats`` from
    :func:`collect_bn_stats`.  The statistics are plan-level (shared by
    every slot), so one calibration serves sessions admitted at any later
    time."""
    ps = plan.static
    if bn_stats is None:
        if x_calib is None:
            raise ValueError(
                "streaming needs frozen BN statistics: pass x_calib (a "
                "representative clip batch) or bn_stats from "
                "collect_bn_stats()")
        bn_stats = collect_bn_stats(plan, x_calib)
    bn_stats = _pad_data_bn_stats(bn_stats, ps)
    K, V = ps.tkernel, ps.joints
    blocks = []
    for b, bs in enumerate(ps.blocks):
        d = {
            "ring_s": jnp.zeros((batch, K, V, bs.cout), dtype),
            "ring_h": jnp.zeros((batch, K, V, bs.cin), dtype),
            "valid": jnp.zeros((batch, K), bool),
            "t": jnp.zeros((batch,), jnp.int32),
        }
        if bs.use_ck:
            # windowed-C_k embedding rings (repro.core.agcn.adaptive):
            # zero rows stand in for the pre-history window frames, so a
            # fresh slot's first windows match clip mode's leading edge.
            # Present only on use_ck plans — a C_k-off slab's state tree
            # (and therefore its snapshots, rings and golden digests) is
            # unchanged.
            ce = int(plan.arrays["blocks"][b]["theta"].shape[-1])
            d["ck_th"] = jnp.zeros((batch, K, V, ce), dtype)
            d["ck_ph"] = jnp.zeros((batch, K, V, ce), dtype)
        blocks.append(d)
    c_last = ps.blocks[-1].cout
    rfc = None
    if ps.use_rfc:
        rfc = [{"vals": jnp.zeros((batch, V, bs.cout), dtype),
                "hot": jnp.zeros((batch, V, bs.cout), dtype)}
               for bs in ps.blocks[:-1]]
    pool_ring = (jnp.zeros((batch, ps.stream_pool, c_last), dtype)
                 if ps.stream_pool > 0 else None)
    return StreamState(
        t_raw=jnp.zeros((batch,), jnp.int32), blocks=blocks,
        pool_ring=pool_ring, pool_sum=jnp.zeros((batch, c_last), dtype),
        pool_t=jnp.zeros((batch,), jnp.int32), bn_stats=bn_stats, rfc=rfc)


def init_session_slab(
    plan: ExecutionPlan,
    slots: int,
    *,
    x_calib: Optional[jnp.ndarray] = None,
    bn_stats: Optional[Dict[str, Dict[str, jnp.ndarray]]] = None,
    dtype=jnp.float32,
) -> StreamState:
    """A fixed-capacity session slab: ``slots`` independent stream slots.

    Identical to :func:`init_stream_state` — a slab *is* a StreamState
    whose leading axis is slot capacity S rather than a lockstep batch.
    Named separately so serving code reads as what it means; the host-side
    admission/eviction scheduler lives in ``repro.serving``."""
    return init_stream_state(plan, slots, x_calib=x_calib,
                             bn_stats=bn_stats, dtype=dtype)


def _select_slots(keep_old, old: StreamState, new: StreamState) -> StreamState:
    """Per-slot select between two StreamStates: slots where ``keep_old`` is
    True keep ``old``'s per-slot leaves, all others take ``new``'s — the
    traced masking behind :func:`step_frames`'s ``hold``.  The shared
    plan-level ``bn_stats`` are taken from ``new`` (they are identical in
    both states by construction)."""
    keep_old = jnp.asarray(keep_old, bool)

    def sel(o, n):
        m = keep_old.reshape(keep_old.shape + (1,) * (n.ndim - 1))
        return jnp.where(m, o, n)

    blocks = [{k: sel(ob[k], nb[k]) for k in nb}
              for ob, nb in zip(old.blocks, new.blocks)]
    rfc = None
    if new.rfc is not None:
        rfc = [{k: sel(orr[k], nr[k]) for k in nr}
               for orr, nr in zip(old.rfc, new.rfc)]
    return StreamState(
        t_raw=sel(old.t_raw, new.t_raw), blocks=blocks,
        pool_ring=(sel(old.pool_ring, new.pool_ring)
                   if new.pool_ring is not None else None),
        pool_sum=sel(old.pool_sum, new.pool_sum),
        pool_t=sel(old.pool_t, new.pool_t),
        bn_stats=new.bn_stats, rfc=rfc)


def reset_slots(state: StreamState, free) -> StreamState:
    """Zero the per-slot streaming state of every slot where ``free`` is
    True — the traced admission reset.

    ``free`` is a (S,) bool mask.  All per-slot leaves (rings, validity
    bits, block clocks, logit pools, RFC carries, raw-frame counters) are
    zeroed via ``jnp.where``, so admitting a new session into a recycled
    slot is one masked select inside the already-compiled step — never a
    retrace, never a state rebuild.  The shared frozen BN statistics are
    plan-level calibration and are left untouched."""
    free = jnp.asarray(free, bool)

    def z(leaf):
        m = free.reshape(free.shape + (1,) * (leaf.ndim - 1))
        return jnp.where(m, jnp.zeros_like(leaf), leaf)

    blocks = [{k: z(v) for k, v in b.items()} for b in state.blocks]
    rfc = ([{k: z(v) for k, v in r.items()} for r in state.rfc]
           if state.rfc is not None else None)
    return StreamState(
        t_raw=z(state.t_raw), blocks=blocks,
        pool_ring=z(state.pool_ring) if state.pool_ring is not None else None,
        pool_sum=z(state.pool_sum), pool_t=z(state.pool_t),
        bn_stats=state.bn_stats, rfc=rfc)


def snapshot_slots(state: StreamState, idx) -> Dict[str, Any]:
    """Gather slot ``idx``'s per-slot streaming state out of the slab — the
    preemption capture.

    ``idx`` is a scalar (one slot) or an (k,) int vector (k slots); pass it
    as a traced array so every preemption reuses one jitted gather, never a
    retrace.  The snapshot covers **every** per-slot leaf of the
    :class:`StreamState` pytree — rings, validity bits, block clocks, logit
    pools, RFC carries, the raw-frame counter — and deliberately excludes
    ``bn_stats``: the frozen calibration is plan-level, shared by all slots,
    and travels with the plan rather than the session.  The returned dict
    is itself a pytree, so it rides jit boundaries and host round-trips.

    The locked invariant (tests/test_sessions.py, both backends):
    snapshot -> evict -> arbitrary foreign traffic in the slot ->
    :func:`restore_slots` -> resume produces logits identical (<=1e-3) to
    the uninterrupted session."""
    idx = jnp.asarray(idx, jnp.int32)

    def g(leaf):
        return jnp.take(leaf, idx, axis=0)

    return {
        "t_raw": g(state.t_raw),
        "blocks": [{k: g(v) for k, v in b.items()} for b in state.blocks],
        "pool_ring": (g(state.pool_ring)
                      if state.pool_ring is not None else None),
        "pool_sum": g(state.pool_sum),
        "pool_t": g(state.pool_t),
        "rfc": ([{k: g(v) for k, v in r.items()} for r in state.rfc]
                if state.rfc is not None else None),
    }


def restore_slots(state: StreamState, idx, snap: Dict[str, Any]
                  ) -> StreamState:
    """Scatter a :func:`snapshot_slots` capture back into slot ``idx`` — the
    preemption restore.

    The inverse of the snapshot gather: every per-slot leaf of ``snap`` is
    written into row ``idx`` of the corresponding slab leaf (one traced
    scatter when ``idx`` rides as an array — never a retrace), all other
    slots are untouched, and the shared frozen BN statistics stay the
    plan-level calibration of ``state``.  After the restore the slot resumes
    exactly where the snapshot left it: same ring phases, same block
    clocks, same running pool, so the next ``step_frame`` continues the
    preempted session as if it was never evicted."""
    idx = jnp.asarray(idx, jnp.int32)

    def s(leaf, sv):
        return leaf.at[idx].set(jnp.asarray(sv, leaf.dtype))

    blocks = [{k: s(v, sb[k]) for k, v in b.items()}
              for b, sb in zip(state.blocks, snap["blocks"])]
    rfc = None
    if state.rfc is not None:
        rfc = [{k: s(v, sr[k]) for k, v in r.items()}
               for r, sr in zip(state.rfc, snap["rfc"])]
    return StreamState(
        t_raw=s(state.t_raw, snap["t_raw"]), blocks=blocks,
        pool_ring=(s(state.pool_ring, snap["pool_ring"])
                   if state.pool_ring is not None else None),
        pool_sum=s(state.pool_sum, snap["pool_sum"]),
        pool_t=s(state.pool_t, snap["pool_t"]),
        bn_stats=state.bn_stats, rfc=rfc)


# sentinel slot/ring index marking a padded no-op event in the fixed-shape
# snapshot/restore order buffers consumed by fused_tick: far out of bounds
# for any slab or ring axis, so the gather clamps it (value discarded) and
# the scatter drops it — a padded event touches nothing
SNAP_SENTINEL = np.int32(2 ** 30)


def init_snapshot_ring(slab: StreamState, capacity: int) -> Dict[str, Any]:
    """Preallocated on-device snapshot ring: ``capacity`` rows, each shaped
    like one slot's :func:`snapshot_slots` capture.

    The ring replaces host-side per-event snapshot tuples in the fused
    serving tick (:func:`fused_tick`): preemption captures are scattered
    into ring rows and restores gather them back out, all inside one
    dispatch, with the host only tracking which row holds which session.
    Row shapes are per-slot (independent of the slab's capacity S), so one
    ring serves every capacity tier and survives elastic migrations."""
    idx = jnp.zeros((int(capacity),), jnp.int32)
    return jax.tree_util.tree_map(jnp.zeros_like, snapshot_slots(slab, idx))


def snapshot_to_ring(slab: StreamState, ring: Dict[str, Any],
                     order) -> Dict[str, Any]:
    """Apply a fixed-shape batch of snapshot events: for each (slot, row)
    pair in ``order``, gather slot ``slot``'s per-slot state out of the
    slab and write it into ring row ``row``.

    ``order`` is an (E, 2) int32 array padded with :data:`SNAP_SENTINEL`
    no-op rows, so any event count from 0 to E reuses one compilation —
    sentinel gathers clamp (their value is discarded) and sentinel
    scatters drop.  Returns the updated ring; the slab is read-only."""
    order = jnp.asarray(order, jnp.int32)
    S = slab.t_raw.shape[0]
    rows = snapshot_slots(slab, jnp.minimum(order[:, 0], S - 1))
    dst = order[:, 1]

    def put(r, x):
        return r.at[dst].set(jnp.asarray(x, r.dtype), mode="drop")

    return jax.tree_util.tree_map(put, ring, rows)


def restore_from_ring(slab: StreamState, ring: Dict[str, Any],
                      order) -> StreamState:
    """Apply a fixed-shape batch of restore events: for each (slot, row)
    pair in ``order``, gather ring row ``row`` and scatter it into slab
    slot ``slot`` — the inverse of :func:`snapshot_to_ring`, with the same
    :data:`SNAP_SENTINEL` padding semantics (sentinel events touch no
    slot).  Returns the updated slab; ring rows are read-only (a restored
    row's stale copy stays in the ring until the host reuses it)."""
    order = jnp.asarray(order, jnp.int32)
    slot = order[:, 0]
    R = ring["t_raw"].shape[0]
    src = jnp.minimum(order[:, 1], R - 1)

    def g(leaf):
        return jnp.take(leaf, src, axis=0, mode="clip")

    def s(leaf, sv):
        return leaf.at[slot].set(jnp.asarray(sv, leaf.dtype), mode="drop")

    blocks = [{k: s(v, g(rb[k])) for k, v in b.items()}
              for b, rb in zip(slab.blocks, ring["blocks"])]
    rfc = None
    if slab.rfc is not None:
        rfc = [{k: s(v, g(rr[k])) for k, v in r.items()}
               for r, rr in zip(slab.rfc, ring["rfc"])]
    return StreamState(
        t_raw=s(slab.t_raw, g(ring["t_raw"])), blocks=blocks,
        pool_ring=(s(slab.pool_ring, g(ring["pool_ring"]))
                   if slab.pool_ring is not None else None),
        pool_sum=s(slab.pool_sum, g(ring["pool_sum"])),
        pool_t=s(slab.pool_t, g(ring["pool_t"])),
        bn_stats=slab.bn_stats, rfc=rfc)


def fused_tick(
    plan: ExecutionPlan,
    slab: StreamState,
    frames: jnp.ndarray,             # (S, V, C) one raw frame per slot
    valid,                           # (S,) bool — per-slot clip/flush phase
    reset,                           # (S,) bool — admission reset
    hold,                            # (S,) bool — freeze starved open slots
    snap_order,                      # (E, 2) int32 (slot, ring row) padded
    rest_order,                      # (E, 2) int32 (slot, ring row) padded
    snap_ring: Dict[str, Any],       # init_snapshot_ring state
    bn_stats: Optional[Dict[str, Dict[str, Any]]] = None,
) -> Tuple[StreamState, jnp.ndarray, Dict[str, Any]]:
    """One serving tick as a single device dispatch: snapshot gathers,
    restore scatters, admission resets, hold masking and the slab step,
    fused — returns ``(slab, logits, snap_ring)``.

    The multi-dispatch tick (one jitted call per snapshot event, one per
    restore event, then :func:`step_frames`) becomes one jitted function:
    ``snap_order``/``rest_order`` are fixed-shape (E, 2) traced index
    arrays padded with :data:`SNAP_SENTINEL` no-ops, so *any* per-tick
    event count reuses one compilation per slab capacity, and the captures
    live in the preallocated on-device ``snap_ring`` instead of host-side
    Python tuples.  Event semantics match the multi-dispatch sequence:
    snapshots gather from the **pre-tick** slab (capture before restore),
    restores scatter ring rows written this tick or earlier (a same-tick
    snapshot→restore resumes correctly), then ``reset`` zeroes fresh
    admissions before their first frame lands.

    Built for donation: jit it with the slab and ring donated
    (``donate_argnums``) so XLA updates the rings in place — after the
    call the *input* slab/ring buffers are dead and the caller must only
    ever touch the returned ones."""
    new_ring = snapshot_to_ring(slab, snap_ring, snap_order)
    slab = restore_from_ring(slab, new_ring, rest_order)
    new_slab, logits = step_frames(plan, slab, frames, valid, reset, hold,
                                   bn_stats=bn_stats)
    return new_slab, logits, new_ring


def stream_flush_frames(plan: ExecutionPlan, frames: int) -> int:
    """Raw flush steps (zero frames, valid=False) needed after a ``frames``-
    long clip so the final valid output drains through every block's
    ``pad``-frame latency — after which streaming logits equal clip logits."""
    ps = plan.static
    pad = ps.tkernel // 2
    t = -(-frames // ps.input_skip)            # frames surviving input skip
    for bs in ps.blocks:
        t = (t - 1) // bs.stride + 1           # clip-mode output length
    o = t - 1                                  # last valid final-block output
    for bs in reversed(ps.blocks):
        o = o * bs.stride + pad                # input index that triggers it
    total = o * ps.input_skip + 1
    return max(0, total - frames)


def stream_first_logit_delay(plan: ExecutionPlan) -> int:
    """Raw frames from slot admission until the first *valid* logit
    contribution lands in the pool — the admission-to-first-logit latency
    in frame ticks (the wall-clock version is measured by the session
    scheduler).  Same backward recurrence as :func:`stream_flush_frames`
    with final output index o = 0."""
    ps = plan.static
    pad = ps.tkernel // 2
    o = 0
    for bs in reversed(ps.blocks):
        o = o * bs.stride + pad
    return o * ps.input_skip + 1


def _pooled_logits(arrays, ps: PlanStatic, pool_sum, pool_t) -> jnp.ndarray:
    """Running prediction from the temporal logit pool: mean over the
    effective pooled-frame count (clamped to the sliding window when
    ``stream_pool`` > 0, and to 1 before the first contribution), through
    the fc head.  Shared by the streaming step and the hold path so the
    two can never desynchronize."""
    n_eff = (jnp.minimum(pool_t, ps.stream_pool) if ps.stream_pool > 0
             else pool_t)
    pooled = pool_sum / jnp.maximum(n_eff, 1)[:, None].astype(pool_sum.dtype)
    return pooled @ arrays["fc_w"] + arrays["fc_b"]


def _stem_frame(arrays, frame: jnp.ndarray, bn) -> jnp.ndarray:
    """Per-frame stem: data_bn on one (N, V, C) frame with frozen stats."""
    x = frame.astype(arrays["data_bn"]["scale"].dtype)
    N, V, C = x.shape
    h = x.reshape(N, V * C)
    return bn("data_bn", h, arrays["data_bn"]).reshape(N, V, C)


def step_frame(
    plan: ExecutionPlan,
    state: StreamState,
    frame: jnp.ndarray,              # (S, V, C) one raw frame per slot
    valid=True,                      # False -> flush step (post-clip drain)
    bn_stats: Optional[Dict[str, Dict[str, Any]]] = None,
) -> Tuple[StreamState, jnp.ndarray]:
    """Advance every stream slot by one raw frame; returns (state, logits).

    ``valid`` is a scalar (lockstep batch — every slot streams the same
    clip timeline) or a (S,) bool vector (session slab — each slot has its
    own clip/flush phase; False slots take the zero-padding drain path).
    Because every clock in the state is per-slot, slots admitted at
    different times decimate, emit and pool independently.

    ``bn_stats`` overrides the slab's frozen calibration for this step —
    the multi-topology service runs one dispatch per skeleton group over
    the same slab, each with its own topology's statistics (padded to the
    slab width here).  ``None`` keeps the state's own stats (the single-
    topology path, unchanged).

    A plan whose topology is narrower than the slab (``valid_joints`` <
    ``joints``) masks the padded joint rows after the stem and after each
    block's ReLUs — BN bias would otherwise leak nonzero values into them
    — and pools logits over the valid joints only, so a session's logits
    equal its dedicated narrow-slab run.

    Pure and jit-stable: the plan and state ride as pytree arguments, all
    data-dependent control (input-skip gaps, stride-decimated emission,
    clip-validity of flushed windows, per-slot ring phases) is traced
    masking — one compilation per ExecutionPlan serves the whole stream at
    any slot occupancy.  The slot axis is constrained to the logical
    "batch" sharding axis, so a slab shards across devices under
    ``distributed.sharding.axis_rules``."""
    from repro.distributed.sharding import constrain

    ps = plan.static
    backend = get_backend(ps.backend, ps.interpret)
    stats = (state.bn_stats if bn_stats is None
             else _pad_data_bn_stats(bn_stats, ps))
    bn = _BNFrozen(stats)
    K = ps.tkernel
    pad = K // 2
    nblocks = len(ps.blocks)
    S = frame.shape[0]
    rows = jnp.arange(S)
    vj = ps.valid_joints or ps.joints
    vmask = vj < ps.joints               # mask padded joints (static)

    valid = jnp.broadcast_to(jnp.asarray(valid, bool), (S,))
    process = (state.t_raw % ps.input_skip) == 0      # C5 input skipping (S,)
    has_input = process
    in_valid = jnp.logical_and(valid, process)
    frame = constrain(frame, "batch", None, None)
    h_in = _stem_frame(plan.arrays, frame, bn)
    if vmask:
        h_in = h_in.at[:, vj:, :].set(0.0)

    new_blocks: List[Dict[str, Any]] = []
    new_rfc: List[Dict[str, Any]] = []
    emit = has_input
    out = h_in
    out_valid = in_valid
    for b, (ba, bs) in enumerate(zip(plan.arrays["blocks"], ps.blocks)):
        sb = state.blocks[b]
        tag = f"b{b}/"
        t = sb["t"]                                    # (S,) block clock
        slot = t % K                                   # (S,) ring phase

        # --- windowed C_k: embedding-ring update + graph (adaptive.py) ----
        ck = None
        ck_th = ck_ph = None
        if bs.use_ck:
            xg = _gather_in(h_in, ba)
            e_th = jnp.einsum("nvc,ce->nve", xg,
                              ba["theta"].astype(h_in.dtype))
            e_ph = jnp.einsum("nvc,ce->nve", xg,
                              ba["phi"].astype(h_in.dtype))
            # invalid (flush) frames write zero embeddings — they trail
            # every valid frame, so valid windows match clip mode exactly
            e_th = jnp.where(in_valid[:, None, None], e_th, 0.0)
            e_ph = jnp.where(in_valid[:, None, None], e_ph, 0.0)
            ck_th = jnp.where(has_input[:, None, None, None],
                              sb["ck_th"].at[rows, slot].set(e_th),
                              sb["ck_th"])
            ck_ph = jnp.where(has_input[:, None, None, None],
                              sb["ck_ph"].at[rows, slot].set(e_ph),
                              sb["ck_ph"])
            vjs = vj if vmask else 0
            if ps.backend == "pallas":
                ck = ops.windowed_similarity(ck_th, ck_ph,
                                             valid_joints=vjs,
                                             interpret=ps.interpret)
            else:
                ck = adaptive.windowed_ck(ck_th.sum(axis=1),
                                          ck_ph.sum(axis=1),
                                          valid_joints=vjs)

        # --- frame-local gcn unit (spatial graph conv + down residual) ----
        s = backend.spatial(h_in[:, None], ba, bs,
                            ck=None if ck is None else ck[:, None])[:, 0]
        s = bn(tag + "bn_s", s, ba["bn_s"])
        down = (bn(tag + "bn_down",
                   jnp.einsum("nvc,co->nvo", h_in, ba["down_w"]),
                   ba["bn_down"])
                if ba["down_w"] is not None else h_in)
        s = jax.nn.relu(s + down)
        if vmask:          # BN bias injects nonzero values at padded joints
            s = s.at[:, vj:, :].set(0.0)
        # invalid inputs become the clip conv's zero padding at this level
        s = jnp.where(in_valid[:, None, None], s, 0.0)

        # --- masked per-slot ring write ----------------------------------
        ring_s = jnp.where(has_input[:, None, None, None],
                           sb["ring_s"].at[rows, slot].set(s), sb["ring_s"])
        ring_h = jnp.where(has_input[:, None, None, None],
                           sb["ring_h"].at[rows, slot].set(h_in),
                           sb["ring_h"])
        vring = jnp.where(has_input[:, None],
                          sb["valid"].at[rows, slot].set(in_valid),
                          sb["valid"])
        t_new = t + has_input.astype(jnp.int32)
        nb = {"ring_s": ring_s, "ring_h": ring_h,
              "valid": vring, "t": t_new}
        if bs.use_ck:
            nb["ck_th"] = ck_th
            nb["ck_ph"] = ck_ph
        new_blocks.append(nb)

        # --- stride-decimated emission (per slot) ------------------------
        # output o of the clip conv completes when input t = o*stride + pad
        # arrives; its center tap (and residual source) is input t - pad
        emit = jnp.logical_and(
            has_input,
            jnp.logical_and(t >= pad, (t - pad) % bs.stride == 0))
        idx = (t[:, None] + 1 + jnp.arange(K)[None, :]) % K   # (S, K) chrono
        win = jnp.take_along_axis(ring_s, idx[:, :, None, None], axis=1)
        out = backend.temporal_step(win, ba, bs)
        out = bn(tag + "bn_t", out, ba["bn_t"])
        center = (t - pad) % K                         # (S,)
        h_c = jnp.take_along_axis(
            ring_h, center[:, None, None, None], axis=1)[:, 0]
        if ba["short_w"] is not None:
            res = bn(tag + "bn_short",
                     jnp.einsum("nvc,co->nvo", h_c, ba["short_w"]),
                     ba["bn_short"])
        else:
            res = h_c
        out = jax.nn.relu(out + res)
        if vmask:
            out = out.at[:, vj:, :].set(0.0)
        out_valid = jnp.take_along_axis(vring, center[:, None], axis=1)[:, 0]

        # --- inter-block transfer: the RFC format, frame-wise -------------
        if b < nblocks - 1:
            if ps.use_rfc:
                vals, hot = ops.rfc_encode(out, bank=ps.rfc_bank,
                                           interpret=ps.interpret)
                old = state.rfc[b]
                keep = emit[:, None, None]
                new_rfc.append(
                    {"vals": jnp.where(keep, vals, old["vals"]),
                     "hot": jnp.where(keep, hot, old["hot"])})
                out = ops.rfc_decode(vals, hot, bank=ps.rfc_bank,
                                     interpret=ps.interpret)
            h_in = out
        has_input = emit
        in_valid = out_valid

    # --- running temporal logit pool (per slot) ---------------------------
    take = jnp.logical_and(emit, out_valid)            # (S,)
    contrib = out[:, :vj].mean(axis=1)                 # (S, C_last): valid
                                                       # joints pooled
    if ps.stream_pool > 0:
        W = ps.stream_pool
        pslot = state.pool_t % W                       # (S,)
        pool_ring = jnp.where(
            take[:, None, None],
            state.pool_ring.at[rows, pslot].set(contrib), state.pool_ring)
        # recompute from the ring (W is small): a running add/subtract
        # would accumulate rounding drift over an unbounded live stream
        pool_sum = pool_ring.sum(axis=1)
        pool_t = state.pool_t + take.astype(jnp.int32)
    else:
        pool_ring = None
        pool_sum = state.pool_sum + jnp.where(take[:, None], contrib, 0.0)
        pool_t = state.pool_t + take.astype(jnp.int32)
    logits = _pooled_logits(plan.arrays, ps, pool_sum, pool_t)
    logits = constrain(logits, "batch", None)

    new_state = StreamState(
        t_raw=state.t_raw + 1, blocks=new_blocks, pool_ring=pool_ring,
        pool_sum=pool_sum, pool_t=pool_t, bn_stats=state.bn_stats,
        rfc=new_rfc if ps.use_rfc else None)
    return new_state, logits


def step_frames(
    plan: ExecutionPlan,
    slab: StreamState,
    frames: jnp.ndarray,             # (S, V, C) one raw frame per slot
    valid,                           # (S,) bool — per-slot clip/flush phase
    reset=None,                      # optional (S,) bool — admission reset
    hold=None,                       # optional (S,) bool — freeze the slot
    bn_stats: Optional[Dict[str, Dict[str, Any]]] = None,
) -> Tuple[StreamState, jnp.ndarray]:
    """One scheduler tick of the session slab; returns (slab, logits[S]).

    The multi-session serving step: ``reset`` zeroes the marked slots
    *before* the frame is consumed (so an admission's first frame lands in
    a clean ring), then every slot advances one raw frame with its own
    ``valid`` bit — active sessions feed real frames (True), draining
    sessions feed the zero-padding flush (False), and free slots are dead
    weight masked by the same validity machinery.  ``hold`` freezes the
    marked slots entirely: their per-slot state is untouched (no clock
    advance, no ring write — *not* the flush path, which would inject
    zero padding mid-stream) and their logits row is the previous running
    prediction.  This is how an open-ended session (``GcnService.submit``)
    starves gracefully when its frame buffer is empty but the stream has
    not been closed.  Everything is traced masking over the compiled
    :func:`step_frame`, so the jitted tick is compiled once per
    ExecutionPlan regardless of admissions, evictions, holds or occupancy.
    Logits row s is slot s's running prediction; the host-side scheduler
    (``repro.serving``) reads it at eviction time."""
    if reset is not None:
        slab = reset_slots(slab, reset)
    new, logits = step_frame(plan, slab, frames, valid, bn_stats=bn_stats)
    if hold is not None:
        from repro.distributed.sharding import constrain

        new = _select_slots(hold, slab, new)
        # recompute the logits from the selected pool: held slots report
        # their previous running prediction, all others are unchanged
        # (re-constrained to the slot axis like the hold=None path)
        logits = _pooled_logits(plan.arrays, plan.static, new.pool_sum,
                                new.pool_t)
        logits = constrain(logits, "batch", None)
    return new, logits
