"""Windowed data-dependent C_k graphs — the adaptive-streaming reformulation.

The paper drops the data-dependent similarity graph C_k at deployment
(Table I: 88.9% w/o C_k) because eq. (1) pools embeddings over the *whole
clip's* time axis — a live stream has no clip to pool over.  This module
reformulates C_k as a **trailing-window** statistic so the same graph is
computable per frame from the streaming engine's existing ring buffers
(Continual ST-GCN, PAPERS.md 2203.11009, applies the same per-frame
continual rewrite to these blocks):

    Θ(t) = Σ_{u=t−K+1..t} θ(x_u)          (zeros before the stream starts)
    Φ(t) = Σ_{u=t−K+1..t} φ(x_u)
    C(t) = softmax(Θ(t)·Φ(t)ᵀ / √Ce)      (per output joint, over inputs)

with K = the block's temporal kernel size — the window the block's tconv
ring already spans, so the streaming state only adds two (S, K, V, Ce)
embedding rings per C_k block.  Both execution modes use the *same*
definition: clip mode evaluates the recurrence at every frame index
(:func:`clip_windowed_ck`), streaming evaluates it incrementally from the
embedding rings (:func:`windowed_ck` on the ring sums, or the fused pallas
kernel ``repro.kernels.ops.windowed_similarity``), which is why post-drain
streaming logits match clip logits ≤1e-3 with C_k **on**
(tests/test_streaming.py) — the invariant the full-clip eq. (1) could
never satisfy.

Normalization matches :func:`repro.core.agcn.graph.similarity_graph`
(logits scaled by 1/√Ce, max-subtracted softmax over the input-joint
axis); slab-padded joints are masked out of the softmax *columns* so a
padded plan's graph rows never pool from dead joints.
"""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["windowed_ck", "clip_windowed_ck"]


def windowed_ck(win_th: jnp.ndarray, win_ph: jnp.ndarray,
                valid_joints: int = 0) -> jnp.ndarray:
    """C = softmax(Θ·Φᵀ/√Ce) from pooled window embeddings.

    ``win_th`` / ``win_ph`` are (..., V, Ce) trailing-window embedding
    sums (the streaming engine's ``ck_th``/``ck_ph`` rings summed over
    their K axis; clip mode builds them with
    :func:`_trailing_window_sum`).  ``valid_joints`` > 0 masks the
    input-joint *columns* ≥ it to −inf before the softmax — a slab-padded
    plan's zero rows would otherwise flatten every row's softmax toward
    the padded joints.  Returns the (..., V, V) normalized graph added to
    ``A_k + B_k`` per subset."""
    ce = win_th.shape[-1]
    logits = jnp.einsum("...ve,...we->...vw", win_th, win_ph) / jnp.sqrt(
        jnp.asarray(ce, win_th.dtype))
    V = logits.shape[-1]
    if 0 < valid_joints < V:
        dead = jnp.arange(V) >= valid_joints            # (V,) input joints
        logits = jnp.where(dead, jnp.asarray(-1e30, logits.dtype), logits)
    m = jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(logits - m)
    return (e / jnp.sum(e, axis=-1, keepdims=True)).astype(win_th.dtype)


def _trailing_window_sum(e: jnp.ndarray, k: int) -> jnp.ndarray:
    """Per-frame trailing-K window sums of (N, T, V, Ce) embeddings:
    ``out[:, t] = Σ_{d=0..K−1} e[:, t−d]`` with zeros before frame 0 —
    exactly the streaming embedding ring's content at block clock t
    (fresh rings are zero-initialized), built as K−1 shifted adds so clip
    mode never materializes a (T, K) window tensor."""
    out = e
    T = e.shape[1]
    for d in range(1, k):
        out = out + jnp.pad(e, ((0, 0), (d, 0), (0, 0), (0, 0)))[:, :T]
    return out


def clip_windowed_ck(x: jnp.ndarray, w_theta: jnp.ndarray,
                     w_phi: jnp.ndarray, k: int,
                     valid_joints: int = 0) -> jnp.ndarray:
    """Per-frame windowed C_k for clip mode: (N, T, V, C) -> (N, T, V, V).

    Evaluates the module recurrence at every frame index — embedding
    projections θ/φ per frame, trailing-K window sums (zeros before the
    clip starts), then :func:`windowed_ck` — so a clip-mode forward with
    ``use_ck`` is frame-for-frame the reference twin of the streaming
    embedding rings (the parity contract in tests/test_streaming.py).
    ``x`` is the block input with kept channels already gathered;
    ``w_theta``/``w_phi`` are the plan's (C_kept, Ce) projections."""
    th = jnp.einsum("ntvc,ce->ntve", x, w_theta.astype(x.dtype))
    ph = jnp.einsum("ntvc,ce->ntve", x, w_phi.astype(x.dtype))
    return windowed_ck(_trailing_window_sum(th, k),
                       _trailing_window_sum(ph, k),
                       valid_joints=valid_joints)
