"""Skeleton graphs for 2s-AGCN (paper §II).

Three graphs per layer & subset k:
  A_k — static human-skeleton graph (NTU RGB+D 25-joint), split into the
        ST-GCN spatial-configuration subsets (identity / centripetal /
        centrifugal), symmetrically normalized.
  B_k — learnable dense connection graph (initialised to zero, trained).
  C_k — data-dependent self-similarity graph, eq. (1):
        C_k = softmax(f_in^T W_theta f_in).  The paper drops C_k at
        deployment (Table I); we implement it for the ablation.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

# NTU RGB+D 25-joint skeleton, 1-indexed bone list (joint, parent).
NTU_EDGES = [
    (1, 2), (2, 21), (3, 21), (4, 3), (5, 21), (6, 5), (7, 6), (8, 7),
    (9, 21), (10, 9), (11, 10), (12, 11), (13, 1), (14, 13), (15, 14),
    (16, 15), (17, 1), (18, 17), (19, 18), (20, 19), (22, 23), (23, 8),
    (24, 25), (25, 12),
]
NTU_CENTER = 21  # spine joint (1-indexed)
NUM_JOINTS = 25


def _hop_distance(num_joints: int, edges) -> np.ndarray:
    adj = np.eye(num_joints, dtype=np.int32)
    for i, j in edges:
        adj[i - 1, j - 1] = 1
        adj[j - 1, i - 1] = 1
    dist = np.full((num_joints, num_joints), np.inf)
    power = np.eye(num_joints, dtype=np.int64)
    for d in range(num_joints):
        if d > 0:
            power = power @ adj
        dist[(power > 0) & np.isinf(dist)] = d
    return dist


def build_subsets(edges, center: int, num_joints: int,
                  num_subsets: int = 3) -> np.ndarray:
    """Return A of shape (K, V, V) for an arbitrary skeleton: identity /
    centripetal / centrifugal subsets split by hop distance to ``center``
    (1-indexed), each column-normalized (D^-1 A as in ST-GCN)."""
    V = num_joints
    dist = _hop_distance(V, edges)
    adj1 = (dist <= 1).astype(np.float64)       # self + 1-hop
    # normalize: A_norm[i,j] = adj[i,j] / indegree(j)
    deg = adj1.sum(0)
    norm = adj1 / np.maximum(deg[None, :], 1)

    center_d = dist[:, center - 1]
    subsets = np.zeros((num_subsets, V, V), dtype=np.float64)
    for i in range(V):
        for j in range(V):
            if dist[i, j] > 1:
                continue
            if center_d[j] == center_d[i]:
                subsets[0, i, j] = norm[i, j]           # root (same distance)
            elif center_d[j] < center_d[i]:
                subsets[1, i, j] = norm[i, j]           # centripetal
            else:
                subsets[2, i, j] = norm[i, j]           # centrifugal
    return subsets.astype(np.float32)


def build_ntu_subsets(num_subsets: int = 3) -> np.ndarray:
    """Return A of shape (K, V, V) for the NTU 25-joint skeleton: identity
    / centripetal / centrifugal subsets, each column-normalized."""
    return build_subsets(NTU_EDGES, NTU_CENTER, NUM_JOINTS, num_subsets)


def static_graph(num_subsets: int = 3) -> jnp.ndarray:
    """The normalized NTU subset graphs A as a device array (K, V, V)."""
    return jnp.asarray(build_ntu_subsets(num_subsets))


def graph_sparsity(a: np.ndarray) -> float:
    """Fraction of zero entries — A_k is sparse, B_k is dense (paper §I)."""
    return float((a == 0).mean())


def similarity_graph(x: jnp.ndarray, w_theta: jnp.ndarray, w_phi: jnp.ndarray) -> jnp.ndarray:
    """C_k = softmax(theta(x)^T phi(x)) over joints, eq. (1).

    x: (N, T, V, C); w_theta/w_phi: (C, Ce).  Returns (N, V, V).

    This is the paper's *full-clip* ablation form — one graph per clip,
    pooled over all T frames at once.  The streaming engine serves the
    causal per-frame reformulation instead
    (:func:`repro.core.agcn.adaptive.windowed_ck`: a trailing-K window of
    pooled embeddings per tick), which converges to this form after the
    drain; see tests/test_streaming.py for the parity lock.
    """
    theta = jnp.einsum("ntvc,ce->nve", x, w_theta)   # pool T implicitly below
    phi = jnp.einsum("ntvc,ce->nve", x, w_phi)
    logits = jnp.einsum("nve,nwe->nvw", theta, phi) / jnp.sqrt(
        jnp.asarray(theta.shape[-1], x.dtype)
    )
    m = jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(logits - m)
    return (e / jnp.sum(e, axis=-1, keepdims=True)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Variable-topology support: first-class GraphTopology + registry.
# ---------------------------------------------------------------------------

def dense_to_csr(a: np.ndarray, eps: float = 0.0):
    """Convert a dense (K, V, V) subset stack to per-k CSR over output rows.

    Row w of subset k holds the input joints v with ``|a[k, w, v]| > eps``.
    Returns ``(indptr (K, V+1) int32, indices (K, E) int32, values (K, E)
    float32)`` where E is the max nnz over k and shorter subsets are
    zero-padded (a zero value is a no-op in the gather-accumulate).
    """
    a = np.asarray(a)
    K, V, _ = a.shape
    per_k = []
    for k in range(K):
        rows, cols = np.nonzero(np.abs(a[k]) > eps)
        per_k.append((rows.astype(np.int64), cols.astype(np.int64),
                      a[k][rows, cols].astype(np.float32)))
    E = max(1, max(len(r) for r, _, _ in per_k))
    indptr = np.zeros((K, V + 1), np.int32)
    indices = np.zeros((K, E), np.int32)
    values = np.zeros((K, E), np.float32)
    for k, (rows, cols, vals) in enumerate(per_k):
        counts = np.bincount(rows, minlength=V)
        indptr[k, 1:] = np.cumsum(counts)
        indices[k, : len(cols)] = cols       # np.nonzero is already row-major
        values[k, : len(vals)] = vals
    return indptr, indices, values


def csr_to_dense(indptr: np.ndarray, indices: np.ndarray,
                 values: np.ndarray) -> np.ndarray:
    """Inverse of :func:`dense_to_csr` — rebuild the (K, V, V) stack."""
    K, V1 = np.asarray(indptr).shape
    V = V1 - 1
    out = np.zeros((K, V, V), np.float32)
    for k in range(K):
        for w in range(V):
            lo, hi = int(indptr[k, w]), int(indptr[k, w + 1])
            out[k, w, indices[k, lo:hi]] += values[k, lo:hi]
    return out


def parents_from_edges(edges, num_joints: int) -> np.ndarray:
    """(V,) int32 parent index (0-indexed) per joint; roots parent
    themselves so the bone vector ``x - x[parents]`` is zero there."""
    parents = np.arange(num_joints, dtype=np.int32)
    for joint, parent in edges:
        parents[joint - 1] = parent - 1
    return parents


@dataclasses.dataclass(frozen=True, eq=False)
class GraphTopology:
    """A skeleton graph the engine can compile an ExecutionPlan for.

    Holds the dense normalized subset stack *and* its CSR factorization so
    the spatial conv can pick either path per block, plus the parent map
    that generalizes the bone stream and a joint-validity mask used when
    this topology rides in a slab padded to a wider ``Vmax``.
    """

    name: str
    num_joints: int
    center: int
    edges: Tuple[Tuple[int, int], ...]
    parents: np.ndarray        # (V,) int32, 0-indexed, roots self-parent
    adjacency: np.ndarray      # (K, V, V) float32 normalized subsets
    indptr: np.ndarray         # (K, V+1) int32 CSR row pointers
    indices: np.ndarray        # (K, E) int32 CSR column indices
    values: np.ndarray         # (K, E) float32 CSR values
    valid: np.ndarray          # (V,) bool joint-validity mask

    @property
    def num_subsets(self) -> int:
        """K, the number of spatial-configuration subsets."""
        return int(self.adjacency.shape[0])

    @property
    def density(self) -> float:
        """Fraction of nonzero entries in the normalized adjacency."""
        return 1.0 - graph_sparsity(self.adjacency)

    def padded_valid(self, vmax: int) -> np.ndarray:
        """(vmax,) bool mask — this topology's joints inside a Vmax slab."""
        out = np.zeros(vmax, bool)
        out[: self.num_joints] = self.valid
        return out


def make_topology(name: str, edges: Sequence[Tuple[int, int]], center: int,
                  num_joints: int, num_subsets: int = 3) -> GraphTopology:
    """Build a :class:`GraphTopology` from a 1-indexed bone list."""
    adjacency = build_subsets(edges, center, num_joints, num_subsets)
    indptr, indices, values = dense_to_csr(adjacency)
    return GraphTopology(
        name=name,
        num_joints=num_joints,
        center=center,
        edges=tuple((int(j), int(p)) for j, p in edges),
        parents=parents_from_edges(edges, num_joints),
        adjacency=adjacency,
        indptr=indptr,
        indices=indices,
        values=values,
        valid=np.ones(num_joints, bool),
    )


def _ntu50_edges():
    """Two-person NTU scene: block-diagonal person graphs plus one
    inter-person link tying person 2's spine to person 1's spine."""
    edges = list(NTU_EDGES)
    edges += [(j + NUM_JOINTS, p + NUM_JOINTS) for j, p in NTU_EDGES]
    edges.append((NTU_CENTER + NUM_JOINTS, NTU_CENTER))
    return edges


# 21-joint hand: wrist (1) plus five 4-joint finger chains.
HAND_EDGES = [
    (2, 1), (3, 2), (4, 3), (5, 4),          # thumb
    (6, 1), (7, 6), (8, 7), (9, 8),          # index
    (10, 1), (11, 10), (12, 11), (13, 12),   # middle
    (14, 1), (15, 14), (16, 15), (17, 16),   # ring
    (18, 1), (19, 18), (20, 19), (21, 20),   # pinky
]


def _body_hand46_edges():
    """Mixed body+hand graph: the NTU body with a 21-joint hand grafted
    onto the right-hand joint (NTU joint 12)."""
    edges = list(NTU_EDGES)
    edges += [(j + NUM_JOINTS, p + NUM_JOINTS) for j, p in HAND_EDGES]
    edges.append((1 + NUM_JOINTS, 12))       # hand wrist -> body right hand
    return edges


_TOPOLOGY_SPECS = {
    "ntu25": (NTU_EDGES, NTU_CENTER, NUM_JOINTS),
    "ntu50": (_ntu50_edges(), NTU_CENTER, 2 * NUM_JOINTS),
    "hand21": (HAND_EDGES, 1, 21),
    "body_hand46": (_body_hand46_edges(), NTU_CENTER, NUM_JOINTS + 21),
}
_TOPOLOGY_CACHE: Dict[Tuple[str, int], GraphTopology] = {}


def topology_names() -> Tuple[str, ...]:
    """Names of the registered skeleton topologies."""
    return tuple(_TOPOLOGY_SPECS)


def get_topology(name: str, num_subsets: int = 3) -> GraphTopology:
    """Fetch (and cache) a registry topology by name."""
    key = (name, num_subsets)
    if key not in _TOPOLOGY_CACHE:
        if name not in _TOPOLOGY_SPECS:
            raise KeyError(
                f"unknown topology {name!r}; registered: {topology_names()}")
        edges, center, num_joints = _TOPOLOGY_SPECS[name]
        _TOPOLOGY_CACHE[key] = make_topology(
            name, edges, center, num_joints, num_subsets)
    return _TOPOLOGY_CACHE[key]
