"""Skeleton graphs for 2s-AGCN (paper §II).

Three graphs per layer & subset k:
  A_k — static human-skeleton graph (NTU RGB+D 25-joint), split into the
        ST-GCN spatial-configuration subsets (identity / centripetal /
        centrifugal), symmetrically normalized.
  B_k — learnable dense connection graph (initialised to zero, trained).
  C_k — data-dependent self-similarity graph, eq. (1):
        C_k = softmax(f_in^T W_theta f_in).  The paper drops C_k at
        deployment (Table I); we implement it for the ablation.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

# NTU RGB+D 25-joint skeleton, 1-indexed bone list (joint, parent).
NTU_EDGES = [
    (1, 2), (2, 21), (3, 21), (4, 3), (5, 21), (6, 5), (7, 6), (8, 7),
    (9, 21), (10, 9), (11, 10), (12, 11), (13, 1), (14, 13), (15, 14),
    (16, 15), (17, 1), (18, 17), (19, 18), (20, 19), (22, 23), (23, 8),
    (24, 25), (25, 12),
]
NTU_CENTER = 21  # spine joint (1-indexed)
NUM_JOINTS = 25


def _hop_distance(num_joints: int, edges) -> np.ndarray:
    adj = np.eye(num_joints, dtype=np.int32)
    for i, j in edges:
        adj[i - 1, j - 1] = 1
        adj[j - 1, i - 1] = 1
    dist = np.full((num_joints, num_joints), np.inf)
    power = np.eye(num_joints, dtype=np.int64)
    for d in range(num_joints):
        if d > 0:
            power = power @ adj
        dist[(power > 0) & np.isinf(dist)] = d
    return dist


def build_ntu_subsets(num_subsets: int = 3) -> np.ndarray:
    """Return A of shape (K, V, V): identity / centripetal / centrifugal
    subsets, each column-normalized (D^-1 A as in ST-GCN)."""
    V = NUM_JOINTS
    dist = _hop_distance(V, NTU_EDGES)
    adj1 = (dist <= 1).astype(np.float64)       # self + 1-hop
    # normalize: A_norm[i,j] = adj[i,j] / indegree(j)
    deg = adj1.sum(0)
    norm = adj1 / np.maximum(deg[None, :], 1)

    center_d = dist[:, NTU_CENTER - 1]
    subsets = np.zeros((num_subsets, V, V), dtype=np.float64)
    for i in range(V):
        for j in range(V):
            if dist[i, j] > 1:
                continue
            if center_d[j] == center_d[i]:
                subsets[0, i, j] = norm[i, j]           # root (same distance)
            elif center_d[j] < center_d[i]:
                subsets[1, i, j] = norm[i, j]           # centripetal
            else:
                subsets[2, i, j] = norm[i, j]           # centrifugal
    return subsets.astype(np.float32)


def static_graph(num_subsets: int = 3) -> jnp.ndarray:
    """The normalized NTU subset graphs A as a device array (K, V, V)."""
    return jnp.asarray(build_ntu_subsets(num_subsets))


def graph_sparsity(a: np.ndarray) -> float:
    """Fraction of zero entries — A_k is sparse, B_k is dense (paper §I)."""
    return float((a == 0).mean())


def similarity_graph(x: jnp.ndarray, w_theta: jnp.ndarray, w_phi: jnp.ndarray) -> jnp.ndarray:
    """C_k = softmax(theta(x)^T phi(x)) over joints, eq. (1).

    x: (N, T, V, C); w_theta/w_phi: (C, Ce).  Returns (N, V, V).
    """
    theta = jnp.einsum("ntvc,ce->nve", x, w_theta)   # pool T implicitly below
    phi = jnp.einsum("ntvc,ce->nve", x, w_phi)
    logits = jnp.einsum("nve,nwe->nvw", theta, phi) / jnp.sqrt(
        jnp.asarray(theta.shape[-1], x.dtype)
    )
    m = jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(logits - m)
    return (e / jnp.sum(e, axis=-1, keepdims=True)).astype(x.dtype)
