"""2s-AGCN in JAX (paper §II), with the hybrid pruning plan (C1+C2) applied
as static channel compaction, optional C_k self-similarity graph, Q8.8
quantization and input-skipping (C5).

Data layout: (N, T, V, C) with the person axis M folded into N (NTU clips are
(N, C, T, V, M); the loader reshapes).  Ten TCN-GCN blocks + global pool + FC,
channels (64,)*4 + (128,)*3 + (256,)*3, temporal strides 1,1,1,1,2,1,1,2,1,1
as in the reference implementation of Shi et al. [9]:

    block(x) = relu( bn(tconv(gcnunit(x), stride)) + residual(x) )
    gcnunit(x) = relu( bn(sum_k (G_k·x)·W_k) + down(x) )

BatchNorm is implemented statelessly (batch statistics at both train and
inference time — the paper's accelerator runs fixed batches, and this keeps
the step functions pure); the learned scale/bias are real parameters.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.config import ModelConfig
from repro.core.agcn.graph import similarity_graph, static_graph
from repro.core.pruning.plan import PrunePlan
from repro.core.quant import quantize_q88

AGCN_CHANNELS = (64, 64, 64, 64, 128, 128, 128, 256, 256, 256)
AGCN_STRIDES = (1, 1, 1, 1, 2, 1, 1, 2, 1, 1)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _conv_init(key, shape, fan_in):
    return jax.random.normal(key, shape, jnp.float32) * np.sqrt(2.0 / fan_in)


def _bn_init(c: int) -> Dict[str, jnp.ndarray]:
    return {"scale": jnp.ones((c,), jnp.float32), "bias": jnp.zeros((c,), jnp.float32)}


def init_params(cfg: ModelConfig, key: jax.Array) -> Dict[str, Any]:
    """Parameter pytree for one (single-stream) AGCN model."""
    channels = cfg.gcn_channels or AGCN_CHANNELS
    strides = cfg.gcn_strides or AGCN_STRIDES
    V, K, TK = cfg.gcn_joints, cfg.gcn_kv, cfg.gcn_tkernel
    cin = cfg.gcn_in_channels
    keys = jax.random.split(key, len(channels) * 8 + 2)
    ki = iter(range(len(keys)))

    blocks = []
    for b, cout in enumerate(channels):
        blk: Dict[str, Any] = {
            "Bk": jnp.full((K, V, V), 1e-6, jnp.float32),
            "Wk": _conv_init(keys[next(ki)], (K, cin, cout), cin),
            "bn_s": _bn_init(cout),
            "tconv_w": _conv_init(keys[next(ki)], (cout, cout, TK), cout * TK),
            "tconv_b": jnp.zeros((cout,), jnp.float32),
            "bn_t": _bn_init(cout),
        }
        if cfg.use_ck:
            ce = max(4, cin // 4)
            blk["theta"] = _conv_init(keys[next(ki)], (cin, ce), cin)
            blk["phi"] = _conv_init(keys[next(ki)], (cin, ce), cin)
        if cin != cout:
            blk["down_w"] = _conv_init(keys[next(ki)], (cin, cout), cin)
            blk["bn_down"] = _bn_init(cout)
        if cin != cout or strides[b] != 1:
            blk["short_w"] = _conv_init(keys[next(ki)], (cin, cout), cin)
            blk["bn_short"] = _bn_init(cout)
        blocks.append(blk)
        cin = cout

    return {
        "data_bn": _bn_init(cfg.gcn_in_channels * V),
        "blocks": blocks,
        "fc_w": _conv_init(keys[next(ki)], (channels[-1], cfg.gcn_num_classes), channels[-1]),
        "fc_b": jnp.zeros((cfg.gcn_num_classes,), jnp.float32),
    }


def _bn(x: jnp.ndarray, p: Dict[str, jnp.ndarray], eps: float = 1e-5) -> jnp.ndarray:
    """Dtype-preserving batch norm: stats are reduced with f32 accumulation
    (XLA reduce semantics) but the elementwise normalisation stays in the
    activation dtype — no convert ops materialising f32 copies of the
    activation tensor (perf iteration 3, EXPERIMENTS §Perf)."""
    axes = tuple(range(x.ndim - 1))
    mean = jnp.mean(x, axes, keepdims=True)
    var = jnp.var(x, axes, keepdims=True)
    inv = jax.lax.rsqrt(var.astype(jnp.float32) + eps).astype(x.dtype)
    return (x - mean) * inv * p["scale"] + p["bias"]


# ---------------------------------------------------------------------------
# block pieces
# ---------------------------------------------------------------------------

def _spatial_conv(
    x: jnp.ndarray,            # (N, T, V, Cin)
    blk: Dict[str, Any],
    A: jnp.ndarray,            # (K, V, V) static graph
    kept_in: Optional[Tuple[int, ...]],
    use_ck: bool,
    quant: bool,
) -> jnp.ndarray:
    """Reorganized-dataflow graph + 1×1 conv (paper eq. (5)).

    With pruning, only kept input channels enter the graph matmul *and* the
    conv — the paper's graph-skipping, realised as compaction (DESIGN §2).
    """
    Wk = blk["Wk"]                                   # (K, Cin, Cout)
    if quant:
        Wk = quantize_q88(Wk)
    theta, phi = blk.get("theta"), blk.get("phi")
    if kept_in is not None:
        idx = jnp.asarray(kept_in, jnp.int32)
        x = jnp.take(x, idx, axis=-1)
        Wk = jnp.take(Wk, idx, axis=1)
        if use_ck:
            theta = jnp.take(theta, idx, axis=0)
            phi = jnp.take(phi, idx, axis=0)
    G = (A + blk["Bk"]).astype(x.dtype)              # (K, V, V)
    if use_ck:
        Ck = similarity_graph(x, theta, phi)
        Gn = G[None] + Ck[:, None]                   # (N, K, V, V)
        y = jnp.einsum("ntvc,nkwv->nktwc", x, Gn)
    else:
        # fused (G·f)·W summed over subsets — the reorganized order lets a
        # pruned channel skip both multiplies.  Single einsum: XLA picks the
        # contraction order and fuses without materialising the transposed
        # (n,k,t,w,c) intermediate (perf iteration 2, EXPERIMENTS §Perf).
        return jnp.einsum("ntvc,kwv,kco->ntwo", x, G, Wk.astype(x.dtype))
    return jnp.einsum("nktwc,kco->ntwo", y, Wk.astype(y.dtype))


def _temporal_conv(
    x: jnp.ndarray,            # (N, T, V, C)
    blk: Dict[str, Any],
    stride: int,
    plan_block,
    quant: bool,
) -> jnp.ndarray:
    """9×1 temporal conv with coarse filter pruning + cavity tap masks (C2).

    Pruned filters are *not computed* (compaction) and scattered back as
    zeros so the residual path stays full-width, matching the accelerator's
    shortcut storage.
    """
    w = blk["tconv_w"]                               # (F=cout, Cin=cout, K)
    if quant:
        w = quantize_q88(w)
    cout = w.shape[0]
    fidx = None
    if plan_block is not None:
        fidx = jnp.asarray(plan_block.kept_filters, jnp.int32)
        w = jnp.take(w, fidx, axis=0)
        mask = jnp.asarray(plan_block.tap_mask, w.dtype)  # (F_kept, K)
        w = w * mask[:, None, :]
    K = w.shape[-1]
    pad = K // 2
    rhs = jnp.transpose(w, (2, 1, 0))[:, None, :, :]  # (K, 1, Cin, F)
    out = jax.lax.conv_general_dilated(
        x, rhs,
        window_strides=(stride, 1),
        padding=((pad, pad), (0, 0)),
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    if fidx is not None:
        out = out + jnp.take(blk["tconv_b"], fidx)
        full = jnp.zeros((*out.shape[:-1], cout), out.dtype)
        out = full.at[..., fidx].set(out)
    else:
        out = out + blk["tconv_b"]
    return out


def _proj(x, w, bn, stride):
    if stride != 1:
        x = x[:, ::stride]
    return _bn(jnp.einsum("ntvc,co->ntvo", x, w), bn)


def _block(h, blk, A, strides_b, pb, use_ck, quant):
    kept_in = pb.kept_in if pb is not None else None
    s = _spatial_conv(h, blk, A, kept_in, use_ck, quant)
    s = _bn(s, blk["bn_s"])
    down = _proj(h, blk["down_w"], blk["bn_down"], 1) if "down_w" in blk else h
    s = jax.nn.relu(s + down)
    t = _temporal_conv(s, blk, strides_b, pb, quant)
    t = _bn(t, blk["bn_t"])
    if "short_w" in blk:
        res = _proj(h, blk["short_w"], blk["bn_short"], strides_b)
    else:
        res = h if strides_b == 1 else h[:, ::strides_b]
    return jax.nn.relu(t + res)


# ---------------------------------------------------------------------------
# model
# ---------------------------------------------------------------------------

def _stem(params, x, cfg, plan):
    x = x.astype(params["data_bn"]["scale"].dtype)   # compute dtype of params
    skip = plan.input_skip if plan is not None else cfg.input_skip
    if skip > 1:
        x = x[:, ::skip]                  # C5 input-skipping (frame sampling)
    N, T, V, C = x.shape
    h = x.reshape(N, T, V * C)
    return _bn(h, params["data_bn"]).reshape(N, T, V, C)


def forward(
    params: Dict[str, Any],
    x: jnp.ndarray,                       # (N, T, V, C)
    cfg: ModelConfig,
    plan: Optional[PrunePlan] = None,
    quant: bool = False,
) -> jnp.ndarray:
    """Logits (N, num_classes)."""
    strides = cfg.gcn_strides or AGCN_STRIDES
    A = static_graph(cfg.gcn_kv).astype(x.dtype)
    h = _stem(params, x, cfg, plan)
    for b, blk in enumerate(params["blocks"]):
        pb = plan.blocks[b] if plan is not None else None
        h = _block(h, blk, A, strides[b], pb, cfg.use_ck, quant)
    pooled = h.mean(axis=(1, 2))                       # (N, C_last)
    return pooled @ params["fc_w"] + params["fc_b"]


def bone_stream(x: jnp.ndarray) -> jnp.ndarray:
    """Second stream of 2s-AGCN: bone vectors = joint − parent joint."""
    from repro.core.agcn.graph import NTU_EDGES
    out = jnp.zeros_like(x)
    for j, p in NTU_EDGES:
        out = out.at[..., j - 1, :].set(x[..., j - 1, :] - x[..., p - 1, :])
    return out


def two_stream_logits(params_joint, params_bone, x, cfg, plan=None, quant=False):
    """Ensemble of the joint and bone streams (the '2s' in 2s-AGCN)."""
    lj = forward(params_joint, x, cfg, plan, quant)
    lb = forward(params_bone, bone_stream(x), cfg, plan, quant)
    return 0.5 * (lj + lb)


def feature_sparsity_per_block(params, x, cfg, plan=None) -> List[float]:
    """Post-ReLU sparsity per block output — drives RFC mini-bank sizing and
    the Drop-* channel schedules (paper Fig. 9, Table III)."""
    strides = cfg.gcn_strides or AGCN_STRIDES
    A = static_graph(cfg.gcn_kv).astype(x.dtype)
    h = _stem(params, x, cfg, plan)
    out = []
    for b, blk in enumerate(params["blocks"]):
        pb = plan.blocks[b] if plan is not None else None
        h = _block(h, blk, A, strides[b], pb, cfg.use_ck, False)
        out.append(float((h == 0).mean()))
    return out
