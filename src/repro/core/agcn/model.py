"""2s-AGCN in JAX (paper §II), with the hybrid pruning plan (C1+C2) applied
as static channel compaction, optional windowed C_k self-similarity graph
(``repro.core.agcn.adaptive`` — streaming/clip parity by construction), Q8.8
quantization and input-skipping (C5).

Data layout: (N, T, V, C) with the person axis M folded into N (NTU clips are
(N, C, T, V, M); the loader reshapes).  Ten TCN-GCN blocks + global pool + FC,
channels (64,)*4 + (128,)*3 + (256,)*3, temporal strides 1,1,1,1,2,1,1,2,1,1
as in the reference implementation of Shi et al. [9]:

    block(x) = relu( bn(tconv(gcnunit(x), stride)) + residual(x) )
    gcnunit(x) = relu( bn(sum_k (G_k·x)·W_k) + down(x) )

BatchNorm is implemented statelessly (batch statistics at both train and
inference time — the paper's accelerator runs fixed batches, and this keeps
the step functions pure); the learned scale/bias are real parameters.

This module owns parameters and the public API; the per-op math lives in
``repro.core.agcn.engine`` behind a backend-dispatched ExecutionPlan:
``forward`` compiles the plan (or takes a prebuilt one) and executes it.
The default ``reference`` backend is fully traceable/differentiable — the
train path is unchanged; the ``pallas`` backend runs the fused kernels.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.common.config import ModelConfig
from repro.core.pruning.plan import PrunePlan

AGCN_CHANNELS = (64, 64, 64, 64, 128, 128, 128, 256, 256, 256)
AGCN_STRIDES = (1, 1, 1, 1, 2, 1, 1, 2, 1, 1)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _conv_init(key, shape, fan_in):
    return jax.random.normal(key, shape, jnp.float32) * np.sqrt(2.0 / fan_in)


def _bn_init(c: int) -> Dict[str, jnp.ndarray]:
    return {"scale": jnp.ones((c,), jnp.float32), "bias": jnp.zeros((c,), jnp.float32)}


def init_params(cfg: ModelConfig, key: jax.Array) -> Dict[str, Any]:
    """Parameter pytree for one (single-stream) AGCN model."""
    channels = cfg.gcn_channels or AGCN_CHANNELS
    strides = cfg.gcn_strides or AGCN_STRIDES
    V, K, TK = cfg.gcn_joints, cfg.gcn_kv, cfg.gcn_tkernel
    cin = cfg.gcn_in_channels
    keys = jax.random.split(key, len(channels) * 8 + 2)
    ki = iter(range(len(keys)))

    blocks = []
    for b, cout in enumerate(channels):
        blk: Dict[str, Any] = {
            "Bk": jnp.full((K, V, V), 1e-6, jnp.float32),
            "Wk": _conv_init(keys[next(ki)], (K, cin, cout), cin),
            "bn_s": _bn_init(cout),
            "tconv_w": _conv_init(keys[next(ki)], (cout, cout, TK), cout * TK),
            "tconv_b": jnp.zeros((cout,), jnp.float32),
            "bn_t": _bn_init(cout),
        }
        if cfg.use_ck:
            ce = max(4, cin // 4)
            blk["theta"] = _conv_init(keys[next(ki)], (cin, ce), cin)
            blk["phi"] = _conv_init(keys[next(ki)], (cin, ce), cin)
        if cin != cout:
            blk["down_w"] = _conv_init(keys[next(ki)], (cin, cout), cin)
            blk["bn_down"] = _bn_init(cout)
        if cin != cout or strides[b] != 1:
            blk["short_w"] = _conv_init(keys[next(ki)], (cin, cout), cin)
            blk["bn_short"] = _bn_init(cout)
        blocks.append(blk)
        cin = cout

    return {
        "data_bn": _bn_init(cfg.gcn_in_channels * V),
        "blocks": blocks,
        "fc_w": _conv_init(keys[next(ki)], (channels[-1], cfg.gcn_num_classes), channels[-1]),
        "fc_b": jnp.zeros((cfg.gcn_num_classes,), jnp.float32),
    }


# ---------------------------------------------------------------------------
# model — a thin dispatcher over the execution engine
# ---------------------------------------------------------------------------

def forward(
    params: Dict[str, Any],
    x: jnp.ndarray,                       # (N, T, V, C)
    cfg: ModelConfig,
    plan: Optional[PrunePlan] = None,
    quant: bool = False,
    backend: Optional[str] = None,
    exec_plan=None,
    interpret: bool = True,
) -> jnp.ndarray:
    """Logits (N, num_classes).

    ``backend`` selects the engine implementation (``reference`` |
    ``pallas``); ``None`` falls back to ``cfg.gcn_backend``.  A prebuilt
    ``exec_plan`` (see ``engine.build_execution_plan``) skips plan
    compilation entirely — the serving hot path; otherwise the plan is
    compiled here from ``(params, plan, cfg)``, which for the reference
    backend stays traceable (so the differentiable train path is this same
    call).  Pallas plans must be compiled outside jit.
    """
    from repro.core.agcn import engine
    if exec_plan is not None:
        return engine.execute(exec_plan, x)
    name = backend or cfg.gcn_backend or "reference"
    ep = engine.build_execution_plan(
        params, cfg, plan, quant=quant, backend=name, interpret=interpret)
    return engine.execute(ep, x)


def init_stream(
    params: Dict[str, Any],
    cfg: ModelConfig,
    x_calib: jnp.ndarray,                 # (N, T, V, C) representative clip
    plan: Optional[PrunePlan] = None,
    quant: bool = False,
    backend: Optional[str] = None,
    exec_plan=None,
    interpret: bool = True,
):
    """State-init API for per-frame continual inference (engine streaming
    mode).  Returns ``(exec_plan, StreamState)``.

    ``x_calib`` fixes the stream's batch size and calibrates the frozen
    batch-norm statistics that make ``engine.step_frame`` reproduce the
    clip engine post-drain (the streaming correctness contract, locked in
    tests/test_streaming.py).  A prebuilt ``exec_plan`` skips plan
    compilation; otherwise one is compiled exactly as in :func:`forward`."""
    from repro.core.agcn import engine
    ep = exec_plan
    if ep is None:
        name = backend or cfg.gcn_backend or "reference"
        ep = engine.build_execution_plan(
            params, cfg, plan, quant=quant, backend=name, interpret=interpret)
    state = engine.init_stream_state(ep, x_calib.shape[0], x_calib=x_calib)
    return ep, state


def bone_stream(x: jnp.ndarray) -> jnp.ndarray:
    """Second stream of 2s-AGCN: bone vectors = joint − parent joint
    (the fixed NTU-25 skeleton; see :func:`bone_stream_parents` for any
    other topology)."""
    from repro.core.agcn.graph import NTU_EDGES
    out = jnp.zeros_like(x)
    for j, p in NTU_EDGES:
        out = out.at[..., j - 1, :].set(x[..., j - 1, :] - x[..., p - 1, :])
    return out


def bone_stream_parents(x: jnp.ndarray, parents) -> jnp.ndarray:
    """Topology-generic bone stream: one gather against a (V,) parent map
    (``GraphTopology.parents`` / ``plan.arrays["parents"]``).  Roots parent
    themselves, so their bone vector is zero — identical to
    :func:`bone_stream` on the NTU-25 map."""
    return x - jnp.take(x, jnp.asarray(parents, jnp.int32), axis=-2)


def two_stream_logits(params_joint, params_bone, x, cfg, plan=None,
                      quant=False, backend=None):
    """Ensemble of the joint and bone streams (the '2s' in 2s-AGCN)."""
    lj = forward(params_joint, x, cfg, plan, quant, backend=backend)
    lb = forward(params_bone, bone_stream(x), cfg, plan, quant,
                 backend=backend)
    return 0.5 * (lj + lb)


def feature_sparsity_per_block(params, x, cfg, plan=None) -> List[float]:
    """Post-ReLU sparsity per block output — drives RFC mini-bank sizing and
    the Drop-* channel schedules (paper Fig. 9, Table III)."""
    from repro.core.agcn import engine
    ep = engine.build_execution_plan(params, cfg, plan, backend="reference")
    return [float((h == 0).mean()) for h in engine.block_outputs(ep, x)]
