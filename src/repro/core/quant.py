"""Quantization (paper C5): Q8.8 fixed point (bit-faithful reproduction) and
an int8 weight-quantization path that is TPU-native (DESIGN.md §2).
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp


Q88_SCALE = 256.0          # 8 fractional bits
Q88_MAX = 32767.0 / Q88_SCALE
Q88_MIN = -32768.0 / Q88_SCALE


def quantize_q88(x: jnp.ndarray) -> jnp.ndarray:
    """Simulated Q8.8 fixed point: 8 integer + 8 fractional bits."""
    return jnp.clip(jnp.round(x * Q88_SCALE), -32768, 32767) / Q88_SCALE


def quantize_int8(w: jnp.ndarray, axis: int = -1) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-channel symmetric int8 quantization.  Returns (q, scale)."""
    amax = jnp.max(jnp.abs(w), axis=axis, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(w / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    """Inverse of :func:`quantize_int8`: q·scale back in the scale dtype."""
    return q.astype(scale.dtype) * scale


def int8_matmul(x: jnp.ndarray, q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    """x @ dequant(q) with the dequant folded after the matmul so the MXU
    sees int8 weights (XLA fuses the scale)."""
    y = jnp.einsum("...i,io->...o", x, q.astype(x.dtype))
    return y * scale.reshape(1, -1) if scale.ndim <= 1 else y * scale.T
