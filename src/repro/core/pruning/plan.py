"""Hybrid pruning plan (paper §IV) — dataflow reorganization (C1) +
coarse/fine temporal pruning (C2).

The plan is *static*: after pruning we know exactly which input channels of
each block's spatial conv survive.  On TPU we realise the skip as channel
**compaction** — gather the kept channels of both the feature and the weight
and run dense einsums on the smaller shapes (DESIGN.md §2).  The FLOPs
skipped are identical to the paper's element-skipping dataflow, but the MXU
sees dense tiles.

Key identities reproduced from the paper:
  * graph-skip efficiency  = fraction of graph-matmul work removed
    (73.20% for the paper's final model),
  * coarse temporal pruning rate = spatial channel-drop rate of the *next*
    block (Fig. 2 neighbour connection),
  * compression ratio = total params before / after (3.0×–8.4×).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.pruning.cavity import balance_stats, cavity_pattern, tile_pattern


@dataclasses.dataclass(frozen=True)
class BlockPrunePlan:
    """Static pruning decisions for one conv block."""

    kept_in: Tuple[int, ...]        # spatial-conv input channels kept (C1)
    kept_filters: Tuple[int, ...]   # temporal filters kept (C2 coarse,
                                    # = next block's kept_in, Fig. 2)
    tap_mask: np.ndarray            # (num_kept_filters, K) cavity mask (C2 fine)

    @property
    def in_keep_frac(self) -> float:
        """Kept fraction of this block's spatial input channels (C1)."""
        return len(self.kept_in) / max(1, self._cin)

    _cin: int = 0
    _cout: int = 0


@dataclasses.dataclass(frozen=True)
class PrunePlan:
    """The whole-model hybrid pruning plan: per-block C1/C2 decisions
    (``blocks``), the fine cavity pattern name (C2), and the C5 input-frame
    skip — everything ``engine.build_execution_plan`` compacts into an
    ExecutionPlan's gathers and packed weights."""

    blocks: Tuple[BlockPrunePlan, ...]
    cavity_name: str
    input_skip: int = 1

    def summary(self, channels: Sequence[int], in_channels: int,
                kv: int = 3, tkernel: int = 9, joints: int = 25) -> Dict:
        """Compression-ratio / skip-efficiency accounting (paper Fig. 8, §VI)."""
        dense_params = 0
        kept_params = 0
        dense_graph_flops = 0
        kept_graph_flops = 0
        cin = in_channels
        for b, plan in enumerate(self.blocks):
            cout = channels[b]
            # spatial: kv subsets of 1x1 convs (cin, cout)
            dense_params += kv * cin * cout
            kept_params += kv * len(plan.kept_in) * cout
            # graph matmul work ∝ number of input channels entering G·f
            dense_graph_flops += cin * joints * joints
            kept_graph_flops += len(plan.kept_in) * joints * joints
            # temporal: (cout filters) × (cout in-ch) × K taps
            dense_params += cout * cout * tkernel
            kept_params += int(plan.tap_mask.sum()) * cout
            cin = cout
        return {
            "compression_ratio": dense_params / max(1, kept_params),
            "graph_skip_efficiency": 1.0 - kept_graph_flops / max(1, dense_graph_flops),
            "param_reduction": 1.0 - kept_params / max(1, dense_params),
            "dense_params": dense_params,
            "kept_params": kept_params,
        }


def select_channels_by_magnitude(w: np.ndarray, keep_frac: float) -> Tuple[int, ...]:
    """C1 channel choice: keep input channels with the largest mean |W|
    (paper: 'cut off the input channels which have least averaging absolute
    value').  w: (K_v, C_in, C_out)."""
    cin = w.shape[1]
    keep = max(1, int(round(cin * keep_frac)))
    score = np.abs(w).mean(axis=(0, 2))
    kept = np.argsort(-score, kind="stable")[:keep]
    return tuple(sorted(int(i) for i in kept))


def build_prune_plan(
    spatial_weights: List[np.ndarray],
    channels: Sequence[int],
    keep_fracs: Sequence[float],
    cavity_name: str = "cav-70-1",
    tkernel: int = 9,
    input_skip: int = 1,
) -> PrunePlan:
    """Construct the full hybrid plan for a stack of conv blocks.

    spatial_weights[b]: (K_v, C_in_b, C_out_b) — used for magnitude selection.
    keep_fracs[b]: kept fraction of block b's spatial input channels
    (block 0 is never pruned — it has only 3 input channels, paper §VI-A).
    """
    nblocks = len(channels)
    assert len(spatial_weights) == nblocks and len(keep_fracs) == nblocks
    kept_ins: List[Tuple[int, ...]] = []
    for b in range(nblocks):
        if b == 0:
            kept_ins.append(tuple(range(spatial_weights[0].shape[1])))
        else:
            kept_ins.append(select_channels_by_magnitude(spatial_weights[b], keep_fracs[b]))

    pat = cavity_pattern(cavity_name, kernel=tkernel)
    blocks = []
    for b in range(nblocks):
        cout = channels[b]
        # Coarse: temporal filters of block b that feed pruned input channels
        # of block b+1 are dropped (Fig. 2).  Last block keeps all.
        kept_filters = kept_ins[b + 1] if b + 1 < nblocks else tuple(range(cout))
        tap = tile_pattern(pat, len(kept_filters))
        blocks.append(
            BlockPrunePlan(
                kept_in=kept_ins[b],
                kept_filters=kept_filters,
                tap_mask=tap,
                _cin=spatial_weights[b].shape[1],
                _cout=cout,
            )
        )
    return PrunePlan(blocks=tuple(blocks), cavity_name=cavity_name, input_skip=input_skip)


def drop_scheme(sparsities: Sequence[float], shift: float = 0.0) -> List[float]:
    """Channel keep-fractions from observed feature sparsity (paper Fig. 9):
    base scheme sets each block's drop rate ≈ its feature sparsity; Drop-2/3
    progressively raise compression by `shift`."""
    return [max(0.05, min(1.0, 1.0 - (s + shift))) for s in sparsities]


def unstructured_prune(w: np.ndarray, frac: float) -> np.ndarray:
    """Baseline: magnitude unstructured pruning (paper's comparison, Fig. 8)."""
    flat = np.abs(w).ravel()
    k = int(len(flat) * frac)
    if k == 0:
        return w.copy()
    thresh = np.partition(flat, k - 1)[k - 1]
    out = w.copy()
    out[np.abs(out) <= thresh] = 0.0
    return out


def cavity_report(name: str, tkernel: int = 9) -> Dict:
    """Balance statistics of a named cavity pattern (paper Fig. 10): kept
    fraction plus per-loop tap min/max — the tile-balance check."""
    return balance_stats(cavity_pattern(name, kernel=tkernel))


def plan_from_config(cfg) -> Optional[PrunePlan]:
    """Static plan from a ModelConfig (no weights needed — used by the
    dry-run, where parameters are abstract).  Channel *identity* does not
    affect FLOPs/bytes, so kept channels are simply the first ⌈frac·cin⌉;
    at deployment the magnitude-selected plan from build_prune_plan is a
    drop-in replacement with identical compiled structure."""
    if not cfg.prune_channel_fracs:
        return None
    channels = cfg.gcn_channels
    fracs = cfg.prune_channel_fracs
    assert len(fracs) == len(channels)
    pat = cavity_pattern(cfg.cavity_pattern or "none", kernel=cfg.gcn_tkernel)
    kept_ins = []
    cin = cfg.gcn_in_channels
    for b, cout in enumerate(channels):
        keep = cin if b == 0 else max(1, int(round(cin * fracs[b])))
        kept_ins.append(tuple(range(keep)))
        cin = cout
    blocks = []
    for b, cout in enumerate(channels):
        kept_filters = (
            kept_ins[b + 1] if b + 1 < len(channels) else tuple(range(cout))
        )
        blocks.append(BlockPrunePlan(
            kept_in=kept_ins[b],
            kept_filters=kept_filters,
            tap_mask=tile_pattern(pat, len(kept_filters)),
            _cin=cfg.gcn_in_channels if b == 0 else channels[b - 1],
            _cout=cout,
        ))
    return PrunePlan(blocks=tuple(blocks), cavity_name=cfg.cavity_pattern,
                     input_skip=cfg.input_skip)
