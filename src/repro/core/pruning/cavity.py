"""Fine-grained "cavity" pruning patterns for temporal filters (paper §IV-B).

A cavity pattern is a (loop, K) binary mask — ``loop`` recurring 9×1 kernels
(the paper uses loops of 8) applied cyclically across the temporal filters of
a block.  A zero tap means "do not sample this time offset" (Fig. 3).

Balanced patterns (variant 1) keep every tap position between floor and ceil
of the average count per loop, which is what makes the hardware (and, on TPU,
the SIMD lanes / MXU tiles) load-balanced; variant 2 patterns are the paper's
deliberately unbalanced baseline (Fig. 10: cav-70-2, cav-75-2).
"""
from __future__ import annotations

import numpy as np


def cavity_pattern(name: str, kernel: int = 9, loop: int = 8) -> np.ndarray:
    """Return mask of shape (loop, kernel), dtype bool.  True = kept.

    ``name`` is ``cav-<percent>-<variant>`` (paper's naming, Fig. 10):
    percent = pruned fraction of the loop×kernel grid, variant 1 balanced,
    variant 2 unbalanced.  ``"none"``/empty keeps everything.
    """
    if not name or name == "none":
        return np.ones((loop, kernel), dtype=bool)
    parts = name.split("-")
    if len(parts) != 3 or parts[0] != "cav":
        raise ValueError(f"bad cavity pattern name: {name!r}")
    percent, variant = int(parts[1]), int(parts[2])
    total = loop * kernel
    keep_total = total - int(round(total * percent / 100.0))
    if variant == 1:
        return _balanced(keep_total, kernel, loop)
    return _unbalanced(keep_total, kernel, loop)


def _balanced(keep_total: int, kernel: int, loop: int) -> np.ndarray:
    """Doubly-balanced assignment: per-tap-position (column) keep counts are
    exactly ⌊k/K⌋ or ⌈k/K⌉, and per-kernel (row) counts differ by at most 1
    (the paper: 'every position ... evenly kept by two or three times').

    Columns get exact quotas; each column then claims the rows with the
    lowest keep-count so far (ties broken by a rotating offset so kept taps
    spread across time offsets instead of clustering)."""
    mask = np.zeros((loop, kernel), dtype=bool)
    base, extra = divmod(keep_total, kernel)
    quotas = [base + (1 if c < extra else 0) for c in range(kernel)]
    row_count = np.zeros(loop, dtype=int)
    for c, q in enumerate(quotas):
        # rows sorted by (count, rotated index) — stable spread
        order = sorted(range(loop), key=lambda r: (row_count[r], (r - c) % loop))
        for r in order[:q]:
            mask[r, c] = True
            row_count[r] += 1
    return mask


def _unbalanced(keep_total: int, kernel: int, loop: int) -> np.ndarray:
    """Same keep rate but skewed per-position quotas (paper cav-*-2:
    'different lines are kept from one time to four times')."""
    base, extra = divmod(keep_total, kernel)
    quotas = [base + (1 if c < extra else 0) for c in range(kernel)]
    for c in range(0, kernel - 1, 2):               # shift odd -> even
        move = min(quotas[c + 1], loop - quotas[c], 2)
        quotas[c] += move
        quotas[c + 1] -= move
    mask = np.zeros((loop, kernel), dtype=bool)
    row_count = np.zeros(loop, dtype=int)
    for c, q in enumerate(quotas):
        order = sorted(range(loop), key=lambda r: (row_count[r], (r - c) % loop))
        for r in order[:q]:
            mask[r, c] = True
            row_count[r] += 1
    return mask


def tile_pattern(mask: np.ndarray, num_filters: int) -> np.ndarray:
    """Tile the (loop, K) pattern over ``num_filters`` filters -> (F, K)."""
    loop = mask.shape[0]
    reps = int(np.ceil(num_filters / loop))
    return np.tile(mask, (reps, 1))[:num_filters]


def balance_stats(mask: np.ndarray) -> dict:
    """Per-tap-position keep counts across the loop (paper's balance metric)."""
    col = mask.sum(axis=0)
    row = mask.sum(axis=1)
    return {
        "keep_frac": float(mask.mean()),
        "per_position_min": int(col.min()),
        "per_position_max": int(col.max()),
        "per_kernel_min": int(row.min()),
        "per_kernel_max": int(row.max()),
        "balanced": bool(col.max() - col.min() <= 1),
    }
