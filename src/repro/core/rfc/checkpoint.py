"""RFC-compressed activation checkpointing (beyond-paper application of C3).

For a squared-ReLU MLP  y = relu(x·wi)² · wo  the hidden activation h is
sparse (~50-60% zeros) and — because h = relu(z)² — the pre-activation is
recoverable as sqrt(h) wherever h > 0.  So saving h in the paper's RFC
bank/mini-bank format gives an *exact* backward pass:

    dwo = hᵀ·g          dh = g·woᵀ
    dz  = dh · 2·√h     (zero where h == 0, exactly relu's mask)
    dwi = xᵀ·dz         dx = dz·wiᵀ

with the stored bytes reduced by the activation sparsity (the paper's
35.93% BRAM saving, applied to the HBM activation-checkpoint footprint)
and no recompute of the up-projection — a third point on the usual
remat/save trade-off curve.

The jnp RFC codec here is the reference path; on TPU the Pallas
`rfc_pack` kernels fuse encode with the producing matmul.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.rfc.format import rfc_decode, rfc_encode


@jax.custom_vjp
def mlp_relu2_rfc(x: jnp.ndarray, wi: jnp.ndarray, wo: jnp.ndarray
                  ) -> jnp.ndarray:
    """y = relu(x·wi)² · wo with RFC-checkpointed hidden activations."""
    h = jnp.square(jax.nn.relu(x @ wi))
    return h @ wo


def _fwd(x, wi, wo):
    z = x @ wi
    h = jnp.square(jax.nn.relu(z))
    y = h @ wo
    vals, hot = rfc_encode(h, apply_relu=False)     # compressed residual
    return y, (x, vals, hot, wi, wo)


def _bwd(res, g):
    x, vals, hot, wi, wo = res
    h = rfc_decode(vals, hot)
    dwo = jnp.einsum("...f,...d->fd", h, g)
    dh = jnp.einsum("...d,fd->...f", g, wo)
    dz = dh * 2.0 * jnp.sqrt(h)                      # zero exactly off-mask
    dwi = jnp.einsum("...c,...f->cf", x, dz)
    dx = jnp.einsum("...f,cf->...c", dz, wi)
    return dx, dwi, dwo


mlp_relu2_rfc.defvjp(_fwd, _bwd)


def checkpoint_bytes(h: jnp.ndarray, bank: int = 16, minibank: int = 4
                     ) -> Tuple[int, int]:
    """(dense_bytes, rfc_bytes) for the stored hidden activation."""
    import numpy as np
    from repro.core.rfc.format import storage_cost
    _, hot = rfc_encode(h, apply_relu=False)
    c = storage_cost(np.asarray(hot) > 0, bank=bank, minibank=minibank,
                     elem_bits=8 * h.dtype.itemsize)
    return int(c["dense_bits"] // 8), int(c["rfc_bits"] // 8)
