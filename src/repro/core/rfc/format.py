"""RFC — Runtime Sparse Feature Compress format (paper §V-C, Fig. 7).

A feature vector is split along channels into *banks* of width 16.  Each bank
is ReLU'd, its non-zero elements are compacted to the front (the paper packs
to the "higher bits" of the stream — same thing), a 16-bit *hot code* records
which positions were non-zero, and an *mbhot* code records how many 4-deep
*mini-banks* the compacted data occupies.  Loads/stores stay aligned — no
CSC-style serial decode.

This module is the pure-jnp reference (also the oracle for the Pallas
kernels in ``repro.kernels``) plus the storage-cost model used for the
paper's Fig. 11 comparison (dense vs CSC vs RFC).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def rfc_encode(x: jnp.ndarray, bank: int = 16, apply_relu: bool = True
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Encode the last axis of ``x`` bank-by-bank.

    Returns (values, hot):
      values: same shape as x — each bank's non-zeros compacted to the front,
              zero-padded (mini-bank truncation is a *storage* decision,
              handled by the cost model / kernel, not by the math).
      hot:    (..., C//bank, bank) bool — the per-bank hot code.
    """
    if x.shape[-1] % bank:
        raise ValueError(f"channels {x.shape[-1]} not divisible by bank {bank}")
    if apply_relu:
        x = jnp.maximum(x, 0)
    banks = x.reshape(*x.shape[:-1], x.shape[-1] // bank, bank)
    hot = banks != 0
    # stable partition: non-zeros first, preserving order (matches hardware
    # gather-at-higher-bits behaviour)
    order = jnp.argsort(~hot, axis=-1, stable=True)
    values = jnp.take_along_axis(banks, order, axis=-1)
    return values.reshape(x.shape), hot


def rfc_decode(values: jnp.ndarray, hot: jnp.ndarray, bank: int = 16) -> jnp.ndarray:
    """Inverse of :func:`rfc_encode` — scatter compacted values back."""
    vb = values.reshape(*values.shape[:-1], values.shape[-1] // bank, bank)
    # position of each original slot inside the compacted stream
    pos = jnp.cumsum(hot.astype(jnp.int32), axis=-1) - 1
    gathered = jnp.take_along_axis(vb, jnp.maximum(pos, 0), axis=-1)
    out = jnp.where(hot, gathered, 0)
    return out.reshape(values.shape)


def mbhot(hot: jnp.ndarray, minibank: int = 4) -> jnp.ndarray:
    """Number of mini-banks each bank occupies: ceil(nnz / minibank)."""
    nnz = hot.sum(axis=-1)
    return (nnz + minibank - 1) // minibank


# ---------------------------------------------------------------------------
# Storage-cost model (paper Fig. 11): bytes to hold one layer's activations.
# ---------------------------------------------------------------------------

def storage_cost(hot: np.ndarray, bank: int = 16, minibank: int = 4,
                 elem_bits: int = 16) -> Dict[str, float]:
    """Compare dense / CSC / RFC storage for activations with hot-mask ``hot``
    of shape (..., n_banks, bank)."""
    hot = np.asarray(hot)
    n_elems = hot.size
    nnz = int(hot.sum())
    n_banks = n_elems // bank

    dense_bits = n_elems * elem_bits
    # CSC: values + row indices (log2(bank-dim) won't cut it for a real
    # vector; the paper compares against per-element index + column pointers)
    idx_bits = 8
    csc_bits = nnz * (elem_bits + idx_bits) + (n_elems // bank) * 16
    # RFC: mini-bank-rounded values + 16-bit hot + mbhot per bank
    per_bank_nnz = hot.reshape(-1, bank).sum(axis=1)
    mini_used = np.ceil(per_bank_nnz / minibank)
    rfc_bits = int(mini_used.sum()) * minibank * elem_bits + n_banks * (bank + 4)

    return {
        "dense_bits": float(dense_bits),
        "csc_bits": float(csc_bits),
        "rfc_bits": float(rfc_bits),
        "rfc_vs_dense_reduction": 1.0 - rfc_bits / dense_bits,
        "csc_vs_dense_reduction": 1.0 - csc_bits / dense_bits,
        "sparsity": 1.0 - nnz / n_elems,
    }


def minibank_depths(sparsity_quartiles: Tuple[float, float, float, float],
                    total_depth: int, minibank: int = 4) -> Tuple[int, ...]:
    """Paper §V-C: size mini-bank depths from the offline sparsity
    distribution (fraction of vectors per sparsity quartile I..IV: 75-100%,
    50-75%, 25-50%, 0-25% sparse -> needing 1..4 mini-banks)."""
    q = np.asarray(sparsity_quartiles, dtype=np.float64)
    q = q / q.sum()
    # mini-bank m is used by vectors needing >= m+1 mini-banks
    need = np.cumsum(q[::-1])[::-1]  # fraction needing >= k+1 banks, k=0..3
    depths = np.ceil(need * total_depth).astype(int)
    return tuple(int(d) for d in depths)


def expected_sparsity_categories(hot: np.ndarray, bank: int = 16) -> Tuple[float, ...]:
    """Bucket bank vectors into the paper's four sparsity categories
    (Table III): I 75-100%, II 50-75%, III 25-50%, IV 0-25% sparse."""
    s = 1.0 - np.asarray(hot).reshape(-1, bank).mean(axis=1)
    return (
        float((s >= 0.75).mean()),
        float(((s >= 0.5) & (s < 0.75)).mean()),
        float(((s >= 0.25) & (s < 0.5)).mean()),
        float((s < 0.25).mean()),
    )
