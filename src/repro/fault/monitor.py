"""Fault-tolerance runtime pieces sized for 1000+ nodes:

  * HeartbeatMonitor — per-host liveness with grace windows; drives restart
    and elastic re-mesh decisions.
  * StragglerDetector — per-step duration statistics (EWMA + MAD); flags
    hosts whose step times exceed median + k·MAD, the standard mitigation
    trigger (re-shard away / preempt).
  * Both are pure-python state machines over injected timestamps so they are
    fully unit-testable without a cluster; launch/train.py wires them to
    wall-clock time.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Set


@dataclasses.dataclass
class HeartbeatMonitor:
    num_hosts: int
    timeout_s: float = 60.0
    _last: Dict[int, float] = dataclasses.field(default_factory=dict)

    def beat(self, host: int, now: Optional[float] = None):
        self._last[host] = time.monotonic() if now is None else now

    def dead_hosts(self, now: Optional[float] = None) -> List[int]:
        now = time.monotonic() if now is None else now
        return [
            h for h in range(self.num_hosts)
            if now - self._last.get(h, -1e18) > self.timeout_s
        ]

    def healthy(self, now: Optional[float] = None) -> bool:
        return not self.dead_hosts(now)


@dataclasses.dataclass
class StragglerDetector:
    """Median + k·MAD step-time outlier detection with EWMA smoothing."""
    num_hosts: int
    k: float = 4.0
    ewma: float = 0.3
    _t: Dict[int, float] = dataclasses.field(default_factory=dict)

    def record(self, host: int, step_seconds: float):
        prev = self._t.get(host)
        self._t[host] = (
            step_seconds if prev is None
            else (1 - self.ewma) * prev + self.ewma * step_seconds
        )

    def stragglers(self) -> Set[int]:
        if len(self._t) < max(3, self.num_hosts // 2):
            return set()
        vals = sorted(self._t.values())
        med = vals[len(vals) // 2]
        mad = sorted(abs(v - med) for v in vals)[len(vals) // 2]
        cut = med + self.k * max(mad, 0.05 * med)
        return {h for h, v in self._t.items() if v > cut}


@dataclasses.dataclass
class FaultPolicy:
    """What the trainer does when the monitors fire (see launch/train.py):
       dead host      -> restore latest checkpoint on the survivor mesh
                         (fault/elastic.py plans the re-sharding)
       straggler      -> log + (on TPU) request scheduler swap; training
                         continues — data parallel work is re-balanced by
                         shrinking that host's shard in the next epoch.
    """
    checkpoint_every: int = 100
    max_restarts: int = 10
    elastic: bool = True
