"""Elastic re-meshing: when hosts die mid-run, plan a smaller mesh, re-derive
every sharding for it, and restore the latest checkpoint onto it.

The planner keeps the model axis intact (tensor-parallel degree is baked
into layer math performance, and all our dims divide 16) and shrinks the
data axis to the largest power-of-two that the surviving chip count
supports — the standard elastic-DP policy.  Global batch is preserved by
raising gradient-accumulation microbatches, so optimization is bit-wise
comparable before/after the shrink.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax

from repro.common.config import TrainConfig


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    data: int
    model: int
    pods: int
    microbatch_multiplier: int

    @property
    def chips(self) -> int:
        return self.data * self.model * self.pods


def plan_degraded_mesh(
    alive_chips: int,
    *,
    model: int = 16,
    old_data: int = 16,
    pods: int = 1,
) -> Optional[MeshPlan]:
    """Largest power-of-two data axis that fits the survivors (model axis
    fixed).  Returns None if fewer than one model group survives."""
    if alive_chips < model:
        return None
    data = 1
    while data * 2 * model * pods <= alive_chips and data * 2 <= old_data:
        data *= 2
    return MeshPlan(
        data=data, model=model, pods=pods,
        microbatch_multiplier=old_data // data,
    )


def degraded_mesh(plan: MeshPlan):
    shape = ((plan.pods, plan.data, plan.model) if plan.pods > 1
             else (plan.data, plan.model))
    axes = ("pod", "data", "model") if plan.pods > 1 else ("data", "model")
    return jax.make_mesh(shape, axes)


def adjust_train_config(tcfg: TrainConfig, plan: MeshPlan) -> TrainConfig:
    return dataclasses.replace(
        tcfg, microbatches=tcfg.microbatches * plan.microbatch_multiplier
    )


def reshard_checkpoint(ckpt_dir: str, step: int, like, mesh, shardings):
    """Restore a checkpoint saved under any mesh onto a new mesh: leaves are
    stored unsharded (per-leaf .npy), so restore + device_put with the new
    shardings IS the reshard."""
    from repro.checkpoint.store import restore
    host_tree = restore(ckpt_dir, step, like)
    return jax.device_put(host_tree, shardings)
