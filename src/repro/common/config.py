"""Shared configuration dataclasses for the framework.

A single ``ModelConfig`` covers every architecture family supported by the
framework (dense decoder LMs, MoE, SSM, hybrid, encoder-decoder audio, VLM,
and the paper's skeleton-GCN).  Family-specific fields default to "off".

Configs are frozen dataclasses so they can be hashed and closed over by
jit'd step functions without retracing hazards.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


# The serve driver's --batch 0 family defaults, resolved in ONE place
# (ModelConfig.serve_batch) — subcommand code must never hardcode its own
# fallback, so `serve clip` / `serve stream` / legacy flag spellings can
# not skew apart.  Keyed "<family>:<mode>", with a global fallback.
SERVE_BATCH_DEFAULTS = {
    "gcn:clip": 8,       # batched two-stream clip inference
    "gcn:stream": 4,     # lockstep per-frame streaming
    "default": 4,        # LM families (decode batch)
}


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture description.

    ``family`` selects the model builder:
      dense   — decoder-only transformer (GQA, optional SWA / local:global)
      moe     — decoder-only transformer with MoE FFN
      ssm     — xLSTM-style (mLSTM + sLSTM blocks)
      hybrid  — Mamba2 backbone + shared attention blocks (Zamba2)
      audio   — encoder-decoder transformer, stub conv frontend (Whisper)
      vlm     — decoder transformer consuming mixed text+patch embeddings
      gcn     — the paper's 2s-AGCN skeleton model
    """

    name: str
    family: str
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                      # 0 -> d_model // num_heads

    # --- attention pattern ---
    window_size: int = 0                   # >0 -> sliding-window attention
    local_global_ratio: int = 0            # n -> n local layers per 1 global
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    act: str = "silu"                      # silu | gelu | relu2

    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0
    moe_capacity_factor: float = 1.25

    # --- SSM / hybrid ---
    ssm_state: int = 0                     # mamba2 state dim per head
    ssm_conv: int = 4                      # short conv width
    slstm_every: int = 0                   # xlstm: 1 sLSTM per this many blocks
    shared_attn_every: int = 0             # zamba2: shared attn block period
    ssm_expand: int = 2

    # --- encoder-decoder (audio) ---
    encoder_layers: int = 0
    encoder_frames: int = 1500             # whisper stub frontend output length

    # --- vlm ---
    num_image_tokens: int = 0              # patch embeddings per sample (stub)

    # --- gcn (2s-AGCN) ---
    gcn_joints: int = 25
    gcn_frames: int = 300
    gcn_persons: int = 2
    gcn_in_channels: int = 3
    gcn_num_classes: int = 60
    gcn_channels: Tuple[int, ...] = ()     # per-block output channels
    gcn_strides: Tuple[int, ...] = ()
    gcn_kv: int = 3                        # K_v neighbour subsets
    gcn_tkernel: int = 9                   # temporal kernel size
    use_ck: bool = False                   # windowed data-dependent C_k graph

    # --- paper technique knobs (first-class features) ---
    prune_channel_fracs: Tuple[float, ...] = ()  # per-block kept fraction (C1)
    cavity_pattern: str = ""               # e.g. "cav-70-1" (C2)
    input_skip: int = 1                    # keep 1 of every `input_skip` frames
    rfc_bank: int = 16                     # RFC bank width (C3)
    rfc_minibank: int = 4                  # RFC mini-bank depth granularity
    gcn_stream_pool: int = 0               # streaming logit pool: 0 = running
                                           # mean over every emitted frame
                                           # (clip-parity contract); W > 0 =
                                           # sliding window of the last W
                                           # emitted frames (live streams
                                           # where the action changes)
    gcn_backend: str = "reference"         # engine backend: reference | pallas.
                                           # Default for eager forward() calls;
                                           # jitted steps (train/loss_fn) always
                                           # run the differentiable reference —
                                           # pallas rides prebuilt ExecutionPlans
                                           # (steps.make_gcn_infer_step, serve)

    # --- distribution hints ---
    scan_group: int = 1                    # layers per scan body group
    remat: str = "full"                    # full | dots | none
    sharding: str = "2d"                   # 2d (TP+FSDP) | dp_only (small
                                           # models: replicate weights, use
                                           # the model axis as extra DP)
    train_microbatches: int = 2            # grad-accum steps so activation
                                           # temp fits 16 GB/chip HBM

    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    def serve_batch(self, mode: str = "", requested: int = 0) -> int:
        """Resolve the serve driver's batch size in one place.

        ``requested`` (an explicit ``--batch N``) always wins; ``0`` falls
        back to the ``SERVE_BATCH_DEFAULTS`` entry for ``(family, mode)``
        — e.g. ``gcn:clip`` → 8, ``gcn:stream`` → 4 — then to the global
        default.  Every serve subcommand routes through here so defaults
        cannot skew across CLI spellings."""
        if requested:
            return requested
        return SERVE_BATCH_DEFAULTS.get(
            f"{self.family}:{mode}", SERVE_BATCH_DEFAULTS["default"])

    # ---- derived sizes ----
    @property
    def padded_vocab(self) -> int:
        return _round_up(self.vocab_size, 256)

    @property
    def padded_experts(self) -> int:
        """Experts padded so the mesh model axis divides them (see DESIGN §5)."""
        if self.num_experts == 0:
            return 0
        return _round_up(self.num_experts, 16)

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    def param_count_estimate(self) -> int:
        """Analytic parameter count (used for 6·N·D model FLOPs)."""
        if self.family == "gcn":
            total = 0
            cin = self.gcn_in_channels
            for cout in self.gcn_channels:
                total += self.gcn_kv * cin * cout          # spatial 1x1 per subset
                total += cout * cout * self.gcn_tkernel    # temporal 9x1
                total += self.gcn_kv * self.gcn_joints**2  # B_k graphs
                cin = cout
            total += cin * self.gcn_num_classes
            return total
        d, L = self.d_model, self.num_layers
        attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        if self.family == "moe":
            ffn = 3 * d * self.moe_d_ff * self.num_experts + d * self.num_experts
        elif self.family == "ssm":
            inner = self.ssm_expand * d
            ffn = 0
            attn = 2 * d * inner + inner * d + inner * d  # mLSTM projections (approx)
        elif self.family == "hybrid":
            inner = self.ssm_expand * d
            ffn = d * self.d_ff * 3 // max(1, self.shared_attn_every)
            attn = 2 * d * inner + inner * d
        else:
            ffn = 3 * d * self.d_ff if self.act in ("silu", "gelu") else 2 * d * self.d_ff
        emb = self.padded_vocab * d
        enc = 0
        if self.encoder_layers:
            enc = self.encoder_layers * (attn + 2 * d * self.d_ff)
        return L * (attn + ffn) + emb + enc

    def active_param_count_estimate(self) -> int:
        """Active params per token (MoE uses top-k experts only)."""
        if self.family != "moe":
            return self.param_count_estimate()
        d, L = self.d_model, self.num_layers
        attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        ffn = 3 * d * self.moe_d_ff * self.experts_per_token
        return L * (attn + ffn) + self.padded_vocab * d


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell (arch × shape makes a dry-run cell)."""

    name: str                # train_4k | prefill_32k | decode_32k | long_500k | gcn_*
    kind: str                # train | prefill | decode
    seq_len: int
    global_batch: int

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}

# GCN (paper) shapes: batch of skeleton clips (N, C, T, V, M).
GCN_SHAPES = {
    "gcn_train": ShapeConfig("gcn_train", "train", 300, 512),
    "gcn_infer": ShapeConfig("gcn_infer", "prefill", 300, 2048),
}


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1_000
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    grad_clip: float = 1.0
    microbatches: int = 1
    seed: int = 0
    checkpoint_every: int = 100
    checkpoint_dir: str = "/tmp/repro_ckpt"
    dtype: str = "bfloat16"
    grad_compression: str = "none"   # none | bf16 — compress the gradients
                                     # before the data-parallel sync (halves
                                     # DP collective bytes; moments stay f32)
