from repro.common.config import ModelConfig, ShapeConfig, TrainConfig
from repro.common.tree import param_count, tree_bytes

__all__ = ["ModelConfig", "ShapeConfig", "TrainConfig", "param_count", "tree_bytes"]
