"""Small pytree utilities used across the framework."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def param_count(tree) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(tree))


def tree_bytes(tree) -> int:
    return sum(
        int(np.prod(x.shape)) * jnp.dtype(x.dtype).itemsize
        for x in jax.tree_util.tree_leaves(tree)
    )


def tree_norm(tree):
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def cast_tree(tree, dtype):
    return jax.tree_util.tree_map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x, tree
    )
