"""Step-function factories: train_step (fwd+bwd+AdamW, optional gradient
accumulation over microbatches) and serve_step (one decode token against a
KV cache, cache donated)."""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.common.config import ModelConfig, TrainConfig
from repro.models import registry
from repro.optim import adamw


def make_loss_fn(cfg: ModelConfig) -> Callable:
    def loss(params, batch):
        return registry.loss_fn(params, batch, cfg)
    return loss


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig,
                    grad_shardings=None) -> Callable:
    """grad_shardings: optional sharding tree applied to the gradients before
    the optimizer update — lets XLA reduce-scatter the data-parallel grad
    sync straight into the (2D-sharded) moment update instead of
    all-reducing full gradients (ZeRO-2).

    When ``cfg.use_ck`` is set the loss differentiates through the
    windowed C_k similarity graph (``adaptive.clip_windowed_ck`` in the
    model forward), so the per-block theta/phi projections train jointly
    with the conv weights — no separate step is needed for the adaptive
    graph."""
    loss_fn = make_loss_fn(cfg)
    nmb = max(1, tcfg.microbatches)

    def train_step(params, opt_state, batch):
        if nmb == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        else:
            def mb(carry, mb_batch):
                gacc, lacc = carry
                (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mb_batch)
                gacc = jax.tree_util.tree_map(jnp.add, gacc, g)
                if grad_shardings is not None:
                    # keep the accumulator 2D-sharded: each microbatch's
                    # grad sync lowers as a reduce-scatter into the shard
                    gacc = jax.lax.with_sharding_constraint(
                        gacc, grad_shardings)
                return (gacc, lacc + l), None

            split = jax.tree_util.tree_map(
                lambda x: x.reshape(nmb, x.shape[0] // nmb, *x.shape[1:]),
                batch,
            )
            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            if grad_shardings is not None:
                zeros = jax.lax.with_sharding_constraint(zeros, grad_shardings)
            (grads, loss), _ = jax.lax.scan(mb, (zeros, jnp.zeros(())), split)
            grads = jax.tree_util.tree_map(lambda g: g / nmb, grads)
            loss = loss / nmb
            metrics = {"loss": loss}
        if tcfg.grad_compression == "bf16":
            # compress before the DP sync: the reduce happens on bf16
            # payloads (half the collective bytes); AdamW accumulates its
            # moments in f32 regardless
            grads = jax.tree_util.tree_map(
                lambda g: g.astype(jnp.bfloat16), grads)
        if grad_shardings is not None:
            grads = jax.lax.with_sharding_constraint(grads, grad_shardings)
        params, opt_state, opt_metrics = adamw.update(
            params, grads, opt_state, tcfg)
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        return params, opt_state, metrics

    return train_step


def _gcn_bone_fn(plans) -> Callable:
    """Bone transform for the ensemble's second stream: the plan's own
    (V,) parent map when present (any topology — the map rides as a plan
    leaf, so no retrace), else the fixed NTU-25 :func:`bone_stream`."""
    from repro.core.agcn.model import bone_stream, bone_stream_parents

    parents = plans[1].arrays.get("parents") if len(plans) > 1 else None
    if parents is None:
        return bone_stream
    return lambda x: bone_stream_parents(x, parents[: x.shape[-2]])


def make_gcn_infer_step(cfg: ModelConfig) -> Callable:
    """Batched GCN inference step over prebuilt ExecutionPlans.

    Returns ``step(plans, x) -> logits`` where ``plans`` is a tuple of one
    (joint) or two (joint, bone) engine ExecutionPlans.  The plans ride as
    pytree *arguments*, so the jit cache is keyed on their shapes/static
    metadata — rebuilding an identical plan never retraces, and no packing
    happens inside the step (engine invariant, tested in test_engine.py).
    """
    from repro.core.agcn import engine

    def infer_step(plans, x):
        logits = engine.execute(plans[0], x)
        if len(plans) > 1:
            logits = 0.5 * (logits + engine.execute(
                plans[1], _gcn_bone_fn(plans)(x)))
        return logits

    return infer_step


def make_gcn_stream_step(cfg: ModelConfig) -> Callable:
    """Per-frame continual-inference step over prebuilt ExecutionPlans.

    Returns ``step(plans, states, frame, valid=True) -> (states, logits)``
    where ``plans``/``states`` are matched tuples of one (joint) or two
    (joint, bone) engine ExecutionPlans and StreamStates, and ``frame`` is
    one raw (N, V, C) skeleton frame.  The bone transform is frame-local
    (joint − parent joint), so the two-stream ensemble streams too.  Like
    the clip step, everything rides as pytree arguments: one compilation
    per plan pair serves the whole stream, and ``valid=False`` drains the
    per-block latency after the clip ends (engine.stream_flush_frames)."""
    from repro.core.agcn import engine

    def stream_step(plans, states, frame, valid=True):
        s0, logits = engine.step_frame(plans[0], states[0], frame,
                                       valid=valid)
        if len(plans) > 1:
            s1, lb = engine.step_frame(plans[1], states[1],
                                       _gcn_bone_fn(plans)(frame),
                                       valid=valid)
            return (s0, s1), 0.5 * (logits + lb)
        return (s0,), logits

    return stream_step


def make_gcn_slab_step(cfg: ModelConfig) -> Callable:
    """Multi-session slab step over prebuilt ExecutionPlans.

    Returns ``step(plans, slabs, frames, valid, reset, hold=None) ->
    (slabs, logits)`` — the scheduler-tick form of
    :func:`make_gcn_stream_step`: ``frames`` is one raw (S, V, C) frame per
    slab slot, ``valid`` (S,) marks slots feeding real clip frames (False =
    flush drain or free slot), ``reset`` (S,) zeroes this tick's admissions
    before the frame lands (engine.reset_slots — a traced mask, so
    admissions never retrace), and ``hold`` (S,) freezes starved open
    sessions in place (engine.step_frames hold).  Both ensemble streams
    (joint + bone) share the same slot schedule; the host-side
    admission/eviction logic lives in ``repro.serving``.

    ``stats`` (keyword, optional) is a per-stream tuple of frozen BN
    statistics overriding each slab's own calibration for this tick — the
    multi-topology service's per-skeleton dispatch; ``None`` keeps the
    slabs' stats (single-topology path, unchanged)."""
    from repro.core.agcn import engine

    def slab_step(plans, slabs, frames, valid, reset, hold=None, stats=None):
        st = stats or (None,) * len(plans)
        s0, logits = engine.step_frames(plans[0], slabs[0], frames, valid,
                                        reset, hold, bn_stats=st[0])
        if len(plans) > 1:
            s1, lb = engine.step_frames(plans[1], slabs[1],
                                        _gcn_bone_fn(plans)(frames), valid,
                                        reset, hold, bn_stats=st[1])
            return (s0, s1), 0.5 * (logits + lb)
        return (s0,), logits

    return slab_step


def make_gcn_fused_tick(cfg: ModelConfig) -> Callable:
    """One-dispatch multi-session serving tick over prebuilt ExecutionPlans.

    Returns ``tick(plans, slabs, frames, valid, reset, hold, snap_order,
    rest_order, rings) -> (slabs, logits, rings)`` — the fused form of
    :func:`make_gcn_slab_step`: the tick's snapshot gathers, restore
    scatters, admission resets, hold masking and the slab step execute as
    a single jitted call per ensemble stream (engine.fused_tick), with
    the snapshot captures living in preallocated on-device rings (one per
    stream, ``engine.init_snapshot_ring``).  ``snap_order``/``rest_order``
    are fixed-shape (E, 2) sentinel-padded event buffers shared by both
    ensemble streams (joint + bone ride the same slot schedule).  Jit it
    with ``donate_argnums=(1, 8)`` so the slab and ring pytrees update in
    place; the caller must never re-read the donated inputs.  ``stats``
    (keyword — kwargs are never donated) mirrors
    :func:`make_gcn_slab_step`'s per-stream BN-stats override."""
    from repro.core.agcn import engine

    def fused_tick(plans, slabs, frames, valid, reset, hold,
                   snap_order, rest_order, rings, stats=None):
        st = stats or (None,) * len(plans)
        s0, logits, r0 = engine.fused_tick(
            plans[0], slabs[0], frames, valid, reset, hold,
            snap_order, rest_order, rings[0], bn_stats=st[0])
        if len(plans) > 1:
            s1, lb, r1 = engine.fused_tick(
                plans[1], slabs[1], _gcn_bone_fn(plans)(frames), valid,
                reset, hold, snap_order, rest_order, rings[1],
                bn_stats=st[1])
            return (s0, s1), 0.5 * (logits + lb), (r0, r1)
        return (s0,), logits, (r0,)

    return fused_tick


def make_serve_step(cfg: ModelConfig) -> Callable:
    def serve_step(params, cache, batch):
        logits, new_cache = registry.serve_fn(params, batch, cache, cfg)
        # greedy next token (sampling handled by the serving loop)
        next_tok = jnp.argmax(logits[:, -1, : cfg.padded_vocab], axis=-1)
        return next_tok.astype(jnp.int32), new_cache

    return serve_step


def make_prefill_step(cfg: ModelConfig) -> Callable:
    """Forward pass producing logits only (the prefill_32k cells)."""
    def prefill_step(params, batch):
        loss, metrics = registry.loss_fn(params, batch, cfg, inference=True)
        return metrics

    return prefill_step
