"""Render the checked-in BENCH_*.json artifacts as the README's markdown
tables (stdlib only).

    python tools/bench_tables.py [BENCH_kernels_bench.json ...]

The README's benchmark section is this script's output pasted in — when
the artifacts are regenerated (``python -m benchmarks.run --only <mod>``),
re-run this and refresh the tables so prose never drifts from the numbers.
Rows carry whatever caveat the benchmark emitted (the checked-in artifacts
come from ``--smoke`` runs: one timed iteration including compile,
interpret-mode CPU — structure, not TPU wall time).
"""
from __future__ import annotations

import json
import pathlib
import sys

DEFAULT = ["BENCH_kernels_bench.json", "BENCH_throughput.json",
           "BENCH_sessions.json"]


def render(path: pathlib.Path) -> str:
    rows = json.load(open(path))
    out = [f"### `{path.name}`", "",
           "| row | µs/call | derived |", "|---|---:|---|"]
    for r in rows:
        if isinstance(r, dict) and "name" in r:
            us = r.get("us_per_call", 0.0)
            out.append(f"| `{r['name']}` | {us:,.0f} | {r.get('derived', '')} |")
        else:  # sessions rows are flat metric dicts, one per (backend,
               # slots, qos, capacity, load, mesh, replicas) — the merge key
            qos = r.get("qos", "fifo")
            label = f"sessions/{r['backend']}/{qos}"
            if r.get("policy", "demand") != "demand":
                label += f"/{r['policy']}"
            if r.get("capacity", "fixed") != "fixed":
                label += f"/{r['capacity']}"
            if r.get("load", "poisson") != "poisson":
                # trace replays name the trace; synthetic loads name the shape
                label += f"[{r['trace'] or r['load']}]" if r.get("trace") \
                    else f"[{r['load']}]"
            if r.get("mesh", 1) > 1:
                label += f"/mesh{r['mesh']}"
            if r.get("replicas", 1) > 1:
                label += f"/x{r['replicas']}"
            # adaptive-streaming axes: absent on legacy rows (= off)
            if r.get("ck", False):
                label += "/+ck"
            if r.get("saliency", 0):
                label += f"/sal{r['saliency']}"
            extra = ""
            if r.get("saliency", 0):
                extra += (f", skip {r.get('skip_rate', 0)*100:.0f}% "
                          f"({r.get('frames_skipped', 0)} frames)")
            if r.get("mesh", 1) > 1:
                extra += (f", collective "
                          f"{r.get('collective_ms_per_tick', 0):.1f}ms/tick")
            if r.get("replicas", 1) > 1:
                extra += f", {r.get('rebalances', 0)} rebalances"
            if r.get("preemptions"):
                extra = (f", preempt/restore "
                         f"{r['preemptions']}/{r.get('restores', 0)}")
            if r.get("deadline_missed"):
                extra += (f", missed {r['deadline_missed']} "
                          f"({r.get('deadline_miss_rate', 0)*100:.0f}%)")
            if r.get("migrations"):
                extra += (f", {r.get('migrations_grow', 0)} grow / "
                          f"{r.get('migrations_shrink', 0)} shrink "
                          f"@ {r.get('migration_ms_mean', 0):.1f}ms")
            hp = r.get("latency_ms_by_priority", {}).get("1")
            if r.get("trace") and hp:  # the A/B headline number
                extra += (f", hp first-logit p99 "
                          f"{hp['first_logit_p99_ticks']:.0f} ticks")
            if r.get("policy") == "slo":
                extra += (f", shed {r.get('sessions_rejected', 0)} rej / "
                          f"{r.get('sessions_degraded', 0)} deg")
            if "wall_host_s" in r:   # one-dispatch tick rows split the wall
                extra += (f", wall {r['wall_host_s']:.2f}s host + "
                          f"{r['wall_device_s']:.2f}s device "
                          f"({r.get('tick_path', 'fused')})")
            out.append(
                f"| `{label}` | — | "
                f"{r['sessions']} sessions / {r['slots']} slots, "
                f"{r['frames_per_s']:.1f} frames/s, "
                f"occupancy(time-weighted) {r['occupancy']*100:.0f}%, "
                f"p50/p99 {r['latency_ms_p50']:.0f}/"
                f"{r['latency_ms_p99']:.0f}ms{extra} |")
    return "\n".join(out) + "\n"


def main() -> None:
    """Print one markdown table per artifact (missing files are skipped)."""
    paths = [pathlib.Path(p) for p in (sys.argv[1:] or DEFAULT)]
    for p in paths:
        if p.exists():
            print(render(p))


if __name__ == "__main__":
    main()
