#!/usr/bin/env python
"""Regenerate the golden outcome locks for the checked-in traces.

Replays ``tests/data/traces/smoke.json`` through the (qos × policy)
matrix on the reference backend and writes the scheduler-tick-level
outcome summary each cell must reproduce — the per-tick outcome log's
digest plus the admission/preemption/shed/miss counters, the tier walk
and the per-class first-logit percentiles — to
``tests/data/traces/golden_smoke.json``.

Run only when the traces (tools/gen_traces.py) or the scheduler's tick
semantics *intentionally* change; the golden tests
(tests/test_traces_golden.py) exist to make unintentional drift loud.

A second lock file, ``golden_saliency.json``, replays the same trace with
the temporal-attention saliency gate on (``saliency_thresh``) and pins the
gated outcome digests plus the skip counters — the determinism half of
the adaptive-streaming acceptance (tests/test_saliency.py).  Pass an
argument to regenerate just one lock:

    JAX_PLATFORMS=cpu PYTHONPATH=src python tools/gen_golden_outcomes.py
    JAX_PLATFORMS=cpu PYTHONPATH=src python tools/gen_golden_outcomes.py saliency
"""
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.core.agcn import engine  # noqa: E402
from repro.core.agcn import model as M  # noqa: E402
from repro.core.pruning.plan import build_prune_plan  # noqa: E402
from repro.serving.slo import SloConfig  # noqa: E402
from repro.serving.traffic import Trace, outcome_digest, replay  # noqa: E402

DATA_DIR = os.path.join(os.path.dirname(__file__), "..", "tests", "data",
                        "traces")

# one SloConfig for every golden slo cell — 45 ticks sits just above the
# pipeline's 41-tick first-logit floor, so the smoke burst breaches while
# its tail is still arriving and the shed path actually fires (the tests
# assert rejections > 0; a slack bound would let every arrival land
# before shedding engages).  recover_patience no longer has to paper over
# the admitted-but-unlatched blind spot (the controller now sees in-flight
# committed latencies directly), so it sits at the no-thrash minimum
GOLDEN_SLO = dict(target_p99_ticks=45, window=16, breach_patience=2,
                  recover_patience=4, shed_mode="reject")
GOLDEN_TIERS = (2, 4)

CELLS = [(qos, policy) for qos in ("fifo", "preempt", "deadline")
         for policy in ("demand", "slo")] + [("fifo", "slo-degrade")]

# saliency lock: same trace, gate on — fifo covers the plain feed path,
# preempt covers saliency state riding snapshot/requeue
SALIENCY_THRESH = 1.05
SALIENCY_CELLS = [("fifo", "demand"), ("preempt", "demand")]


def build_plans(cfg):
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    sw = [np.asarray(b["Wk"]) for b in params["blocks"]]
    pp = build_prune_plan(sw, cfg.gcn_channels, [1.0, 0.5, 0.5, 0.5],
                         "cav-70-1", input_skip=2)
    plan = engine.build_execution_plan(params, cfg, pp, quant=True,
                                       backend="reference")
    bn = engine.collect_bn_stats(plan, jax.random.normal(
        jax.random.PRNGKey(1),
        (2, cfg.gcn_frames, cfg.gcn_joints, cfg.gcn_in_channels)))
    return (plan,), (bn,)


def cell_row(cfg, trace, plans, bn, qos, policy, saliency_thresh=0.0):
    shed_mode = "degrade" if policy == "slo-degrade" else "reject"
    pol = "slo" if policy.startswith("slo") else "demand"
    out = replay(cfg, trace, backend="reference", qos=qos, policy=pol,
                 capacity_tiers=GOLDEN_TIERS,
                 slo_config=(SloConfig(**{**GOLDEN_SLO,
                                          "shed_mode": shed_mode})
                             if pol == "slo" else None),
                 plans=plans, bn_stats=bn, record_outcomes=True,
                 saliency_thresh=saliency_thresh)
    row = {
        "outcome_digest": outcome_digest(out["outcomes"]),
        "ticks": out["ticks"],
        "sessions": out["sessions"],
        "preemptions": out["preemptions"],
        "restores": out["restores"],
        "deadline_missed": out["deadline_missed"],
        "migrations": out["resize_events"],
        "capacity_final": out["capacity_final"],
        "per_priority": {
            p: {"n": d["n"],
                "first_logit_p50_ticks": d["first_logit_p50_ticks"],
                "first_logit_p99_ticks": d["first_logit_p99_ticks"],
                "e2e_p99_ticks": d["e2e_p99_ticks"]}
            for p, d in out["latency_ms_by_priority"].items()},
    }
    if pol == "slo":
        row["sessions_rejected"] = out["sessions_rejected"]
        row["sessions_degraded"] = out["sessions_degraded"]
        row["shed_windows"] = out["shed_windows"]
    if saliency_thresh:
        row["frames_scored"] = out["frames_scored"]
        row["frames_skipped"] = out["frames_skipped"]
        row["skip_rate"] = out["skip_rate"]
    return row


def write_lock(golden, name):
    path = os.path.join(DATA_DIR, name)
    with open(path, "w") as f:
        json.dump(golden, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"wrote {path}")


def main():
    only = sys.argv[1] if len(sys.argv) > 1 else None
    cfg = get_config("agcn-2s", reduced=True)
    trace = Trace.load(os.path.join(DATA_DIR, "smoke.json"))
    plans, bn = build_plans(cfg)
    if only in (None, "smoke"):
        golden = {"trace": trace.name, "trace_digest": trace.digest(),
                  "tiers": list(GOLDEN_TIERS), "slo": GOLDEN_SLO,
                  "cells": {}}
        for qos, policy in CELLS:
            row = cell_row(cfg, trace, plans, bn, qos, policy)
            golden["cells"][f"{qos}/{policy}"] = row
            print(f"{qos}/{policy}: digest={row['outcome_digest'][:12]} "
                  f"ticks={row['ticks']} sessions={row['sessions']} "
                  f"migrations={row['migrations']}")
        write_lock(golden, "golden_smoke.json")
    if only in (None, "saliency"):
        golden = {"trace": trace.name, "trace_digest": trace.digest(),
                  "tiers": list(GOLDEN_TIERS),
                  "saliency_thresh": SALIENCY_THRESH, "cells": {}}
        for qos, policy in SALIENCY_CELLS:
            row = cell_row(cfg, trace, plans, bn, qos, policy,
                           saliency_thresh=SALIENCY_THRESH)
            golden["cells"][f"{qos}/{policy}"] = row
            print(f"saliency {qos}/{policy}: "
                  f"digest={row['outcome_digest'][:12]} "
                  f"ticks={row['ticks']} skip_rate={row['skip_rate']:.3f}")
        write_lock(golden, "golden_saliency.json")


if __name__ == "__main__":
    main()
