"""Docs tier (./test.sh --docs): stdlib-only documentation gates.

Two checks, both hard failures:

1. **Intra-repo markdown links** — every relative link in README.md and
   docs/**/*.md must resolve to a file in the repo (external http(s)/mailto
   links and pure #anchors are skipped; a trailing #anchor on a file link
   is stripped before the existence check).  Docs that point at moved or
   deleted files are worse than no docs.

2. **Docstring coverage** — every *public* module, class, function and
   method under ``src/repro/core``, ``src/repro/kernels`` and
   ``src/repro/serving`` must carry a docstring (names starting with
   ``_`` are exempt).  These trees hold the paper mechanisms (pruning,
   RFC format, cavity/graph kernels, the execution engine) and the public
   serving API; the coverage floor is 100%, so any public addition
   without a shape-contract docstring fails CI rather than rotting.

(The sibling ``tools/check_api.py`` gate snapshots the *signatures* of
the serving + engine surface — see ``docs/api_surface.txt``.)

Run directly (``python tools/check_docs.py``) or via ``./test.sh --docs``;
the full ``./test.sh`` tier includes it.  Exit code 0 = both gates hold.
"""
from __future__ import annotations

import ast
import pathlib
import re
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent
DOC_FILES = [REPO / "README.md", *sorted((REPO / "docs").glob("**/*.md"))]
COVERED_TREES = [REPO / "src/repro/core", REPO / "src/repro/kernels",
                 REPO / "src/repro/serving"]
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def check_links() -> list[str]:
    """Broken intra-repo links in README.md + docs/**/*.md."""
    errors = []
    for md in DOC_FILES:
        if not md.exists():
            errors.append(f"{md.relative_to(REPO)}: file missing")
            continue
        for n, line in enumerate(md.read_text().splitlines(), 1):
            for target in LINK_RE.findall(line):
                if target.startswith(("http://", "https://", "mailto:", "#")):
                    continue
                path = target.split("#", 1)[0]
                if not path:
                    continue
                resolved = (md.parent / path).resolve()
                if not resolved.exists():
                    errors.append(
                        f"{md.relative_to(REPO)}:{n}: broken link -> {target}")
    return errors


def _public_defs(tree: ast.Module, modname: str):
    """Yield (qualname, node) for the module + every public def/class."""
    yield modname, tree
    for node in tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            if node.name.startswith("_"):
                continue
            yield f"{modname}.{node.name}", node
            if isinstance(node, ast.ClassDef):
                for sub in node.body:
                    if isinstance(sub, (ast.FunctionDef,
                                        ast.AsyncFunctionDef)) \
                            and not sub.name.startswith("_"):
                        yield f"{modname}.{node.name}.{sub.name}", sub


def check_docstrings() -> tuple[list[str], int, int]:
    """Public defs without docstrings under the covered trees."""
    missing, total = [], 0
    for root in COVERED_TREES:
        for py in sorted(root.glob("**/*.py")):
            modname = str(py.relative_to(REPO / "src")).removesuffix(".py") \
                .replace("/", ".")
            tree = ast.parse(py.read_text())
            for qual, node in _public_defs(tree, modname):
                total += 1
                if not ast.get_docstring(node):
                    missing.append(qual)
    return missing, total - len(missing), total


def main() -> int:
    link_errors = check_links()
    for e in link_errors:
        print(f"LINK  {e}")
    missing, have, total = check_docstrings()
    for m in missing:
        print(f"DOC   missing docstring: {m}")
    pct = 100.0 * have / total if total else 100.0
    print(f"docs: {len(DOC_FILES)} markdown files, "
          f"{len(link_errors)} broken links; "
          f"docstring coverage {have}/{total} ({pct:.1f}%) "
          f"over {', '.join(str(t.relative_to(REPO)) for t in COVERED_TREES)}")
    return 1 if (link_errors or missing) else 0


if __name__ == "__main__":
    sys.exit(main())
