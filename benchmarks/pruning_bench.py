"""Paper Fig. 8/9/10 + §Abstract claims: hybrid-pruning compression ratios,
graph-skipping efficiency, cavity-scheme balance, and accuracy comparison of
hybrid vs unstructured pruning at matched reduction (synthetic-data proxy for
NTU — we compare *relative* behaviour, which is what Fig. 8 shows)."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.common.config import TrainConfig
from repro.configs import get_config
from repro.core.agcn import model as M
from repro.core.pruning.cavity import balance_stats, cavity_pattern
from repro.core.pruning.plan import build_prune_plan, unstructured_prune
from repro.data.pipeline import DataConfig, make_batches
from repro.models import registry
from repro.optim import adamw
from repro.train.steps import make_train_step

PAPER_CHANNELS = (64, 64, 64, 64, 128, 128, 128, 256, 256, 256)

# Drop schemes from paper Fig. 9 (channel keep-fractions per block; block 1
# unpruned).  Drop-1 tracks base sparsity; Drop-2/3 compress harder.
DROP_SCHEMES = {
    "drop1": [1.0, 0.6, 0.6, 0.55, 0.5, 0.5, 0.45, 0.4, 0.35, 0.3],
    "drop2": [1.0, 0.5, 0.5, 0.45, 0.4, 0.4, 0.35, 0.3, 0.3, 0.25],
    "drop3": [1.0, 0.4, 0.4, 0.35, 0.3, 0.3, 0.3, 0.25, 0.25, 0.2],
}


def compression_table():
    """Fig. 8-analogue: compression ratio + graph-skip per scheme/pattern."""
    rng = np.random.default_rng(0)
    cin = 3
    sw = []
    for cout in PAPER_CHANNELS:
        sw.append(rng.standard_normal((3, cin, cout)).astype(np.float32))
        cin = cout
    rows = []
    for scheme, keeps in DROP_SCHEMES.items():
        for cav in ("cav-50-1", "cav-70-1", "cav-75-1"):
            plan = build_prune_plan(sw, PAPER_CHANNELS, keeps, cav)
            s = plan.summary(PAPER_CHANNELS, 3)
            rows.append((scheme, cav, s))
            emit(
                f"pruning/{scheme}/{cav}", 0.0,
                f"compress={s['compression_ratio']:.2f}x "
                f"graphskip={s['graph_skip_efficiency']*100:.2f}% "
                f"param_red={s['param_reduction']*100:.1f}%",
            )
    return rows


def cavity_balance_table():
    """Fig. 10-analogue: balance stats per cavity scheme."""
    for name in ("cav-50-1", "cav-67-1", "cav-70-1", "cav-70-2", "cav-75-1",
                 "cav-75-2"):
        b = balance_stats(cavity_pattern(name))
        emit(
            f"cavity/{name}", 0.0,
            f"keep={b['keep_frac']*100:.1f}% pos_keeps="
            f"{b['per_position_min']}-{b['per_position_max']} "
            f"balanced={b['balanced']}",
        )


def accuracy_comparison(steps: int = 120):
    """Fig. 8 proxy: train one dense reduced AGCN on synthetic skeletons,
    then apply (a) the hybrid plan and (b) unstructured magnitude pruning at
    MATCHED reduction post-training (no fine-tune), and compare the
    accuracy retained — the paper's hybrid-vs-unstructured comparison."""
    cfg = get_config("agcn-2s", reduced=True)
    cfg = dataclasses.replace(cfg, input_skip=1)
    tcfg = TrainConfig(learning_rate=3e-3, total_steps=steps, warmup_steps=10)
    data = make_batches(cfg, DataConfig(global_batch=16, seq_len=0))
    test_batch = jax.tree_util.tree_map(jnp.asarray, next(data))

    init = registry.init_params(cfg, jax.random.PRNGKey(0))
    sw = [np.asarray(b["Wk"]) for b in init["blocks"]]
    plan = build_prune_plan(sw, cfg.gcn_channels, [1.0, 0.5, 0.5, 0.5],
                            "cav-70-1")
    frac = 1 - 1 / plan.summary(cfg.gcn_channels, 3)["compression_ratio"]

    # unstructured masks at matched reduction, fixed from init magnitudes
    masks = [
        {k: jnp.asarray(unstructured_prune(np.asarray(v), frac) != 0)
         for k, v in blk.items() if k in ("Wk", "tconv_w")}
        for blk in init["blocks"]
    ]

    def project(params):
        out = dict(params)
        out["blocks"] = [
            {k: (v * masks[i][k] if k in masks[i] else v)
             for k, v in blk.items()}
            for i, blk in enumerate(params["blocks"])
        ]
        return out

    def train(plan_=None, masked=False):
        """Prune-aware training (the paper's Fig. 8 setting)."""
        params = jax.tree_util.tree_map(lambda x: x, init)

        def loss_fn(p, batch):
            pp = project(p) if masked else p
            logits = M.forward(pp, batch["x"], cfg, plan=plan_)
            logz = jax.nn.logsumexp(logits, -1)
            gold = jnp.take_along_axis(
                logits, batch["labels"][:, None], axis=-1)[:, 0]
            return (logz - gold).mean()

        step = jax.jit(lambda p, o, b: _upd(p, o, b))

        def _upd(p, o, b):
            loss, g = jax.value_and_grad(loss_fn)(p, b)
            return (*adamw.update(p, g, o, tcfg)[:2], loss)

        opt = adamw.init(params)
        it = make_batches(cfg, DataConfig(global_batch=16, seq_len=0, seed=1))
        for _ in range(steps):
            b = jax.tree_util.tree_map(jnp.asarray, next(it))
            params, opt, _ = step(params, opt, b)
        pp = project(params) if masked else params
        logits = M.forward(pp, test_batch["x"], cfg, plan=plan_)
        return float((logits.argmax(-1) == test_batch["labels"]).mean())

    acc_dense = train()
    acc_hybrid = train(plan_=plan)
    acc_unstruct = train(masked=True)
    emit("pruning/accuracy", 0.0,
         f"dense={acc_dense:.3f} hybrid={acc_hybrid:.3f} "
         f"unstructured={acc_unstruct:.3f} "
         f"(prune-aware training, matched {frac*100:.0f}% reduction)")
    return acc_dense, acc_hybrid, acc_unstruct


def inference_speed():
    """Pruned vs dense inference wall time (reduced scale, CPU jit)."""
    cfg = get_config("agcn-2s", reduced=True)
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (8, cfg.gcn_frames, 25, 3))
    sw = [np.asarray(b["Wk"]) for b in params["blocks"]]
    plan = build_prune_plan(sw, cfg.gcn_channels, [1.0, 0.4, 0.4, 0.4],
                            "cav-70-1", input_skip=2)
    dense = jax.jit(lambda p, xx: M.forward(p, xx, cfg))
    pruned = jax.jit(lambda p, xx: M.forward(p, xx, cfg, plan=plan))
    t_d = time_fn(dense, params, x)
    t_p = time_fn(pruned, params, x)
    emit("pruning/infer_dense", t_d, "")
    emit("pruning/infer_pruned", t_p, f"speedup={t_d/t_p:.2f}x")


def main():
    compression_table()
    cavity_balance_table()
    inference_speed()
    accuracy_comparison()


if __name__ == "__main__":
    main()
