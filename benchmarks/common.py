"""Shared benchmark helpers: timing, CSV emission, row collection.

``emit`` both prints the CSV row and appends it to ``ROWS`` so the harness
(benchmarks/run.py) can dump a module's rows to ``BENCH_<module>.json`` —
the backend-comparison artifact consumed by CI.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List

import jax

ROWS: List[Dict] = []

# --smoke (benchmarks.run): one timed iteration, no warmup — CI's guard
# that every module still runs end-to-end and emits its BENCH json rows
# (wall-clock numbers in smoke mode are *not* comparable across runs).
SMOKE = False


def time_fn(fn: Callable, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall time in microseconds of a jitted callable."""
    if SMOKE:
        warmup, iters = 0, 1
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


def emit(name: str, us: float, derived: str = ""):
    ROWS.append({"name": name, "us_per_call": round(us, 1), "derived": derived})
    print(f"{name},{us:.1f},{derived}")


def drain_rows() -> List[Dict]:
    """Return and clear the collected rows (per-module snapshot)."""
    rows, ROWS[:] = list(ROWS), []
    return rows


def demo_prune_plan(cfg, params):
    """The canonical reduced-config pruning plan used across the benches
    (and mirrored by test_engine): magnitude selection from the init
    weights, half the channels kept from block 1 on, cav-70-1, skip 2."""
    import numpy as np

    from repro.core.pruning.plan import build_prune_plan

    sw = [np.asarray(b["Wk"]) for b in params["blocks"]]
    fracs = [1.0] + [0.5] * (len(cfg.gcn_channels) - 1)
    return build_prune_plan(sw, cfg.gcn_channels, fracs, "cav-70-1",
                            input_skip=2)


def parse_backends(argv) -> tuple:
    """Shared ``--backend`` axis parser (choices derive from the engine's
    backend registry, so new backends appear here automatically).  Unknown
    flags are tolerated — modules run under benchmarks.run's argv."""
    import argparse

    from repro.core.agcn.engine import BACKENDS

    ap = argparse.ArgumentParser(add_help=False)
    ap.add_argument("--backend", default="both", choices=(*BACKENDS, "both"))
    args, _ = ap.parse_known_args(argv)
    return BACKENDS if args.backend == "both" else (args.backend,)
