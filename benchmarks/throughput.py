"""Paper Tables IV/V: throughput + peak-performance comparison.

The paper reports 271.25 fps / 1142 GOP/s on an XCKU-115 FPGA.  We cannot
measure TPU wall time in this container, so we derive the TPU-v5e-projected
throughput from the model's analytic op counts and the pruning plan:

    fps = peak_FLOPs × util / (GOPs per clip)

using the paper's own accounting (GOP counted on the *dense* model, skips
credited to the accelerator — the same convention behind 1142 GOP/s), and
report the FLOP-reduction chain original → w/oC → +skip → +prune.
The ``--backend`` axis adds *measured* clips/s for the execution engine's
reference and pallas backends on the reduced config (interpret-mode CPU —
relative structure, not TPU wall time).
"""
from __future__ import annotations

import sys

import numpy as np

from benchmarks.common import emit, parse_backends
from repro.configs import get_config
from repro.core.pruning.plan import build_prune_plan
from repro.launch.mesh import PEAK_FLOPS_BF16

PAPER = {
    "ours_fpga_fps": 271.25,
    "2080ti_fps": 29.53, "v100_fps": 69.38,
    "2080ti_woC": 45.42, "v100_woC": 98.87,
    "2080ti_skip": 104.0, "v100_skip": 199.09,
    "peak_gops": 1142.0,
}

CHANNELS = (64, 64, 64, 64, 128, 128, 128, 256, 256, 256)
STRIDES = (1, 1, 1, 1, 2, 1, 1, 2, 1, 1)


def agcn_gops(kv=3, V=25, T=300, persons=2, use_ck=True, input_skip=1,
              keep=None, cav_keep=1.0):
    """Multiply-add count (GOP, 2 ops per MAC) for one clip."""
    cin, t = 3, T // input_skip
    total = 0.0
    for b, cout in enumerate(CHANNELS):
        kc = keep[b] if keep else 1.0
        cin_eff = max(1, int(cin * kc))
        # graph matmul: kv × (t·V·V·cin_eff)  — skipped channels drop out
        total += 2 * kv * t * V * V * cin_eff
        # spatial 1x1: kv × t·V·cin_eff·cout
        total += 2 * kv * t * V * cin_eff * cout
        if use_ck:
            ce = max(4, cin // 4)
            total += 2 * (2 * t * V * cin * ce + V * V * ce * t)
        t //= STRIDES[b]
        # temporal 9x1 conv with coarse (next block keep) + fine (cavity)
        kf = keep[b + 1] if keep and b + 1 < len(CHANNELS) else 1.0
        total += 2 * t * V * cout * int(cout * kf) * 9 * cav_keep
        cin = cout
    total += 2 * CHANNELS[-1] * 60
    return total * persons / 1e9


def main():
    drop1 = [1.0, 0.6, 0.6, 0.55, 0.5, 0.5, 0.45, 0.4, 0.35, 0.3]
    variants = {
        "original": dict(use_ck=True),
        "woC": dict(use_ck=False),
        "woC+skip": dict(use_ck=False, input_skip=2),
        "woC+skip+prune": dict(use_ck=False, input_skip=2, keep=drop1,
                               cav_keep=0.3),
    }
    g0 = agcn_gops(**variants["original"])
    for name, kw in variants.items():
        g = agcn_gops(**kw)
        emit(f"throughput/gop/{name}", 0.0,
             f"GOP={g:.2f} reduction={(1-g/g0)*100:.1f}%")

    # TPU-v5e projection at a conservative 40% MFU on the pruned model
    g_final = agcn_gops(**variants["woC+skip+prune"])
    mfu = 0.40
    fps = PEAK_FLOPS_BF16 * mfu / (g_final * 1e9)
    emit("throughput/tpu_v5e_projected", 0.0,
         f"fps={fps:.0f} vs paper FPGA {PAPER['ours_fpga_fps']} "
         f"vs V100-skip {PAPER['v100_skip']}")
    # paper speedup table reproduction (their numbers, our ratio check)
    for k in ("2080ti_fps", "v100_fps", "2080ti_skip", "v100_skip"):
        emit(f"throughput/paper/{k}", 0.0,
             f"speedup_vs_fpga={PAPER['ours_fpga_fps']/PAPER[k]:.2f}x")

    # measured backend axis: engine forward on the reduced config, clip
    # mode vs streaming mode (per-frame step against a StreamState) — the
    # streaming row is the latency-bound serving shape: one frame in, one
    # logit update out, no 64-frame window re-pay
    backends = parse_backends(sys.argv[1:])
    import jax
    import jax.numpy as jnp
    from benchmarks.common import time_fn
    from repro.core.agcn import engine
    from repro.core.agcn import model as M

    cfg = get_config("agcn-2s", reduced=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (8, cfg.gcn_frames, 25, 3))
    run = jax.jit(engine.execute)
    stepf = jax.jit(engine.step_frame)
    for backend in backends:
        ep = engine.build_execution_plan(params, cfg, quant=True,
                                         backend=backend)
        t = time_fn(run, ep, x, iters=3)
        emit(f"throughput/measured/clip/{backend}", t,
             f"clips_per_s={x.shape[0] / (t * 1e-6):.1f} (interpret CPU)")
        st = engine.init_stream_state(ep, x.shape[0], x_calib=x)
        ts = time_fn(stepf, ep, st, x[:, 0], jnp.asarray(True), iters=3)
        frames = cfg.gcn_frames
        # one step advances all x.shape[0] concurrent streams by one frame —
        # aggregate frames/s, comparable with the clip row's clips_per_s
        emit(f"throughput/measured/stream/{backend}", ts,
             f"frames_per_s={x.shape[0] * 1e6 / ts:.1f} "
             f"clip_equiv_us={ts * frames:.0f} (interpret CPU)")
        # sessions axis: the multi-session slab tick (staggered slots,
        # admission resets traced in) at the serving slot counts — the
        # marginal cost of slot capacity, and of the reset/validity masking
        # vs the lockstep stream row above
        stepS = jax.jit(engine.step_frames)
        for S in (4, 16):
            slab = engine.init_session_slab(ep, S, x_calib=x)
            frames_in = jnp.zeros((S, cfg.gcn_joints, cfg.gcn_in_channels))
            valid = np.arange(S) % 2 == 0                # half occupancy
            reset = jnp.asarray(np.arange(S) == 0)       # one admission
            tS = time_fn(stepS, ep, slab, frames_in, jnp.asarray(valid),
                         reset, iters=3)
            # only occupied slots serve real frames — frames/s counts those
            # (same definition as repro.serving.run_sessions), while the
            # tick itself always pays for all S slots
            n_act = int(valid.sum())
            emit(f"throughput/measured/sessions/{backend}/S{S}", tS,
                 f"frames_per_s={n_act * 1e6 / tS:.1f} "
                 f"active={n_act}/{S} per_active_slot_us={tS / n_act:.0f} "
                 f"(interpret CPU)")
        # preempt-vs-fifo: a preemption tick pays a snapshot gather of the
        # victim slot plus a restore scatter of the incoming session's
        # snapshot before the step — measure that marginal QoS cost against
        # the plain fifo tick at the serving slot count
        S = 4
        slab = engine.init_session_slab(ep, S, x_calib=x)
        frames_in = jnp.zeros((S, cfg.gcn_joints, cfg.gcn_in_channels))
        valid = jnp.asarray(np.arange(S) % 2 == 0)
        noreset = jnp.zeros((S,), bool)
        stored = jax.jit(engine.snapshot_slots)(slab, jnp.asarray(1))

        @jax.jit
        def preempt_tick(ep, slab, stored, frames, valid):
            snap = engine.snapshot_slots(slab, jnp.asarray(0))
            slab = engine.restore_slots(slab, jnp.asarray(0), stored)
            state, logits = engine.step_frames(ep, slab, frames, valid,
                                               noreset)
            return state, logits, snap

        # more iterations than the other rows: this row is a *difference* of
        # two timings, so interpret-mode CPU noise bites twice
        t_fifo = time_fn(stepS, ep, slab, frames_in, valid, noreset, iters=9)
        t_pre = time_fn(preempt_tick, ep, slab, stored, frames_in, valid,
                        iters=9)
        emit(f"throughput/measured/sessions/{backend}/S{S}_preempt", t_pre,
             f"fifo_tick_us={t_fifo:.0f} "
             f"preempt_overhead={(t_pre / t_fifo - 1) * 100:.1f}% "
             f"(snapshot+restore+step, interpret CPU)")
        # elastic axis: the tier-migration primitive the GcnService
        # capacity manager executes on a grow/shrink — the service's
        # *fixed-shape* form: always min(S_old, S_new) rows (occupied
        # first, free-row padding), so each ordered tier pair compiles
        # once regardless of occupancy.  Grow 4->8 and shrink 8->4,
        # priced against the plain S=4 tick above.
        slab8 = engine.init_session_slab(ep, 8, x_calib=x)
        idx4 = jnp.arange(min(4, 8), dtype=jnp.int32)

        @jax.jit
        def migrate_tick(src, dst, old_idx, new_idx):
            snap = engine.snapshot_slots(src, old_idx)
            return engine.restore_slots(dst, new_idx, snap)

        t_grow = time_fn(migrate_tick, slab, slab8, idx4, idx4, iters=9)
        t_shrink = time_fn(migrate_tick, slab8, slab, idx4, idx4, iters=9)
        emit(f"throughput/measured/sessions/{backend}/grow_4to8", t_grow,
             f"rows=4 vs_fifo_tick={(t_grow / t_fifo) * 100:.0f}% "
             f"(fixed-shape min(S_old,S_new)-row gather/scatter into "
             f"pristine tier, interpret CPU)")
        emit(f"throughput/measured/sessions/{backend}/shrink_8to4", t_shrink,
             f"rows=4 vs_fifo_tick={(t_shrink / t_fifo) * 100:.0f}% "
             f"(interpret CPU)")


if __name__ == "__main__":
    main()
