"""Paper Tables IV/V: throughput + peak-performance comparison.

The paper reports 271.25 fps / 1142 GOP/s on an XCKU-115 FPGA.  We cannot
measure TPU wall time in this container, so we derive the TPU-v5e-projected
throughput from the model's analytic op counts and the pruning plan:

    fps = peak_FLOPs × util / (GOPs per clip)

using the paper's own accounting (GOP counted on the *dense* model, skips
credited to the accelerator — the same convention behind 1142 GOP/s), and
report the FLOP-reduction chain original → w/oC → +skip → +prune.
The ``--backend`` axis adds *measured* clips/s for the execution engine's
reference and pallas backends on the reduced config (interpret-mode CPU —
relative structure, not TPU wall time).
"""
from __future__ import annotations

import sys

import numpy as np

from benchmarks.common import emit, parse_backends
from repro.configs import get_config
from repro.core.pruning.plan import build_prune_plan
from repro.launch.mesh import PEAK_FLOPS_BF16

PAPER = {
    "ours_fpga_fps": 271.25,
    "2080ti_fps": 29.53, "v100_fps": 69.38,
    "2080ti_woC": 45.42, "v100_woC": 98.87,
    "2080ti_skip": 104.0, "v100_skip": 199.09,
    "peak_gops": 1142.0,
}

CHANNELS = (64, 64, 64, 64, 128, 128, 128, 256, 256, 256)
STRIDES = (1, 1, 1, 1, 2, 1, 1, 2, 1, 1)


def agcn_gops(kv=3, V=25, T=300, persons=2, use_ck=True, input_skip=1,
              keep=None, cav_keep=1.0):
    """Multiply-add count (GOP, 2 ops per MAC) for one clip."""
    cin, t = 3, T // input_skip
    total = 0.0
    for b, cout in enumerate(CHANNELS):
        kc = keep[b] if keep else 1.0
        cin_eff = max(1, int(cin * kc))
        # graph matmul: kv × (t·V·V·cin_eff)  — skipped channels drop out
        total += 2 * kv * t * V * V * cin_eff
        # spatial 1x1: kv × t·V·cin_eff·cout
        total += 2 * kv * t * V * cin_eff * cout
        if use_ck:
            ce = max(4, cin // 4)
            total += 2 * (2 * t * V * cin * ce + V * V * ce * t)
        t //= STRIDES[b]
        # temporal 9x1 conv with coarse (next block keep) + fine (cavity)
        kf = keep[b + 1] if keep and b + 1 < len(CHANNELS) else 1.0
        total += 2 * t * V * cout * int(cout * kf) * 9 * cav_keep
        cin = cout
    total += 2 * CHANNELS[-1] * 60
    return total * persons / 1e9


def main():
    drop1 = [1.0, 0.6, 0.6, 0.55, 0.5, 0.5, 0.45, 0.4, 0.35, 0.3]
    variants = {
        "original": dict(use_ck=True),
        "woC": dict(use_ck=False),
        "woC+skip": dict(use_ck=False, input_skip=2),
        "woC+skip+prune": dict(use_ck=False, input_skip=2, keep=drop1,
                               cav_keep=0.3),
    }
    g0 = agcn_gops(**variants["original"])
    for name, kw in variants.items():
        g = agcn_gops(**kw)
        emit(f"throughput/gop/{name}", 0.0,
             f"GOP={g:.2f} reduction={(1-g/g0)*100:.1f}%")

    # TPU-v5e projection at a conservative 40% MFU on the pruned model
    g_final = agcn_gops(**variants["woC+skip+prune"])
    mfu = 0.40
    fps = PEAK_FLOPS_BF16 * mfu / (g_final * 1e9)
    emit("throughput/tpu_v5e_projected", 0.0,
         f"fps={fps:.0f} vs paper FPGA {PAPER['ours_fpga_fps']} "
         f"vs V100-skip {PAPER['v100_skip']}")
    # paper speedup table reproduction (their numbers, our ratio check)
    for k in ("2080ti_fps", "v100_fps", "2080ti_skip", "v100_skip"):
        emit(f"throughput/paper/{k}", 0.0,
             f"speedup_vs_fpga={PAPER['ours_fpga_fps']/PAPER[k]:.2f}x")

    # measured backend axis: engine forward on the reduced config, clip
    # mode vs streaming mode (per-frame step against a StreamState) — the
    # streaming row is the latency-bound serving shape: one frame in, one
    # logit update out, no 64-frame window re-pay
    backends = parse_backends(sys.argv[1:])
    import jax
    import jax.numpy as jnp
    from benchmarks.common import time_fn
    from repro.core.agcn import engine
    from repro.core.agcn import model as M

    cfg = get_config("agcn-2s", reduced=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (8, cfg.gcn_frames, 25, 3))
    run = jax.jit(engine.execute)
    stepf = jax.jit(engine.step_frame)
    for backend in backends:
        ep = engine.build_execution_plan(params, cfg, quant=True,
                                         backend=backend)
        t = time_fn(run, ep, x, iters=3)
        emit(f"throughput/measured/clip/{backend}", t,
             f"clips_per_s={x.shape[0] / (t * 1e-6):.1f} (interpret CPU)")
        st = engine.init_stream_state(ep, x.shape[0], x_calib=x)
        ts = time_fn(stepf, ep, st, x[:, 0], jnp.asarray(True), iters=3)
        frames = cfg.gcn_frames
        # one step advances all x.shape[0] concurrent streams by one frame —
        # aggregate frames/s, comparable with the clip row's clips_per_s
        emit(f"throughput/measured/stream/{backend}", ts,
             f"frames_per_s={x.shape[0] * 1e6 / ts:.1f} "
             f"clip_equiv_us={ts * frames:.0f} (interpret CPU)")
        # sessions axis: the multi-session slab tick (staggered slots,
        # admission resets traced in) at the serving slot counts — the
        # marginal cost of slot capacity, and of the reset/validity masking
        # vs the lockstep stream row above
        stepS = jax.jit(engine.step_frames)
        for S in (4, 16):
            slab = engine.init_session_slab(ep, S, x_calib=x)
            frames_in = jnp.zeros((S, cfg.gcn_joints, cfg.gcn_in_channels))
            valid = np.arange(S) % 2 == 0                # half occupancy
            reset = jnp.asarray(np.arange(S) == 0)       # one admission
            tS = time_fn(stepS, ep, slab, frames_in, jnp.asarray(valid),
                         reset, iters=3)
            # only occupied slots serve real frames — frames/s counts those
            # (same definition as repro.serving.run_sessions), while the
            # tick itself always pays for all S slots
            n_act = int(valid.sum())
            emit(f"throughput/measured/sessions/{backend}/S{S}", tS,
                 f"frames_per_s={n_act * 1e6 / tS:.1f} "
                 f"active={n_act}/{S} per_active_slot_us={tS / n_act:.0f} "
                 f"(interpret CPU)")
        # preempt-vs-fifo: a preemption tick pays a snapshot gather of the
        # victim slot plus a restore scatter of the incoming session's
        # snapshot before the step — measure that marginal QoS cost against
        # the plain fifo tick at the serving slot count
        S = 4
        slab = engine.init_session_slab(ep, S, x_calib=x)
        frames_in = jnp.zeros((S, cfg.gcn_joints, cfg.gcn_in_channels))
        valid = jnp.asarray(np.arange(S) % 2 == 0)
        noreset = jnp.zeros((S,), bool)
        stored = jax.jit(engine.snapshot_slots)(slab, jnp.asarray(1))

        @jax.jit
        def preempt_tick(ep, slab, stored, frames, valid):
            snap = engine.snapshot_slots(slab, jnp.asarray(0))
            slab = engine.restore_slots(slab, jnp.asarray(0), stored)
            state, logits = engine.step_frames(ep, slab, frames, valid,
                                               noreset)
            return state, logits, snap

        # more iterations than the other rows: this row is a *difference* of
        # two timings, so interpret-mode CPU noise bites twice
        t_fifo = time_fn(stepS, ep, slab, frames_in, valid, noreset, iters=9)
        t_pre = time_fn(preempt_tick, ep, slab, stored, frames_in, valid,
                        iters=9)
        emit(f"throughput/measured/sessions/{backend}/S{S}_preempt", t_pre,
             f"fifo_tick_us={t_fifo:.0f} "
             f"preempt_overhead={(t_pre / t_fifo - 1) * 100:.1f}% "
             f"(snapshot+restore+step, interpret CPU)")
        # elastic axis: the tier-migration primitive the GcnService
        # capacity manager executes on a grow/shrink — the service's
        # *fixed-shape* form: always min(S_old, S_new) rows (occupied
        # first, free-row padding), so each ordered tier pair compiles
        # once regardless of occupancy.  Grow 4->8 and shrink 8->4,
        # priced against the plain S=4 tick above.
        slab8 = engine.init_session_slab(ep, 8, x_calib=x)
        idx4 = jnp.arange(min(4, 8), dtype=jnp.int32)

        @jax.jit
        def migrate_tick(src, dst, old_idx, new_idx):
            snap = engine.snapshot_slots(src, old_idx)
            return engine.restore_slots(dst, new_idx, snap)

        t_grow = time_fn(migrate_tick, slab, slab8, idx4, idx4, iters=9)
        t_shrink = time_fn(migrate_tick, slab8, slab, idx4, idx4, iters=9)
        emit(f"throughput/measured/sessions/{backend}/grow_4to8", t_grow,
             f"rows=4 vs_fifo_tick={(t_grow / t_fifo) * 100:.0f}% "
             f"(fixed-shape min(S_old,S_new)-row gather/scatter into "
             f"pristine tier, interpret CPU)")
        emit(f"throughput/measured/sessions/{backend}/shrink_8to4", t_shrink,
             f"rows=4 vs_fifo_tick={(t_shrink / t_fifo) * 100:.0f}% "
             f"(interpret CPU)")
        # trace_replay axis: the checked-in smoke trace through the full
        # GcnService under both capacity policies — the measured cost of
        # the SLO control loop (latency window, admission gating, shed
        # bookkeeping) against the demand controller on identical traffic
        _trace_replay_axis(ep, backend, cfg, x)
        # tick_fused axis: the one-dispatch serving tick (hybrid: plain
        # async step on event-free ticks, donated engine.fused_tick on
        # event ticks) against the legacy multi-dispatch tick (per-event
        # snapshot/restore jits + a synchronous per-tick logit readback —
        # GcnService's fused=False path), at the serving slot counts under
        # two workloads: fifo (no events) and preempt-heavy (one snapshot
        # + one restore every tick, the shape where legacy pays 2 extra
        # dispatches + a host sync per tick)
        _tick_fused_axis(ep, backend, cfg, x)
        # ck_saliency axis: the adaptive-streaming matrix (windowed C_k
        # graph on/off × saliency frame gating on/off) through the full
        # GcnService on identical traffic — the C_k graph's marginal tick
        # cost and the sessions-per-slab win saliency skipping buys
        _ck_saliency_axis(backend, cfg)


def _ck_saliency_axis(backend, cfg):
    """Emit throughput/measured/ck_saliency rows: 2×2 matrix (ck on/off ×
    saliency on/off) at S=16 via ``run_sessions`` on identical poisson
    traffic — frames/s plus effective sessions per slab-slot-tick (the
    headline saliency gain at equal slab capacity)."""
    from benchmarks import common
    from repro.serving import run_sessions

    S = 16
    n = 8 if common.SMOKE else 32
    for ck in (0, 1):
        for sal in (0, 1):
            out = run_sessions(
                cfg, slots=S, n_sessions=n, mean_interarrival=2.0,
                backend=backend, seed=0, use_ck=bool(ck),
                saliency_thresh=1.05 if sal else 0.0)
            per_tick = out["wall_s"] * 1e6 / max(out["ticks"], 1)
            spst = out["sessions"] / (S * max(out["ticks"], 1))
            emit(f"throughput/measured/ck_saliency/{backend}/S{S}"
                 f"/ck{ck}/sal{sal}", per_tick,
                 f"frames_per_s={out['frames_per_s']:.1f} "
                 f"sessions={out['sessions']} ticks={out['ticks']} "
                 f"eff_sessions_per_slot_tick={spst:.4f} "
                 f"skip_rate={out.get('skip_rate', 0.0):.2f} "
                 f"(interpret CPU)")


def _paired(fa, fb, warmup: int = 1, iters: int = 5):
    """Interleaved A/B minima (µs): alternating fa/fb per round so slow
    wall-clock drift hits both variants equally — a plain back-to-back
    ``time_fn`` pair separates them by minutes on the interpret-mode
    points and the drift swamps the few-percent deltas this axis reads.
    Interpret-mode noise (collector pauses, scheduler preemptions, cache
    state) is strictly additive, so min-of-N converges on the true cost
    — the same estimator ``timeit`` documents for exactly this reason."""
    import gc
    import time as _time

    from benchmarks import common
    if common.SMOKE:
        warmup, iters = 0, 1
    for _ in range(warmup):
        fa()
        fb()
    ta, tb = [], []
    # interpret-mode calls churn enough Python objects that collector
    # pauses land mid-call and read as per-variant jitter — collect once,
    # then keep the collector out of the timed rounds
    gc.collect()
    gc.disable()
    try:
        for _ in range(iters):
            t0 = _time.perf_counter()
            fa()
            ta.append(_time.perf_counter() - t0)
            t0 = _time.perf_counter()
            fb()
            tb.append(_time.perf_counter() - t0)
    finally:
        gc.enable()
    return min(ta) * 1e6, min(tb) * 1e6


def _trace_replay_axis(ep, backend, cfg, x):
    """Emit throughput/measured/trace_replay rows: the smoke trace
    replayed through a (2, 4)-tier GcnService under policy=demand vs
    policy=slo — identical traffic by construction, so the delta is the
    controller itself."""
    import pathlib

    from benchmarks import common
    from repro.core.agcn import engine
    from repro.serving import SloConfig, Trace, replay

    path = (pathlib.Path(__file__).resolve().parent.parent
            / "tests" / "data" / "traces" / "smoke.json")
    trace = Trace.load(str(path))
    if common.SMOKE:
        # smoke tier: the first half of the burst exercises the whole
        # path (admission gating, growth, shed) in a fraction of the wall
        import dataclasses
        trace = dataclasses.replace(trace, events=trace.events[:7],
                                    name=trace.name + "-head7")
    bn = engine.collect_bn_stats(ep, x)
    scfg = SloConfig(target_p99_ticks=45, window=16, breach_patience=2,
                     recover_patience=8, shed_mode="reject")
    for policy in ("demand", "slo"):
        out = replay(cfg, trace, backend=backend, qos="fifo", policy=policy,
                     capacity_tiers=(2, 4),
                     slo_config=scfg if policy == "slo" else None,
                     plans=(ep,), bn_stats=(bn,))
        per_tick = out["wall_s"] * 1e6 / max(out["ticks"], 1)
        hp = out["latency_ms_by_priority"].get("1", {})
        emit(f"throughput/measured/trace_replay/{backend}/{trace.name}"
             f"/{policy}", per_tick,
             f"ticks={out['ticks']} sessions={out['sessions']} "
             f"rejected={out.get('sessions_rejected', 0)} "
             f"hp_first_logit_p99_ticks="
             f"{hp.get('first_logit_p99_ticks', -1.0):.1f} "
             f"(interpret CPU)")


def _tick_fused_axis(ep, backend, cfg, x):
    """Emit throughput/measured/tick_fused rows: fused vs legacy ticks/s
    at the serving slot counts, fifo vs preempt-heavy workloads."""
    import jax
    import jax.numpy as jnp

    from benchmarks import common
    from repro.core.agcn import engine
    from repro.serving.scheduler import max_events_for, pad_event_orders

    fused_fn = jax.jit(engine.fused_tick, donate_argnums=(1, 8))
    snap_j = jax.jit(engine.snapshot_slots)
    rest_j = jax.jit(engine.restore_slots)
    stepS = jax.jit(engine.step_frames)
    # smoke tier: exercise the fused path end-to-end at S=4 only (the
    # full axis at S=256 is minutes of interpret-mode wall time)
    s_list = (4,) if common.SMOKE else (16, 64, 256)
    for S in s_list:
        # ticks per timed call (amortises the fused path's end-of-burst
        # readback); a pallas-interpret S=256 tick is ~6 s, so that point
        # trades burst length for more median samples
        nticks = 4 if S <= 64 else 2
        pristine = engine.init_session_slab(ep, S, x_calib=x)
        frames = jnp.zeros((S, cfg.gcn_joints, cfg.gcn_in_channels))
        valid = jnp.asarray(np.arange(S) % 2 == 0)     # half occupancy
        zeros = jnp.zeros((S,), bool)
        E = max_events_for(S)
        # preempt-heavy = the scheduler's full per-tick event budget:
        # every tick snapshots slots 0..E-1 into ring rows 0..E-1 and
        # restores ring rows E..2E-1 back into the same slots (steady-
        # state churn at max admissible rate) — legacy pays 2 dispatches
        # *per event* here, the fused megakernel still pays one total
        snap_o = jnp.asarray(pad_event_orders([(i, i) for i in range(E)], E))
        rest_o = jnp.asarray(pad_event_orders(
            [(i, E + i) for i in range(E)], E))

        # each variant carries its slab (and ring) across timed calls so
        # the timed region holds exactly what the service's tick loop
        # pays — the one-time slab copy / ring init happens here, outside
        st = {
            "leg": {"slab": pristine,
                    "hot": [snap_j(pristine, jnp.asarray(i))
                            for i in range(E)]},
            "fus": {"slab": jax.tree_util.tree_map(jnp.copy, pristine),
                    "ring": engine.init_snapshot_ring(pristine, 2 * E)},
        }

        def run_legacy(preempt):
            s = st["leg"]
            slab, logits = s["slab"], None
            for _ in range(nticks):
                if preempt:
                    for i in range(E):
                        hot2 = snap_j(slab, jnp.asarray(i))
                        slab = rest_j(slab, jnp.asarray(i), s["hot"][i])
                        s["hot"][i] = hot2
                slab, logits = stepS(ep, slab, frames, valid, zeros, zeros)
                np.asarray(logits)   # the legacy per-tick host sync
            s["slab"] = slab
            return logits

        def run_fused(preempt):
            # the service's hybrid dispatch: event-free ticks run the
            # plain step, event ticks run the donated megakernel —
            # either way one dispatch per tick, logits left on device
            s = st["fus"]
            slab, logits = s["slab"], None
            if preempt:
                ring = s["ring"]
                for _ in range(nticks):
                    slab, logits, ring = fused_fn(
                        ep, slab, frames, valid, zeros, zeros,
                        snap_o, rest_o, ring)
                s["ring"] = ring
            else:
                for _ in range(nticks):
                    slab, logits = stepS(ep, slab, frames, valid,
                                         zeros, zeros)
            s["slab"] = slab
            np.asarray(logits)       # async: one readback per burst
            return logits

        # S=256 interpret ticks are seconds, so fewer samples there
        iters = 9 if S <= 16 else (7 if S <= 64 else 5)
        for wl in ("fifo", "preempt"):
            pre = wl == "preempt"
            t_leg, t_fus = _paired(lambda: run_legacy(pre),
                                   lambda: run_fused(pre), iters=iters)
            t_leg /= nticks
            t_fus /= nticks
            emit(f"throughput/measured/tick_fused/{backend}/S{S}/legacy/{wl}",
                 t_leg, f"ticks_per_s={1e6 / t_leg:.1f} (interpret CPU)")
            emit(f"throughput/measured/tick_fused/{backend}/S{S}/fused/{wl}",
                 t_fus, f"ticks_per_s={1e6 / t_fus:.1f} "
                 f"speedup_vs_legacy={t_leg / t_fus:.2f}x "
                 f"(1 dispatch/tick, async readback, interpret CPU)")


if __name__ == "__main__":
    main()
