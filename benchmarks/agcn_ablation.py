"""Paper Table I: cost of the data-dependent C_k similarity graph, plus the
execution-backend axis (reference jnp engine vs fused Pallas kernels).

Measures jitted forward wall time with/without C_k (reduced scale) and
derives the throughput ratio (paper: 69.38 -> 98.87 fps, 1.43x).  The
``--backend`` flag (reference | pallas | both) selects which engine
backends the backend rows cover; forwards go through the same compiled
ExecutionPlan flow as serving.
"""
from __future__ import annotations

import dataclasses
import sys

import jax

from benchmarks.common import demo_prune_plan, emit, parse_backends, time_fn
from repro.configs import get_config
from repro.core.agcn import engine
from repro.core.agcn import model as M
from repro.models import registry


def main():
    backends = parse_backends(sys.argv[1:])

    cfg = get_config("agcn-2s", reduced=True)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, cfg.gcn_frames, 25, 3))

    cfg_ck = dataclasses.replace(cfg, use_ck=True)
    p_ck = registry.init_params(cfg_ck, jax.random.PRNGKey(0))
    with_ck = jax.jit(lambda p, xx: M.forward(p, xx, cfg_ck))
    t_with = time_fn(with_ck, p_ck, x)

    p = registry.init_params(cfg, jax.random.PRNGKey(0))
    without = jax.jit(lambda pp, xx: M.forward(pp, xx, cfg))
    t_without = time_fn(without, p, x)

    emit("ablation/with_ck", t_with, "")
    emit("ablation/without_ck", t_without,
         f"speedup={t_with/t_without:.2f}x (paper: 1.43x on V100)")

    # backend axis: dense and genuinely-pruned+quantized plans per backend
    # (the reduced config carries no prune fracs, so build the canonical
    # demo plan from the init weights — shared with kernels_bench)
    prune = demo_prune_plan(cfg, p)
    run = jax.jit(engine.execute)
    for backend in backends:
        for label, plan_, kwargs in (("dense", None, {}),
                                     ("pruned_q", prune, {"quant": True})):
            ep = engine.build_execution_plan(p, cfg, plan_, backend=backend,
                                             **kwargs)
            t = time_fn(run, ep, x, iters=3)
            emit(f"ablation/backend_{backend}_{label}", t,
                 f"clips_per_s={x.shape[0] / (t * 1e-6):.1f}")


if __name__ == "__main__":
    main()
