"""Paper Table I: cost of the data-dependent C_k similarity graph.
Measures jitted forward wall time with/without C_k (reduced scale) and
derives the throughput ratio (paper: 69.38 -> 98.87 fps, 1.43x)."""
from __future__ import annotations

import dataclasses

import jax

from benchmarks.common import emit, time_fn
from repro.configs import get_config
from repro.core.agcn import model as M
from repro.models import registry


def main():
    cfg = get_config("agcn-2s", reduced=True)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, cfg.gcn_frames, 25, 3))

    cfg_ck = dataclasses.replace(cfg, use_ck=True)
    p_ck = registry.init_params(cfg_ck, jax.random.PRNGKey(0))
    with_ck = jax.jit(lambda p, xx: M.forward(p, xx, cfg_ck))
    t_with = time_fn(with_ck, p_ck, x)

    p = registry.init_params(cfg, jax.random.PRNGKey(0))
    without = jax.jit(lambda pp, xx: M.forward(pp, xx, cfg))
    t_without = time_fn(without, p, x)

    emit("ablation/with_ck", t_with, "")
    emit("ablation/without_ck", t_without,
         f"speedup={t_with/t_without:.2f}x (paper: 1.43x on V100)")


if __name__ == "__main__":
    main()
