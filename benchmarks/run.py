"""Benchmark harness entry point — one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows.

    PYTHONPATH=src python -m benchmarks.run [--only MODULE]
"""
from __future__ import annotations

import argparse
import sys
import traceback

MODULES = [
    "pruning_bench",      # Fig. 8/9/10 — hybrid pruning
    "agcn_ablation",      # Table I    — C_k cost
    "rfc_storage",        # Table III + Fig. 11 — RFC storage
    "dyn_sched",          # Table II   — Dyn-Mult-PE sizing
    "throughput",         # Tables IV/V — throughput & peak perf
    "kernels_bench",      # kernel micro-benchmarks
    "roofline_report",    # §Roofline from the dry-run artifacts
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    mods = [args.only] if args.only else MODULES
    print("name,us_per_call,derived")
    failed = []
    for m in mods:
        try:
            mod = __import__(f"benchmarks.{m}", fromlist=["main"])
            mod.main()
        except Exception as e:  # noqa: BLE001
            failed.append(m)
            traceback.print_exc()
            print(f"{m},0.0,ERROR {e!r}")
    sys.exit(1 if failed else 0)


if __name__ == "__main__":
    main()
