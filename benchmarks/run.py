"""Benchmark harness entry point — one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows.

    PYTHONPATH=src python -m benchmarks.run [--only MODULE]

With ``--only MODULE`` the module's rows are also written to
``BENCH_<MODULE>.json`` (e.g. ``--only kernels_bench`` →
``BENCH_kernels_bench.json`` with the backend-comparison rows); ``--json``
forces the dump for a full run (one file per module).  ``--smoke`` times a
single iteration per row — ``test.sh`` runs ``--only kernels --smoke`` so
the json emission path cannot silently rot.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import traceback

from benchmarks import common

MODULES = [
    "pruning_bench",      # Fig. 8/9/10 — hybrid pruning
    "agcn_ablation",      # Table I    — C_k cost
    "rfc_storage",        # Table III + Fig. 11 — RFC storage
    "dyn_sched",          # Table II   — Dyn-Mult-PE sizing
    "throughput",         # Tables IV/V — throughput & peak perf
    "kernels_bench",      # kernel micro-benchmarks
    "roofline_report",    # §Roofline from the dry-run artifacts
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="run one module (accepts 'kernels' for kernels_bench)")
    ap.add_argument("--json", action="store_true",
                    help="write BENCH_<module>.json for every module run")
    ap.add_argument("--smoke", action="store_true",
                    help="single timed iteration per row (fast end-to-end "
                         "check that BENCH json emission still works)")
    ap.add_argument("--out-dir", default=".",
                    help="directory for the BENCH_<module>.json artifacts — "
                         "smoke runs should point this at a temp dir so "
                         "1-iteration timings never overwrite the checked-in "
                         "artifacts (see test.sh)")
    # unknown flags (e.g. --backend) pass through to the modules' own parsers
    args, _ = ap.parse_known_args()
    if args.smoke:
        common.SMOKE = True
    only = args.only
    if only and only not in MODULES and f"{only}_bench" in MODULES:
        only = f"{only}_bench"           # `--only kernels` shorthand
    mods = [only] if only else MODULES
    print("name,us_per_call,derived")
    failed = []
    for m in mods:
        try:
            mod = __import__(f"benchmarks.{m}", fromlist=["main"])
            mod.main()
        except Exception as e:  # noqa: BLE001
            failed.append(m)
            traceback.print_exc()
            # emit (not print) so the failure lands in the JSON artifact too
            # — a partial BENCH_<module>.json must not look like a full run
            common.emit(f"{m}/ERROR", 0.0, repr(e))
        rows = common.drain_rows()
        if rows and (only or args.json):
            path = os.path.join(args.out_dir, f"BENCH_{m}.json")
            with open(path, "w") as f:
                json.dump(rows, f, indent=1)
            print(f"# wrote {len(rows)} rows -> {path}", file=sys.stderr)
    sys.exit(1 if failed else 0)


if __name__ == "__main__":
    main()
