"""Paper Table III + Fig. 11: feature sparsity distribution of real model
activations and the storage cost of dense vs CSC vs RFC formats (paper:
RFC saves 35.93% of BRAM vs sparse storage, loads in 1 cycle vs 64)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.configs import get_config
from repro.core.agcn import model as M
from repro.core.rfc.format import (
    expected_sparsity_categories, rfc_encode, storage_cost,
)
from repro.data.pipeline import DataConfig, make_batches
from repro.models import registry


def main():
    cfg = get_config("agcn-2s", reduced=True)
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    data = make_batches(cfg, DataConfig(global_batch=16, seq_len=0))
    x = jnp.asarray(next(data)["x"])

    # per-block activation sparsity (Table III analogue)
    sparsities = M.feature_sparsity_per_block(params, x, cfg)
    for b, s in enumerate(sparsities):
        emit(f"rfc/sparsity/block{b}", 0.0, f"sparsity={s*100:.2f}%")

    # run a real activation tensor through the RFC encoder and compare
    # storage formats (Fig. 11)
    h = jax.random.normal(jax.random.PRNGKey(2), (2048, 64))
    h = jax.nn.relu(h - 0.4)                     # ~65% sparse like tconv outs
    _, hot = rfc_encode(h, apply_relu=False)
    hot = np.asarray(hot) > 0
    cats = expected_sparsity_categories(hot)
    emit("rfc/categories", 0.0,
         "I/II/III/IV=" + "/".join(f"{c*100:.1f}%" for c in cats))
    c = storage_cost(hot)
    emit("rfc/storage", 0.0,
         f"dense={c['dense_bits']/8e3:.1f}kB csc={c['csc_bits']/8e3:.1f}kB "
         f"rfc={c['rfc_bits']/8e3:.1f}kB "
         f"rfc_saves={c['rfc_vs_dense_reduction']*100:.2f}% "
         f"(paper: 35.93%)")
    # access regularity: RFC loads one aligned line per cycle; CSC decodes
    # serially (paper: 64 cycles)
    emit("rfc/access", 0.0, "rfc_load_cycles=1 csc_load_cycles=64 (by design)")


if __name__ == "__main__":
    main()
