"""Kernel micro-benchmarks: Pallas (interpret) vs pure-jnp oracle wall time
on CPU.  Interpret-mode timing is NOT TPU-representative — the quantity that
matters is the FLOP/byte skip encoded in the kernel shapes, which is also
reported."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.core.pruning.cavity import cavity_pattern, tile_pattern
from repro.kernels import ops, ref


def main():
    # RFC encode/decode
    x = jax.random.normal(jax.random.PRNGKey(0), (512, 256))
    t_enc = time_fn(lambda a: ops.rfc_encode(a), x, iters=3)
    t_ref = time_fn(lambda a: ref.rfc_encode_ref(a), x, iters=3)
    emit("kernels/rfc_encode_pallas", t_enc, "")
    emit("kernels/rfc_encode_ref", t_ref, "")

    # cavity tconv: FLOP skip from packed shapes
    F, C = 64, 64
    w = np.asarray(jax.random.normal(jax.random.PRNGKey(1), (F, C, 9)),
                   np.float32)
    mask = tile_pattern(cavity_pattern("cav-70-1"), F)
    wp, taps, inv = ops.pack_cavity_weights(w * mask[:, None, :], mask)
    xt = jax.random.normal(jax.random.PRNGKey(2), (16, 128, C))
    t_k = time_fn(
        lambda a: ops.cavity_tconv(a, jnp.asarray(wp), jnp.asarray(taps),
                                   inv, F), xt, iters=3)
    t_r = time_fn(
        lambda a: ref.cavity_tconv_ref(a, jnp.asarray(w * mask[:, None, :])),
        xt, iters=3)
    emit("kernels/cavity_tconv_pallas", t_k,
         f"taps={wp.shape[1]}/9 flop_skip={(1-wp.shape[1]/9)*100:.0f}%")
    emit("kernels/cavity_tconv_ref", t_r, "")

    # fused graph+spatial conv
    xg = jax.random.normal(jax.random.PRNGKey(3), (4, 64, 25, 64))
    g = jax.random.normal(jax.random.PRNGKey(4), (3, 25, 25))
    wg = jax.random.normal(jax.random.PRNGKey(5), (3, 64, 128))
    t_k = time_fn(lambda a: ops.graph_sconv(a, g, wg), xg, iters=3)
    t_r = time_fn(
        lambda a: ref.graph_sconv_ref(a.reshape(-1, 25, 64), g, wg), xg,
        iters=3)
    emit("kernels/graph_sconv_pallas", t_k, "fused G-matmul+1x1 (1 HBM pass)")
    emit("kernels/graph_sconv_ref", t_r, "")


if __name__ == "__main__":
    main()
