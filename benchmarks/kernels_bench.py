"""Kernel micro-benchmarks: Pallas (interpret) vs pure-jnp oracle wall time
on CPU.  Interpret-mode timing is NOT TPU-representative — the quantity that
matters is the FLOP/byte skip encoded in the kernel shapes, which is also
reported.

The cavity/graph inputs come from the same ExecutionPlan compiler the model
uses (engine.build_execution_plan) instead of hand-packing, so the bench
exercises exactly the layouts the serving path runs; the final rows compare
full-model forward time per backend (``--backend`` selects which; these are
the rows that land in BENCH_kernels_bench.json via
``benchmarks.run --only kernels``).
"""
from __future__ import annotations

import sys

import jax

from benchmarks.common import demo_prune_plan, emit, parse_backends, time_fn
from repro.configs import get_config
from repro.core.agcn import engine
from repro.core.agcn import model as M
from repro.kernels import ops, ref


def _block_inputs():
    """Compile the canonical reduced plan for both backends: the pallas one
    supplies the packed/padded kernel inputs, the reference one the dense
    oracle forms (pallas plans deliberately drop them)."""
    cfg = get_config("agcn-2s", reduced=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    prune = demo_prune_plan(cfg, params)
    pallas_plan = engine.build_execution_plan(params, cfg, prune,
                                              backend="pallas")
    ref_plan = engine.build_execution_plan(params, cfg, prune,
                                           backend="reference")
    return cfg, params, prune, pallas_plan, ref_plan


def main():
    backends = parse_backends(sys.argv[1:])

    # RFC encode/decode
    x = jax.random.normal(jax.random.PRNGKey(0), (512, 256))
    t_enc = time_fn(lambda a: ops.rfc_encode(a), x, iters=3)
    t_ref = time_fn(lambda a: ref.rfc_encode_ref(a), x, iters=3)
    emit("kernels/rfc_encode_pallas", t_enc, "")
    emit("kernels/rfc_encode_ref", t_ref, "")

    cfg, params, prune, pplan, rplan = _block_inputs()

    # cavity tconv: packed shapes straight from the ExecutionPlan (block 1:
    # pruned filters + cavity taps), vs the dense masked-conv oracle
    pa, bs = pplan.arrays["blocks"][1], pplan.static.blocks[1]
    ra = rplan.arrays["blocks"][1]
    C = ra["tw"].shape[1]
    xt = jax.random.normal(jax.random.PRNGKey(2), (16, 128, C))
    t_k = time_fn(
        lambda a: ops.cavity_tconv(a, pa["wp"], pa["taps"], pa["inv_perm"],
                                   bs.n_kept_filters), xt, iters=3)
    t_r = time_fn(lambda a: ref.cavity_tconv_ref(a, ra["tw"]), xt, iters=3)
    n_keep, K = pa["wp"].shape[1], bs.tkernel
    emit("kernels/cavity_tconv_pallas", t_k,
         f"taps={n_keep}/{K} flop_skip={(1 - n_keep / K) * 100:.0f}%")
    emit("kernels/cavity_tconv_ref", t_r, "")

    # fused graph+spatial conv: plan-precomputed padded graph + gathered W
    xg = jax.random.normal(jax.random.PRNGKey(3),
                           (4, 64, cfg.gcn_joints, pa["Wk"].shape[1]))
    t_k = time_fn(lambda a: ops.graph_sconv(a, pa["Gp"], pa["Wk"]), xg,
                  iters=3)
    t_r = time_fn(
        lambda a: ref.graph_sconv_ref(
            a.reshape(-1, cfg.gcn_joints, ra["Wk"].shape[1]),
            ra["G"], ra["Wk"]), xg, iters=3)
    emit("kernels/graph_sconv_pallas", t_k, "fused G-matmul+1x1 (1 HBM pass)")
    emit("kernels/graph_sconv_ref", t_r, "")

    # CSR vs dense spatial conv over skeleton widths × graph densities: the
    # variable-topology compiler picks CSR per block when the merged graph's
    # density falls below csr_density (0.5 default) — these rows measure the
    # crossover that threshold encodes.  The registry graphs give the
    # natural-skeleton density; the synthetic d25/d50 graphs sweep toward
    # the selector boundary.
    import numpy as np

    import jax.numpy as jnp

    from repro.core.agcn.graph import dense_to_csr, get_topology

    Cin = Cout = 16
    N, T = 2, 16
    rng = np.random.default_rng(7)
    for tname in ("ntu25", "ntu50"):
        topo = get_topology(tname)
        V, K = topo.num_joints, topo.num_subsets
        w = jnp.asarray(rng.standard_normal((K, Cin, Cout)), jnp.float32)
        xg = jnp.asarray(rng.standard_normal((N, T, V, Cin)), jnp.float32)
        xr = xg.reshape(-1, V, Cin)
        sweeps = [(f"d{int(round(topo.density * 100)):02d}", topo.adjacency)]
        for target in (0.25, 0.50):
            mask = rng.random((K, V, V)) < target
            sweeps.append((f"d{int(target * 100):02d}",
                           (rng.standard_normal((K, V, V)) * mask)
                           .astype(np.float32)))
        for tag, g in sweeps:
            dens = float((np.abs(g) > 0).mean())
            indptr, indices, values = dense_to_csr(g)
            vp = -(-V // 8) * 8
            idx, val = ops.pack_csr_ell(indptr, indices, values, vp)
            gj, ip, ix, vl, ej, ev = map(
                jnp.asarray, (g, indptr, indices, values, idx, val))
            t_d = time_fn(lambda a, g_=gj: ref.graph_sconv_ref(a, g_, w),
                          xr, iters=3)
            t_c = time_fn(
                lambda a, p=ip, i=ix, v=vl:
                    ref.graph_sconv_csr_ref(a, p, i, v, w), xr, iters=3)
            emit(f"kernels/sconv_csr/{tname}/{tag}/dense_ref", t_d,
                 f"V={V} density={dens:.2f}")
            emit(f"kernels/sconv_csr/{tname}/{tag}/csr_ref", t_c,
                 f"nnz_skip={(1 - dens) * 100:.0f}%")
            t_d = time_fn(lambda a, g_=gj: ops.graph_sconv(a, g_, w),
                          xg, iters=3)
            t_c = time_fn(
                lambda a, e=ej, v=ev: ops.graph_sconv_csr(a, e, v, w),
                xg, iters=3)
            emit(f"kernels/sconv_csr/{tname}/{tag}/dense_pallas", t_d,
                 f"V={V} density={dens:.2f}")
            emit(f"kernels/sconv_csr/{tname}/{tag}/csr_pallas", t_c,
                 f"ell_deg={idx.shape[-1]} "
                 f"nnz_skip={(1 - dens) * 100:.0f}%")

    # backend comparison: full-model forward through the engine, identical
    # ExecutionPlan flow for both backends (parity is locked by test_engine)
    xm = jax.random.normal(jax.random.PRNGKey(4), (8, cfg.gcn_frames, 25, 3))
    times = {}
    for backend in backends:
        ep = engine.build_execution_plan(params, cfg, prune, quant=True,
                                         backend=backend)
        fn = jax.jit(engine.execute)
        times[backend] = time_fn(fn, ep, xm, iters=3)
        emit(f"kernels/backend_forward_{backend}", times[backend],
             f"clips_per_s={8 / (times[backend] * 1e-6):.1f}")
    if len(times) > 1:
        emit("kernels/backend_forward_ratio", 0.0,
             f"pallas/reference={times['pallas'] / times['reference']:.2f}x "
             "(interpret-mode CPU; not TPU-representative)")


if __name__ == "__main__":
    main()
