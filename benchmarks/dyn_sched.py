"""Paper Table II: Dyn-Mult-PE sizing from the E(D) model — DSP utilisation,
working efficiency and delay probability per layer given measured feature
sparsities (paper: 23.24% DSP saving at 6.48% max delay)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.configs import get_config
from repro.core.agcn import model as M
from repro.core.sched.expectation import scheduling_report
from repro.data.pipeline import DataConfig, make_batches
from repro.models import registry


def main():
    cfg = get_config("agcn-2s", reduced=True)
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    data = make_batches(cfg, DataConfig(global_batch=16, seq_len=0))
    x = jnp.asarray(next(data)["x"])
    sparsities = M.feature_sparsity_per_block(params, x, cfg)

    # cav-70-1 rows keep 2-3 taps; sub-filters of 16 channels hold 4 or 6
    # kept weights (paper Fig. 6) — size DSPs for both queue widths
    total_dsp = 0
    total_static = 0
    weighted_eff = 0.0
    for b, s in enumerate(sparsities):
        for w in (4, 6):
            rep = scheduling_report(w, s)
            total_dsp += rep["dsps"]
            total_static += w
            weighted_eff += rep["efficiency"]
            emit(
                f"dyn_sched/block{b}/w{w}", 0.0,
                f"E(D)={rep['expected_valid']:.2f} dsps={rep['dsps']}/{w} "
                f"eff={rep['efficiency']*100:.1f}% "
                f"delayP={rep['delay_prob']*100:.2f}%",
            )
    emit(
        "dyn_sched/total", 0.0,
        f"dsp_saving={(1-total_dsp/total_static)*100:.2f}% "
        f"(paper: 23.24%) mean_eff="
        f"{weighted_eff/(2*len(sparsities))*100:.1f}% (paper: 75.38%)",
    )


if __name__ == "__main__":
    main()
