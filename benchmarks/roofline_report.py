"""Roofline report builder: reads experiments/dryrun/*.json and emits the
§Roofline table (CSV rows + a markdown table written to
experiments/roofline.md).  Single-pod cells only, per the spec; the
multi-pod cells prove the pod axis shards and are listed in §Dry-run."""
from __future__ import annotations

import json
import pathlib
from typing import Dict, List

from benchmarks.common import emit
from repro.launch.mesh import HBM_BW, PEAK_FLOPS_BF16


def load_cells(dryrun_dir: str = "experiments/dryrun") -> List[Dict]:
    cells = []
    for p in sorted(pathlib.Path(dryrun_dir).glob("*.json")):
        cells.append(json.loads(p.read_text()))
    return cells


def markdown_table(cells: List[Dict]) -> str:
    lines = [
        "| arch | shape | dominant | t_comp (s) | t_mem (s) | t_coll (s) | "
        "useful FLOP ratio | roofline frac |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for c in cells:
        if c.get("status") != "ok" or c.get("mesh") != "pod16x16":
            continue
        r = c["roofline"]
        lines.append(
            f"| {c['arch']} | {c['shape']} | {r['dominant']} "
            f"| {r['t_compute_s']:.3e} | {r['t_memory_s']:.3e} "
            f"| {r['t_collective_s']:.3e} | {r['useful_flop_ratio']:.3f} "
            f"| {r['roofline_fraction']:.4f} |"
        )
    skips = [c for c in cells if c.get("status") == "skipped"]
    if skips:
        lines.append("")
        lines.append("Skipped cells: " + "; ".join(
            f"{c['cell']} ({c['reason']})" for c in skips
            if "pod16x16" in c["cell"]))
    return "\n".join(lines)


def comparison_table(final: List[Dict], baseline: List[Dict]) -> str:
    base = {c["cell"]: c for c in baseline if c.get("status") == "ok"}
    lines = [
        "| cell | frac (baseline) | frac (final) | Δ | dominant (final) |",
        "|---|---|---|---|---|",
    ]
    for c in final:
        if c.get("status") != "ok" or c.get("mesh") != "pod16x16":
            continue
        b = base.get(c["cell"])
        rf = c["roofline"]["roofline_fraction"]
        if b is None:
            lines.append(f"| {c['cell']} | — | {rf:.4f} | — | "
                         f"{c['roofline']['dominant']} |")
            continue
        bf = b["roofline"]["roofline_fraction"]
        ratio = rf / bf if bf else float("inf")
        lines.append(
            f"| {c['cell']} | {bf:.4f} | {rf:.4f} | {ratio:.2f}x "
            f"| {c['roofline']['dominant']} |"
        )
    return "\n".join(lines)


def main():
    cells = load_cells()
    ok = [c for c in cells if c.get("status") == "ok"]
    single = [c for c in ok if c.get("mesh") == "pod16x16"]
    multi = [c for c in ok if c.get("mesh") == "pod2x16x16"]
    emit("roofline/cells_ok", 0.0,
         f"single={len(single)} multi={len(multi)} "
         f"skipped={sum(1 for c in cells if c.get('status')=='skipped')} "
         f"errors={sum(1 for c in cells if c.get('status')=='error')}")
    for c in single:
        r = c["roofline"]
        emit(f"roofline/{c['arch']}/{c['shape']}", 0.0,
             f"dominant={r['dominant']} frac={r['roofline_fraction']:.4f} "
             f"useful={r['useful_flop_ratio']:.3f}")
    out = pathlib.Path("experiments/roofline.md")
    out.parent.mkdir(exist_ok=True, parents=True)
    text = markdown_table(cells)
    if pathlib.Path("experiments/dryrun_baseline").exists():
        baseline = load_cells("experiments/dryrun_baseline")
        text += "\n\n## Baseline vs optimized (single pod)\n\n"
        text += comparison_table(cells, baseline)
    out.write_text(text)
    emit("roofline/report", 0.0, str(out))


if __name__ == "__main__":
    main()
