"""Batched serving example: prefill + KV-cache decode across architecture
families (dense GQA, MoE, SSM, hybrid) — the small-scale twin of the
decode_32k / long_500k dry-run cells.

    PYTHONPATH=src python examples/serve_lm.py
"""
from repro.launch.serve import generate

ARCHS = ["smollm-360m", "qwen3-moe-30b-a3b", "xlstm-1.3b", "zamba2-7b"]


def main():
    for arch in ARCHS:
        seqs, tps = generate(arch, reduced=True, batch=2, prompt_len=8,
                             gen=24)
        print(f"{arch:24s} {seqs.shape[1]} tokens/seq  {tps:7.1f} tok/s  "
              f"sample={seqs[0, 8:16].tolist()}")


if __name__ == "__main__":
    main()
