"""Fault-tolerance walkthrough: checkpoint, simulated host failure, elastic
re-mesh plan, and restore onto the degraded mesh with preserved global batch.

    PYTHONPATH=src python examples/elastic_restart.py
"""
import jax
import jax.numpy as jnp

from repro.common.config import TrainConfig
from repro.checkpoint import store
from repro.fault.elastic import (
    adjust_train_config, plan_degraded_mesh, reshard_checkpoint,
)
from repro.fault.monitor import HeartbeatMonitor, StragglerDetector


def main():
    # a 256-chip pod reduced to a toy tree for the demo
    tree = {"w": jnp.arange(64.0).reshape(8, 8), "b": jnp.ones(8)}
    store.save("/tmp/elastic_demo", 100, tree)
    print("checkpoint written at step 100")

    # heartbeat monitor notices 40 chips (2.5 hosts) died
    hb = HeartbeatMonitor(num_hosts=64, timeout_s=30)
    for h in range(64):
        hb.beat(h, now=0.0)
    for h in range(61):                   # three hosts stop heartbeating
        hb.beat(h, now=40.0)
    dead = hb.dead_hosts(now=60.0)
    print(f"dead hosts: {len(dead)} -> alive chips = {256 - len(dead) * 4}")

    # plan the survivor mesh (model axis kept, data axis shrunk pow2)
    plan = plan_degraded_mesh(alive_chips=256 - len(dead) * 4)
    print(f"new mesh: data={plan.data} model={plan.model} "
          f"({plan.chips} chips), microbatch x{plan.microbatch_multiplier}")

    tcfg = adjust_train_config(TrainConfig(microbatches=1), plan)
    print(f"grad-accum microbatches now {tcfg.microbatches} "
          f"(global batch preserved)")

    # restore the checkpoint onto the new (demo 1x1) mesh
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    from jax.sharding import NamedSharding, PartitionSpec as P
    sh = jax.tree_util.tree_map(
        lambda _: NamedSharding(mesh, P()), tree)
    back = reshard_checkpoint("/tmp/elastic_demo", 100, tree, mesh, sh)
    print("restored + resharded:", jax.tree_util.tree_map(
        lambda x: x.shape, back))

    # straggler detection on recorded step times
    sd = StragglerDetector(num_hosts=8)
    for step in range(6):
        for h in range(8):
            sd.record(h, 1.0 + (2.5 if h == 3 else 0.0))
    print("stragglers:", sd.stragglers())


if __name__ == "__main__":
    main()
