"""End-to-end LM training driver example: trains a ~smoke-scale model from
the assigned-architecture zoo for a few hundred steps with checkpointing and
fault monitors active, then resumes from the checkpoint to show restart.

    PYTHONPATH=src python examples/train_lm.py --arch xlstm-1.3b
"""
import argparse
import dataclasses
import shutil

from repro.common.config import TrainConfig
from repro.launch.train import train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--steps", type=int, default=200)
    args = ap.parse_args()

    ckpt = f"/tmp/example_lm_{args.arch}"
    shutil.rmtree(ckpt, ignore_errors=True)
    tcfg = TrainConfig(
        learning_rate=1e-3, total_steps=args.steps,
        warmup_steps=args.steps // 10,
        checkpoint_every=args.steps // 2, checkpoint_dir=ckpt,
    )
    _, losses = train_loop(args.arch, tcfg, reduced=True, batch=8, seq=128,
                           resume=False)
    print(f"\nphase 1: loss {losses[0]:.3f} -> {losses[-1]:.3f}")

    # simulate a restart: resume from the mid-run checkpoint
    tcfg2 = dataclasses.replace(tcfg, total_steps=args.steps + 50)
    _, losses2 = train_loop(args.arch, tcfg2, reduced=True, batch=8, seq=128,
                            resume=True)
    print(f"phase 2 (resumed): {len(losses2)} more steps, "
          f"final loss {losses2[-1]:.3f}")


if __name__ == "__main__":
    main()
