"""Quickstart: the paper's full pipeline in ~60 lines.

Trains a reduced 2s-AGCN on synthetic NTU-like skeletons, applies the
RFC-HyPGCN hybrid pruning (C1 dataflow-reorg channel pruning + C2 cavity
temporal pruning), quantizes to Q8.8, and runs compressed inference with
the RFC activation format.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.common.config import TrainConfig
from repro.configs import get_config
from repro.core.agcn import model as agcn
from repro.core.pruning.plan import build_prune_plan, drop_scheme
from repro.core.rfc.format import rfc_encode, storage_cost
from repro.data.pipeline import DataConfig, make_batches
from repro.launch.train import train_loop


def main():
    # 1. train the dense model for a few steps
    tcfg = TrainConfig(learning_rate=3e-3, total_steps=60, warmup_steps=6,
                       checkpoint_every=0, checkpoint_dir="/tmp/quickstart")
    params, losses = train_loop("agcn-2s", tcfg, reduced=True, batch=16,
                                seq=0, resume=False)
    print(f"\ntrained: loss {losses[0]:.3f} -> {losses[-1]:.3f}")

    # 2. measure per-block feature sparsity to drive the Drop scheme (Fig. 9)
    cfg = get_config("agcn-2s", reduced=True)
    batch = next(make_batches(cfg, DataConfig(global_batch=8, seq_len=0)))
    x = jnp.asarray(batch["x"])
    sparsity = agcn.feature_sparsity_per_block(params, x, cfg)
    keep = drop_scheme(sparsity)
    keep[0] = 1.0
    print("per-block sparsity:", [f"{s:.2f}" for s in sparsity])

    # 3. hybrid prune: C1 channel drop + C2 cavity pattern cav-70-1
    sw = [np.asarray(b["Wk"]) for b in params["blocks"]]
    plan = build_prune_plan(sw, cfg.gcn_channels, keep, "cav-70-1",
                            input_skip=2)
    s = plan.summary(cfg.gcn_channels, cfg.gcn_in_channels)
    print(f"compression {s['compression_ratio']:.2f}x, "
          f"graph-skip {s['graph_skip_efficiency']*100:.1f}%")

    # 4. quantized compressed inference
    logits = agcn.forward(params, x, cfg, plan=plan, quant=True)
    acc = float((logits.argmax(-1) == jnp.asarray(batch["labels"])).mean())
    print(f"pruned+quantized accuracy on batch: {acc:.3f}")

    # 5. RFC-compress an intermediate activation
    acts = jax.nn.relu(jax.random.normal(jax.random.PRNGKey(0), (512, 64)))
    _, hot = rfc_encode(acts, apply_relu=False)
    c = storage_cost(np.asarray(hot) > 0)
    print(f"RFC storage saving on activations: "
          f"{c['rfc_vs_dense_reduction']*100:.1f}%")


if __name__ == "__main__":
    main()
