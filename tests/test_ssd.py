"""SSD core: chunked scan == step recurrence (property over shapes/chunks),
plus the mLSTM/mamba2 layer decode-vs-parallel consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st  # optional test extra

from repro.models.layers.mamba2 import mamba2_init, mamba2_layer
from repro.models.layers.ssd import ssd_scan, ssd_step
from repro.models.layers.xlstm import (
    mlstm_init, mlstm_layer, slstm_init, slstm_layer,
)


def _naive(x, log_a, dt, Bm, Cm):
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    s = jnp.zeros((B, H, N, P))
    ys = []
    for t in range(S):
        y, s = ssd_step(s, x[:, t], log_a[:, t], dt[:, t], Bm[:, t], Cm[:, t])
        ys.append(y)
    return jnp.stack(ys, 1), s


@given(
    st.integers(1, 3),            # B
    st.integers(3, 40),           # S
    st.integers(1, 4),            # H
    st.sampled_from([4, 8, 16]),  # chunk
    st.integers(0, 1000),
)
@settings(max_examples=15, deadline=None)
def test_ssd_chunked_equals_recurrence(B, S, H, chunk, seed):
    P, N = 5, 4
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    log_a = -jnp.abs(jax.random.normal(ks[1], (B, S, H))) * 0.2
    dt = jnp.abs(jax.random.normal(ks[2], (B, S, H)))
    Bm = jax.random.normal(ks[3], (B, S, N))
    Cm = jax.random.normal(ks[4], (B, S, N))
    y, s = ssd_scan(x, log_a, dt, Bm, Cm, chunk=chunk)
    y_ref, s_ref = _naive(x, log_a, dt, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref),
                               atol=1e-3, rtol=1e-3)


@pytest.mark.parametrize("layer_init,layer_fn,kwargs,cache_init", [
    (
        lambda k, d: mamba2_init(k, d, 8),
        lambda p, x, c: mamba2_layer(p, x, 8, cache=c),
        {},
        lambda B, d: {
            "conv": jnp.zeros((B, 3, 2 * d)),
            "ssm": jnp.zeros((B, 2 * d // 64, 8, 64), jnp.float32),
        },
    ),
])
def test_mamba_decode_matches_parallel(layer_init, layer_fn, kwargs, cache_init):
    d, B, S = 128, 2, 12
    p = layer_init(jax.random.PRNGKey(0), d)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, d)) * 0.1
    y_par, _ = mamba2_layer(p, x, 8)
    cache = cache_init(B, d)
    ys = []
    for t in range(S):
        y, cache = mamba2_layer(p, x[:, t : t + 1], 8, cache=cache)
        ys.append(y)
    y_seq = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_seq),
                               atol=2e-3, rtol=2e-3)


def test_mlstm_decode_matches_parallel():
    d, B, S, H = 64, 2, 10, 2
    p = mlstm_init(jax.random.PRNGKey(0), d, H)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, d)) * 0.1
    y_par, _ = mlstm_layer(p, x, H)
    dh = 2 * d // H
    cache = {"ssm": jnp.zeros((B, H, dh, dh + 1), jnp.float32)}
    ys = []
    for t in range(S):
        y, cache = mlstm_layer(p, x[:, t : t + 1], H, cache=cache)
        ys.append(y)
    y_seq = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_seq),
                               atol=2e-3, rtol=2e-3)


def test_slstm_decode_matches_scan():
    d, B, S, H = 32, 2, 8, 2
    p = slstm_init(jax.random.PRNGKey(0), d, H)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, d)) * 0.2
    y_par, _ = slstm_layer(p, x, H)
    dh = d // H
    zeros = jnp.zeros((B, H, dh), jnp.float32)
    cache = {"c": zeros, "n": zeros, "h": zeros, "m": zeros}
    ys = []
    for t in range(S):
        y, cache = slstm_layer(p, x[:, t : t + 1], H, cache=cache)
        ys.append(y)
    y_seq = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_seq),
                               atol=2e-3, rtol=2e-3)
