"""Config-registry and input-spec invariants for all assigned archs."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.config import GCN_SHAPES, SHAPES
from repro.configs import (
    ASSIGNED, CONFIGS, applicable_shapes, get_config, input_specs,
    shape_applicable, sub_quadratic,
)

EXPECTED_ARCHS = {
    "h2o-danube-1.8b", "gemma3-12b", "internlm2-20b", "smollm-360m",
    "whisper-small", "llava-next-mistral-7b", "qwen3-moe-30b-a3b",
    "granite-moe-3b-a800m", "xlstm-1.3b", "zamba2-7b", "agcn-2s",
}


def test_registry_complete():
    assert set(CONFIGS) == EXPECTED_ARCHS
    assert len(ASSIGNED) == 10


def test_get_config_accepts_underscores():
    assert get_config("h2o_danube_1_8b").name == "h2o-danube-1.8b"
    with pytest.raises(KeyError):
        get_config("not-an-arch")


def test_long500k_applicability_matches_spec():
    """Spec: run long_500k for SSM/hybrid/SWA/local-global; skip for pure
    full attention."""
    runs = {a for a in ASSIGNED if shape_applicable(get_config(a), "long_500k")[0]}
    assert runs == {"h2o-danube-1.8b", "gemma3-12b", "xlstm-1.3b", "zamba2-7b"}


def test_40_cells_accounted():
    """10 archs × 4 shapes = 40 cells: every cell is either applicable or
    has a recorded skip reason."""
    total = 0
    for a in ASSIGNED:
        cfg = get_config(a)
        for s in SHAPES:
            ok, reason = shape_applicable(cfg, s)
            assert ok or reason
            total += 1
    assert total == 40


@pytest.mark.parametrize("arch", sorted(ASSIGNED))
@pytest.mark.parametrize("shape", list(SHAPES))
def test_input_specs_well_formed(arch, shape):
    cfg = get_config(arch)
    ok, _ = shape_applicable(cfg, shape)
    if not ok:
        pytest.skip("inapplicable cell")
    batch, axes = input_specs(cfg, shape)
    assert set(batch) == set(axes)
    shp = SHAPES[shape]
    for name, sds in batch.items():
        assert len(axes[name]) == len(sds.shape), name
        if name == "tokens":
            assert sds.shape[0] == shp.global_batch
            assert sds.dtype == jnp.int32
    if shp.is_decode:
        assert batch["tokens"].shape[1] == 1
        assert "pos" in batch
    elif cfg.family == "vlm":
        assert (batch["tokens"].shape[1] + cfg.num_image_tokens
                == shp.seq_len)


def test_gcn_shapes():
    cfg = get_config("agcn-2s")
    assert applicable_shapes(cfg) == list(GCN_SHAPES)
    batch, axes = input_specs(cfg, "gcn_train")
    n = GCN_SHAPES["gcn_train"].global_batch * cfg.gcn_persons
    assert batch["x"].shape == (n, cfg.gcn_frames, 25, 3)


def test_head_dim_kv_divisibility_for_mesh():
    """kv head_dim (cache 'kv_hd' rule) must divide by 16 for every arch —
    the invariant behind the decode-cell shardings."""
    for a in ASSIGNED:
        cfg = get_config(a)
        if cfg.num_kv_heads:
            assert cfg.head_dim % 16 == 0 or cfg.head_dim % 16 in (0,) or \
                cfg.head_dim * cfg.num_kv_heads % 16 == 0, a
        # fused qkv flat dim divisible too
        flat = (cfg.num_heads + 2 * cfg.num_kv_heads) * cfg.head_dim
        if flat:
            assert flat % 16 == 0, a


def test_padded_sizes():
    assert get_config("granite-moe-3b-a800m").padded_experts == 48
    assert get_config("whisper-small").padded_vocab % 256 == 0
    assert get_config("gemma3-12b").padded_vocab == 262144
