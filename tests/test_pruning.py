"""Hybrid-pruning plan tests: cavity balance invariants (hypothesis),
magnitude channel selection, coarse/fine plan accounting vs paper claims."""
import numpy as np
import pytest
from hypothesis_compat import given, settings, st  # optional test extra

from repro.core.pruning.cavity import balance_stats, cavity_pattern, tile_pattern
from repro.core.pruning.plan import (
    build_prune_plan, cavity_report, drop_scheme, select_channels_by_magnitude,
    unstructured_prune,
)


@given(st.integers(30, 85), st.integers(1, 2))
@settings(max_examples=40, deadline=None)
def test_cavity_keep_fraction(percent, variant):
    m = cavity_pattern(f"cav-{percent}-{variant}")
    keep = 1 - percent / 100
    assert abs(m.mean() - keep) < 2 / 72 + 1e-9      # rounding slack


@given(st.integers(30, 85))
@settings(max_examples=40, deadline=None)
def test_variant1_balanced_variant2_not(percent):
    b1 = balance_stats(cavity_pattern(f"cav-{percent}-1"))
    assert b1["balanced"], b1
    # paper: balanced patterns keep every position 2-3x in a cav-70 loop
    if percent == 70:
        assert b1["per_position_min"] >= 2
        assert b1["per_position_max"] <= 3


def test_cav70_2_unbalanced():
    b2 = balance_stats(cavity_pattern("cav-70-2"))
    assert not b2["balanced"]
    # paper: positions kept 1x..4x instead of 2-3x
    assert b2["per_position_max"] - b2["per_position_min"] >= 3


def test_magnitude_selection_keeps_biggest():
    w = np.zeros((3, 8, 4))
    w[:, 2] = 10.0
    w[:, 5] = 5.0
    kept = select_channels_by_magnitude(w, 0.25)
    assert kept == (2, 5)


def test_unstructured_prune_fraction():
    rng = np.random.default_rng(0)
    w = rng.standard_normal((64, 64))
    out = unstructured_prune(w, 0.7)
    assert abs((out == 0).mean() - 0.7) < 0.02


def _plan(keep_fracs, channels=(8, 8, 16, 16), cavity="cav-70-1"):
    rng = np.random.default_rng(0)
    cin = 3
    sw = []
    for cout in channels:
        sw.append(rng.standard_normal((3, cin, cout)).astype(np.float32))
        cin = cout
    return build_prune_plan(sw, channels, keep_fracs, cavity), channels


def test_plan_neighbour_connection():
    """Coarse temporal pruning = next block's kept input channels (Fig. 2)."""
    plan, channels = _plan([1.0, 0.5, 0.5, 0.5])
    for b in range(len(channels) - 1):
        assert plan.blocks[b].kept_filters == plan.blocks[b + 1].kept_in
    # last block keeps all filters
    assert len(plan.blocks[-1].kept_filters) == channels[-1]


def test_plan_block0_never_pruned():
    plan, _ = _plan([0.1, 0.5, 0.5, 0.5])
    assert len(plan.blocks[0].kept_in) == 3


def test_compression_ratio_in_paper_band():
    """Paper: 3.0x-8.4x compression across its pruning schemes."""
    agcn_channels = (64, 64, 64, 64, 128, 128, 128, 256, 256, 256)
    rng = np.random.default_rng(0)
    cin = 3
    sw = []
    for cout in agcn_channels:
        sw.append(rng.standard_normal((3, cin, cout)).astype(np.float32))
        cin = cout
    light = build_prune_plan(sw, agcn_channels, [1.0] + [0.5] * 9, "cav-50-1")
    heavy = build_prune_plan(sw, agcn_channels, [1.0] + [0.3] * 9, "cav-75-1")
    r_light = light.summary(agcn_channels, 3)["compression_ratio"]
    r_heavy = heavy.summary(agcn_channels, 3)["compression_ratio"]
    assert 2.4 < r_light < 4.5
    assert 5.0 < r_heavy < 9.0
    # graph-skip efficiency ~ channel drop rate (paper: 73.20% at Drop-*)
    gs = heavy.summary(agcn_channels, 3)["graph_skip_efficiency"]
    assert 0.6 < gs < 0.78


def test_drop_scheme_from_sparsity():
    keep = drop_scheme([0.3, 0.5, 0.7])
    assert keep == [0.7, 0.5, pytest.approx(0.3)]
    shifted = drop_scheme([0.3, 0.5, 0.7], shift=0.1)
    assert all(s < k for s, k in zip(shifted, keep))


def test_cavity_report():
    r = cavity_report("cav-70-1")
    assert r["balanced"]
