"""Blockwise flash attention vs naive reference: causal, SWA, GQA, cache."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.layers.attention import flash_attention


def naive_attention(q, k, v, causal=True, window=0, kv_valid=None):
    B, Sq, H, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    qg = q.reshape(B, Sq, Hkv, G, D)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k) / np.sqrt(D)
    qi = jnp.arange(Sq)[:, None]
    kj = jnp.arange(Skv)[None, :]
    mask = jnp.zeros((Sq, Skv))
    if causal:
        mask = jnp.where(kj > qi, -1e30, mask)
    if window:
        mask = jnp.where(qi - kj >= window, -1e30, mask)
    if kv_valid is not None:
        mask = jnp.where(kj >= kv_valid, -1e30, mask)
    p = jax.nn.softmax(s + mask, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, v)
    return out.reshape(B, Sq, H, D)


@pytest.mark.parametrize("S,H,Hkv,D,causal,window", [
    (64, 4, 2, 16, True, 0),
    (100, 4, 4, 8, True, 0),       # non-multiple of block
    (128, 8, 2, 16, True, 24),     # SWA
    (64, 4, 2, 16, False, 0),      # encoder
])
def test_flash_matches_naive(S, H, Hkv, D, causal, window):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(k1, (2, S, H, D))
    k = jax.random.normal(k2, (2, S, Hkv, D))
    v = jax.random.normal(k3, (2, S, Hkv, D))
    out = flash_attention(q, k, v, causal=causal, window=window,
                          q_block=16, kv_block=32)
    expected = naive_attention(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected),
                               atol=2e-5, rtol=2e-5)


def test_decode_query_offset():
    """Single query at position pos attends over kv_valid cache slots."""
    kk = jax.random.PRNGKey(3)
    S, H, D = 48, 4, 16
    q = jax.random.normal(kk, (1, 1, H, D))
    k = jax.random.normal(kk, (1, S, H, D))
    v = jax.random.normal(kk, (1, S, H, D))
    pos = 20
    out = flash_attention(q, k, v, causal=True, q_offset=pos,
                          kv_valid=jnp.asarray(pos + 1), kv_block=16)
    full_q = jnp.zeros((1, pos + 1, H, D)).at[:, -1].set(q[:, 0])
    expected = naive_attention(full_q, k[:, : pos + 1], v[:, : pos + 1],
                               causal=True)[:, -1:]
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected),
                               atol=2e-5, rtol=2e-5)
