"""Golden-trace regression locks — the deterministic replay contract.

Replaying a checked-in trace through a ``GcnService`` must reproduce the
*scheduler-tick-level* outcome sequence exactly: which sessions were
admitted, preempted, held, shed and finished on every tick, the tier
walk, and the per-class first-logit percentiles in ticks.  The locks in
``tests/data/traces/golden_smoke.json`` (regenerate with
``tools/gen_golden_outcomes.py`` after *intentional* scheduler-semantic
changes) cover the full (qos × policy) matrix on the reference backend.

The acceptance A/B rides here too: on the checked-in bursty+diurnal
trace, the demand policy breaches the high-priority p99 first-logit
bound that the SLO policy holds by shedding — on identical traffic.
"""
import json
import pathlib

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.agcn import engine
from repro.core.agcn import model as M
from repro.core.pruning.plan import build_prune_plan
from repro.serving import (SloConfig, Trace, outcome_digest, replay,
                           write_bench)

CFG = get_config("agcn-2s", reduced=True)
DATA = pathlib.Path(__file__).resolve().parent / "data" / "traces"

GOLDEN = json.loads((DATA / "golden_smoke.json").read_text())
SMOKE = Trace.load(str(DATA / "smoke.json"))
TIERS = tuple(GOLDEN["tiers"])


def _slo_config(shed_mode):
    return SloConfig(**{**GOLDEN["slo"], "shed_mode": shed_mode})


@pytest.fixture(scope="module")
def plans_bn():
    params = M.init_params(CFG, jax.random.PRNGKey(0))
    sw = [np.asarray(b["Wk"]) for b in params["blocks"]]
    pp = build_prune_plan(sw, CFG.gcn_channels, [1.0, 0.5, 0.5, 0.5],
                         "cav-70-1", input_skip=2)
    plan = engine.build_execution_plan(params, CFG, pp, quant=True,
                                       backend="reference")
    bn = engine.collect_bn_stats(plan, jax.random.normal(
        jax.random.PRNGKey(1),
        (2, CFG.gcn_frames, CFG.gcn_joints, CFG.gcn_in_channels)))
    return (plan,), (bn,)


def _replay_cell(plans_bn, qos, policy, trace=SMOKE, record=True):
    plans, bn = plans_bn
    shed_mode = "degrade" if policy == "slo-degrade" else "reject"
    pol = "slo" if policy.startswith("slo") else "demand"
    return replay(CFG, trace, backend="reference", qos=qos, policy=pol,
                  capacity_tiers=TIERS,
                  slo_config=_slo_config(shed_mode) if pol == "slo" else None,
                  plans=plans, bn_stats=bn, record_outcomes=record)


def test_trace_files_are_pinned():
    """The checked-in traces are the locks' inputs — their digests are
    part of the golden contract (regenerating with a drifted generator
    must fail here, not silently rebase the outcomes)."""
    assert SMOKE.digest() == GOLDEN["trace_digest"]
    assert SMOKE.name == GOLDEN["trace"] == "smoke-v1"
    big = Trace.load(str(DATA / "bursty_diurnal.json"))
    assert big.name == "bursty-diurnal-v1"
    assert big.digest() == "bed3d1610297"
    assert len(big.events) == 64


@pytest.mark.parametrize("qos,policy", [
    ("fifo", "demand"),
    ("fifo", "slo"),
    ("fifo", "slo-degrade"),
    pytest.param("preempt", "demand", marks=pytest.mark.slow),
    pytest.param("preempt", "slo", marks=pytest.mark.slow),
    pytest.param("deadline", "demand", marks=pytest.mark.slow),
    pytest.param("deadline", "slo", marks=pytest.mark.slow),
])
def test_golden_outcomes(plans_bn, qos, policy):
    """Tick-level outcome lock per (qos, policy) cell: the per-tick
    admission/preemption/shed/finish log hashes to the golden digest and
    the summary counters + tier walk + per-class first-logit percentiles
    match exactly."""
    want = GOLDEN["cells"][f"{qos}/{policy}"]
    out = _replay_cell(plans_bn, qos, policy)
    assert outcome_digest(out["outcomes"]) == want["outcome_digest"]
    assert out["ticks"] == want["ticks"]
    assert out["sessions"] == want["sessions"]
    assert out["preemptions"] == want["preemptions"]
    assert out["restores"] == want["restores"]
    assert out["deadline_missed"] == want["deadline_missed"]
    assert out["resize_events"] == want["migrations"]
    assert out["capacity_final"] == want["capacity_final"]
    for p, d in want["per_priority"].items():
        got = out["latency_ms_by_priority"][p]
        assert got["n"] == d["n"]
        assert got["first_logit_p50_ticks"] == d["first_logit_p50_ticks"]
        assert got["first_logit_p99_ticks"] == d["first_logit_p99_ticks"]
        assert got["e2e_p99_ticks"] == d["e2e_p99_ticks"]
    if policy.startswith("slo"):
        assert out["sessions_rejected"] == want["sessions_rejected"]
        assert out["sessions_degraded"] == want["sessions_degraded"]
        assert out["shed_windows"] == want["shed_windows"]
        # the golden slo cells must actually exercise shedding
        assert want["shed_windows"] > 0
        assert (want["sessions_rejected"] + want["sessions_degraded"]) > 0


def test_replay_twice_is_identical(plans_bn):
    """Replaying the same trace twice yields identical scheduler-tick
    outcomes — the determinism half of the acceptance criterion."""
    a = _replay_cell(plans_bn, "fifo", "slo")
    b = _replay_cell(plans_bn, "fifo", "slo")
    assert a["outcomes"] == b["outcomes"]
    assert outcome_digest(a["outcomes"]) == outcome_digest(b["outcomes"])


def test_trace_row_carries_merge_axes(plans_bn):
    """Replay rows merge into BENCH_sessions.json keyed on policy+trace
    (the A/B axes) and never leak the bulky outcome log."""
    out = _replay_cell(plans_bn, "fifo", "demand")
    assert out["policy"] == "demand"
    assert out["load"] == "trace"
    assert out["trace"] == "smoke-v1"
    from repro.serving import bench_key
    k1 = bench_key(out)
    k2 = bench_key(_replay_cell(plans_bn, "fifo", "slo"))
    assert k1 != k2 and k1[-5:-3] == ("demand", "smoke-v1")
    # the adaptive-streaming axes default to off for legacy rows
    assert k1[-2:] == (False, 0.0)


@pytest.mark.slow
def test_acceptance_slo_holds_where_demand_breaches(plans_bn, tmp_path):
    """THE acceptance criterion: on the checked-in bursty+diurnal trace,
    replayed under both policies on identical events, the demand
    controller breaches the high-priority p99 first-logit bound and the
    SLO controller holds it (by shedding low-priority opens at the top
    tier) — and the comparison rows land in a BENCH file with the
    ``policy`` key."""
    big = Trace.load(str(DATA / "bursty_diurnal.json"))
    target = 90
    # recover_patience used to be stretched to 12 so a long streak of
    # healthy *latched* samples couldn't un-shed while admitted sessions
    # were still in flight; the controller now tracks in-flight committed
    # latencies itself, so the stock patience suffices
    scfg = SloConfig(target_p99_ticks=target, window=24, breach_patience=2,
                     recover_patience=6, shed_mode="reject")
    plans, bn = plans_bn
    rows = []
    for policy in ("demand", "slo"):
        rows.append(replay(
            CFG, big, backend="reference", qos="fifo", policy=policy,
            capacity_tiers=(2, 4),
            slo_config=scfg if policy == "slo" else None,
            plans=plans, bn_stats=bn))
    demand, slo = rows
    hp_demand = demand["latency_ms_by_priority"]["1"]
    hp_slo = slo["latency_ms_by_priority"]["1"]
    assert hp_demand["first_logit_p99_ticks"] > target, \
        "demand was expected to breach on this trace"
    assert hp_slo["first_logit_p99_ticks"] <= target, \
        "slo must hold the high-priority bound"
    assert slo["sessions_rejected"] > 0          # held it BY shedding
    assert demand.get("sessions_rejected", 0) == 0
    bench = tmp_path / "BENCH_sessions.json"
    write_bench(rows, path=str(bench))
    saved = json.loads(bench.read_text())
    assert {r["policy"] for r in saved} == {"demand", "slo"}
    assert all(r["trace"] == "bursty-diurnal-v1" for r in saved)
