"""Streaming↔clip parity — the continual-inference correctness contract.

A clip fed frame-by-frame through ``engine.step_frame`` (plus the
``stream_flush_frames`` drain that materialises each block's 'same'-padding
latency) must produce the same logits as the batched clip engine, for both
backends — with the windowed C_k graph off *and* on (the adaptive-streaming
subsystem, repro.core.agcn.adaptive).  Also locks the stride-decimated
emission count, the jit-cache friendliness of the step (state/plan as
pytree args), the sliding-window pool, and the calibration preconditions.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.agcn import engine
from repro.core.agcn import model as M
from repro.core.pruning.plan import build_prune_plan
from repro.train.steps import make_gcn_infer_step, make_gcn_stream_step

CFG = get_config("agcn-2s", reduced=True)
N = 2


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def x():
    return jax.random.normal(jax.random.PRNGKey(1), (N, CFG.gcn_frames, 25, 3))


@pytest.fixture(scope="module")
def prune_plan(params):
    sw = [np.asarray(b["Wk"]) for b in params["blocks"]]
    return build_prune_plan(sw, CFG.gcn_channels, [1.0, 0.5, 0.5, 0.5],
                            "cav-70-1", input_skip=2)


def _stream(plan, x, state=None):
    """Feed a clip frame-by-frame + the flush drain; return (state, logits)."""
    if state is None:
        state = engine.init_stream_state(plan, x.shape[0], x_calib=x)
    step = jax.jit(engine.step_frame)
    T = x.shape[1]
    zeros = jnp.zeros_like(x[:, 0])
    logits = None
    for r in range(T + engine.stream_flush_frames(plan, T)):
        frame = x[:, r] if r < T else zeros
        state, logits = step(plan, state, frame, jnp.asarray(r < T))
    return state, logits


# ------------------------------------------------------------------- parity

@pytest.mark.parametrize("backend", ["reference", "pallas"])
def test_streaming_matches_clip_pruned_quant(params, x, prune_plan, backend):
    """The tentpole lock: post-warmup (fully drained) streaming logits equal
    the batched engine's on the paper's pruned+quantized target."""
    plan = engine.build_execution_plan(params, CFG, prune_plan, quant=True,
                                       backend=backend)
    want = engine.execute(plan, x)
    _, got = _stream(plan, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-3, rtol=1e-3)


@pytest.mark.slow
@pytest.mark.parametrize("backend", ["reference", "pallas"])
def test_streaming_matches_clip_dense(params, x, backend):
    plan = engine.build_execution_plan(params, CFG, backend=backend)
    want = engine.execute(plan, x)
    _, got = _stream(plan, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-3, rtol=1e-3)


def test_two_stream_step_matches_clip_ensemble(params, x):
    """make_gcn_stream_step (joint+bone ensemble) drains to the clip-mode
    two-stream step's logits — the serve --stream path."""
    pb = M.init_params(CFG, jax.random.PRNGKey(7))
    plans = tuple(engine.build_execution_plan(p, CFG, backend="reference")
                  for p in (params, pb))
    states = (engine.init_stream_state(plans[0], N, x_calib=x),
              engine.init_stream_state(plans[1], N,
                                       x_calib=M.bone_stream(x)))
    step = jax.jit(make_gcn_stream_step(CFG))
    T = x.shape[1]
    zeros = jnp.zeros_like(x[:, 0])
    logits = None
    for r in range(T + engine.stream_flush_frames(plans[0], T)):
        frame = x[:, r] if r < T else zeros
        states, logits = step(plans, states, frame, jnp.asarray(r < T))
    want = jax.jit(make_gcn_infer_step(CFG))(plans, x)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(want),
                               atol=1e-3, rtol=1e-3)


def test_streaming_matches_clip_odd_stride_length(params, prune_plan):
    """Odd frame count into the stride-2 block (the full 300-frame config's
    shape: 300 → skip 2 → 150 → stride 2 → 75 odd): the pallas clip kernel
    must produce conv-semantics ceil(T/stride) outputs — and streaming must
    still drain to clip parity — not silently drop the trailing output."""
    x_odd = jax.random.normal(jax.random.PRNGKey(3), (N, 30, 25, 3))
    ref = engine.execute(
        engine.build_execution_plan(params, CFG, prune_plan, quant=True,
                                    backend="reference"), x_odd)
    plan = engine.build_execution_plan(params, CFG, prune_plan, quant=True,
                                       backend="pallas")
    np.testing.assert_allclose(np.asarray(engine.execute(plan, x_odd)),
                               np.asarray(ref), atol=1e-3, rtol=1e-3)
    _, got = _stream(plan, x_odd)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               atol=1e-3, rtol=1e-3)


# -------------------------------------------------------- state machinery

def test_emission_count_matches_clip_output_length(params, x):
    """Stride decimation + input skip: exactly the clip engine's pooled
    frame count reaches the logit pool — no more (flush garbage is gated by
    the validity ring), no fewer."""
    plan = engine.build_execution_plan(params, CFG, backend="reference")
    state, _ = _stream(plan, x)
    t = -(-x.shape[1] // CFG.input_skip)
    for s in CFG.gcn_strides:
        t = (t - 1) // s + 1
    # pool_t is per-slot; a lockstep batch keeps every slot's clock equal
    np.testing.assert_array_equal(np.asarray(state.pool_t), t)


def test_stream_state_rides_jit_cache(params, x):
    """step_frame never retraces for a rebuilt plan or a fresh state — the
    streaming analogue of the clip engine's no-retrace invariant."""
    traces = []

    @jax.jit
    def counted(plan, state, frame, valid):
        traces.append(1)
        return engine.step_frame(plan, state, frame, valid)

    p1 = engine.build_execution_plan(params, CFG, backend="reference")
    p2 = engine.build_execution_plan(params, CFG, backend="reference")
    s1 = engine.init_stream_state(p1, N, x_calib=x)
    s2 = engine.init_stream_state(p2, N, x_calib=x)
    s1, a = counted(p1, s1, x[:, 0], jnp.asarray(True))
    s2, b = counted(p2, s2, x[:, 0], jnp.asarray(True))
    _, _ = counted(p1, s1, x[:, 1], jnp.asarray(False))
    assert len(traces) == 1
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_sliding_window_pool(params, x):
    """gcn_stream_pool=W: a window at least as long as the emission count is
    cumulative (clip parity); a shorter window changes the logits but keeps
    them finite (the live-stream mode)."""
    cfg_big = dataclasses.replace(CFG, gcn_stream_pool=16)
    cfg_small = dataclasses.replace(CFG, gcn_stream_pool=3)
    want = engine.execute(
        engine.build_execution_plan(params, CFG, backend="reference"), x)
    plan_big = engine.build_execution_plan(params, cfg_big,
                                           backend="reference")
    _, big = _stream(plan_big, x)
    np.testing.assert_allclose(np.asarray(big), np.asarray(want),
                               atol=1e-3, rtol=1e-3)
    plan_small = engine.build_execution_plan(params, cfg_small,
                                             backend="reference")
    state, small = _stream(plan_small, x)
    assert np.isfinite(np.asarray(small)).all()
    assert state.pool_ring.shape == (N, 3, CFG.gcn_channels[-1])
    assert not np.allclose(np.asarray(small), np.asarray(want), atol=1e-3)


def test_rfc_state_holds_encoded_interlayer_activations(params, x,
                                                        prune_plan):
    """Pallas streams carry the running RFC-encoded activations between
    blocks: hot is a 0/1 mask, values are front-packed non-negative
    (post-ReLU), and popcount matches the nonzero count."""
    plan = engine.build_execution_plan(params, CFG, prune_plan,
                                       backend="pallas")
    assert plan.static.use_rfc
    state, _ = _stream(plan, x)
    assert len(state.rfc) == len(plan.static.blocks) - 1
    for boundary in state.rfc:
        hot = np.asarray(boundary["vals"] != 0)
        assert int(hot.sum()) == int(np.asarray(boundary["hot"]).sum())


# -------------------------------------------------------- preconditions

def test_calibration_required(params):
    plan = engine.build_execution_plan(params, CFG, backend="reference")
    with pytest.raises(ValueError, match="frozen BN statistics"):
        engine.init_stream_state(plan, N)


# ------------------------------------------------- adaptive windowed C_k

CFG_CK = dataclasses.replace(CFG, use_ck=True)


@pytest.fixture(scope="module")
def ck_params():
    return M.init_params(CFG_CK, jax.random.PRNGKey(0))


@pytest.mark.parametrize("backend", ["reference", "pallas"])
def test_streaming_ck_matches_clip_dense(ck_params, x, backend):
    """The adaptive-streaming lock: with the windowed C_k graph ON, fully
    drained streaming logits equal clip logits on both backends — the
    embedding rings evaluate exactly the per-frame trailing-window
    recurrence clip mode runs (repro.core.agcn.adaptive)."""
    plan = engine.build_execution_plan(ck_params, CFG_CK, backend=backend)
    assert any(bs.use_ck for bs in plan.static.blocks)
    want = engine.execute(plan, x)
    state, got = _stream(plan, x)
    assert any("ck_th" in b for b in state.blocks)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-3, rtol=1e-3)


@pytest.mark.parametrize("backend", ["reference", "pallas"])
def test_streaming_ck_matches_clip_pruned_quant(ck_params, x, prune_plan,
                                                backend):
    """C_k parity survives the paper's deployment transforms: kept-channel
    gathers apply to the θ/φ projections identically in both modes, and
    quant leaves them untouched (only Wk/tconv weights are Q8.8)."""
    plan = engine.build_execution_plan(ck_params, CFG_CK, prune_plan,
                                       quant=True, backend=backend)
    want = engine.execute(plan, x)
    _, got = _stream(plan, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-3, rtol=1e-3)


def test_ck_changes_logits(params, ck_params, x):
    """use_ck=True must actually route through the windowed graph — C_k-on
    and C_k-off logits differ (same weights otherwise)."""
    on = engine.execute(
        engine.build_execution_plan(ck_params, CFG_CK, backend="reference"),
        x)
    base = {k: v for k, v in ck_params.items()}
    off = engine.execute(
        engine.build_execution_plan(base, CFG, backend="reference"), x)
    assert not np.allclose(np.asarray(on), np.asarray(off), atol=1e-3)


def test_ck_state_snapshot_restore_roundtrip(ck_params, x):
    """The embedding rings are ordinary per-slot leaves: snapshotting a
    mid-stream C_k slot, trampling it, and restoring resumes bit-identical
    to the uninterrupted stream."""
    plan = engine.build_execution_plan(ck_params, CFG_CK,
                                       backend="reference")
    state = engine.init_stream_state(plan, N, x_calib=x)
    step = jax.jit(engine.step_frame)
    for r in range(6):
        state, _ = step(plan, state, x[:, r], jnp.asarray(True))
    snap = engine.snapshot_slots(state, jnp.asarray(0))
    assert any("ck_th" in b for b in snap["blocks"])
    # trample slot 0 with foreign frames, then restore
    trampled = state
    for r in range(6, 10):
        trampled, _ = step(plan, trampled, x[:, r] * 3.0, jnp.asarray(True))
    restored = engine.restore_slots(trampled, jnp.asarray(0), snap)
    ref = state
    for r in range(6, 12):
        ref, ref_logits = step(plan, ref, x[:, r], jnp.asarray(True))
        restored, got_logits = step(plan, restored, x[:, r],
                                    jnp.asarray(True))
    np.testing.assert_array_equal(np.asarray(ref_logits)[0],
                                  np.asarray(got_logits)[0])
    for rb, gb in zip(ref.blocks, restored.blocks):
        np.testing.assert_array_equal(np.asarray(rb["ck_th"])[0],
                                      np.asarray(gb["ck_th"])[0])


def test_flush_frames_formula(params):
    """stream_flush_frames covers every block's pad·stride-product latency
    in raw-frame time (exact backward recurrence, not an upper bound)."""
    plan = engine.build_execution_plan(params, CFG, backend="reference")
    # reduced cfg: skip 2, strides (1,1,2,1), K=9 -> drain worked by hand
    assert engine.stream_flush_frames(plan, CFG.gcn_frames) == 37
    assert engine.stream_flush_frames(plan, 0) >= 0
