"""Per-architecture smoke tests (required deliverable f): every assigned
arch instantiates its REDUCED config and runs one forward/train step on CPU,
asserting output shapes and no NaNs; decode consistency for the LM families."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.config import TrainConfig
from repro.configs import ASSIGNED, CONFIGS, REDUCED, get_config
from repro.data.pipeline import DataConfig, make_batches
from repro.models import registry
from repro.optim import adamw
from repro.train.steps import make_serve_step, make_train_step

ARCHS = list(REDUCED)


def _batch(cfg, B=2, S=32):
    if cfg.family == "gcn":
        d = make_batches(cfg, DataConfig(global_batch=B, seq_len=0))
        return jax.tree_util.tree_map(jnp.asarray, next(d))
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32),
    }
    if cfg.family == "vlm":
        batch["image_embeds"] = jnp.asarray(
            rng.standard_normal((B, cfg.num_image_tokens, cfg.d_model)),
            jnp.float32)
    if cfg.family == "audio":
        batch["frames"] = jnp.asarray(
            rng.standard_normal((B, cfg.encoder_frames, cfg.d_model)),
            jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = get_config(arch, reduced=True)
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    tcfg = TrainConfig(total_steps=10, warmup_steps=2)
    step = jax.jit(make_train_step(cfg, tcfg))
    opt = adamw.init(params)
    new_params, new_opt, metrics = step(params, opt, batch)
    assert not bool(jnp.isnan(metrics["loss"]))
    assert int(new_opt.step) == 1
    # params actually moved
    moved = jax.tree_util.tree_map(
        lambda a, b: float(jnp.abs(a - b).max()), params, new_params)
    assert max(jax.tree_util.tree_leaves(moved)) > 0


@pytest.mark.parametrize("arch", [a for a in ARCHS if a != "agcn-2s"])
def test_smoke_decode_step(arch):
    cfg = get_config(arch, reduced=True)
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    B = 2
    cache = registry.init_cache(cfg, B, 16, jnp.float32)
    step = jax.jit(make_serve_step(cfg))
    b = {"tokens": jnp.zeros((B, 1), jnp.int32), "pos": jnp.asarray(0, jnp.int32)}
    if cfg.family == "audio":
        b["memory"] = jnp.zeros((B, cfg.encoder_frames, cfg.d_model))
    tok, new_cache = step(params, cache, b)
    assert tok.shape == (B,)
    assert tok.dtype == jnp.int32


@pytest.mark.parametrize("arch", ["h2o-danube-1.8b", "gemma3-12b",
                                  "qwen3-moe-30b-a3b", "xlstm-1.3b",
                                  "zamba2-7b"])
def test_decode_consistency_with_parallel_forward(arch):
    """Teacher-forced decode through the cache reproduces the parallel
    forward logits at every position (flash+cache vs train path)."""
    cfg = get_config(arch, reduced=True)
    params = registry.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 1, 8
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    if cfg.family in ("dense", "moe", "vlm"):
        from repro.models import decoder
        logits_par, _, _ = decoder.forward(params, toks, cfg)
    elif cfg.family == "ssm":
        from repro.models import ssm_model
        logits_par, _ = ssm_model.forward(params, toks, cfg)
    else:
        from repro.models import hybrid
        logits_par, _ = hybrid.forward(params, toks, cfg)

    cache = registry.init_cache(cfg, B, S, jnp.float32)
    outs = []
    for t in range(S):
        b = {"tokens": toks[:, t : t + 1], "pos": jnp.asarray(t, jnp.int32)}
        logits, cache = registry.serve_fn(params, b, cache, cfg)
        outs.append(logits[:, 0])
    logits_seq = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(logits_seq, np.float32), np.asarray(logits_par, np.float32),
        atol=2e-2, rtol=2e-2)


def test_all_assigned_archs_present():
    assert len(ASSIGNED) == 10
    assert "agcn-2s" in CONFIGS


def test_param_count_estimates_in_range():
    """Full-config analytic param counts land near the advertised sizes."""
    expect = {
        "h2o-danube-1.8b": (1.2e9, 2.5e9),
        "gemma3-12b": (8e9, 14e9),
        "internlm2-20b": (15e9, 23e9),
        "smollm-360m": (2.5e8, 5e8),
        "qwen3-moe-30b-a3b": (20e9, 36e9),
        "xlstm-1.3b": (0.8e9, 2.0e9),
        "zamba2-7b": (5e9, 9e9),
    }
    for name, (lo, hi) in expect.items():
        n = get_config(name).param_count_estimate()
        assert lo < n < hi, (name, n)


def test_swa_ring_buffer_cache_matches_full():
    """Decode past the window with the ring-buffer KV cache reproduces the
    parallel SWA forward logits exactly (wrap-around correctness)."""
    cfg = get_config("h2o-danube-1.8b", reduced=True)     # window 16
    from repro.models import decoder
    params = registry.init_params(cfg, jax.random.PRNGKey(3))
    B, S = 1, 28                                          # > window: wraps
    rng = np.random.default_rng(3)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    logits_par, _, _ = decoder.forward(params, toks, cfg)

    cache = registry.init_cache(cfg, B, S, jnp.float32)
    assert cache["k"].shape[3] == cfg.window_size         # ring allocated
    outs = []
    for t in range(S):
        b = {"tokens": toks[:, t : t + 1], "pos": jnp.asarray(t, jnp.int32)}
        logits, cache = registry.serve_fn(params, b, cache, cfg)
        outs.append(logits[:, 0])
    logits_seq = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(logits_seq, np.float32), np.asarray(logits_par, np.float32),
        atol=2e-2, rtol=2e-2)
