"""The one-dispatch serving tick (engine.fused_tick + GcnService fused path).

The tentpole locks:

* **Fused == legacy, bitwise** — on both backends, a scripted QoS trace
  (admissions, preemptions with restores, a mid-clip elastic grow/shrink
  migration) produces byte-identical final logits whether the service
  runs the fused single-dispatch tick or the legacy multi-dispatch
  sequence; bystander sessions riding alongside the churn are identical
  too (every session in the trace is compared).
* **Single dispatch per tick** — the fused service issues exactly one
  jitted call per tick regardless of event counts, while the legacy path
  pays 2 extra dispatches per snapshot/restore event per stream.
* **One compilation per tier** — snapshot/restore event counts (0, 1,
  max) are traced values of the fixed-shape sentinel-padded order
  buffers, so they never retrace; overflowing the static buffer raises
  instead of silently retracing.

Plus the host-side satellites: the scheduler's per-tick event budget
defers surplus preemptions (never overflows the static buffers), the
snapshot-ring allocator raises on exhaustion, and the jax-free sentinel
mirror in the scheduler equals the engine's.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.agcn import engine
from repro.core.agcn import model as M
from repro.core.pruning.plan import build_prune_plan
from repro.serving import CapacityConfig, GcnService, SessionRequest
from repro.serving import scheduler as sched_mod
from repro.serving.scheduler import SlabScheduler, pad_event_orders

CFG = get_config("agcn-2s", reduced=True)
V, C = CFG.gcn_joints, CFG.gcn_in_channels


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def prune_plan(params):
    sw = [np.asarray(b["Wk"]) for b in params["blocks"]]
    return build_prune_plan(sw, CFG.gcn_channels, [1.0, 0.5, 0.5, 0.5],
                            "cav-70-1", input_skip=2)


def _plan_and_bn(params, prune_plan, backend):
    plan = engine.build_execution_plan(params, CFG, prune_plan, quant=True,
                                       backend=backend)
    bn = engine.collect_bn_stats(
        plan, jax.random.normal(jax.random.PRNGKey(1),
                                (2, CFG.gcn_frames, V, C)))
    return plan, bn


def _qos_trace(rng):
    """(arrival, priority, T) script: fills a 2-slot tier with low-prio
    clips, lands high-prio arrivals that force snapshot evictions and
    later restores, and keeps enough backlog to trip an elastic grow."""
    spec = [(0, 0, 12), (0, 0, 12), (1, 0, 10), (1, 0, 10),
            (2, 1, 6), (3, 1, 6), (5, 0, 8), (18, 0, 7)]
    return [SessionRequest(
        sid=i, arrival=a, priority=p,
        clip=rng.standard_normal((T, V, C)).astype(np.float32))
        for i, (a, p, T) in enumerate(spec)]


def _drive_requests(svc, reqs, max_ticks=600):
    """Feed a SessionRequest script through the handle API, run to idle;
    returns ({sid: final logits}, metrics)."""
    pending = sorted(reqs, key=lambda r: r.arrival)
    i = 0
    while svc.now < max_ticks:
        while i < len(pending) and pending[i].arrival <= svc.now:
            r = pending[i]
            h = svc.open_session(priority=r.priority, arrival=r.arrival)
            svc.submit_clip(h, r.clip)
            i += 1
        if svc.idle():
            if i == len(pending):
                break
            svc.advance_clock(pending[i].arrival)
            continue
        svc.tick()
    assert svc.idle(), "service did not drain within the tick budget"
    m = svc.metrics()
    return {rec.sid: rec.logits for rec in m["records"]}, m


@pytest.mark.slow
@pytest.mark.parametrize("backend", ["reference", "pallas"])
def test_fused_matches_legacy_qos_trace(params, prune_plan, backend):
    """Fused single-dispatch ticks == legacy multi-dispatch ticks, bitwise,
    across preemptions + restores + an elastic grow/shrink migration —
    including every bystander session riding through the churn — and the
    fused path really is one device dispatch per tick."""
    plan, bn = _plan_and_bn(params, prune_plan, backend)
    ccfg = CapacityConfig(tiers=(2, 4), grow_patience=1, shrink_patience=2,
                          cooldown=3)
    runs = {}
    for fused in (True, False):
        svc = GcnService(CFG, backend=backend, plans=(plan,),
                         bn_stats=(bn,), qos="preempt",
                         capacity_tiers=(2, 4), capacity_config=ccfg,
                         fused=fused)
        runs[fused] = _drive_requests(svc, _qos_trace(np.random.default_rng(7)))
    of, mf = runs[True]
    ol, ml = runs[False]
    # the trace actually exercised the churn it scripts
    assert mf["preemptions"] > 0 and mf["restores"] > 0
    assert mf["migrations"] > 0
    assert mf["preemptions"] == ml["preemptions"]
    assert mf["migrations"] == ml["migrations"]
    # single dispatch per tick, fused; legacy pays per-event dispatches
    assert mf["device_dispatches"] == mf["ticks"]
    assert ml["device_dispatches"] > ml["ticks"]
    assert mf["tick_path"] == "fused" and ml["tick_path"] == "legacy"
    # wall split satellite: both components present and sum to wall_s
    assert mf["wall_s"] == pytest.approx(
        mf["wall_host_s"] + mf["wall_device_s"])
    assert set(of) == set(ol)
    for sid in sorted(of):
        np.testing.assert_array_equal(of[sid], ol[sid],
                                      err_msg=f"session {sid}")


def test_fused_no_retrace_across_event_counts(params, prune_plan):
    """0, 1 and max snapshot/restore events per tick reuse ONE compilation
    per entry point per tier: event-free ticks hit the plain step, event
    ticks hit the fused megakernel whose order buffers are traced values
    of the static sentinel-padded shape — never shape changes."""
    plan, bn = _plan_and_bn(params, prune_plan, "reference")
    svc = GcnService(CFG, plans=(plan,), bn_stats=(bn,), qos="preempt",
                     capacity_tiers=(2,), warm=False, fused=True)
    from repro.train.steps import make_gcn_fused_tick, make_gcn_slab_step
    inner = make_gcn_fused_tick(CFG)
    inner_step = make_gcn_slab_step(CFG)
    traces = []
    step_traces = []

    def counted(plans, slabs, frames, valid, reset, hold,
                snap_order, rest_order, rings):
        traces.append(1)
        return inner(plans, slabs, frames, valid, reset, hold,
                     snap_order, rest_order, rings)

    def counted_step(plans, slabs, frames, valid, reset, hold):
        step_traces.append(1)
        return inner_step(plans, slabs, frames, valid, reset, hold)

    svc._fused_tick = jax.jit(counted, donate_argnums=(1, 8))
    svc._step = jax.jit(counted_step)
    rng = np.random.default_rng(11)

    def arrive(priority, T):
        h = svc.open_session(priority=priority)
        svc.submit_clip(h, rng.standard_normal((T, V, C)).astype(np.float32))
        return h

    arrive(0, 8)
    svc.tick()                       # 0 events -> plain step dispatch
    arrive(0, 8)
    svc.tick()                       # 0 events, second slot fills
    assert len(traces) == 0          # no events yet: megakernel untouched
    arrive(1, 4)
    svc.tick()                       # 1 snapshot event (preempt)
    arrive(1, 4)
    svc.tick()                       # max events for S=2: both slots evict
    assert svc.sched.preemptions >= 2
    svc.run_until_idle()             # restores drain the preempted pair
    assert svc.sched.restores == svc.sched.preemptions
    assert len(traces) == 1, "fused tick retraced within one tier"
    assert len(step_traces) == 1, "no-event step retraced within one tier"


def test_sentinel_and_overflow():
    """The scheduler's jax-free sentinel mirrors the engine's, and
    overflowing the static order buffer raises instead of retracing."""
    assert sched_mod.SNAP_SENTINEL == int(engine.SNAP_SENTINEL)
    buf = pad_event_orders([(0, 3), (1, 0)], 4)
    assert buf.shape == (4, 2) and buf.dtype == np.int32
    assert (buf[2:] == sched_mod.SNAP_SENTINEL).all()
    np.testing.assert_array_equal(buf[:2], [[0, 3], [1, 0]])
    with pytest.raises(ValueError, match="overflow"):
        pad_event_orders([(0, 0), (1, 1), (2, 2)], 2)


def _host_sched(slots, snap_ring=None):
    return SlabScheduler(slots, V, C, flush_frames=lambda n: 0,
                         first_logit_delay=1, policy="preempt",
                         snap_ring=snap_ring)


def test_event_budget_defers_surplus_preemptions():
    """A preempt storm beyond the per-tick budget defers to later ticks —
    the fixed-shape order buffers can never overflow — and every deferred
    eviction still happens."""
    S = 16
    sched = _host_sched(S, snap_ring=64)
    assert sched.max_events == sched_mod.MAX_EVENTS_PER_TICK == 8
    for sid in range(S):             # fill every slot with low priority
        sched.submit(SessionRequest(sid=sid, arrival=0, priority=0,
                                    clip=np.zeros((20, V, C), np.float32)))
    sched.tick_inputs(0, 0.0)
    assert sched.busy() == S
    for sid in range(S, 2 * S):      # a full-slab high-priority storm
        sched.submit(SessionRequest(sid=sid, arrival=1, priority=1,
                                    clip=np.zeros((4, V, C), np.float32)))
    tp = sched.tick_inputs(1, 1.0)
    assert len(tp.snapshot) == 8     # capped at the budget...
    assert len(tp.snap_order) == 8
    tp = sched.tick_inputs(2, 2.0)
    assert len(tp.snapshot) == 8     # ...and the rest evict next tick
    assert sched.preemptions == 16


def test_snapshot_ring_exhaustion_raises():
    """More live device snapshots than ring rows is a loud RuntimeError
    naming the knob, not a silent overwrite."""
    sched = _host_sched(2, snap_ring=1)
    for sid in range(2):
        sched.submit(SessionRequest(sid=sid, arrival=0, priority=0,
                                    clip=np.zeros((20, V, C), np.float32)))
    sched.tick_inputs(0, 0.0)
    for sid in range(2, 4):
        sched.submit(SessionRequest(sid=sid, arrival=1, priority=1,
                                    clip=np.zeros((4, V, C), np.float32)))
    with pytest.raises(RuntimeError, match="snap_capacity"):
        sched.tick_inputs(1, 1.0)


def test_queue_sid_index_tracks_membership():
    """The O(1) poll indexes stay consistent through push/pop/drop_if."""
    sched = _host_sched(2)
    q = sched.queue
    reqs = [SessionRequest(sid=i, arrival=i, priority=i % 2,
                           clip=np.zeros((2, V, C), np.float32))
            for i in range(5)]
    for r in reqs:
        q.push(r)
    assert all(q.get(r.sid) is r for r in reqs)
    popped = q.pop()                 # highest priority, earliest arrival
    assert q.get(popped.sid) is None
    dropped = q.drop_if(lambda it: it.sid == 4)
    assert [d.sid for d in dropped] == [4] and q.get(4) is None
    assert len(q) == 3 and all(q.get(i) is not None for i in (0, 2))


def test_poll_async_default_never_forces_readback(params, prune_plan):
    """Regression: poll() used to force the pending tick's logits to host
    on every call, so a polling client serialized the fused pipeline.
    The default poll is now async — mid-clip polls return logits=None and
    leave ``_last_logits`` as a device future — and only ``wait=True``
    (or a finishing session) pays the readback, which lands in the
    wall_device_s / device_dispatches accounting."""
    plan, bn = _plan_and_bn(params, prune_plan, "reference")
    svc = GcnService(CFG, plans=(plan,), bn_stats=(bn,), capacity_tiers=(2,),
                     fused=True)
    rng = np.random.default_rng(13)
    h = svc.open_session()
    svc.submit_clip(h, rng.standard_normal((20, V, C)).astype(np.float32))
    for _ in range(4):                    # a polling client, every tick
        svc.tick()
        st = svc.poll(h)
        assert st.state == "active" and st.logits is None
        # the tick's logits are still an un-forced device future
        assert not isinstance(svc._last_logits, np.ndarray)
    wd0 = svc.wall_device_s
    st = svc.poll(h, wait=True)           # opt-in sync point
    assert isinstance(st.logits, np.ndarray)
    assert isinstance(svc._last_logits, np.ndarray)
    assert svc.wall_device_s >= wd0
    # once forced, further async polls read the host buffer for free
    assert svc.poll(h).logits is not None
    svc.run_until_idle()
    m = svc.metrics()
    assert m["device_dispatches"] == m["ticks"]   # polling added none
    assert svc.poll(h).state == "done"
    assert np.isfinite(svc.poll(h).logits).all()
