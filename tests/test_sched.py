"""Dynamic-scheduling expectation model (paper eq. 6 / Table II)."""
import numpy as np
import pytest
from hypothesis_compat import given, settings, st  # optional test extra

from repro.core.sched.expectation import (
    delay_probability, dsp_allocation, expected_valid, scheduling_report,
    valid_work_pmf,
)


@given(st.integers(1, 12), st.floats(0.0, 1.0))
@settings(max_examples=60, deadline=None)
def test_expectation_closed_form(w, s):
    """E(D) = w·(1-s) — the binomial mean (paper eq. 6 is the w=6 case)."""
    assert expected_valid(w, s) == pytest.approx(w * (1 - s), abs=1e-9)


@given(st.integers(1, 12), st.floats(0.0, 1.0))
@settings(max_examples=60, deadline=None)
def test_pmf_normalised(w, s):
    assert valid_work_pmf(w, s).sum() == pytest.approx(1.0, abs=1e-9)


def test_expectation_matches_monte_carlo():
    """expected_valid against a sampled Binomial(w, 1-s) simulation of the
    Dyn-Mult-PE waiting queues: the analytic mean must sit within 2% of the
    Monte-Carlo mean over a (w, sparsity) grid — the direct check that the
    closed form models the process it claims to (paper eq. 6)."""
    rng = np.random.default_rng(42)
    n = 200_000
    for w in (2, 4, 6, 12):
        for s in (0.1, 0.35, 0.5, 0.65, 0.8):
            sampled = rng.binomial(w, 1.0 - s, size=n).mean()
            assert expected_valid(w, s) == pytest.approx(sampled, rel=0.02)


def test_delay_probability_matches_monte_carlo():
    """delay_probability(w, s, d) == P(valid work > d multipliers), sampled:
    the Table II 'max delay' proxy is a real tail probability."""
    rng = np.random.default_rng(7)
    n = 200_000
    for w, s in ((6, 0.5), (6, 0.35), (4, 0.65)):
        draws = rng.binomial(w, 1.0 - s, size=n)
        for d in (1, 3, w):
            assert delay_probability(w, s, d) == pytest.approx(
                float((draws > d).mean()), abs=5e-3)


def test_dsp_allocation_bounds():
    for w in (4, 6):
        for s in (0.2, 0.5, 0.8):
            d = dsp_allocation(w, s)
            assert 1 <= d <= w


def test_paper_table2_ballpark():
    """Paper Table II: dynamic scheduling saves ~23% DSPs at ≤7.4% delay for
    ~50-65% feature sparsity (Table III shows most vectors in II/III)."""
    rep = scheduling_report(6, 0.5)
    assert rep["dsp_saving"] >= 0.2
    assert rep["delay_prob"] <= 0.15
    assert rep["efficiency"] >= 0.6


def test_delay_monotone_in_dsps():
    probs = [delay_probability(6, 0.5, d) for d in range(1, 7)]
    assert all(a >= b for a, b in zip(probs, probs[1:]))
    assert probs[-1] == 0.0
