"""MoE dispatch tests: the cumsum-compaction (RFC-analogous) routing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.layers.moe import moe_ffn, moe_init


def dense_moe_oracle(p, x, num_experts, top_k, act="silu"):
    """Compute every expert on every token and combine with top-k gates —
    the no-capacity-limit ground truth."""
    B, S, d = x.shape
    E = p["router"].shape[-1]
    logits = (x.reshape(-1, d) @ p["router"]).astype(jnp.float32)
    logits = jnp.where(jnp.arange(E) < num_experts, logits, -1e30)
    probs = jax.nn.softmax(logits, -1)
    gv, gi = jax.lax.top_k(probs, top_k)
    gv = gv / gv.sum(-1, keepdims=True)
    h = jax.nn.silu(jnp.einsum("td,edf->tef", x.reshape(-1, d), p["wg"])) * \
        jnp.einsum("td,edf->tef", x.reshape(-1, d), p["wi"])
    out_all = jnp.einsum("tef,efd->ted", h, p["wo"])
    gates = jnp.zeros((B * S, E)).at[
        jnp.arange(B * S)[:, None], gi].set(gv)
    out = jnp.einsum("ted,te->td", out_all, gates)
    return out.reshape(B, S, d)


def test_moe_matches_dense_oracle_with_big_capacity():
    E, k, d, ff = 8, 2, 16, 32
    p = moe_init(jax.random.PRNGKey(0), d, ff, E, E)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, d))
    out, aux = moe_ffn(p, x, num_experts=E, top_k=k, capacity_factor=8.0)
    expected = dense_moe_oracle(p, x, E, k)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected),
                               atol=1e-4, rtol=1e-4)
    assert float(aux) > 0


def test_moe_padded_experts_never_routed():
    E, Ep, k, d, ff = 5, 8, 2, 16, 32
    p = moe_init(jax.random.PRNGKey(0), d, ff, E, Ep)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, d))
    logits = (x.reshape(-1, d) @ p["router"]).astype(jnp.float32)
    logits = jnp.where(jnp.arange(Ep) < E, logits, -1e30)
    _, gi = jax.lax.top_k(jax.nn.softmax(logits, -1), k)
    assert int(gi.max()) < E                      # pads masked out
    out, _ = moe_ffn(p, x, num_experts=E, top_k=k, capacity_factor=4.0)
    assert not bool(jnp.isnan(out).any())


def test_moe_capacity_drops_dont_nan():
    E, k, d, ff = 4, 2, 8, 16
    p = moe_init(jax.random.PRNGKey(0), d, ff, E, E)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, d))
    out, _ = moe_ffn(p, x, num_experts=E, top_k=k, capacity_factor=0.25)
    assert not bool(jnp.isnan(out).any())
    # with tiny capacity some tokens must produce zero output
    norms = jnp.linalg.norm(out[0], axis=-1)
    assert float(norms.min()) < float(norms.max())


def test_moe_gates_sum_preserved():
    """Dispatch+combine with huge capacity preserves gate normalisation:
    scaling x scales out linearly (homogeneity sanity)."""
    E, k, d, ff = 4, 2, 8, 16
    p = moe_init(jax.random.PRNGKey(0), d, ff, E, E)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, d))
    out1, _ = moe_ffn(p, x, num_experts=E, top_k=k, capacity_factor=8.0)
    assert out1.shape == x.shape
