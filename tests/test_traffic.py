"""The traffic model, trace format and SLO controller — host-side units.

Everything here is jax-free (the scheduler/controller/traffic layers are
pure host logic), so the module runs in seconds.  The tentpole locks:

* **Exact serialization** — ``Trace.from_json(trace.to_json())`` is
  event-for-event identical, clip bytes included (each event's clip
  derives from its own ``clip_seed``, never from generator state), and
  unknown schema versions are rejected loudly.
* **Determinism across processes** — the same ``TrafficConfig`` yields
  the same digest in a fresh interpreter (the golden traces are
  regenerable), and two *interleaved* ``TraceGenerator``\\ s reproduce
  their solo sequences exactly (no global RNG state anywhere).
* **The model's statistics** — the diurnal non-homogeneous Poisson
  integrates to the requested mean rate, heavy-tailed length draws match
  their tail index (Hill estimator, Monte-Carlo bounds), flash crowds
  cluster within their span.
* **SloController state walk** — grow on breach, shed at the top tier,
  two-step recovery (un-shed before SLO-safe shrink), cooldown
  hysteresis, and the admission verdicts (protected class never shed).
"""
import dataclasses
import json
import math
import subprocess
import sys

import numpy as np
import pytest

from hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st
from repro.serving.capacity import CapacityConfig, CapacityManager
from repro.serving.scheduler import bursty_arrivals, poisson_arrivals
from repro.serving.slo import (CONTROL_POLICIES, SHED_MODES, SloConfig,
                               SloController)
from repro.serving.traffic import (LENGTH_DISTS, TRACE_SCHEMA_VERSION, Trace,
                                   TraceEvent, TraceGenerator, TrafficConfig,
                                   event_clip, generate_trace)

V, C = 25, 3


# ---------------------------------------------------------------------------
# trace format: exact round-trip + schema versioning
# ---------------------------------------------------------------------------

def _sample_config(**kw):
    base = dict(n_sessions=40, mean_interarrival=6.0, diurnal_amplitude=0.7,
                diurnal_period=120.0, flash_crowd_prob=0.3,
                flash_crowd_size=3.0, flash_crowd_span=4.0,
                length_dist="lognormal", mean_frames=12.0, length_sigma=0.5,
                min_frames=3, max_frames=40, high_priority_ratio=0.25,
                seed=9)
    base.update(kw)
    return TrafficConfig(**base)


def test_trace_roundtrip_exact():
    trace = generate_trace(_sample_config(), name="rt")
    back = Trace.from_json(trace.to_json())
    assert back == trace                      # frozen dataclass equality
    assert back.digest() == trace.digest()
    # and the round-trip is idempotent at the byte level
    assert back.to_json() == trace.to_json()


def test_trace_events_sorted_and_ids_unique():
    trace = generate_trace(_sample_config())
    arr = [e.arrival for e in trace.events]
    assert arr == sorted(arr)
    assert len({e.sid for e in trace.events}) == len(trace.events)


def test_trace_rejects_unknown_schema_version():
    trace = generate_trace(_sample_config(n_sessions=3))
    doc = json.loads(trace.to_json())
    doc["version"] = TRACE_SCHEMA_VERSION + 1
    with pytest.raises(ValueError, match="schema version"):
        Trace.from_json(json.dumps(doc))


def test_trace_save_load(tmp_path):
    trace = generate_trace(_sample_config(), name="disk")
    p = tmp_path / "t.json"
    trace.save(str(p))
    assert Trace.load(str(p)) == trace


def test_event_clip_is_byte_deterministic():
    e = TraceEvent(sid=0, arrival=0, frames=7, clip_seed=12345)
    a, b = event_clip(e, V, C), event_clip(e, V, C)
    assert a.dtype == np.float32 and a.shape == (7, V, C)
    np.testing.assert_array_equal(a, b)
    # a different seed means different bytes — clips are per-event, not
    # positional
    e2 = dataclasses.replace(e, clip_seed=54321)
    assert not np.array_equal(a, event_clip(e2, V, C))


# ---------------------------------------------------------------------------
# determinism: same seed, fresh process, interleaved generators
# ---------------------------------------------------------------------------

def test_same_seed_same_trace():
    cfg = _sample_config()
    assert generate_trace(cfg) == generate_trace(cfg)


def test_cross_process_determinism():
    """The checked-in traces are regenerable: a fresh interpreter draws
    the identical event sequence from the same TrafficConfig."""
    cfg = _sample_config(n_sessions=24)
    here = generate_trace(cfg).digest()
    code = (
        "import sys; sys.path.insert(0, 'src')\n"
        "from repro.serving.traffic import TrafficConfig, generate_trace\n"
        f"cfg = TrafficConfig(**{dataclasses.asdict(cfg)!r})\n"
        "print(generate_trace(cfg).digest())\n")
    out = subprocess.run([sys.executable, "-c", code], cwd=".",
                         capture_output=True, text=True, check=True)
    assert out.stdout.strip() == here


def test_interleaved_generators_reproduce_solo():
    """Two generators advanced in lockstep draw exactly what each draws
    alone — the no-global-RNG contract."""
    ca, cb = _sample_config(seed=1), _sample_config(seed=2, mean_frames=20.0)
    solo_a = list(TraceGenerator(ca))
    solo_b = list(TraceGenerator(cb))
    ga, gb = TraceGenerator(ca), TraceGenerator(cb)
    inter_a, inter_b = [], []
    for _ in range(ca.n_sessions):
        inter_a.append(next(ga))
        inter_b.append(next(gb))
    assert inter_a == solo_a
    assert inter_b == solo_b


def test_poisson_bursty_rng_threading():
    """The legacy load generators take an explicit Generator and never
    touch global numpy state: interleaving two of them reproduces each
    solo sequence, and the seed fallback is unchanged."""
    lengths = [8] * 12
    def arr(reqs):
        return [(r.arrival, len(r.clip), r.priority) for r in reqs]

    solo_p = arr(poisson_arrivals(12, 4.0, lengths, V, C,
                                  rng=np.random.default_rng(3),
                                  high_priority_ratio=0.5))
    solo_b = arr(bursty_arrivals(12, lengths, V, C,
                                 rng=np.random.default_rng(4),
                                 high_priority_ratio=0.5))
    # interleave: the *other* generator's draws must not perturb ours
    ra, rb = np.random.default_rng(3), np.random.default_rng(4)
    np.random.seed(0)                      # pollute global state on purpose
    inter_p = arr(poisson_arrivals(12, 4.0, lengths, V, C, rng=ra,
                                   high_priority_ratio=0.5))
    np.random.seed(1234)
    inter_b = arr(bursty_arrivals(12, lengths, V, C, rng=rb,
                                  high_priority_ratio=0.5))
    assert inter_p == solo_p
    assert inter_b == solo_b
    # seed fallback still deterministic
    assert arr(poisson_arrivals(12, 4.0, lengths, V, C, seed=7)) == \
        arr(poisson_arrivals(12, 4.0, lengths, V, C, seed=7))


# ---------------------------------------------------------------------------
# the model's statistics (deterministic grid; Monte-Carlo cells are slow)
# ---------------------------------------------------------------------------

def test_config_validation():
    with pytest.raises(ValueError):
        TrafficConfig(mean_interarrival=0.0)
    with pytest.raises(ValueError):
        TrafficConfig(diurnal_amplitude=1.0)      # rate must stay positive
    with pytest.raises(ValueError):
        TrafficConfig(length_dist="weibull")
    with pytest.raises(ValueError):
        TrafficConfig(length_dist="pareto", pareto_alpha=1.0)
    with pytest.raises(ValueError):
        TrafficConfig(min_frames=5, max_frames=4)
    assert "lognormal" in LENGTH_DISTS and "pareto" in LENGTH_DISTS


def test_rate_is_diurnal():
    cfg = _sample_config(diurnal_amplitude=0.5, diurnal_period=100.0,
                         mean_interarrival=10.0)
    assert cfg.rate(25.0) == pytest.approx(0.15)     # peak: (1+A)/mean
    assert cfg.rate(75.0) == pytest.approx(0.05)     # trough: (1-A)/mean
    # integrates to the base rate over a whole period
    ts = np.linspace(0.0, 100.0, 10_001)
    mean_rate = np.trapezoid([cfg.rate(t) for t in ts], ts) / 100.0
    assert mean_rate == pytest.approx(0.1, rel=1e-3)


def test_diurnal_empirical_mean_matches_requested():
    """The thinned non-homogeneous process integrates to the requested
    mean inter-arrival (flash crowds off — they add arrivals on top)."""
    cfg = TrafficConfig(n_sessions=4000, mean_interarrival=5.0,
                        diurnal_amplitude=0.8, diurnal_period=200.0,
                        length_dist="fixed", mean_frames=8.0, seed=11)
    ev = generate_trace(cfg).events
    span = ev[-1].arrival - ev[0].arrival
    empirical = span / (len(ev) - 1)
    assert empirical == pytest.approx(5.0, rel=0.06)


def test_fixed_lengths_are_exact():
    cfg = _sample_config(length_dist="fixed", mean_frames=9.0,
                         min_frames=1, max_frames=0, flash_crowd_prob=0.0)
    assert {e.frames for e in generate_trace(cfg).events} == {9}


def test_lengths_respect_clamp():
    cfg = _sample_config(length_dist="pareto", pareto_alpha=1.5,
                         mean_frames=10.0, min_frames=4, max_frames=32)
    fr = [e.frames for e in generate_trace(cfg).events]
    assert min(fr) >= 4 and max(fr) <= 32


def test_flash_crowds_cluster_within_span():
    """With crowds on, some inter-arrival gaps must collapse below the
    crowd span even though the base mean is far larger."""
    cfg = TrafficConfig(n_sessions=300, mean_interarrival=50.0,
                        flash_crowd_prob=0.5, flash_crowd_size=4.0,
                        flash_crowd_span=3.0, length_dist="fixed",
                        mean_frames=8.0, seed=2)
    ev = generate_trace(cfg).events
    gaps = np.diff([e.arrival for e in ev])
    # crowds make small gaps common; a plain exp(50) process would put
    # ~6% of gaps at <= 3 ticks — crowds push that way up
    assert (gaps <= 3).mean() > 0.3
    off = TrafficConfig(n_sessions=300, mean_interarrival=50.0,
                        length_dist="fixed", mean_frames=8.0, seed=2)
    gaps_off = np.diff([e.arrival for e in generate_trace(off).events])
    assert (gaps <= 3).mean() > 4 * max((gaps_off <= 3).mean(), 1e-3)


@pytest.mark.slow
def test_lognormal_mean_converges():
    cfg = TrafficConfig(n_sessions=20_000, mean_interarrival=1.0,
                        length_dist="lognormal", mean_frames=30.0,
                        length_sigma=0.6, min_frames=1, max_frames=0,
                        seed=13)
    fr = np.asarray([e.frames for e in generate_trace(cfg).events], float)
    assert fr.mean() == pytest.approx(30.0, rel=0.05)


@pytest.mark.slow
def test_pareto_tail_index_matches():
    """Hill estimator over the top decile recovers the configured tail
    index within Monte-Carlo bounds — the draws really are heavy-tailed,
    not a clipped exponential."""
    alpha = 2.0
    cfg = TrafficConfig(n_sessions=20_000, mean_interarrival=1.0,
                        length_dist="pareto", pareto_alpha=alpha,
                        mean_frames=20.0, min_frames=1, max_frames=0,
                        seed=17)
    fr = np.sort(np.asarray(
        [e.frames for e in generate_trace(cfg).events], float))[::-1]
    k = len(fr) // 10
    hill = 1.0 / np.mean(np.log(fr[:k] / fr[k]))
    assert hill == pytest.approx(alpha, rel=0.15)


# ---------------------------------------------------------------------------
# SloController: the state walk the service drives
# ---------------------------------------------------------------------------

def _controller(**kw):
    base = dict(target_p99_ticks=50, window=16, breach_patience=2,
                recover_patience=3, cooldown=3, shed_mode="reject")
    base.update(kw)
    return SloController(SloConfig(**base), tiers=(2, 4), start_tier=2)


def test_slo_config_validation():
    with pytest.raises(ValueError):
        SloConfig(target_p99_ticks=0)
    with pytest.raises(ValueError):
        SloConfig(shed_mode="drop")
    with pytest.raises(ValueError):
        SloConfig(degrade_stride=1)
    with pytest.raises(ValueError):
        SloConfig(cooldown=2)
    with pytest.raises(ValueError):
        SloConfig(shrink_margin=0.0)
    with pytest.raises(ValueError):
        SloConfig(degrade_stride=4, degrade_stride_max=2)
    assert SloConfig(degrade_stride_max=0).degrade_stride_max == 0
    assert SloConfig(degrade_stride=2, degrade_stride_max=8) is not None
    assert CONTROL_POLICIES == ("demand", "slo")
    assert SHED_MODES == ("reject", "degrade")


def test_slo_grows_then_sheds_then_recovers_then_shrinks():
    c = _controller()
    # sustained breach at tier 0 -> grow to 4
    for p in (1, 1, 1):
        c.record_first_logit(p, 80)
    t = 0
    target = None
    while target is None:
        target = c.observe(busy=2, queued=3, tick=t)
        t += 1
    assert target == 4 and c.capacity == 4 and not c.shedding
    # breach persists at the top tier -> shedding switches on
    t += c.config.cooldown
    while not c.shedding:
        c.observe(busy=4, queued=3, tick=t)
        t += 1
    assert c.shed_windows == 1
    assert c.admit(0) == "reject" and c.admit(1) == "accept"
    # recovery: healthy samples -> un-shed FIRST (no resize that tick)
    c._samples.clear()
    for _ in range(8):
        c.record_first_logit(1, 10)
    while c.shedding:
        assert c.observe(busy=1, queued=0, tick=t) is None
        t += 1
    assert c.capacity == 4                     # un-shed before any shrink
    # continued health + demand fitting the lower tier -> SLO-safe shrink
    target = None
    while target is None:
        target = c.observe(busy=1, queued=0, tick=t)
        t += 1
    assert target == 2 and c.capacity == 2
    ev = [(e.old, e.new) for e in c.events]
    assert ev == [(2, 4), (4, 2)]


def test_slo_shrink_requires_healthy_latency():
    """Low occupancy alone never shrinks — the measured p99 must sit
    under shrink_margin x target (the SLO-safe half of the contract)."""
    c = _controller(shrink_margin=0.5)
    c._idx = 1                                  # start at the top tier
    for _ in range(8):
        c.record_first_logit(1, 40)             # healthy vs 50, but > 25
    for t in range(40):
        assert c.observe(busy=1, queued=0, tick=t) is None
    assert c.capacity == 4


def test_slo_anticipates_breach_from_queue_age():
    """A queued session older than target - latency_floor is already
    committed to breaching; the controller must not wait for the latch."""
    c = SloController(SloConfig(target_p99_ticks=50, breach_patience=2,
                                cooldown=3), tiers=(2, 4), start_tier=2,
                      latency_floor=41)
    assert c.breached(queue_age=10)            # 10 + 41 > 50
    assert not c.breached(queue_age=9)
    c.observe(busy=2, queued=1, tick=0, queue_age=10)
    assert c.observe(busy=2, queued=1, tick=1, queue_age=11) == 4


def test_slo_cooldown_no_thrash():
    """No second resize can land inside the cooldown window."""
    c = _controller(cooldown=5)
    for _ in range(4):
        c.record_first_logit(1, 90)
    t = 0
    while c.observe(busy=2, queued=2, tick=t) is None:
        t += 1
    grow_tick = t
    # now feed perfect health — the shrink must still wait out cooldown
    c._samples.clear()
    for _ in range(8):
        c.record_first_logit(1, 5)
    for tt in range(grow_tick + 1, grow_tick + 5):
        assert c.observe(busy=0, queued=0, tick=tt) is None
    assert all(b.tick - a.tick >= 5
               for a, b in zip(c.events, c.events[1:]) if True)


def test_slo_idle_reset_clears_stale_window():
    c = _controller()
    for _ in range(8):
        c.record_first_logit(1, 500)
    c.shedding = True
    c.idle_reset()
    assert c.measured_p99() is None and not c.shedding
    assert c.admit(0) == "accept"


def test_slo_degrade_mode_counts():
    c = _controller(shed_mode="degrade", degrade_stride=3)
    c.shedding = True
    assert c.admit(0) == "degrade"
    assert c.admit(1) == "accept"
    assert c.shed_degraded == 1 and c.shed_rejected == 0


def test_slo_degrade_stride_adapts_to_breach_depth():
    """With ``degrade_stride_max`` set, every further breach_patience-long
    streak that fires while already shedding doubles the stride handed to
    newly degraded opens (2 -> 4 -> 8, capped at the max), and a recovery
    that un-sheds resets it to the configured base."""
    c = _controller(shed_mode="degrade", degrade_stride=2,
                    degrade_stride_max=8)
    c._idx = 1                                  # top tier: breaches shed
    for _ in range(4):
        c.record_first_logit(1, 500)            # deep sustained breach
    t = 0
    while not c.shedding:
        c.observe(busy=4, queued=4, tick=t)
        t += 1
    assert c.shed_depth == 1
    assert c.degrade_stride_now() == 2          # first shed: base stride
    for depth, stride in ((2, 4), (3, 8)):
        while c.shed_depth < depth:
            c.observe(busy=4, queued=4, tick=t)
            t += 1
        assert c.degrade_stride_now() == stride
    for _ in range(10):                         # depth keeps rising...
        c.observe(busy=4, queued=4, tick=t)
        t += 1
    assert c.shed_depth > 3
    assert c.degrade_stride_now() == 8          # ...stride stays capped
    assert c.admit(0) == "degrade"
    # recovery un-sheds and zeroes the depth -> base stride again
    c._samples.clear()
    for _ in range(8):
        c.record_first_logit(1, 5)
    while c.shedding:
        c.observe(busy=1, queued=0, tick=t)
        t += 1
    assert c.shed_depth == 0 and c.degrade_stride_now() == 2
    # legacy contract: max=0 pins the stride no matter how deep
    fixed = _controller(shed_mode="degrade", degrade_stride=3)
    fixed.shed_depth = 7
    assert fixed.degrade_stride_now() == 3


def test_slo_protected_p99_prefers_protected_class():
    c = _controller()
    for _ in range(4):
        c.record_first_logit(0, 900)            # low-priority noise
    c.record_first_logit(1, 30)
    assert c.measured_p99() == 30.0             # protected only
    assert c.measured_p99(protected_only=False) == 900.0


def test_demand_manager_unchanged_contract():
    """The demand controller the SLO policy replaces still grows on raw
    demand with no latency signal at all — the A/B's other arm."""
    m = CapacityManager(CapacityConfig(tiers=(2, 4), grow_patience=2,
                                       cooldown=3), start_tier=2)
    assert m.observe(busy=2, queued=1, tick=0) is None
    assert m.observe(busy=2, queued=1, tick=1) == 4


# ---------------------------------------------------------------------------
# hypothesis cells (skip cleanly when the library is absent)
# ---------------------------------------------------------------------------

@given(st.integers(min_value=0, max_value=2**31 - 1),
       st.integers(min_value=0, max_value=10**6),
       st.integers(min_value=1, max_value=10**4),
       st.integers(min_value=0, max_value=3),
       st.integers(min_value=0, max_value=2**31 - 1))
@settings(max_examples=50, deadline=None)
def test_event_roundtrip_property(sid, arrival, frames, priority, clip_seed):
    e = TraceEvent(sid=sid, arrival=arrival, frames=frames,
                   priority=priority, clip_seed=clip_seed)
    assert TraceEvent.from_json(json.loads(json.dumps(e.to_json()))) == e


@given(st.lists(st.integers(min_value=0, max_value=10**6), min_size=1,
                max_size=200))
@settings(max_examples=50, deadline=None)
def test_measured_p99_is_order_statistic(samples):
    c = SloController(SloConfig(window=len(samples)), tiers=(4,))
    for s in samples:
        c.record_first_logit(1, s)
    p99 = c.measured_p99()
    assert min(samples) <= p99 <= max(samples)


@given(st.integers(min_value=0, max_value=2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_trace_same_seed_identical_property(seed):
    cfg = TrafficConfig(n_sessions=8, mean_interarrival=3.0,
                        flash_crowd_prob=0.4, seed=seed)
    assert generate_trace(cfg) == generate_trace(cfg)
