"""Per-kernel allclose tests: shape/dtype sweeps against the pure-jnp
oracles in repro.kernels.ref (interpret-mode Pallas on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.pruning.cavity import cavity_pattern, tile_pattern
from repro.kernels import ops, ref


@pytest.mark.parametrize("rows,cols", [(8, 16), (32, 64), (100, 48), (7, 160)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rfc_encode_matches_ref(rows, cols, dtype):
    x = jax.random.normal(jax.random.PRNGKey(rows * cols), (rows, cols), dtype)
    v_k, h_k = ops.rfc_encode(x)
    v_r, h_r = ref.rfc_encode_ref(x.astype(jnp.float32))
    np.testing.assert_allclose(
        np.asarray(v_k, np.float32), np.asarray(v_r), atol=1e-2)
    np.testing.assert_array_equal(np.asarray(h_k) > 0, np.asarray(h_r) > 0)


@pytest.mark.parametrize("rows,cols", [(8, 16), (32, 64), (100, 48)])
def test_rfc_roundtrip(rows, cols):
    x = jax.random.normal(jax.random.PRNGKey(1), (rows, cols))
    v, h = ops.rfc_encode(x)
    out = ops.rfc_decode(v, h)
    np.testing.assert_allclose(np.asarray(out), np.maximum(np.asarray(x), 0),
                               atol=1e-6)


def test_rfc_multidim():
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 5, 64))
    v, h = ops.rfc_encode(x)
    out = ops.rfc_decode(v, h)
    assert out.shape == x.shape
    np.testing.assert_allclose(np.asarray(out), np.maximum(np.asarray(x), 0),
                               atol=1e-6)


@pytest.mark.parametrize("pattern", ["cav-50-1", "cav-70-1", "cav-75-1"])
@pytest.mark.parametrize("F,C,T,stride", [
    (16, 16, 64, 1), (24, 32, 48, 2), (8, 8, 32, 1),
])
def test_cavity_tconv_matches_ref(pattern, F, C, T, stride):
    k = jax.random.PRNGKey(F * C + stride)
    w = np.asarray(jax.random.normal(k, (F, C, 9)), np.float32)
    mask = tile_pattern(cavity_pattern(pattern), F)
    wm = w * mask[:, None, :]
    x = jax.random.normal(k, (4, T, C))
    out_ref = ref.cavity_tconv_ref(x, jnp.asarray(wm), stride=stride)
    wp, taps, inv = ops.pack_cavity_weights(wm, mask)
    out = ops.cavity_tconv(x, jnp.asarray(wp), jnp.asarray(taps), inv, F,
                           stride=stride)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_ref),
                               atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("R,V,Ci,Co,K", [
    (32, 25, 16, 32, 3), (64, 25, 64, 64, 3), (16, 25, 3, 8, 3),
    # odd batch×time products: row axis > one tile and not a tile multiple
    # must be padded by ops.graph_sconv, not handed to the grid raw
    (260, 25, 8, 16, 3), (130, 25, 4, 8, 3),
])
def test_graph_sconv_matches_ref(R, V, Ci, Co, K):
    k = jax.random.PRNGKey(R + Ci)
    x = jax.random.normal(k, (2, R // 2, V, Ci))
    g = jax.random.normal(k, (K, V, V))
    w = jax.random.normal(k, (K, Ci, Co))
    out = ops.graph_sconv(x, g, w)
    expected = ref.graph_sconv_ref(x.reshape(R, V, Ci), g, w).reshape(
        2, R // 2, V, Co)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected),
                               atol=1e-3, rtol=1e-3)


@pytest.mark.parametrize("B,S,Hkv,G,D,valid", [
    (1, 512, 2, 4, 32, 512),
    (2, 1024, 4, 3, 64, 700),
    (3, 512, 1, 1, 128, 17),
])
def test_flash_decode_matches_ref(B, S, Hkv, G, D, valid):
    from repro.kernels.flash_decode import flash_decode_pallas
    ks = jax.random.split(jax.random.PRNGKey(B * S), 3)
    q = jax.random.normal(ks[0], (B, Hkv, G, D))
    k = jax.random.normal(ks[1], (B, S, Hkv, D))
    v = jax.random.normal(ks[2], (B, S, Hkv, D))
    out = flash_decode_pallas(q, k, v, jnp.asarray(valid, jnp.int32))
    expected = ref.flash_decode_ref(q, k, v, valid)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected),
                               atol=3e-5, rtol=3e-5)


def test_cavity_flop_skip_ratio():
    """The packed kernel issues n_keep taps instead of 9 — the paper's
    compute skip, visible in the packed weight shapes."""
    mask = cavity_pattern("cav-70-1")
    F = 32
    w = np.ones((F, 8, 9), np.float32) * tile_pattern(mask, F)[:, None, :]
    wp, taps, _ = ops.pack_cavity_weights(w, tile_pattern(mask, F))
    assert wp.shape[1] <= 4          # ≤4 kept taps vs 9 → ≥55% skipped
    assert wp.shape[1] >= 2
