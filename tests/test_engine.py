"""Execution-engine tests: full-model reference↔pallas parity (dense,
pruned+quantized, RFC-roundtrip variants) on the reduced 4-block config,
and ExecutionPlan compile invariants (pure/idempotent build, jit-cache
friendliness, no re-packing inside the jitted step)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.agcn import engine
from repro.core.agcn import model as M
from repro.core.pruning.plan import build_prune_plan
from repro.train.steps import make_gcn_infer_step

CFG = get_config("agcn-2s", reduced=True)


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def x():
    return jax.random.normal(jax.random.PRNGKey(1), (4, CFG.gcn_frames, 25, 3))


@pytest.fixture(scope="module")
def prune_plan(params):
    sw = [np.asarray(b["Wk"]) for b in params["blocks"]]
    return build_prune_plan(sw, CFG.gcn_channels, [1.0, 0.5, 0.5, 0.5],
                            "cav-70-1", input_skip=2)


def _assert_logits_close(a, b, atol=1e-3):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               atol=atol, rtol=1e-3)


# ------------------------------------------------------------------ parity
#
# Full backend parity matrix: {dense, pruned, pruned+quant} × {rfc on/off}.
# The two cells the engine serves by default (dense+rfc, pruned+quant+rfc —
# pallas plans default use_rfc=True) stay in the fast tier; the remaining
# pallas-interpret cells are `slow` (deselected by ./test.sh --fast).

_FAST_CELLS = {("dense", True), ("pruned_quant", True)}
MATRIX = [
    pytest.param(variant, rfc,
                 id=f"{variant}-{'rfc' if rfc else 'norfc'}",
                 marks=() if (variant, rfc) in _FAST_CELLS
                 else pytest.mark.slow)
    for variant in ("dense", "pruned", "pruned_quant")
    for rfc in (True, False)
]


@pytest.mark.parametrize("variant,rfc", MATRIX)
def test_backend_parity_matrix(params, x, prune_plan, variant, rfc):
    plan = None if variant == "dense" else prune_plan
    quant = variant == "pruned_quant"
    ref = engine.execute(
        engine.build_execution_plan(params, CFG, plan, quant=quant,
                                    backend="reference"), x)
    pal = engine.execute(
        engine.build_execution_plan(params, CFG, plan, quant=quant,
                                    backend="pallas", use_rfc=rfc), x)
    _assert_logits_close(ref, pal)


def test_rfc_roundtrip_is_exact_interlayer_format(params, x, prune_plan):
    """RFC encode/decode between blocks is lossless on post-ReLU
    activations — the pallas inter-layer format changes no logits."""
    with_rfc = engine.build_execution_plan(
        params, CFG, prune_plan, backend="pallas", use_rfc=True)
    without = engine.build_execution_plan(
        params, CFG, prune_plan, backend="pallas", use_rfc=False)
    assert with_rfc.static.use_rfc and not without.static.use_rfc
    _assert_logits_close(engine.execute(with_rfc, x),
                         engine.execute(without, x), atol=1e-5)


def test_forward_dispatches_backend_plan_quant_kwargs(params, x, prune_plan):
    """model.forward's (backend=, plan=, quant=) plumbing compiles the same
    plan the engine would — the PR-1 dispatcher API stays covered now that
    the parity matrix drives engine.execute directly."""
    via_forward = M.forward(params, x, CFG, plan=prune_plan, quant=True,
                            backend="pallas")
    direct = engine.execute(
        engine.build_execution_plan(params, CFG, prune_plan, quant=True,
                                    backend="pallas"), x)
    _assert_logits_close(via_forward, direct, atol=0)


def test_forward_accepts_prebuilt_plan(params, x):
    ep = engine.build_execution_plan(params, CFG, backend="pallas")
    direct = engine.execute(ep, x)
    via_forward = M.forward(params, x, CFG, exec_plan=ep)
    _assert_logits_close(direct, via_forward, atol=0)


# ------------------------------------------------------------ plan compile

def test_plan_build_is_pure_and_idempotent(params):
    p1 = engine.build_execution_plan(params, CFG, backend="pallas")
    p2 = engine.build_execution_plan(params, CFG, backend="pallas")
    assert p1.static == p2.static
    l1, l2 = jax.tree_util.tree_leaves(p1), jax.tree_util.tree_leaves(p2)
    assert len(l1) == len(l2)
    for a, b in zip(l1, l2):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_jitted_step_does_not_retrace_on_rebuilt_plan(params, x):
    """Plans ride as pytree args: a rebuilt (identical) plan must hit the
    same jit cache entry — all packing happened at build time."""
    traces = []
    step = make_gcn_infer_step(CFG)

    @jax.jit
    def counted(plans, xx):
        traces.append(1)
        return step(plans, xx)

    p1 = engine.build_execution_plan(params, CFG, backend="pallas")
    p2 = engine.build_execution_plan(params, CFG, backend="pallas")
    a = counted((p1,), x)
    b = counted((p2,), x)
    assert len(traces) == 1
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_pallas_plan_cannot_be_built_inside_jit(params, x):
    """Cavity packing is host-side by design: tracing a pallas plan build
    raises instead of silently re-packing inside the step."""
    def bad_step(p, xx):
        ep = engine.build_execution_plan(p, CFG, backend="pallas")
        return engine.execute(ep, xx)

    with pytest.raises(ValueError, match="outside jit"):
        jax.jit(bad_step)(params, x)


def test_unknown_backend_rejected(params):
    with pytest.raises(ValueError, match="unknown backend"):
        engine.build_execution_plan(params, CFG, backend="cuda")


def test_two_stream_step_matches_model_ensemble(params, x):
    pb = M.init_params(CFG, jax.random.PRNGKey(7))
    plans = tuple(engine.build_execution_plan(p, CFG, backend="reference")
                  for p in (params, pb))
    step = jax.jit(make_gcn_infer_step(CFG))
    got = step(plans, x)
    want = M.two_stream_logits(params, pb, x, CFG)
    _assert_logits_close(got, want, atol=1e-5)
