"""Multi-session slab serving — the session-scheduler correctness contract.

The lock: S sessions streamed *concurrently* through one session slab
(staggered admissions, different clip lengths, slot recycling through the
traced reset mask) must produce, at each session's eviction, the same
logits as S *independent* single-stream ``step_frame`` runs — on both
backends.  Plus host-side SlabScheduler bookkeeping (admission queueing,
occupancy, first-logit ticks), Poisson load-generation determinism,
``reset_slots`` isolation, and the no-retrace invariant of the slab step.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.agcn import engine
from repro.core.agcn import model as M
from repro.core.pruning.plan import build_prune_plan
from repro.launch import sessions as sess

CFG = get_config("agcn-2s", reduced=True)
V, C = CFG.gcn_joints, CFG.gcn_in_channels


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def x():
    return jax.random.normal(jax.random.PRNGKey(1), (2, CFG.gcn_frames, V, C))


@pytest.fixture(scope="module")
def prune_plan(params):
    sw = [np.asarray(b["Wk"]) for b in params["blocks"]]
    return build_prune_plan(sw, CFG.gcn_channels, [1.0, 0.5, 0.5, 0.5],
                            "cav-70-1", input_skip=2)


def _run_scheduled(plan, reqs, slots):
    """Drive a slab through the SlabScheduler; return {sid: final logits}."""
    bn = engine.collect_bn_stats(
        plan, jax.random.normal(jax.random.PRNGKey(1),
                                (2, CFG.gcn_frames, V, C)))
    slab = engine.init_session_slab(plan, slots, bn_stats=bn)
    sched = sess.SlabScheduler(
        slots, V, C,
        flush_frames=lambda T: engine.stream_flush_frames(plan, T),
        first_logit_delay=engine.stream_first_logit_delay(plan))
    step = jax.jit(engine.step_frames)
    pending = sorted(reqs, key=lambda r: r.arrival)
    i = 0
    for tick in range(500):
        while i < len(pending) and pending[i].arrival <= tick:
            sched.submit(pending[i])
            i += 1
        if i == len(pending) and sched.idle():
            break
        frames, valid, reset = sched.tick_inputs(tick, 0.0)
        slab, logits = step(plan, slab, jnp.asarray(frames),
                            jnp.asarray(valid), jnp.asarray(reset))
        sched.tick_outputs(tick, np.asarray(logits), 0.0)
    assert sched.idle(), "scheduler did not drain within the tick budget"
    return {r.sid: r.logits for r in sched.completed}, bn


def _run_independent(plan, bn, clip):
    """One session alone: batch-1 step_frame over clip + flush drain."""
    state = engine.init_stream_state(plan, 1, bn_stats=bn)
    step = jax.jit(engine.step_frame)
    xc = jnp.asarray(clip)[None]
    T = xc.shape[1]
    zeros = jnp.zeros_like(xc[:, 0])
    logits = None
    for r in range(T + engine.stream_flush_frames(plan, T)):
        frame = xc[:, r] if r < T else zeros
        state, logits = step(plan, state, frame, jnp.asarray(r < T))
    return np.asarray(logits)[0]


# ------------------------------------------------------------------- parity

@pytest.mark.parametrize("backend", ["reference", "pallas"])
def test_slab_matches_independent_streams(params, prune_plan, backend):
    """The tentpole lock: staggered concurrent sessions through the slab
    (including a queued session admitted into a *recycled* slot) equal
    independent single-stream runs, on the paper's pruned+quant target."""
    plan = engine.build_execution_plan(params, CFG, prune_plan, quant=True,
                                       backend=backend)
    rng = np.random.default_rng(3)
    lengths = (24, 14, 10)                 # different clip lengths
    clips = [rng.standard_normal((T, V, C)).astype(np.float32)
             for T in lengths]
    # 2 slots, 3 sessions: sid 2 queues until sid 1's drain frees its slot
    reqs = [sess.SessionRequest(sid=i, arrival=a, clip=c)
            for i, (a, c) in enumerate(zip((0, 4, 9), clips))]
    got, bn = _run_scheduled(plan, reqs, slots=2)
    assert sorted(got) == [0, 1, 2]
    for i, clip in enumerate(clips):
        want = _run_independent(plan, bn, clip)
        np.testing.assert_allclose(got[i], want, atol=1e-3, rtol=1e-3,
                                   err_msg=f"session {i} (backend={backend})")


def test_slab_step_never_retraces(params, x):
    """Admissions, evictions and occupancy changes are traced masking: one
    compilation serves every (reset, valid) combination."""
    plan = engine.build_execution_plan(params, CFG, backend="reference")
    slab = engine.init_session_slab(plan, 3, x_calib=x)
    traces = []

    @jax.jit
    def counted(plan, slab, frames, valid, reset):
        traces.append(1)
        return engine.step_frames(plan, slab, frames, valid, reset)

    frames = jnp.zeros((3, V, C))
    for valid, reset in (((1, 0, 0), (1, 0, 0)),
                         ((1, 1, 0), (0, 1, 0)),
                         ((0, 0, 0), (0, 0, 0))):
        slab, _ = counted(plan, slab, frames,
                          jnp.asarray(valid, bool), jnp.asarray(reset, bool))
    assert len(traces) == 1


def test_reset_slots_isolates(params, x):
    """reset_slots zeroes exactly the masked slots' per-slot state and
    never touches other slots or the shared frozen BN calibration."""
    plan = engine.build_execution_plan(params, CFG, backend="reference")
    slab = engine.init_session_slab(plan, 2, x_calib=x)
    step = jax.jit(engine.step_frame)
    for r in range(4):
        slab, _ = step(plan, slab, jnp.asarray(x[:2, r]), jnp.asarray(True))
    reset = engine.reset_slots(slab, jnp.asarray([True, False]))
    assert int(reset.t_raw[0]) == 0 and int(reset.t_raw[1]) == 4
    b0 = reset.blocks[0]
    assert not np.asarray(b0["ring_s"][0]).any()
    np.testing.assert_array_equal(np.asarray(b0["ring_s"][1]),
                                  np.asarray(slab.blocks[0]["ring_s"][1]))
    for site in reset.bn_stats:
        np.testing.assert_array_equal(
            np.asarray(reset.bn_stats[site]["mean"]),
            np.asarray(slab.bn_stats[site]["mean"]))


# --------------------------------------------------------------- scheduler

def _mini_sched(slots=2, flush=3, first=2):
    return sess.SlabScheduler(slots, V, C,
                              flush_frames=lambda T: flush,
                              first_logit_delay=first)


def test_scheduler_admission_queueing_and_recycling():
    """More sessions than slots: FIFO queueing, admission only into free
    slots, reset raised exactly on the admission tick, eviction after
    clip + flush, recycled slot admits the queued session."""
    sched = _mini_sched(slots=1, flush=2)
    clip = np.zeros((3, V, C), np.float32)
    sched.submit(sess.SessionRequest(sid=0, arrival=0, clip=clip))
    sched.submit(sess.SessionRequest(sid=1, arrival=0, clip=clip))
    logits = np.zeros((1, 4))
    done_at = {}
    for tick in range(12):
        if sched.idle():
            break
        frames, valid, reset = sched.tick_inputs(tick, 0.0)
        if tick in (0, 5):                  # admissions: tick 0 and recycle
            assert reset[0]
        else:
            assert not reset[0]
        assert valid[0] == (tick in (0, 1, 2, 5, 6, 7))  # clip frames only
        for rec in sched.tick_outputs(tick, logits, 0.0):
            done_at[rec.sid] = tick
    # total per session = 3 clip + 2 flush = 5 ticks; sid 1 waits 5 ticks
    assert done_at == {0: 4, 1: 9}
    assert [r.sid for r in sched.completed] == [0, 1]
    assert sched.completed[1].admitted == 5
    assert sched.completed[1].arrival == 0


def test_scheduler_counts_valid_frames_and_occupancy():
    sched = _mini_sched(slots=2, flush=1)
    clip = np.zeros((2, V, C), np.float32)
    sched.submit(sess.SessionRequest(sid=0, arrival=0, clip=clip))
    logits = np.zeros((2, 4))
    for tick in range(3):
        sched.tick_inputs(tick, 0.0)
        sched.tick_outputs(tick, logits, 0.0)
    assert sched.valid_frames == 2          # flush ticks don't count
    assert sched.occupancy_samples == [0.5, 0.5, 0.5]


def test_poisson_arrivals_deterministic():
    a = sess.poisson_arrivals(8, 4.0, (10, 20), V, C, seed=7)
    b = sess.poisson_arrivals(8, 4.0, (10, 20), V, C, seed=7)
    assert [r.arrival for r in a] == [r.arrival for r in b]
    assert a[0].arrival == 0                # first arrival anchors the clock
    assert all(x.arrival <= y.arrival for x, y in zip(a, a[1:]))
    assert all(r.clip.shape in ((10, V, C), (20, V, C)) for r in a)
    np.testing.assert_array_equal(a[3].clip, b[3].clip)


def test_run_sessions_end_to_end():
    """The serve --sessions path (two-stream ensemble, Poisson traffic):
    every session completes, metrics are populated, logits finite."""
    res = sess.run_sessions(CFG, slots=2, n_sessions=3,
                            mean_interarrival=4.0, lengths=(8, 12),
                            backend="reference", seed=0)
    assert res["sessions"] == 3
    assert res["frames_per_s"] > 0 and 0 < res["occupancy"] <= 1
    assert res["first_logit_frames"] == 41  # reduced cfg, worked by hand
    for rec in res["records"]:
        assert np.isfinite(rec.logits).all()
        assert rec.frames in (8, 12)
        # occupancy ticks = clip + flush drain (37 for the reduced cfg's
        # K=9 / skip-2 / stride-2 pipeline, same hand-worked number as
        # test_streaming.test_flush_frames_formula)
        assert rec.finished - rec.admitted + 1 == rec.frames + 37
