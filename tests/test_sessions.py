"""Multi-session slab serving — the session-scheduler correctness contract.

The lock: S sessions streamed *concurrently* through one session slab
(staggered admissions, different clip lengths, slot recycling through the
traced reset mask) must produce, at each session's eviction, the same
logits as S *independent* single-stream ``step_frame`` runs — on both
backends.  Plus host-side SlabScheduler bookkeeping (admission queueing,
occupancy, first-logit ticks), Poisson load-generation determinism,
``reset_slots`` isolation, and the no-retrace invariant of the slab step.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.agcn import engine
from repro.core.agcn import model as M
from repro.core.pruning.plan import build_prune_plan
from repro import serving as sess

CFG = get_config("agcn-2s", reduced=True)
V, C = CFG.gcn_joints, CFG.gcn_in_channels


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def x():
    return jax.random.normal(jax.random.PRNGKey(1), (2, CFG.gcn_frames, V, C))


@pytest.fixture(scope="module")
def prune_plan(params):
    sw = [np.asarray(b["Wk"]) for b in params["blocks"]]
    return build_prune_plan(sw, CFG.gcn_channels, [1.0, 0.5, 0.5, 0.5],
                            "cav-70-1", input_skip=2)


def _run_scheduled(plan, reqs, slots, policy="fifo"):
    """Drive a slab through the SlabScheduler under ``policy``, executing
    the TickPlan's snapshot/restore orders; return ({sid: final logits},
    bn_stats, scheduler)."""
    bn = engine.collect_bn_stats(
        plan, jax.random.normal(jax.random.PRNGKey(1),
                                (2, CFG.gcn_frames, V, C)))
    slab = engine.init_session_slab(plan, slots, bn_stats=bn)
    sched = sess.SlabScheduler(
        slots, V, C,
        flush_frames=lambda T: engine.stream_flush_frames(plan, T),
        first_logit_delay=engine.stream_first_logit_delay(plan),
        policy=policy)
    step = jax.jit(engine.step_frames)
    snap_fn = jax.jit(engine.snapshot_slots)
    rest_fn = jax.jit(engine.restore_slots)
    snaps = {}
    pending = sorted(reqs, key=lambda r: r.arrival)
    i = 0
    for tick in range(500):
        while i < len(pending) and pending[i].arrival <= tick:
            sched.submit(pending[i])
            i += 1
        if i == len(pending) and sched.idle():
            break
        tp = sched.tick_inputs(tick, 0.0)
        for s, sid in tp.snapshot:
            snaps[sid] = snap_fn(slab, jnp.asarray(s))
        for s, sid in tp.restore:
            slab = rest_fn(slab, jnp.asarray(s), snaps.pop(sid))
        slab, logits = step(plan, slab, jnp.asarray(tp.frames),
                            jnp.asarray(tp.valid), jnp.asarray(tp.reset))
        sched.tick_outputs(tick, np.asarray(logits), 0.0)
    assert sched.idle(), "scheduler did not drain within the tick budget"
    return {r.sid: r.logits for r in sched.completed}, bn, sched


def _run_independent(plan, bn, clip):
    """One session alone: batch-1 step_frame over clip + flush drain."""
    state = engine.init_stream_state(plan, 1, bn_stats=bn)
    step = jax.jit(engine.step_frame)
    xc = jnp.asarray(clip)[None]
    T = xc.shape[1]
    zeros = jnp.zeros_like(xc[:, 0])
    logits = None
    for r in range(T + engine.stream_flush_frames(plan, T)):
        frame = xc[:, r] if r < T else zeros
        state, logits = step(plan, state, frame, jnp.asarray(r < T))
    return np.asarray(logits)[0]


# ------------------------------------------------------------------- parity

@pytest.mark.parametrize("backend", ["reference", "pallas"])
def test_slab_matches_independent_streams(params, prune_plan, backend):
    """The tentpole lock: staggered concurrent sessions through the slab
    (including a queued session admitted into a *recycled* slot) equal
    independent single-stream runs, on the paper's pruned+quant target."""
    plan = engine.build_execution_plan(params, CFG, prune_plan, quant=True,
                                       backend=backend)
    rng = np.random.default_rng(3)
    lengths = (24, 14, 10)                 # different clip lengths
    clips = [rng.standard_normal((T, V, C)).astype(np.float32)
             for T in lengths]
    # 2 slots, 3 sessions: sid 2 queues until sid 1's drain frees its slot
    reqs = [sess.SessionRequest(sid=i, arrival=a, clip=c)
            for i, (a, c) in enumerate(zip((0, 4, 9), clips))]
    got, bn, _ = _run_scheduled(plan, reqs, slots=2)
    assert sorted(got) == [0, 1, 2]
    for i, clip in enumerate(clips):
        want = _run_independent(plan, bn, clip)
        np.testing.assert_allclose(got[i], want, atol=1e-3, rtol=1e-3,
                                   err_msg=f"session {i} (backend={backend})")


def test_slab_step_never_retraces(params, x):
    """Admissions, evictions and occupancy changes are traced masking: one
    compilation serves every (reset, valid) combination."""
    plan = engine.build_execution_plan(params, CFG, backend="reference")
    slab = engine.init_session_slab(plan, 3, x_calib=x)
    traces = []

    @jax.jit
    def counted(plan, slab, frames, valid, reset):
        traces.append(1)
        return engine.step_frames(plan, slab, frames, valid, reset)

    frames = jnp.zeros((3, V, C))
    for valid, reset in (((1, 0, 0), (1, 0, 0)),
                         ((1, 1, 0), (0, 1, 0)),
                         ((0, 0, 0), (0, 0, 0))):
        slab, _ = counted(plan, slab, frames,
                          jnp.asarray(valid, bool), jnp.asarray(reset, bool))
    assert len(traces) == 1


def test_reset_slots_isolates(params, x):
    """reset_slots zeroes exactly the masked slots' per-slot state and
    never touches other slots or the shared frozen BN calibration."""
    plan = engine.build_execution_plan(params, CFG, backend="reference")
    slab = engine.init_session_slab(plan, 2, x_calib=x)
    step = jax.jit(engine.step_frame)
    for r in range(4):
        slab, _ = step(plan, slab, jnp.asarray(x[:2, r]), jnp.asarray(True))
    reset = engine.reset_slots(slab, jnp.asarray([True, False]))
    assert int(reset.t_raw[0]) == 0 and int(reset.t_raw[1]) == 4
    b0 = reset.blocks[0]
    assert not np.asarray(b0["ring_s"][0]).any()
    np.testing.assert_array_equal(np.asarray(b0["ring_s"][1]),
                                  np.asarray(slab.blocks[0]["ring_s"][1]))
    for site in reset.bn_stats:
        np.testing.assert_array_equal(
            np.asarray(reset.bn_stats[site]["mean"]),
            np.asarray(slab.bn_stats[site]["mean"]))


# --------------------------------------------------------------- scheduler

def _mini_sched(slots=2, flush=3, first=2):
    return sess.SlabScheduler(slots, V, C,
                              flush_frames=lambda T: flush,
                              first_logit_delay=first)


def test_scheduler_admission_queueing_and_recycling():
    """More sessions than slots: FIFO queueing, admission only into free
    slots, reset raised exactly on the admission tick, eviction after
    clip + flush, recycled slot admits the queued session."""
    sched = _mini_sched(slots=1, flush=2)
    clip = np.zeros((3, V, C), np.float32)
    sched.submit(sess.SessionRequest(sid=0, arrival=0, clip=clip))
    sched.submit(sess.SessionRequest(sid=1, arrival=0, clip=clip))
    logits = np.zeros((1, 4))
    done_at = {}
    for tick in range(12):
        if sched.idle():
            break
        tp = sched.tick_inputs(tick, 0.0)
        if tick in (0, 5):                  # admissions: tick 0 and recycle
            assert tp.reset[0]
        else:
            assert not tp.reset[0]
        assert tp.valid[0] == (tick in (0, 1, 2, 5, 6, 7))  # clip frames only
        assert not tp.hold.any()            # closed clips never starve
        for rec in sched.tick_outputs(tick, logits, 0.0):
            done_at[rec.sid] = tick
    # total per session = 3 clip + 2 flush = 5 ticks; sid 1 waits 5 ticks
    assert done_at == {0: 4, 1: 9}
    assert [r.sid for r in sched.completed] == [0, 1]
    assert sched.completed[1].admitted == 5
    assert sched.completed[1].arrival == 0


def test_scheduler_counts_valid_frames_and_occupancy():
    sched = _mini_sched(slots=2, flush=1)
    clip = np.zeros((2, V, C), np.float32)
    sched.submit(sess.SessionRequest(sid=0, arrival=0, clip=clip))
    logits = np.zeros((2, 4))
    for tick in range(3):
        sched.tick_inputs(tick, 0.0)
        sched.tick_outputs(tick, logits, 0.0)
    assert sched.valid_frames == 2          # flush ticks don't count
    assert sched.occupancy_samples == [0.5, 0.5, 0.5]


def test_poisson_arrivals_deterministic():
    a = sess.poisson_arrivals(8, 4.0, (10, 20), V, C, seed=7)
    b = sess.poisson_arrivals(8, 4.0, (10, 20), V, C, seed=7)
    assert [r.arrival for r in a] == [r.arrival for r in b]
    assert a[0].arrival == 0                # first arrival anchors the clock
    assert all(x.arrival <= y.arrival for x, y in zip(a, a[1:]))
    assert all(r.clip.shape in ((10, V, C), (20, V, C)) for r in a)
    np.testing.assert_array_equal(a[3].clip, b[3].clip)


# ------------------------------------------------------- QoS / preemption

@pytest.mark.parametrize("backend", ["reference", "pallas"])
def test_snapshot_restore_roundtrip(params, prune_plan, backend):
    """The QoS tentpole lock: snapshot a mid-clip slot, evict it, run
    arbitrary foreign traffic in the slot, restore, resume — the final
    logits equal the uninterrupted session's, and a neighbour slot fed the
    identical frame sequence in both runs is bit-for-bit untouched."""
    plan = engine.build_execution_plan(params, CFG, prune_plan, quant=True,
                                       backend=backend)
    bn = engine.collect_bn_stats(
        plan, jax.random.normal(jax.random.PRNGKey(1),
                                (2, CFG.gcn_frames, V, C)))
    rng = np.random.default_rng(11)
    T = 8
    clip_a = jnp.asarray(rng.standard_normal((T, V, C)).astype(np.float32))
    clip_b = jnp.asarray(rng.standard_normal((T, V, C)).astype(np.float32))
    foreign = jnp.asarray(rng.standard_normal((5, V, C)).astype(np.float32))
    total = T + engine.stream_flush_frames(plan, T)
    zeros = jnp.zeros((V, C))
    step = jax.jit(engine.step_frames)
    snap_fn = jax.jit(engine.snapshot_slots)
    rest_fn = jax.jit(engine.restore_slots)

    def frame_of(clip, r):
        return clip[r] if r < T else zeros

    def run(interrupt):
        slab = engine.init_session_slab(plan, 2, bn_stats=bn)
        out = {}
        rel = [0, 0]                          # per-slot session clocks
        snap = None
        while rel[0] < total or rel[1] < total:
            if interrupt and rel[0] == 5 and snap is None:
                snap = snap_fn(slab, jnp.asarray(0))
                for i in range(len(foreign)):  # foreign session in slot 0
                    fr = jnp.stack([foreign[i], frame_of(clip_b, rel[1])])
                    slab, lg = step(plan, slab,
                                    fr, jnp.asarray([True, rel[1] < T]),
                                    jnp.asarray([i == 0, False]))
                    if rel[1] == total - 1:
                        out["b"] = np.asarray(lg)[1]
                    rel[1] = min(rel[1] + 1, total)
                slab = rest_fn(slab, jnp.asarray(0), snap)
            fr = jnp.stack([frame_of(clip_a, rel[0]),
                            frame_of(clip_b, rel[1])])
            slab, lg = step(plan, slab, fr,
                            jnp.asarray([rel[0] < T, rel[1] < T]),
                            jnp.asarray([False, False]))
            if rel[0] == total - 1:
                out["a"] = np.asarray(lg)[0]
            if rel[1] == total - 1:
                out["b"] = np.asarray(lg)[1]
            rel = [min(rel[0] + 1, total), min(rel[1] + 1, total)]
        return out

    want = run(interrupt=False)
    got = run(interrupt=True)
    np.testing.assert_allclose(got["a"], want["a"], atol=1e-3, rtol=1e-3,
                               err_msg=f"preempted slot ({backend})")
    np.testing.assert_array_equal(got["b"], want["b"],
                                  err_msg=f"bystander slot ({backend})")


def test_preempt_policy_matches_independent(params, prune_plan):
    """A high-priority arrival snapshot-evicts the lowest-priority active
    slot; the victim re-queues, restores into a freed slot and resumes —
    every session (victim, preemptor, bystander) still equals its
    independent single-stream run."""
    plan = engine.build_execution_plan(params, CFG, prune_plan, quant=True,
                                       backend="reference")
    rng = np.random.default_rng(7)
    clips = [rng.standard_normal((T, V, C)).astype(np.float32)
             for T in (24, 24, 10)]
    reqs = [
        sess.SessionRequest(sid=0, arrival=0, clip=clips[0], priority=0),
        sess.SessionRequest(sid=1, arrival=2, clip=clips[1], priority=0),
        # both slots busy with priority 0 -> sid 2 preempts the latest
        # admission (sid 1), which later restores and resumes
        sess.SessionRequest(sid=2, arrival=6, clip=clips[2], priority=1),
    ]
    got, bn, sched = _run_scheduled(plan, reqs, slots=2, policy="preempt")
    assert sorted(got) == [0, 1, 2]
    assert sched.preemptions == 1 and sched.restores == 1
    by_sid = {r.sid: r for r in sched.completed}
    assert by_sid[1].preemptions == 1          # the victim
    assert by_sid[0].preemptions == 0 and by_sid[2].preemptions == 0
    for i, clip in enumerate(clips):
        want = _run_independent(plan, bn, clip)
        np.testing.assert_allclose(got[i], want, atol=1e-3, rtol=1e-3,
                                   err_msg=f"session {i}")


def test_preempt_fifo_never_preempts(params, prune_plan):
    """Priorities without the preempt policy are admission order only: the
    fifo policy runs every session to completion."""
    plan = engine.build_execution_plan(params, CFG, prune_plan, quant=True,
                                       backend="reference")
    rng = np.random.default_rng(8)
    clips = [rng.standard_normal((10, V, C)).astype(np.float32)
             for _ in range(2)]
    reqs = [sess.SessionRequest(sid=0, arrival=0, clip=clips[0], priority=0),
            sess.SessionRequest(sid=1, arrival=3, clip=clips[1], priority=5)]
    got, _, sched = _run_scheduled(plan, reqs, slots=1, policy="fifo")
    assert sorted(got) == [0, 1]
    assert sched.preemptions == 0 and sched.restores == 0


def test_admission_queue_strict_priority_arrival_order():
    """The admission queue pops strictly by (priority desc, arrival asc,
    submission order) — with uniform priorities it degenerates to FIFO."""
    q = sess.AdmissionQueue()
    clip = np.zeros((1, V, C), np.float32)
    for sid, prio, arr in [(0, 0, 0), (1, 1, 5), (2, 1, 3), (3, 0, 1),
                           (4, 2, 9), (5, 0, 0)]:
        q.push(sess.SessionRequest(sid=sid, arrival=arr, clip=clip,
                                   priority=prio))
    order = [q.pop().sid for _ in range(len(q))]
    assert order == [4, 2, 1, 0, 5, 3]


def test_deadline_policy_drops_expected():
    """Deadline policy: an expired queued session is dropped without ever
    touching a slot, an active session whose deadline passes mid-service is
    evicted, and on-time sessions complete — exactly those and no others."""
    sched = sess.SlabScheduler(1, V, C, flush_frames=lambda T: 2,
                               first_logit_delay=2, policy="deadline")
    clip = np.zeros((3, V, C), np.float32)          # total = 3 clip + 2 flush
    sched.submit(sess.SessionRequest(sid=0, arrival=0, clip=clip,
                                     deadline=10))
    sched.submit(sess.SessionRequest(sid=1, arrival=0, clip=clip,
                                     deadline=3))   # expires while queued
    logits = np.zeros((1, 4))
    reqs2_submitted = False
    for tick in range(20):
        if tick == 6 and not reqs2_submitted:
            # admitted at 6, needs 5 ticks -> finishes 10 > deadline 8
            sched.submit(sess.SessionRequest(sid=2, arrival=6, clip=clip,
                                             deadline=8))
            reqs2_submitted = True
        if reqs2_submitted and sched.idle():
            break
        sched.tick_inputs(tick, 0.0)
        sched.tick_outputs(tick, logits, 0.0)
    assert [r.sid for r in sched.completed] == [0]
    assert sorted(r.sid for r in sched.missed) == [1, 2]
    assert sched.preemptions == 0


def test_run_sessions_deadline_policy():
    """serve --sessions --qos deadline end-to-end: a tight slack under
    contention misses some sessions, and completed + missed account for
    every generated session."""
    res = sess.run_sessions(CFG, slots=1, n_sessions=4,
                            mean_interarrival=2.0, lengths=(8,),
                            backend="reference", seed=0,
                            qos="deadline", deadline_slack=5)
    assert res["qos"] == "deadline"
    assert res["sessions"] + res["deadline_missed"] == 4
    assert res["deadline_missed"] >= 1          # 1-slot contention must miss
    assert res["deadline_miss_rate"] == pytest.approx(
        res["deadline_missed"] / 4)


# ------------------------------------------------- serving-metrics bugfixes

def test_first_logit_sentinel_survives_and_is_reported():
    """A session whose clip+flush total never reaches the first-logit delay
    keeps the -1.0 sentinel (never a bogus latch), so the driver can count
    it instead of silently shrinking the percentile population."""
    sched = sess.SlabScheduler(1, V, C, flush_frames=lambda T: 0,
                               first_logit_delay=5)
    clip = np.zeros((2, V, C), np.float32)          # total = 2 < delay 5
    sched.submit(sess.SessionRequest(sid=0, arrival=0, clip=clip))
    logits = np.zeros((1, 4))
    for tick in range(4):
        sched.tick_inputs(tick, now=1.0)
        sched.tick_outputs(tick, logits, now=1.0)
    assert sched.idle()
    assert sched.completed[0].wall_first_logit == -1.0


def test_first_logit_latch_on_short_clips():
    """Regression (T=1/T=2 with input_skip=2): the >=-latch records a first
    logit for every session — short clips included — and run_sessions
    reports the no-first-logit count explicitly."""
    sched = sess.SlabScheduler(1, V, C, flush_frames=lambda T: 4 - T,
                               first_logit_delay=3)
    clip = np.zeros((1, V, C), np.float32)          # total = 4 >= delay 3
    sched.submit(sess.SessionRequest(sid=0, arrival=0, clip=clip))
    logits = np.zeros((1, 4))
    for tick in range(6):
        sched.tick_inputs(tick, now=float(tick))
        sched.tick_outputs(tick, logits, now=float(tick))
    assert sched.idle()
    assert sched.completed[0].wall_first_logit == 2.0   # tick rel == delay-1
    res = sess.run_sessions(CFG, slots=2, n_sessions=4,
                            mean_interarrival=2.0, lengths=(1, 2),
                            backend="reference", seed=0)
    assert res["sessions"] == 4
    assert res["sessions_no_first_logit"] == 0
    assert res["first_logit_ms_p50"] > 0
    for rec in res["records"]:
        assert rec.frames in (1, 2)
        assert rec.wall_first_logit >= rec.wall_admitted


def test_occupancy_time_weighted_counts_idle_gaps():
    """Sparse Poisson traffic: the busy-conditional occupancy (processed
    ticks only) must overstate the true time-weighted occupancy, which
    counts the fast-forwarded idle gaps as zero."""
    res = sess.run_sessions(CFG, slots=1, n_sessions=2,
                            mean_interarrival=150.0, lengths=(4,),
                            backend="reference", seed=1)
    assert res["occupancy_busy"] == pytest.approx(1.0)
    assert 0.0 < res["occupancy"] < res["occupancy_busy"]


def test_write_bench_merges_by_backend_slots_qos(tmp_path):
    """serve --sessions --backend pallas must not clobber the reference
    rows: write_bench merges by (backend, slots, qos), replacing matching
    rows in place and appending new keys."""
    path = str(tmp_path / "BENCH_sessions.json")
    ref = {"backend": "reference", "slots": 4, "qos": "fifo",
           "frames_per_s": 500.0, "records": ["dropme"]}
    pal = {"backend": "pallas", "slots": 4, "qos": "fifo",
           "frames_per_s": 80.0}
    sess.write_bench([ref, pal], path)
    rows = json.loads(open(path).read())
    assert [r["backend"] for r in rows] == ["reference", "pallas"]
    assert "records" not in rows[0]
    # pallas-only rewrite: reference row survives, pallas row is replaced,
    # a new qos key is appended
    sess.write_bench([{"backend": "pallas", "slots": 4, "qos": "fifo",
                       "frames_per_s": 99.0},
                      {"backend": "pallas", "slots": 4, "qos": "preempt",
                       "frames_per_s": 70.0}], path)
    rows = json.loads(open(path).read())
    assert len(rows) == 3
    assert rows[0]["backend"] == "reference"
    assert rows[0]["frames_per_s"] == 500.0
    assert rows[1] == {"backend": "pallas", "slots": 4, "qos": "fifo",
                       "frames_per_s": 99.0}
    assert rows[2]["qos"] == "preempt"
    # rows written before the qos axis existed merge as qos=fifo
    legacy = [{"backend": "reference", "slots": 4, "frames_per_s": 1.0}]
    with open(path, "w") as f:
        json.dump(legacy, f)
    sess.write_bench([ref], path)
    rows = json.loads(open(path).read())
    assert len(rows) == 1 and rows[0]["frames_per_s"] == 500.0


# ------------------------------------------------------------- deprecations

def test_launch_sessions_shim_forwards_and_warns():
    """The legacy import path (repro.launch.sessions) resolves every moved
    public name from repro.serving — with a DeprecationWarning — and still
    raises AttributeError for unknown names."""
    from repro.launch import sessions as legacy
    with pytest.warns(DeprecationWarning, match="moved to repro.serving"):
        assert legacy.SlabScheduler is sess.SlabScheduler
    with pytest.warns(DeprecationWarning):
        assert legacy.run_sessions is sess.run_sessions
    with pytest.warns(DeprecationWarning):
        assert legacy.QOS_POLICIES == sess.QOS_POLICIES
    with pytest.raises(AttributeError):
        legacy.definitely_not_a_name


def test_tickplan_tuple_unpack_deprecated():
    """Unpacking a TickPlan as the legacy (frames, valid, reset) 3-tuple
    still works but emits a DeprecationWarning (it silently drops the hold
    mask and the snapshot/restore orders)."""
    sched = _mini_sched(slots=1)
    sched.submit(sess.SessionRequest(sid=0, arrival=0,
                                     clip=np.zeros((2, V, C), np.float32)))
    tp = sched.tick_inputs(0, 0.0)
    with pytest.warns(DeprecationWarning, match="TickPlan"):
        frames, valid, reset = tp
    np.testing.assert_array_equal(frames, tp.frames)
    np.testing.assert_array_equal(valid, tp.valid)
    np.testing.assert_array_equal(reset, tp.reset)


def test_run_sessions_end_to_end():
    """The serve --sessions path (two-stream ensemble, Poisson traffic):
    every session completes, metrics are populated, logits finite."""
    res = sess.run_sessions(CFG, slots=2, n_sessions=3,
                            mean_interarrival=4.0, lengths=(8, 12),
                            backend="reference", seed=0)
    assert res["sessions"] == 3
    assert res["frames_per_s"] > 0 and 0 < res["occupancy"] <= 1
    assert res["first_logit_frames"] == 41  # reduced cfg, worked by hand
    for rec in res["records"]:
        assert np.isfinite(rec.logits).all()
        assert rec.frames in (8, 12)
        # occupancy ticks = clip + flush drain (37 for the reduced cfg's
        # K=9 / skip-2 / stride-2 pipeline, same hand-worked number as
        # test_streaming.test_flush_frames_formula)
        assert rec.finished - rec.admitted + 1 == rec.frames + 37


# --------------------------------------------- long-lived-service bugfixes

def test_peek_priority_empty_queue_returns_none():
    """Regression: peeking an empty admission queue (the preempt policy
    probes it every tick) returns None instead of raising IndexError."""
    q = sess.AdmissionQueue()
    assert q.peek_priority() is None
    clip = np.zeros((1, V, C), np.float32)
    q.push(sess.SessionRequest(sid=0, arrival=0, clip=clip, priority=3))
    assert q.peek_priority() == 3
    q.pop()
    assert q.peek_priority() is None


def test_sweep_expired_unit():
    """Regression: sweep_expired drops expired queued sessions *before*
    anyone reads queue depth — stale demand must not linger — is
    idempotent, and is a no-op under non-deadline policies."""
    sched = sess.SlabScheduler(1, V, C, flush_frames=lambda T: 1,
                               first_logit_delay=1, policy="deadline")
    clip = np.zeros((2, V, C), np.float32)
    for sid in range(4):                    # all already expired at tick 5
        sched.submit(sess.SessionRequest(sid=sid, arrival=0, clip=clip,
                                         deadline=2))
    sched.submit(sess.SessionRequest(sid=9, arrival=0, clip=clip,
                                     deadline=50))
    assert len(sched.queue) == 5
    sched.sweep_expired(5)
    assert len(sched.queue) == 1            # only the live one remains
    assert sorted(r.sid for r in sched.missed) == [0, 1, 2, 3]
    sched.sweep_expired(5)                  # idempotent
    assert sched.n_missed == 4
    fifo = sess.SlabScheduler(1, V, C, flush_frames=lambda T: 1,
                              first_logit_delay=1)
    fifo.submit(sess.SessionRequest(sid=0, arrival=0, clip=clip,
                                    deadline=-1))
    fifo.sweep_expired(10)                  # fifo never sheds by deadline
    assert len(fifo.queue) == 1


def test_scheduler_bounded_memory_10k_soak():
    """The long-lived-service lock: 10k sessions (a deadline mix, so both
    the completed and missed paths churn) through a retain=64 scheduler
    leave every host-side record structure bounded by the retention knob,
    while the lifetime aggregates still count all 10k."""
    retain = 64
    sched = sess.SlabScheduler(8, V, C, flush_frames=lambda T: 0,
                               first_logit_delay=1, policy="deadline",
                               retain=retain)
    clip = np.zeros((1, V, C), np.float32)
    logits = np.zeros((8, 4))
    tick, submitted = 0, 0
    while submitted < 10_000 or not sched.idle():
        while submitted < 10_000 and len(sched.queue) < 16:
            # even sids get a hopeless deadline -> the missed path
            dl = tick - 1 if submitted % 2 == 0 else tick + 100
            sched.submit(sess.SessionRequest(sid=submitted, arrival=tick,
                                             clip=clip, deadline=dl))
            submitted += 1
        sched.tick_inputs(tick, 0.0)
        sched.tick_outputs(tick, logits, 0.0)
        tick += 1
        assert tick < 50_000
    assert sched.n_completed + sched.n_missed == 10_000
    assert sched.n_completed >= 5_000       # the live half all complete
    assert len(sched.completed) <= retain
    assert len(sched.missed) <= retain
    assert len(sched.missed_sids) <= retain
    assert len(sched.occupancy_samples) <= retain
    # lifetime aggregates survive the trim: occupancy over *all* ticks
    assert 0 < sched.occ_sum / sched.occ_ticks <= 1
