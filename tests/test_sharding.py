"""Sharding-rule invariants: every assigned arch's parameter tree gets
valid specs on the production mesh shape, and the logical-rule machinery
degrades gracefully (missing axes, no context)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ASSIGNED, CONFIGS, get_config
from repro.distributed import sharding as shd
from repro.distributed.params import leaf_spec, param_specs
from repro.launch.hlo_cost import analyze_hlo


class FakeMesh:
    """Shape-only stand-in so tests don't allocate 256 devices."""
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


MESH = FakeMesh({"data": 16, "model": 16})


@pytest.mark.parametrize("arch", list(CONFIGS))
def test_param_specs_divisible(arch):
    """Every spec'd dim must divide by its mesh axes — the invariant that
    makes the production dry-run compile."""
    cfg = get_config(arch)
    from repro.models import registry
    params = jax.eval_shape(
        lambda: registry.init_params(cfg, jax.random.PRNGKey(0), jnp.bfloat16)
        if cfg.family != "gcn"
        else registry.init_params(cfg, jax.random.PRNGKey(0)))
    specs = param_specs(params, MESH, expert_dim=cfg.padded_experts or None)
    for (path, leaf), (_, spec) in zip(
        jax.tree_util.tree_leaves_with_path(params),
        jax.tree_util.tree_leaves_with_path(
            specs, is_leaf=lambda x: isinstance(x, P)),
    ):
        for dim, ax in zip(leaf.shape, tuple(spec) + (None,) * 10):
            if ax is not None:
                assert dim % MESH.shape[ax] == 0, (path, leaf.shape, spec)


def test_moe_experts_sharded_on_model():
    cfg = get_config("qwen3-moe-30b-a3b")
    from repro.models import registry
    params = jax.eval_shape(
        lambda: registry.init_params(cfg, jax.random.PRNGKey(0), jnp.bfloat16))
    specs = param_specs(params, MESH, expert_dim=cfg.padded_experts)
    wi_spec = specs["layers"]["moe"]["wi"]
    assert "model" in tuple(wi_spec)


def test_logical_spec_drops_missing_axes():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with shd.axis_rules(mesh):
        spec = shd.logical_spec("batch", None, "ffn")
        # 'pod' silently dropped from ('pod','data')
        assert spec == P("data", None, "model")


def test_constrain_noop_outside_context():
    x = jnp.ones((4, 4))
    y = shd.constrain(x, "batch", None)
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_divisible_helper():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with shd.axis_rules(mesh):
        assert shd.divisible(17, "ffn")      # 1-device mesh divides all


# ------------------------------------------------------------------ hlo_cost

def test_hlo_cost_counts_scan_trips():
    def withscan(a, b):
        def f(x, _):
            return jnp.tanh(x @ b), None
        x, _ = jax.lax.scan(f, a, None, length=16)
        return x

    sd = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    c = jax.jit(withscan).lower(sd, sd).compile()
    r = analyze_hlo(c.as_text())
    assert r["flops"] == pytest.approx(16 * 2 * 64**3, rel=0.01)


def test_hlo_cost_flops_plain_matmul():
    sd = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    c = jax.jit(lambda a, b: a @ b).lower(sd, sd).compile()
    r = analyze_hlo(c.as_text())
    assert r["flops"] == pytest.approx(2 * 128**3, rel=0.01)
    assert r["collective_bytes"] == 0
