"""Temporal-attention saliency gating — the adaptive-streaming skip path.

Three layers of lock:

* **Gate unit contract** (model-free numpy): frame 0 always kept, the
  consecutive-skip cap bounds information loss, incremental scoring of a
  growing stream equals batch scoring, and the kept list composes with
  the SLO degrade stride through ``SessionRequest.eff_frames``.
* **Replay determinism**: a ``--saliency-thresh`` replay of the checked-in
  smoke trace reproduces the golden outcome digests + skip counters in
  ``tests/data/traces/golden_saliency.json`` (regenerate with
  ``tools/gen_golden_outcomes.py saliency``), on the plain fifo path and
  through preemption re-queues.
* **Migration bit-identity**: a gated session preempted into the snapshot
  ring, exported and resumed on another replica skips exactly the frames
  it would have skipped in place — logits and skip accounting are
  bit-identical to the uninterrupted gated run.

The acceptance A/B rides at the bottom: on the bursty+diurnal trace under
the deadline QoS at equal slab capacity, the gated run serves >= 1.5x the
sessions of the ungated baseline while holding the high-priority
first-logit p99 under the SLO target.
"""
import json
import pathlib

import numpy as np
import pytest

from repro.configs import get_config
from repro.serving.saliency import SaliencyConfig, SaliencyGate
from repro.serving.scheduler import SessionRequest

CFG = get_config("agcn-2s", reduced=True)
V, C = CFG.gcn_joints, CFG.gcn_in_channels
DATA = pathlib.Path(__file__).resolve().parent / "data" / "traces"

GOLDEN = json.loads((DATA / "golden_saliency.json").read_text())
TIERS = tuple(GOLDEN["tiers"])
THRESH = GOLDEN["saliency_thresh"]


def _req(clip, sid=0):
    return SessionRequest(sid=sid, arrival=0, clip=clip)


# ------------------------------------------------------------ gate unit

def test_saliency_config_validation():
    with pytest.raises(ValueError):
        SaliencyConfig(threshold=0.0)
    with pytest.raises(ValueError):
        SaliencyConfig(threshold=-1.0)
    with pytest.raises(ValueError):
        SaliencyConfig(max_consecutive_skips=0)
    with pytest.raises(ValueError):
        SaliencyConfig(eps=0.0)
    assert SaliencyConfig().max_consecutive_skips == 3


def test_gate_keeps_first_frame_and_caps_consecutive_skips():
    """A frozen pose (zero motion) still samples every cap+1-th frame —
    the worst-case information-loss bound — and frame 0 always feeds."""
    clip = np.ones((13, V, C), np.float32)
    gate = SaliencyGate(SaliencyConfig(threshold=1.0,
                                       max_consecutive_skips=3))
    req = _req(clip)
    gate.extend(req)
    assert req.sal_kept == [0, 4, 8, 12]
    assert gate.frames_scored == 13 and gate.frames_skipped == 9
    assert req.kept_frames() == 4 and req.n_frames() == 13


def test_gate_keeps_motion_spikes():
    """A motion burst scores far above the running mean and is kept both
    entering and leaving the spike; the surrounding freeze is skipped."""
    clip = np.zeros((9, V, C), np.float32)
    clip[5] = 100.0
    gate = SaliencyGate(SaliencyConfig(threshold=1.0,
                                       max_consecutive_skips=8))
    req = _req(clip)
    gate.extend(req)
    assert 5 in req.sal_kept and 6 in req.sal_kept
    assert not {1, 2, 3, 4}.intersection(req.sal_kept)


def test_gate_incremental_equals_batch():
    """Scoring a stream as frames trickle in (extend per tick, the open-
    session path) yields the same kept list and scorer state as scoring
    the full clip at once — the idempotence the scheduler relies on."""
    rng = np.random.default_rng(0)
    clip = rng.standard_normal((20, V, C)).astype(np.float32)
    batch = _req(clip)
    SaliencyGate(SaliencyConfig(threshold=1.05)).extend(batch)
    inc_gate = SaliencyGate(SaliencyConfig(threshold=1.05))
    inc = _req(clip[:1].copy())
    for k in range(1, 21):
        inc.clip = clip[:k]
        inc_gate.extend(inc)
    assert inc.sal_kept == batch.sal_kept
    assert inc.sal_state.scored == batch.sal_state.scored == 20
    assert inc.sal_state.mean == pytest.approx(batch.sal_state.mean)
    assert inc_gate.frames_scored == 20


def test_eff_frames_composes_with_degrade():
    """The scheduler's slot budget is ceil(kept / stride): saliency and
    the SLO degrade stride decimate multiplicatively, and an ungated
    request falls back to the raw frame count."""
    clip = np.ones((13, V, C), np.float32)
    req = _req(clip)
    SaliencyGate(SaliencyConfig()).extend(req)
    assert req.eff_frames() == 4                # kept [0, 4, 8, 12]
    req.degrade = 2
    assert req.eff_frames() == 2
    plain = _req(clip, sid=1)
    assert plain.eff_frames() == 13 and plain.kept_frames() == 13


# -------------------------------------------------------- service layer

jax = pytest.importorskip("jax")

from repro.core.agcn import engine  # noqa: E402
from repro.core.agcn import model as M  # noqa: E402
from repro.core.pruning.plan import build_prune_plan  # noqa: E402
from repro.distributed.router import ReplicaRouter  # noqa: E402
from repro.serving import (GcnService, Trace, bench_key,  # noqa: E402
                           outcome_digest, replay, write_bench)

SMOKE = Trace.load(str(DATA / "smoke.json"))


@pytest.fixture(scope="module")
def plans_bn():
    params = M.init_params(CFG, jax.random.PRNGKey(0))
    sw = [np.asarray(b["Wk"]) for b in params["blocks"]]
    pp = build_prune_plan(sw, CFG.gcn_channels, [1.0, 0.5, 0.5, 0.5],
                         "cav-70-1", input_skip=2)
    plan = engine.build_execution_plan(params, CFG, pp, quant=True,
                                       backend="reference")
    bn = engine.collect_bn_stats(plan, jax.random.normal(
        jax.random.PRNGKey(1),
        (2, CFG.gcn_frames, CFG.gcn_joints, CFG.gcn_in_channels)))
    return (plan,), (bn,)


def _replay_gated(plans_bn, qos, thresh=THRESH):
    plans, bn = plans_bn
    return replay(CFG, SMOKE, backend="reference", qos=qos, policy="demand",
                  capacity_tiers=TIERS, slo_config=None, plans=plans,
                  bn_stats=bn, record_outcomes=True, saliency_thresh=thresh)


@pytest.mark.parametrize("qos", [
    "fifo",
    pytest.param("preempt", marks=pytest.mark.slow),
])
def test_golden_saliency_outcomes(plans_bn, qos):
    """The gated replay reproduces the checked-in outcome digest and skip
    counters exactly — saliency decisions are part of the deterministic
    scheduler contract, including through preemption re-queues."""
    want = GOLDEN["cells"][f"{qos}/demand"]
    out = _replay_gated(plans_bn, qos)
    assert outcome_digest(out["outcomes"]) == want["outcome_digest"]
    assert out["ticks"] == want["ticks"]
    assert out["sessions"] == want["sessions"]
    assert out["frames_scored"] == want["frames_scored"]
    assert out["frames_skipped"] == want["frames_skipped"]
    assert out["skip_rate"] == pytest.approx(want["skip_rate"])
    assert out["saliency"] == THRESH
    assert want["frames_skipped"] > 0           # the gate actually gated


def test_saliency_replay_twice_is_identical(plans_bn):
    """Two gated replays of the same trace agree tick-for-tick — the
    determinism half of the adaptive-streaming acceptance."""
    a = _replay_gated(plans_bn, "fifo")
    b = _replay_gated(plans_bn, "fifo")
    assert a["outcomes"] == b["outcomes"]
    assert a["frames_skipped"] == b["frames_skipped"]


def test_gated_session_bit_identical_across_migration(plans_bn):
    """A gated session preempted into the snapshot ring, exported and
    resumed on the other replica skips exactly the frames it would have
    skipped in place: logits and skip accounting are bit-identical to
    the uninterrupted gated run (the scorer state rides the request)."""
    plans, bn = plans_bn
    rng = np.random.default_rng(5)
    clip_lo = rng.standard_normal((16, V, C)).astype(np.float32)
    clip_hi = rng.standard_normal((12, V, C)).astype(np.float32)

    def mk():
        return GcnService(CFG, plans=plans, bn_stats=bn,
                          capacity_tiers=(1,), qos="preempt",
                          saliency_thresh=THRESH)

    base_svc = mk()
    h = base_svc.open_session()
    base_svc.submit_clip(h, clip_lo)
    base_svc.run_until_idle()
    base = base_svc.poll(h)
    assert base.record.frames_skipped > 0       # the gate engaged

    router = ReplicaRouter([mk(), mk()])
    h_lo = router.open_session(replica=0, priority=0)
    router.submit_clip(h_lo, clip_lo)
    for _ in range(4):
        router.tick()
    h_hi = router.open_session(replica=0, priority=1)
    router.submit_clip(h_hi, clip_hi)
    router.tick()                       # preempts h_lo into the ring
    assert router.poll(h_lo).state == "queued"
    router.migrate_session(h_lo, 1)     # ring row -> host -> replica 1
    router.run_until_idle()
    moved = router.poll(h_lo)
    np.testing.assert_array_equal(moved.logits, base.logits)
    assert moved.record.frames_skipped == base.record.frames_skipped


def test_bench_key_and_merge_default_off(plans_bn, tmp_path):
    """Legacy rows (no ck/saliency keys) and explicit-off rows share one
    merge key; a gated row of the same cell lands beside — not over —
    the ungated one."""
    legacy = {"backend": "reference", "slots": 4, "qos": "fifo"}
    assert bench_key(legacy) == bench_key(
        {**legacy, "ck": False, "saliency": 0.0})
    assert bench_key(legacy) != bench_key({**legacy, "ck": True})
    assert bench_key(legacy) != bench_key({**legacy, "saliency": THRESH})
    base = _replay_gated(plans_bn, "fifo", thresh=0.0)
    gated = _replay_gated(plans_bn, "fifo")
    assert "saliency" not in base and "skip_rate" not in base
    bench = tmp_path / "BENCH_sessions.json"
    write_bench([base], path=str(bench))
    write_bench([gated], path=str(bench))       # merge, not clobber
    write_bench([gated], path=str(bench))       # idempotent re-merge
    rows = json.loads(bench.read_text())
    assert len(rows) == 2
    assert sorted(r.get("saliency", 0.0) for r in rows) == [0.0, THRESH]


@pytest.mark.slow
def test_acceptance_saliency_serves_more_sessions(plans_bn):
    """THE adaptive-streaming acceptance: on the checked-in
    bursty+diurnal trace under the deadline QoS at equal slab capacity,
    the gated run completes >= 1.5x the sessions of the ungated baseline
    (skipped frames shorten service, so queued sessions still make their
    deadlines through the bursts) while the high-priority first-logit
    p99 stays under the SLO target the golden acceptance uses."""
    big = Trace.load(str(DATA / "bursty_diurnal.json"))
    plans, bn = plans_bn
    target = 90

    def run(thresh):
        return replay(CFG, big, backend="reference", qos="deadline",
                      policy="demand", capacity_tiers=(4,), slo_config=None,
                      deadline_slack=40, plans=plans, bn_stats=bn,
                      saliency_thresh=thresh)

    base, gated = run(0.0), run(1.2)
    assert gated["sessions"] >= 1.5 * base["sessions"]
    assert gated["deadline_missed"] < base["deadline_missed"]
    hp = gated["latency_ms_by_priority"]["1"]
    assert hp["first_logit_p99_ticks"] <= target
    assert gated["skip_rate"] > 0.5             # the gate did the work
