"""Distributed serving tier: mesh-sharded slab ticks + the replica router.

Run with ``./test.sh --dist`` (exports
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` so the 1-D batch
mesh is real on CPU).  The tentpole locks:

* **Sharded == single-device** — the same QoS trace (admissions,
  preemptions with restores, an elastic grow/shrink migration) produces
  logits within 1e-3 of the single-device run when the slab, snapshot
  ring and tick are sharded over a 4-device mesh, on both backends.
* **Cross-replica migration parity** — a session drained out of one
  replica (active slot or preempted ring snapshot) and resumed on
  another matches its uninterrupted run ≤1e-3, and bystander sessions on
  both replicas are *bit-identical*.
* **Router mechanics** — consistent sid→replica pinning through
  migrations, load feedback placement, drain-and-rebalance moves, and
  the routed BENCH row (``replicas``/``rebalances`` axes).

The mesh-gated cells skip on a single-device run (the plain full tier);
the router cells run everywhere — replicas don't need extra devices.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.agcn import engine
from repro.core.agcn import model as M
from repro.core.pruning.plan import build_prune_plan
from repro.distributed.router import ReplicaRouter, run_routed_sessions
from repro.distributed.serving import collective_cost_ms, make_batch_mesh
from repro.serving import CapacityConfig, GcnService, SessionRequest

CFG = get_config("agcn-2s", reduced=True)
V, C = CFG.gcn_joints, CFG.gcn_in_channels

needs4 = pytest.mark.skipif(
    jax.device_count() < 4,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=4 "
           "(the ./test.sh --dist tier)")


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def prune_plan(params):
    sw = [np.asarray(b["Wk"]) for b in params["blocks"]]
    return build_prune_plan(sw, CFG.gcn_channels, [1.0, 0.5, 0.5, 0.5],
                            "cav-70-1", input_skip=2)


def _plan_and_bn(params, prune_plan, backend):
    plan = engine.build_execution_plan(params, CFG, prune_plan, quant=True,
                                       backend=backend)
    bn = engine.collect_bn_stats(
        plan, jax.random.normal(jax.random.PRNGKey(1),
                                (2, CFG.gcn_frames, V, C)))
    return plan, bn


def _drive_requests(svc, reqs, max_ticks=600):
    """Feed a SessionRequest script through the handle API, run to idle;
    returns ({sid: final logits}, metrics)."""
    pending = sorted(reqs, key=lambda r: r.arrival)
    i = 0
    while svc.now < max_ticks:
        while i < len(pending) and pending[i].arrival <= svc.now:
            r = pending[i]
            h = svc.open_session(priority=r.priority, arrival=r.arrival)
            svc.submit_clip(h, r.clip)
            i += 1
        if svc.idle():
            if i == len(pending):
                break
            svc.advance_clock(pending[i].arrival)
            continue
        svc.tick()
    assert svc.idle(), "service did not drain within the tick budget"
    m = svc.metrics()
    return {rec.sid: rec.logits for rec in m["records"]}, m


def _qos_trace(rng):
    """Fill a 4-slot tier with low-priority clips, then land high-priority
    arrivals at tick 1 — they preempt *before* the elastic grow triggers,
    and the preempted pair becomes the backlog that grows the tier."""
    spec = [(0, 0, 12), (0, 0, 12), (0, 0, 12), (0, 0, 12),
            (1, 1, 6), (1, 1, 6)]
    return [SessionRequest(
        sid=i, arrival=a, priority=p,
        clip=rng.standard_normal((T, V, C)).astype(np.float32))
        for i, (a, p, T) in enumerate(spec)]


def _single(plan, bn, clip):
    """Uninterrupted single-session baseline on a fresh 1-slot service."""
    svc = GcnService(CFG, plans=(plan,), bn_stats=(bn,), capacity_tiers=(1,))
    h = svc.open_session()
    svc.submit_clip(h, clip)
    svc.run_until_idle()
    return svc.poll(h).logits


# ------------------------------------------------------------- mesh tier

def test_make_batch_mesh_overask_raises():
    """Asking for more devices than visible is a loud error naming the
    fake-device flag, not a short mesh."""
    with pytest.raises(RuntimeError, match="device_count"):
        make_batch_mesh(jax.device_count() + 1)


@needs4
def test_mesh_divisibility_validation(params, prune_plan):
    """Every capacity tier must divide the mesh size — uneven slot shards
    are rejected at construction, naming the tier."""
    plan, bn = _plan_and_bn(params, prune_plan, "reference")
    mesh = make_batch_mesh(4)
    with pytest.raises(ValueError, match="divide"):
        GcnService(CFG, plans=(plan,), bn_stats=(bn,),
                   capacity_tiers=(4, 6), mesh=mesh, warm=False)


@needs4
def test_sharded_parity_reference(params, prune_plan):
    """The tentpole lock (reference backend): a QoS trace with
    preemptions, restores and an elastic grow runs bit-for-bit through
    the mesh-sharded slab — same churn counts, session logits within
    1e-3 of the single-device run."""
    plan, bn = _plan_and_bn(params, prune_plan, "reference")
    # grow_patience=3 so the tick-1 high-priority arrivals preempt while
    # the tier is still full; the preempted backlog then drives the grow
    ccfg = CapacityConfig(tiers=(4, 8), grow_patience=3, shrink_patience=2,
                          cooldown=3)
    runs = {}
    for mesh in (make_batch_mesh(4), None):
        svc = GcnService(CFG, plans=(plan,), bn_stats=(bn,), qos="preempt",
                         capacity_tiers=(4, 8), capacity_config=ccfg,
                         mesh=mesh)
        runs[mesh is not None] = _drive_requests(
            svc, _qos_trace(np.random.default_rng(7)))
    osh, msh = runs[True]
    o1, m1 = runs[False]
    assert msh["mesh"] == 4 and m1["mesh"] == 1
    assert msh["preemptions"] > 0 and msh["migrations"] > 0
    assert msh["preemptions"] == m1["preemptions"]
    assert msh["migrations"] == m1["migrations"]
    assert set(osh) == set(o1)
    for sid in sorted(osh):
        np.testing.assert_allclose(osh[sid], o1[sid], atol=1e-3, rtol=1e-3,
                                   err_msg=f"session {sid}")


@needs4
@pytest.mark.slow
def test_sharded_parity_pallas(params, prune_plan):
    """The same lock on the pallas backend (interpret mode on CPU): a
    fixed 4-slot sharded tier with a preemption round-trip matches the
    single-device run ≤1e-3."""
    plan, bn = _plan_and_bn(params, prune_plan, "pallas")
    spec = [(0, 0, 8), (0, 0, 8), (0, 0, 8), (0, 0, 8), (1, 1, 4)]
    rng = np.random.default_rng(11)
    reqs = [SessionRequest(
        sid=i, arrival=a, priority=p,
        clip=rng.standard_normal((T, V, C)).astype(np.float32))
        for i, (a, p, T) in enumerate(spec)]
    runs = {}
    for mesh in (make_batch_mesh(4), None):
        svc = GcnService(CFG, backend="pallas", plans=(plan,),
                         bn_stats=(bn,), qos="preempt", capacity_tiers=(4,),
                         mesh=mesh)
        runs[mesh is not None] = _drive_requests(svc, reqs)
    osh, msh = runs[True]
    o1, m1 = runs[False]
    assert msh["preemptions"] == m1["preemptions"] > 0
    for sid in sorted(osh):
        np.testing.assert_allclose(osh[sid], o1[sid], atol=1e-3, rtol=1e-3,
                                   err_msg=f"session {sid}")


@needs4
def test_collective_cost_measurable(params, prune_plan):
    """The per-tick collective overhead of the sharded step is a finite
    non-negative number — the ``collective_ms_per_tick`` BENCH axis."""
    plan, bn = _plan_and_bn(params, prune_plan, "reference")
    svc = GcnService(CFG, plans=(plan,), bn_stats=(bn,), capacity_tiers=(4,),
                     mesh=make_batch_mesh(4))
    ms = collective_cost_ms(svc, iters=4)
    assert np.isfinite(ms) and ms >= 0.0


# ------------------------------------------------------------ router tier

def _two_replicas(plan, bn, **kw):
    mk = lambda: GcnService(CFG, plans=(plan,), bn_stats=(bn,), **kw)
    return ReplicaRouter([mk(), mk()])


def test_cross_replica_active_migration_parity(params, prune_plan):
    """The creative-leap lock: a session drained mid-clip out of replica
    0's *slot* and resumed on replica 1 matches its uninterrupted run
    ≤1e-3; the bystander sharing replica 0 is bit-identical to a run
    where no migration happened."""
    plan, bn = _plan_and_bn(params, prune_plan, "reference")
    rng = np.random.default_rng(3)
    clip_a = rng.standard_normal((14, V, C)).astype(np.float32)
    clip_b = rng.standard_normal((10, V, C)).astype(np.float32)
    base = _single(plan, bn, clip_a)

    def run(migrate):
        router = _two_replicas(plan, bn, capacity_tiers=(2,))
        ha = router.open_session(replica=0)
        router.submit_clip(ha, clip_a)
        hb = router.open_session(replica=0)
        router.submit_clip(hb, clip_b)
        for _ in range(5):
            router.tick()
        if migrate:
            assert router.replica_of(ha) == 0
            router.migrate_session(ha, 1)
            assert router.replica_of(ha) == 1      # the pin moved
            assert router.rebalances == 1
        router.run_until_idle()
        return router.poll(ha).logits, router.poll(hb).logits

    logits_a, bystander = run(migrate=True)
    _, bystander_base = run(migrate=False)
    np.testing.assert_allclose(logits_a, base, atol=1e-3, rtol=1e-3)
    np.testing.assert_array_equal(bystander, bystander_base)


def test_cross_replica_preempted_export_parity(params, prune_plan):
    """A *preempted* session (device state parked in the snapshot ring)
    exports through the ring row and resumes on the other replica with
    uninterrupted-run parity — the ring adopt/release allocator path."""
    plan, bn = _plan_and_bn(params, prune_plan, "reference")
    rng = np.random.default_rng(5)
    clip_lo = rng.standard_normal((16, V, C)).astype(np.float32)
    clip_hi = rng.standard_normal((12, V, C)).astype(np.float32)
    base = _single(plan, bn, clip_lo)

    router = _two_replicas(plan, bn, capacity_tiers=(1,), qos="preempt")
    h_lo = router.open_session(replica=0, priority=0)
    router.submit_clip(h_lo, clip_lo)
    for _ in range(4):
        router.tick()
    h_hi = router.open_session(replica=0, priority=1)
    router.submit_clip(h_hi, clip_hi)
    router.tick()                       # preempts h_lo into the ring
    assert router.poll(h_lo).state == "queued"
    src = router.services[0]
    assert src.sched.preemptions == 1
    router.migrate_session(h_lo, 1)     # ring row -> host -> replica 1
    router.run_until_idle()
    np.testing.assert_allclose(router.poll(h_lo).logits, base,
                               atol=1e-3, rtol=1e-3)
    assert router.poll(h_hi).state == "done"
    # the exported session's ring row was returned to replica 0's free list
    assert len(src.sched._ring_free) == src.snap_capacity


def test_router_pinning_and_feedback(params, prune_plan):
    """Placement follows the load feedback (least busy+queued replica,
    index tie-break); handles stay pinned; queue-depth shows up in the
    feedback rows."""
    plan, bn = _plan_and_bn(params, prune_plan, "reference")
    router = _two_replicas(plan, bn, capacity_tiers=(2,))
    rng = np.random.default_rng(2)
    clips = [rng.standard_normal((6, V, C)).astype(np.float32)
             for _ in range(4)]
    hs = [router.open_session() for _ in range(4)]
    for h, c in zip(hs, clips):
        router.submit_clip(h, c)
    # round-robin by load: 0, 1, 0, 1
    assert [router.replica_of(h) for h in hs] == [0, 1, 0, 1]
    fb = router.feedback()
    assert [f["replica"] for f in fb] == [0, 1]
    assert all(f["busy"] + f["queued"] == 2 for f in fb)
    router.run_until_idle()
    assert all(router.poll(h).state == "done" for h in hs)
    with pytest.raises(KeyError):
        router.poll(type(hs[0])(rsid=999))


def test_router_rebalance_drains_hot_replica(params, prune_plan):
    """Sessions force-pinned onto one replica rebalance onto the idle one
    (queued sessions move first), and the move count lands in the merged
    metrics row."""
    plan, bn = _plan_and_bn(params, prune_plan, "reference")
    router = _two_replicas(plan, bn, capacity_tiers=(2,))
    rng = np.random.default_rng(4)
    hs = []
    for _ in range(4):
        h = router.open_session(replica=0)      # manual hot-spotting
        router.submit_clip(h, rng.standard_normal((8, V, C))
                           .astype(np.float32))
        hs.append(h)
    router.tick()
    assert router.feedback()[0]["queued"] == 2
    moved = router.rebalance(threshold=2)
    assert moved == 2
    assert sorted(router.replica_of(h) for h in hs) == [0, 0, 1, 1]
    router.run_until_idle()
    m = router.metrics()
    assert m["rebalances"] == 2 and m["replicas"] == 2
    assert m["sessions"] == 4


def test_run_routed_sessions_row(params, prune_plan):
    """The routed batch driver serves every session and emits the merged
    BENCH row with the distributed axes and the table-rendering fields."""
    m = run_routed_sessions(CFG, replicas=2, slots=2, n_sessions=8,
                            mean_interarrival=2.0, lengths=(6,), seed=0,
                            qos="fifo", rebalance_every=4, max_ticks=4000)
    assert m["sessions"] == 8 and m["replicas"] == 2
    assert m["rebalances"] >= 0 and len(m["per_replica"]) == 2
    for k in ("slots", "frames_per_s", "occupancy",
              "latency_ms_p50", "latency_ms_p99", "load"):
        assert k in m, k
    assert m["frames_per_s"] > 0
