"""2s-AGCN model tests: shapes, pruning consistency, quantization, C_k,
input-skip, bone stream, feature sparsity probe."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.config import ModelConfig
from repro.configs import get_config
from repro.core.agcn import model as M
from repro.core.agcn.graph import build_ntu_subsets, graph_sparsity
from repro.core.pruning.plan import build_prune_plan

CFG = get_config("agcn-2s", reduced=True)


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def x():
    return jax.random.normal(jax.random.PRNGKey(1), (4, CFG.gcn_frames, 25, 3))


def test_static_graph_properties():
    A = build_ntu_subsets()
    assert A.shape == (3, 25, 25)
    # column-normalized D^-1·A: each column of the merged graph sums to 1
    merged = A.sum(0)
    np.testing.assert_allclose(merged.sum(0), np.ones(25), atol=1e-5)
    assert graph_sparsity(A) > 0.8                 # A_k sparse (paper §I)


def test_forward_shapes(params, x):
    logits = M.forward(params, x, CFG)
    assert logits.shape == (4, CFG.gcn_num_classes)
    assert not bool(jnp.isnan(logits).any())


def test_full_keep_plan_matches_dense(params, x):
    """keep_frac=1 + no cavity = numerically identical to dense forward."""
    sw = [np.asarray(b["Wk"]) for b in params["blocks"]]
    plan = build_prune_plan(sw, CFG.gcn_channels, [1.0] * 4, "none",
                            input_skip=1)
    dense = M.forward(params, x, dataclasses.replace(CFG, input_skip=1))
    pruned = M.forward(params, x, dataclasses.replace(CFG, input_skip=1),
                       plan=plan)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(pruned),
                               atol=1e-4, rtol=1e-4)


def test_pruned_plan_reduces_and_runs(params, x):
    sw = [np.asarray(b["Wk"]) for b in params["blocks"]]
    plan = build_prune_plan(sw, CFG.gcn_channels, [1.0, 0.5, 0.5, 0.5],
                            "cav-70-1", input_skip=2)
    logits = M.forward(params, x, CFG, plan=plan)
    assert logits.shape == (4, CFG.gcn_num_classes)
    assert not bool(jnp.isnan(logits).any())
    s = plan.summary(CFG.gcn_channels, 3)
    assert s["compression_ratio"] > 2.0
    assert s["graph_skip_efficiency"] > 0.3


def test_quantization_small_error(params, x):
    a = M.forward(params, x, CFG)
    b = M.forward(params, x, CFG, quant=True)
    rel = float(jnp.abs(a - b).mean() / (jnp.abs(a).mean() + 1e-9))
    assert rel < 0.1                              # Q8.8: negligible loss


def test_ck_path(x):
    cfg = dataclasses.replace(CFG, use_ck=True)
    p = M.init_params(cfg, jax.random.PRNGKey(0))
    logits = M.forward(p, x, cfg)
    assert not bool(jnp.isnan(logits).any())


def test_input_skip_halves_frames(params, x):
    cfg2 = dataclasses.replace(CFG, input_skip=2)
    # runs and differs from non-skipped
    a = M.forward(params, x, dataclasses.replace(CFG, input_skip=1))
    b = M.forward(params, x, cfg2)
    assert not np.allclose(np.asarray(a), np.asarray(b))


def test_bone_stream_and_ensemble(params, x):
    pb = M.init_params(CFG, jax.random.PRNGKey(7))
    bones = M.bone_stream(x)
    assert bones.shape == x.shape
    ens = M.two_stream_logits(params, pb, x, CFG)
    assert ens.shape == (4, CFG.gcn_num_classes)


def test_feature_sparsity_probe(params, x):
    s = M.feature_sparsity_per_block(params, x, CFG)
    assert len(s) == len(CFG.gcn_channels)
    assert all(0.0 <= v <= 1.0 for v in s)
    assert any(v > 0.1 for v in s)                # ReLU produces real zeros
