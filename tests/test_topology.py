"""Variable-topology engine tests.

Three layers of the tentpole are locked here:

* **registry** — `GraphTopology` invariants for every registered
  skeleton, plus bit-exact agreement between `ntu25` and the legacy
  hard-coded NTU graph / bone stream;
* **CSR spatial conv** — the gather-accumulate path matches the dense
  einsum path ≤1e-3 on both backends for every registry topology, in
  the dense and pruned+quant plan variants, and the `sconv="auto"`
  selector picks dense on legacy (noise-floor) graphs and CSR on truly
  sparse ones;
* **mixed-skeleton slab** — one `GcnService` holding `ntu25` + `ntu50`
  sessions concurrently reproduces each session's dedicated
  single-topology run, and a preemption leaves bystander sessions
  bit-identical.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.agcn import engine
from repro.core.agcn import model as M
from repro.core.agcn.graph import (get_topology, static_graph,
                                   topology_names)
from repro.core.pruning.plan import build_prune_plan
from repro.kernels import ops
from repro.serving import GcnService
from repro.serving.slo import SloConfig, SloController

CFG = get_config("agcn-2s", reduced=True)
TOPOLOGIES = ("ntu25", "ntu50", "hand21", "body_hand46")


def _cfg_for(topo):
    return dataclasses.replace(CFG, gcn_joints=topo.num_joints)


# ---------------------------------------------------------------- registry

def test_registry_names():
    assert set(TOPOLOGIES) <= set(topology_names())
    with pytest.raises(KeyError, match="unknown topology"):
        get_topology("ntu26")


@pytest.mark.parametrize("name", TOPOLOGIES)
def test_topology_invariants(name):
    """Shapes, normalization reach and self-consistent CSR factorization
    for every registry skeleton."""
    tp = get_topology(name)
    V, K = tp.num_joints, tp.num_subsets
    assert tp.adjacency.shape == (K, V, V)
    assert tp.parents.shape == (V,)
    assert tp.valid.all() and tp.valid.shape == (V,)
    assert 0.0 < tp.density < 0.5          # skeletons are genuinely sparse
    # the summed subsets reach every joint (no orphaned row)
    assert (np.abs(tp.adjacency).sum(axis=(0, 1)) > 0).all()
    # CSR roundtrips to the dense stack exactly
    from repro.core.agcn.graph import csr_to_dense
    np.testing.assert_array_equal(
        csr_to_dense(tp.indptr, tp.indices, tp.values), tp.adjacency)


def test_ntu25_matches_legacy_graph_and_bone_stream():
    """The registry's ntu25 IS the legacy skeleton: same adjacency bytes
    as static_graph(), and the parent-map bone stream reproduces the
    hard-coded bone_stream bitwise."""
    tp = get_topology("ntu25")
    np.testing.assert_array_equal(tp.adjacency, np.asarray(static_graph()))
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 25, 3))
    np.testing.assert_array_equal(
        np.asarray(M.bone_stream(x)),
        np.asarray(M.bone_stream_parents(x, tp.parents)))


def test_ntu50_is_two_person_block_diagonal_with_one_link():
    """The two-person graph: each 25×25 person block equals the
    single-person graph, and exactly one inter-person bond ties the
    spines."""
    tp25, tp50 = get_topology("ntu25"), get_topology("ntu50")
    a = tp50.adjacency
    off = np.abs(a[:, :25, 25:]).sum(axis=0) + np.abs(a[:, 25:, :25]).sum(axis=0)
    # the spine link makes the coupled rows' normalization differ from the
    # single-person graph only where the bond lands
    assert (off > 0).sum() >= 1
    assert tp50.num_joints == 50
    # person 2's parent chain mirrors person 1's, shifted by 25
    assert (tp50.parents[25:][tp25.parents != np.arange(25)]
            == tp25.parents[tp25.parents != np.arange(25)] + 25).all()


# ------------------------------------------------------- CSR ↔ dense parity

# Full matrix: topology × backend × {dense, pruned+quant}.  Reference
# cells are cheap; pallas-interpret cells beyond the canonical ntu25
# dense cell ride the slow tier.
_FAST = {("ntu25", "reference", False), ("ntu25", "reference", True),
         ("ntu50", "reference", False), ("hand21", "reference", False),
         ("body_hand46", "reference", False), ("ntu25", "pallas", False)}
MATRIX = [
    pytest.param(name, backend, quant,
                 id=f"{name}-{backend}-{'quant' if quant else 'dense'}",
                 marks=() if (name, backend, quant) in _FAST
                 else pytest.mark.slow)
    for name in TOPOLOGIES
    for backend in ("reference", "pallas")
    for quant in (False, True)
]


def _build_pair(name, backend, quant, csr_eps=0.0):
    """Dense-path and (forced) CSR-path plans from identical params."""
    tp = get_topology(name)
    cfg = _cfg_for(tp)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    prune = None
    if quant:
        sw = [np.asarray(b["Wk"]) for b in params["blocks"]]
        prune = build_prune_plan(sw, cfg.gcn_channels,
                                 [1.0] + [0.5] * (len(cfg.gcn_channels) - 1),
                                 "cav-70-1", input_skip=2)
    dense = engine.build_execution_plan(
        params, cfg, prune, quant=quant, backend=backend,
        topology=tp, sconv="dense")
    csr = engine.build_execution_plan(
        params, cfg, prune, quant=quant, backend=backend,
        topology=tp, sconv="csr", csr_eps=csr_eps)
    return tp, cfg, dense, csr


@pytest.mark.parametrize("name,backend,quant", MATRIX)
def test_csr_matches_dense(name, backend, quant):
    tp, cfg, dense, csr = _build_pair(name, backend, quant)
    assert all(b.sconv == "dense" for b in dense.static.blocks)
    assert any(b.sconv == "csr" for b in csr.static.blocks)
    x = jax.random.normal(jax.random.PRNGKey(1),
                          (2, cfg.gcn_frames, tp.num_joints, 3))
    np.testing.assert_allclose(
        np.asarray(engine.execute(dense, x)),
        np.asarray(engine.execute(csr, x)), atol=1e-3, rtol=1e-3)


def test_csr_with_true_sparsity_threshold():
    """csr_eps above the dense-B_k noise floor drops the 1e-6 init noise:
    the CSR plan runs the genuinely sparse skeleton graph and still
    matches the dense path ≤1e-3."""
    tp, cfg, dense, csr = _build_pair("ntu25", "reference", False,
                                      csr_eps=1e-5)
    E_full = tp.num_joints * tp.num_joints
    ba = csr.arrays["blocks"][0]
    assert ba["csr_indices"].shape[-1] < E_full    # actually pruned
    x = jax.random.normal(jax.random.PRNGKey(2),
                          (2, cfg.gcn_frames, tp.num_joints, 3))
    np.testing.assert_allclose(
        np.asarray(engine.execute(dense, x)),
        np.asarray(engine.execute(csr, x)), atol=1e-3, rtol=1e-3)


def test_auto_selector_density_crossover():
    """sconv="auto": the learned B_k is dense at init (1e-6 everywhere),
    so the legacy zero-eps build keeps every block on the dense path —
    existing plans change nothing — while a real sparsity threshold
    flips the (sparse-skeleton) blocks to CSR."""
    tp = get_topology("ntu25")
    cfg = _cfg_for(tp)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    legacy = engine.build_execution_plan(params, cfg, backend="reference")
    assert all(b.sconv == "dense" for b in legacy.static.blocks)
    sparse = engine.build_execution_plan(params, cfg, backend="reference",
                                         topology=tp, csr_eps=1e-5)
    assert all(b.sconv == "csr" for b in sparse.static.blocks
               if not b.use_ck)


def test_graph_sconv_subset_mismatch_error_names_topology():
    """The satellite bugfix: a K-axis mismatch between graph and weights
    raises a topology-named ValueError instead of an opaque shape error
    deep inside the kernel."""
    x = np.zeros((1, 2, 25, 4), np.float32)
    g = np.zeros((2, 25, 25), np.float32)        # K=2
    w = np.zeros((3, 4, 4), np.float32)          # K=3
    with pytest.raises(ValueError, match="subsets.*'ntu25'"):
        ops.graph_sconv(x, g, w, topology="ntu25")
    idx = np.zeros((2, 32, 1), np.int32)
    val = np.zeros((2, 32, 1), np.float32)
    with pytest.raises(ValueError, match="subsets.*'ntu50'"):
        ops.graph_sconv_csr(x, idx, val, w, topology="ntu50")


# ------------------------------------------------------- pad-to-Vmax plans

def test_padded_plan_streams_bit_exact_on_reference():
    """A plan padded to a wider slab (pad_joints=Vmax) with joint-validity
    masking reproduces the narrow dedicated plan bit-for-bit on the
    streaming path (frozen BN stats — what the slab actually runs)."""
    tp = get_topology("ntu25")
    cfg = _cfg_for(tp)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    narrow = engine.build_execution_plan(params, cfg, backend="reference",
                                         topology=tp)
    padded = engine.build_execution_plan(params, cfg, backend="reference",
                                         topology=tp, pad_joints=50)
    assert padded.static.joints == 50
    assert padded.static.valid_joints == 25
    xc = jax.random.normal(jax.random.PRNGKey(3),
                           (2, cfg.gcn_frames, 25, 3))
    bn = engine.collect_bn_stats(narrow, xc)
    st_n = engine.init_stream_state(narrow, 1, bn_stats=bn)
    st_p = engine.init_stream_state(padded, 1, bn_stats=bn)
    clip = np.asarray(jax.random.normal(jax.random.PRNGKey(4),
                                        (cfg.gcn_frames, 25, 3)))
    T = cfg.gcn_frames
    for t in range(T + 45):                      # clip + flush drain
        valid = t < T
        f_n = clip[t][None] if valid else np.zeros((1, 25, 3), np.float32)
        f_p = np.zeros((1, 50, 3), np.float32)
        f_p[:, :25] = f_n
        st_n, log_n = engine.step_frame(narrow, st_n, f_n, valid=valid)
        st_p, log_p = engine.step_frame(padded, st_p, f_p, valid=valid)
        np.testing.assert_array_equal(np.asarray(log_n), np.asarray(log_p))


# ------------------------------------------------------- SLO in-flight unit

def test_slo_inflight_age_breaches_and_blocks_recovery():
    """The admitted-but-unlatched blind spot: a session committed past
    the target must read as a breach even though it is in neither the
    queue nor the latency window."""
    c = SloController(SloConfig(target_p99_ticks=50, window=8,
                                breach_patience=2, recover_patience=4,
                                shed_mode="reject"), tiers=(2,))
    assert not c.breached()
    assert not c.breached(inflight_age=50)       # at the bound is healthy
    assert c.breached(inflight_age=51)
    # a persistent in-flight breach at the (single-tier) top sheds
    for t in range(2):
        c.observe(2, 0, t, inflight_age=60)
    assert c.shedding
    # healthy latched samples alone cannot un-shed while an in-flight
    # session is still committed to breaching
    for _ in range(8):
        c.record_first_logit(1, 10)
    for t in range(2, 6):
        c.observe(2, 0, t, inflight_age=60)
    assert c.shedding
    # once the in-flight signal clears, the recovery streak un-sheds
    for t in range(6, 10):
        c.observe(2, 0, t, inflight_age=0)
    assert not c.shedding


# ----------------------------------------------------- mixed-skeleton slab

def _final_logits(svc, h):
    st = svc.poll(h)
    assert st.state == "done"
    return np.asarray(st.record.logits)


@pytest.fixture(scope="module")
def mixed_runs():
    """One mixed ntu25+ntu50 service under preemption, the same schedule
    without the preemptor, and dedicated single-topology baselines."""
    rng = np.random.default_rng(5)
    clip25 = rng.standard_normal((10, 25, 3)).astype(np.float32)
    clip50 = rng.standard_normal((12, 50, 3)).astype(np.float32)
    clip25b = rng.standard_normal((8, 25, 3)).astype(np.float32)

    def build_mixed():
        return GcnService(CFG, backend="reference", qos="preempt",
                          capacity_tiers=(2,),
                          topologies=("ntu25", "ntu50"), seed=0)

    # run A: X(ntu25, pri 0) + Y(ntu50, pri 1) fill both slots; Z(ntu25,
    # pri 2) arrives mid-flight and preempts X
    svc = build_mixed()
    x_h = svc.open_session(priority=0, topology="ntu25")
    svc.submit_clip(x_h, clip25)
    y_h = svc.open_session(priority=1, topology="ntu50")
    svc.submit_clip(y_h, clip50)
    for _ in range(5):
        svc.tick()
    z_h = svc.open_session(priority=2, topology="ntu25")
    svc.submit_clip(z_h, clip25b)
    while not svc.idle():
        svc.tick()
    run_a = {"svc": svc,
             "X": _final_logits(svc, x_h), "Y": _final_logits(svc, y_h),
             "Z": _final_logits(svc, z_h)}

    # run B: identical schedule minus the preemptor
    svc_b = build_mixed()
    x2 = svc_b.open_session(priority=0, topology="ntu25")
    svc_b.submit_clip(x2, clip25)
    y2 = svc_b.open_session(priority=1, topology="ntu50")
    svc_b.submit_clip(y2, clip50)
    while not svc_b.idle():
        svc_b.tick()
    run_b = {"X": _final_logits(svc_b, x2), "Y": _final_logits(svc_b, y2)}

    # dedicated single-topology baselines (fifo, one session at a time —
    # per-slot clocks make staggered/mixed serving equivalent to these)
    ded = {}
    svc25 = GcnService(CFG, backend="reference", qos="fifo",
                       capacity_tiers=(2,), topologies=("ntu25",), seed=0)
    for key, clip in (("X", clip25), ("Z", clip25b)):
        h = svc25.open_session()
        svc25.submit_clip(h, clip)
        while not svc25.idle():
            svc25.tick()
        ded[key] = _final_logits(svc25, h)
    svc50 = GcnService(CFG, backend="reference", qos="fifo",
                       capacity_tiers=(2,), topologies=("ntu50",), seed=0)
    h = svc50.open_session()
    svc50.submit_clip(h, clip50)
    while not svc50.idle():
        svc50.tick()
    ded["Y"] = _final_logits(svc50, h)
    return run_a, run_b, ded


def test_mixed_slab_matches_dedicated_runs(mixed_runs):
    """Acceptance: every session served from the mixed ntu25+ntu50 slab —
    including across a preemption — matches its dedicated
    single-topology service ≤1e-3."""
    run_a, _, ded = mixed_runs
    assert run_a["svc"].metrics()["preemptions"] >= 1
    for key in ("X", "Y", "Z"):
        np.testing.assert_allclose(run_a[key], ded[key],
                                   atol=1e-3, rtol=1e-3)


def test_mixed_slab_bystander_bit_identical_across_preemption(mixed_runs):
    """The non-preempted ntu50 session's logits are bit-identical whether
    or not a preemption churned the neighbouring slot."""
    run_a, run_b, _ = mixed_runs
    np.testing.assert_array_equal(run_a["Y"], run_b["Y"])


def test_open_session_rejects_unknown_topology():
    svc = GcnService(CFG, backend="reference", capacity_tiers=(2,),
                     topologies=("ntu25",), seed=0)
    with pytest.raises(ValueError, match="unknown topology"):
        svc.open_session(topology="ntu50")


def test_submit_validates_frame_shape_per_topology():
    svc = GcnService(CFG, backend="reference", capacity_tiers=(2,),
                     topologies=("ntu25", "hand21"), seed=0)
    h = svc.open_session(topology="hand21")
    with pytest.raises(ValueError, match="hand21"):
        svc.submit(h, np.zeros((25, 3), np.float32))
    svc.submit(h, np.zeros((21, 3), np.float32))


def test_metrics_carry_topology_axes():
    svc = GcnService(CFG, backend="reference", capacity_tiers=(2,),
                     topologies=("ntu25", "ntu50"), seed=0)
    m = svc.metrics()
    assert m["topologies"] == "ntu25,ntu50"
    assert m["joints"] == 50
