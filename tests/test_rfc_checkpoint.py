"""RFC-compressed activation checkpointing: exact gradients + byte saving."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.rfc.checkpoint import checkpoint_bytes, mlp_relu2_rfc


def _ref(x, wi, wo):
    return jnp.square(jax.nn.relu(x @ wi)) @ wo


def test_forward_matches_ref():
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    x = jax.random.normal(ks[0], (8, 32))
    wi = jax.random.normal(ks[1], (32, 64)) * 0.2
    wo = jax.random.normal(ks[2], (64, 32)) * 0.2
    np.testing.assert_allclose(
        np.asarray(mlp_relu2_rfc(x, wi, wo)), np.asarray(_ref(x, wi, wo)),
        atol=1e-5, rtol=1e-5)


def test_gradients_exact():
    """The RFC round-trip is lossless, so grads match autodiff exactly."""
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    x = jax.random.normal(ks[0], (8, 32))
    wi = jax.random.normal(ks[1], (32, 64)) * 0.2
    wo = jax.random.normal(ks[2], (64, 32)) * 0.2

    def loss_rfc(x, wi, wo):
        return jnp.sum(jnp.square(mlp_relu2_rfc(x, wi, wo)))

    def loss_ref(x, wi, wo):
        return jnp.sum(jnp.square(_ref(x, wi, wo)))

    g1 = jax.grad(loss_rfc, argnums=(0, 1, 2))(x, wi, wo)
    g2 = jax.grad(loss_ref, argnums=(0, 1, 2))(x, wi, wo)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-4, rtol=1e-4)


def test_checkpoint_bytes_reduced():
    x = jax.random.normal(jax.random.PRNGKey(2), (64, 128))
    h = jnp.square(jax.nn.relu(x - 0.3))       # sparse hidden
    dense, rfc = checkpoint_bytes(h)
    assert rfc < dense * 0.8                   # >20% saving at this sparsity
