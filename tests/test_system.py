"""End-to-end system tests: the paper's pipeline (train → sparsity-guided
prune → quantize → compressed inference) and LM train-loop integration."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.config import TrainConfig
from repro.configs import get_config
from repro.core.agcn import model as M
from repro.core.pruning.plan import build_prune_plan, drop_scheme
from repro.core.rfc.format import rfc_decode, rfc_encode, storage_cost
from repro.data.pipeline import DataConfig, make_batches
from repro.launch.train import train_loop


def test_agcn_trains_and_loss_drops(tmp_path):
    tcfg = TrainConfig(learning_rate=3e-3, total_steps=30, warmup_steps=3,
                       checkpoint_every=0, checkpoint_dir=str(tmp_path))
    _, losses = train_loop("agcn-2s", tcfg, reduced=True, batch=8, seq=0,
                           resume=False)
    assert losses[-1] < losses[0]


def test_lm_trains_and_loss_drops(tmp_path):
    tcfg = TrainConfig(learning_rate=1e-3, total_steps=25, warmup_steps=3,
                       checkpoint_every=0, checkpoint_dir=str(tmp_path))
    _, losses = train_loop("smollm-360m", tcfg, reduced=True, batch=8,
                           seq=64, resume=False)
    assert np.mean(losses[-5:]) < np.mean(losses[:5])


def test_checkpoint_resume_continues(tmp_path):
    tcfg = TrainConfig(learning_rate=1e-3, total_steps=10, warmup_steps=2,
                       checkpoint_every=5, checkpoint_dir=str(tmp_path))
    train_loop("smollm-360m", tcfg, reduced=True, batch=4, seq=32,
               resume=False)
    tcfg2 = dataclasses.replace(tcfg, total_steps=15)
    _, losses = train_loop("smollm-360m", tcfg2, reduced=True, batch=4,
                           seq=32, resume=True)
    assert len(losses) == 5                       # resumed from step 10


def test_paper_pipeline_end_to_end():
    """The full RFC-HyPGCN flow on the reduced model:
    measure sparsity → Drop-scheme → hybrid prune → quantize → the pruned
    model still classifies (logits sane), compression in paper band,
    RFC compresses the actual intermediate activations."""
    cfg = get_config("agcn-2s", reduced=True)
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    data = make_batches(cfg, DataConfig(global_batch=8, seq_len=0))
    batch = next(data)
    x = jnp.asarray(batch["x"])

    # 1. feature sparsity per block drives the channel-drop scheme (Fig. 9)
    sparsity = M.feature_sparsity_per_block(params, x, cfg)
    keep = drop_scheme(sparsity)
    keep[0] = 1.0

    # 2. hybrid prune (C1+C2) from weight magnitudes
    sw = [np.asarray(b["Wk"]) for b in params["blocks"]]
    plan = build_prune_plan(sw, cfg.gcn_channels, keep, "cav-70-1",
                            input_skip=2)
    summary = plan.summary(cfg.gcn_channels, cfg.gcn_in_channels)
    assert summary["compression_ratio"] > 1.5
    assert 0 < summary["graph_skip_efficiency"] < 1

    # 3. quantized pruned inference
    logits = M.forward(params, x, cfg, plan=plan, quant=True)
    assert logits.shape == (x.shape[0], cfg.gcn_num_classes)
    assert not bool(jnp.isnan(logits).any())

    # 4. RFC on real activations: roundtrip exact + storage reduced
    acts = jax.nn.relu(jax.random.normal(key, (64, 64)) - 0.5)  # ~70% sparse
    v, hot = rfc_encode(acts, apply_relu=False)
    back = rfc_decode(v, hot)
    np.testing.assert_allclose(np.asarray(back), np.asarray(acts), atol=1e-6)
    cost = storage_cost(np.asarray(hot) > 0)
    assert cost["rfc_vs_dense_reduction"] > 0.2   # paper: 35.93%


def test_gcn_vs_lm_step_interfaces_match():
    """Both families run through the identical train-step factory."""
    from repro.models import registry
    from repro.optim import adamw
    from repro.train.steps import make_train_step
    for arch in ("agcn-2s", "xlstm-1.3b"):
        cfg = get_config(arch, reduced=True)
        params = registry.init_params(cfg, jax.random.PRNGKey(0))
        data = make_batches(cfg, DataConfig(global_batch=2, seq_len=16))
        batch = jax.tree_util.tree_map(jnp.asarray, next(data))
        step = make_train_step(cfg, TrainConfig())
        p2, o2, m = step(params, adamw.init(params), batch)
        assert not bool(jnp.isnan(m["loss"]))
