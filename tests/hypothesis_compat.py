"""Optional-dependency guard for hypothesis (listed as the `test` extra).

When hypothesis is installed this re-exports the real ``given`` /
``settings`` / ``strategies``.  When it is missing, the stubs below make
property-based tests *skip at run time* (via ``pytest.importorskip``)
while the rest of each module still collects and runs — the seed behavior
was five whole-module ``ModuleNotFoundError`` collection errors.
"""
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import pytest

    HAVE_HYPOTHESIS = False

    class _Stub:
        """Stands in for strategy objects and their combinators — any call
        or attribute chain yields another stub, so module-level strategy
        expressions evaluate without the real library."""

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, name):
            return self

    class _Strategies:
        def __getattr__(self, name):
            if name == "composite":
                # @st.composite functions become stub factories; the real
                # body never runs (its @given consumer is skipped anyway)
                return lambda fn: _Stub()
            return _Stub()

    st = _Strategies()

    def given(*_args, **_kwargs):
        def deco(fn):
            def _skipped():
                pytest.importorskip("hypothesis")
            _skipped.__name__ = getattr(fn, "__name__", "property_test")
            _skipped.__doc__ = fn.__doc__
            return _skipped
        return deco

    def settings(*_args, **_kwargs):
        return lambda fn: fn
