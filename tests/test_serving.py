"""The `repro.serving` service API — handle protocol + elastic capacity.

The tentpole locks:

* **Handle protocol** — open/submit/poll/close through ``GcnService``
  (including starved open sessions, which are *held* in place, never
  zero-padded) produces the same logits as an uninterrupted single-stream
  run.
* **Elastic migration parity** (the acceptance criterion): a session
  migrated across capacity tiers (grow *and* shrink, active mid-clip)
  produces logits equal to the uninterrupted fixed-capacity session — on
  both backends — and a bystander session riding along through a
  migration is *bit-identical* to its unmigrated run.
* **No retrace within a tier**: admissions, holds, drains and occupancy
  changes share one compiled step per tier.
* **Hysteresis never thrashes**: the capacity manager under an
  oscillating step load never emits grow→shrink→grow inside 3 ticks.

Plus the satellite units: the (backend, slots, qos, capacity, load)
BENCH merge key, the scheduler's open-session hold bookkeeping, and the
single-source serve batch default.
"""
import json
import pathlib
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import serving
from repro.configs import get_config
from repro.core.agcn import engine
from repro.core.agcn import model as M
from repro.core.pruning.plan import build_prune_plan
from repro.serving import (CapacityConfig, CapacityManager, GcnService,
                           SessionRequest, bench_key, write_bench)

CFG = get_config("agcn-2s", reduced=True)
V, C = CFG.gcn_joints, CFG.gcn_in_channels
REPO = pathlib.Path(__file__).resolve().parent.parent


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def prune_plan(params):
    sw = [np.asarray(b["Wk"]) for b in params["blocks"]]
    return build_prune_plan(sw, CFG.gcn_channels, [1.0, 0.5, 0.5, 0.5],
                            "cav-70-1", input_skip=2)


def _plan_and_bn(params, prune_plan, backend):
    plan = engine.build_execution_plan(params, CFG, prune_plan, quant=True,
                                       backend=backend)
    bn = engine.collect_bn_stats(
        plan, jax.random.normal(jax.random.PRNGKey(1),
                                (2, CFG.gcn_frames, V, C)))
    return plan, bn


def _run_independent(plan, bn, clip):
    """One session alone: batch-1 step_frame over clip + flush drain —
    the uninterrupted fixed-capacity baseline."""
    state = engine.init_stream_state(plan, 1, bn_stats=bn)
    step = jax.jit(engine.step_frame)
    xc = jnp.asarray(clip)[None]
    T = xc.shape[1]
    zeros = jnp.zeros_like(xc[:, 0])
    logits = None
    for r in range(T + engine.stream_flush_frames(plan, T)):
        frame = xc[:, r] if r < T else zeros
        state, logits = step(plan, state, frame, jnp.asarray(r < T))
    return np.asarray(logits)[0]


def _drive(svc, arrivals, max_ticks=600):
    """Open+submit each (clip, kwargs) at its arrival tick, run to idle;
    returns {index: final logits}."""
    handles = {}
    out = {}
    pending = sorted(range(len(arrivals)), key=lambda i: arrivals[i][0])
    i = 0
    while svc.now < max_ticks:
        while i < len(pending) and arrivals[pending[i]][0] <= svc.now:
            at, clip, kw = arrivals[pending[i]]
            h = svc.open_session(arrival=at, **kw)
            svc.submit_clip(h, clip)
            handles[pending[i]] = h
            i += 1
        if svc.idle():
            if i == len(pending):
                break
            svc.advance_clock(arrivals[pending[i]][0])
            continue
        svc.tick()
    assert svc.idle(), "service did not drain within the tick budget"
    for k, h in handles.items():
        st = svc.poll(h)
        assert st.state == "done"
        out[k] = st.logits
    return out


# ------------------------------------------------------- handle protocol

@pytest.mark.parametrize("backend", ["reference", "pallas"])
def test_handle_api_matches_independent(params, prune_plan, backend):
    """open/submit/poll/close with starvation gaps (ticks where the open
    session has no buffered frame are held, not padded) equals the
    uninterrupted single-stream run, on the paper's pruned+quant target."""
    plan, bn = _plan_and_bn(params, prune_plan, backend)
    svc = GcnService(CFG, backend=backend, plans=(plan,), bn_stats=(bn,),
                     capacity_tiers=(2,))
    rng = np.random.default_rng(5)
    T = 10
    clip = rng.standard_normal((T, V, C)).astype(np.float32)
    h = svc.open_session()
    fed = 0
    # feed irregularly: some ticks get 0 frames (hold), some 2 (buffered)
    for burst in (1, 0, 2, 0, 0, 3, 1, 0, 3):
        for _ in range(burst):
            svc.submit(h, clip[fed])
            fed += 1
        st = svc.poll(h)
        assert st.state in ("queued", "active")
        svc.tick()
    assert fed == T
    svc.close(h)
    assert svc.poll(h).state in ("active", "draining")
    svc.run_until_idle()
    st = svc.poll(h)
    assert st.state == "done"
    assert st.record is not None and st.record.frames == T
    want = _run_independent(plan, bn, clip)
    np.testing.assert_allclose(st.logits, want, atol=1e-3, rtol=1e-3,
                               err_msg=f"held session ({backend})")


def test_poll_states_and_errors(params):
    """poll reports queued→active→draining→done; submit validates frame
    shape; submitting to a closed session and unknown handles raise."""
    plan = engine.build_execution_plan(params, CFG, backend="reference")
    bn = engine.collect_bn_stats(
        plan, jax.random.normal(jax.random.PRNGKey(1),
                                (2, CFG.gcn_frames, V, C)))
    svc = GcnService(CFG, plans=(plan,), bn_stats=(bn,), capacity_tiers=(1,))
    h0 = svc.open_session()
    h1 = svc.open_session()
    assert svc.poll(h0).state == "queued" and svc.poll(h1).state == "queued"
    clip = np.zeros((2, V, C), np.float32)
    svc.submit_clip(h0, clip)
    svc.tick()
    assert svc.poll(h0).state == "active"
    assert svc.poll(h1).state == "queued"      # one slot only
    svc.tick()
    svc.tick()
    assert svc.poll(h0).state == "draining"
    # default poll is async (no forced readback); wait=True syncs
    assert svc.poll(h0, wait=True).logits is not None
    with pytest.raises(ValueError):
        svc.submit(h0, clip[0])                # closed stream
    with pytest.raises(ValueError):
        svc.submit(h1, np.zeros((V + 1, C)))   # wrong shape
    with pytest.raises(KeyError):
        svc.poll(serving.SessionHandle(sid=999))
    svc.submit_clip(h1, clip)
    svc.run_until_idle()
    assert svc.poll(h0).state == "done" and svc.poll(h1).state == "done"


def test_run_until_idle_raises_on_unclosed_session(params):
    """An open session that is never closed holds its slot forever — the
    drain helper must fail loudly instead of spinning."""
    plan = engine.build_execution_plan(params, CFG, backend="reference")
    bn = engine.collect_bn_stats(
        plan, jax.random.normal(jax.random.PRNGKey(1),
                                (2, CFG.gcn_frames, V, C)))
    svc = GcnService(CFG, plans=(plan,), bn_stats=(bn,), capacity_tiers=(1,))
    h = svc.open_session()
    svc.submit(h, np.zeros((V, C), np.float32))
    with pytest.raises(RuntimeError, match="close"):
        svc.run_until_idle(max_ticks=5)


# --------------------------------------------------- elastic capacity

ELASTIC_CCFG = CapacityConfig(tiers=(2, 4), grow_patience=1,
                              shrink_patience=2, cooldown=3)


@pytest.mark.parametrize("backend", ["reference", "pallas"])
def test_elastic_migration_parity(params, prune_plan, backend):
    """The acceptance lock: sessions migrated across capacity tiers (a
    grow with two active mid-clip sessions, then a shrink with one) equal
    the uninterrupted fixed-capacity runs on both backends."""
    plan, bn = _plan_and_bn(params, prune_plan, backend)
    svc = GcnService(CFG, backend=backend, plans=(plan,), bn_stats=(bn,),
                     capacity_tiers=(2, 4), capacity_config=ELASTIC_CCFG)
    rng = np.random.default_rng(9)
    lengths = (26, 20, 8, 8)
    clips = [rng.standard_normal((T, V, C)).astype(np.float32)
             for T in lengths]
    # sid 0/1 admitted at the 2-tier; sid 2/3 arrive while both slots are
    # busy -> demand 4 -> grow to 4 migrates two active sessions; after
    # the short sessions drain, demand 1 -> shrink migrates the long one
    arrivals = [(0, clips[0], {}), (1, clips[1], {}),
                (4, clips[2], {}), (4, clips[3], {})]
    got = _drive(svc, arrivals)
    events = svc.capman.events
    assert any(e.new > e.old and e.busy > 0 for e in events), events
    assert any(e.new < e.old and e.busy > 0 for e in events), events
    for i, clip in enumerate(clips):
        want = _run_independent(plan, bn, clip)
        np.testing.assert_allclose(got[i], want, atol=1e-3, rtol=1e-3,
                                   err_msg=f"session {i} ({backend})")


def test_elastic_bystander_bit_identity(params, prune_plan):
    """A session that merely rides along through grow+shrink migrations
    (snapshot-gather → scatter into the other tier's slab) is *bit-
    identical* to the same session served at fixed capacity — migration
    is an exact state copy and per-slot math does not depend on S."""
    plan, bn = _plan_and_bn(params, prune_plan, "reference")
    rng = np.random.default_rng(10)
    clips = [rng.standard_normal((T, V, C)).astype(np.float32)
             for T in (26, 8, 8)]
    arrivals = [(0, clips[0], {}), (2, clips[1], {}), (2, clips[2], {})]

    fixed = GcnService(CFG, plans=(plan,), bn_stats=(bn,),
                       capacity_tiers=(4,))
    elastic = GcnService(CFG, plans=(plan,), bn_stats=(bn,),
                         capacity_tiers=(2, 4),
                         capacity_config=ELASTIC_CCFG)
    want = _drive(fixed, arrivals)
    got = _drive(elastic, arrivals)
    assert elastic.capman.events, "no migration happened"
    for i in range(len(clips)):
        np.testing.assert_array_equal(got[i], want[i],
                                      err_msg=f"session {i}")


def test_no_retrace_within_tier(params):
    """Admissions, holds, flush drains and occupancy changes are traced
    masking: one compilation of the slab step serves a whole tier."""
    plan = engine.build_execution_plan(params, CFG, backend="reference")
    bn = engine.collect_bn_stats(
        plan, jax.random.normal(jax.random.PRNGKey(1),
                                (2, CFG.gcn_frames, V, C)))
    # fused=False pins the legacy step path this test wraps; the fused
    # tick's no-retrace guard lives in tests/test_fused_tick.py
    svc = GcnService(CFG, plans=(plan,), bn_stats=(bn,), capacity_tiers=(3,),
                     warm=False, fused=False)
    # count traces of the service's own step by re-jitting a counting
    # wrapper around the same step factory the service uses
    from repro.train.steps import make_gcn_slab_step
    inner = make_gcn_slab_step(CFG)
    traces = []

    def counted(plans, slabs, frames, valid, reset, hold):
        traces.append(1)
        return inner(plans, slabs, frames, valid, reset, hold)

    svc._step = jax.jit(counted)
    rng = np.random.default_rng(3)
    h0 = svc.open_session()
    svc.submit_clip(h0, rng.standard_normal((4, V, C)).astype(np.float32))
    svc.tick()
    h1 = svc.open_session()               # open session: starved -> hold
    svc.submit(h1, rng.standard_normal((V, C)).astype(np.float32))
    svc.tick()
    svc.tick()                            # h1 starves (hold), h0 drains
    svc.close(h1)
    svc.run_until_idle()
    assert svc.poll(h0).state == "done" and svc.poll(h1).state == "done"
    assert len(traces) == 1


def test_capacity_manager_hysteresis_never_thrashes():
    """Under a worst-case oscillating step load (demand flips between
    over- and under-capacity every tick), resize events are spaced by at
    least the cooldown — never grow→shrink→grow inside 3 ticks — and a
    steady load settles at one tier."""
    cm = CapacityManager(CapacityConfig(tiers=(2, 4, 8), grow_patience=1,
                                        shrink_patience=1, cooldown=3))
    for tick in range(60):                # square-wave step load
        demand = 5 if (tick // 1) % 2 == 0 else 1
        busy = min(demand, cm.capacity)
        cm.observe(busy, demand - busy, tick)
    for a, b in zip(cm.events, cm.events[1:]):
        assert b.tick - a.tick >= 3, (a, b)
    # grow→shrink→grow inside any 3-tick window is impossible
    for a, b, c in zip(cm.events, cm.events[1:], cm.events[2:]):
        if a.new > a.old and b.new < b.old and c.new > c.old:
            assert c.tick - a.tick > 3

    # steady high load: grow once to the fitting tier, then no events
    cm = CapacityManager(CapacityConfig(tiers=(2, 4, 8), grow_patience=2,
                                        shrink_patience=4, cooldown=4))
    for tick in range(30):
        cm.observe(min(6, cm.capacity), 6 - min(6, cm.capacity), tick)
    assert [(-e.old, e.new) for e in cm.events] == [(-2, 8)]
    # steady lull afterwards: walk down one tier per patience+cooldown
    for tick in range(30, 60):
        cm.observe(1, 0, tick)
    assert cm.capacity == 2
    assert [e.new for e in cm.events] == [8, 4, 2]


def test_capacity_manager_validation():
    """Tier/cooldown validation and start_tier selection."""
    with pytest.raises(ValueError):
        CapacityConfig(tiers=())
    with pytest.raises(ValueError):
        CapacityConfig(tiers=(2, 2))
    with pytest.raises(ValueError):
        CapacityConfig(tiers=(2, 4), cooldown=1)
    with pytest.raises(ValueError):
        CapacityManager(CapacityConfig(tiers=(2, 4)), start_tier=3)
    cm = CapacityManager(CapacityConfig(tiers=(8, 2, 4)), start_tier=4)
    assert cm.capacity == 4 and cm.tiers == (2, 4, 8)


def test_scheduler_resize_compacts_and_validates():
    """SlabScheduler.resize packs active sessions into the low slots,
    returns the old→new mapping, and refuses a shrink below busy()."""
    sched = serving.SlabScheduler(4, V, C, flush_frames=lambda T: 1,
                                  first_logit_delay=1)
    clip = np.zeros((3, V, C), np.float32)
    for sid in range(3):
        sched.submit(SessionRequest(sid=sid, arrival=0, clip=clip))
    sched.tick_inputs(0, 0.0)
    sched.tick_outputs(0, np.zeros((4, 8)), 0.0)
    sched.slots[1] = None                 # fake an eviction: occupancy 0,2
    mapping = sched.resize(2)
    assert mapping == {0: 0, 2: 1}
    assert sched.busy() == 2 and len(sched.slots) == 2
    with pytest.raises(ValueError):
        sched.resize(1)


def test_scheduler_holds_starved_open_session():
    """Host-side hold bookkeeping: an admitted open session with an empty
    buffer is held (no rel advance, no valid frame), resumes when frames
    arrive, and drains only after close()."""
    sched = serving.SlabScheduler(1, V, C, flush_frames=lambda T: 2,
                                  first_logit_delay=1)
    req = SessionRequest(sid=0, arrival=0)          # open: clip=None
    sched.submit(req)
    tp = sched.tick_inputs(0, 0.0)
    assert tp.hold[0] and not tp.valid[0]           # admitted, starved
    sched.tick_outputs(0, np.zeros((1, 8)), 0.0)
    assert sched.slots[0].rel == 0                  # held: no advance
    req.push_frame(np.ones((V, C), np.float32))
    tp = sched.tick_inputs(1, 0.0)
    assert tp.valid[0] and not tp.hold[0]
    np.testing.assert_array_equal(tp.frames[0], np.ones((V, C)))
    sched.tick_outputs(1, np.zeros((1, 8)), 0.0)
    assert sched.slots[0].rel == 1 and sched.slots[0].total is None
    req.close()
    done = []
    for tick in range(2, 6):
        tp = sched.tick_inputs(tick, 0.0)
        assert not tp.hold[0] and not tp.valid[0]   # flush drain
        done += sched.tick_outputs(tick, np.zeros((1, 8)), 0.0)
    assert [r.sid for r in done] == [0]
    assert done[0].frames == 1
    assert sched.valid_frames == 1


# ------------------------------------------------------- satellite units

def test_write_bench_elastic_rows_do_not_collide(tmp_path):
    """The merge key includes capacity and load: an elastic run, its fixed
    baselines under burst load, and the legacy steady-state rows under the
    same (backend, slots, qos) all coexist; re-writing one key replaces
    only that row."""
    path = str(tmp_path / "BENCH_sessions.json")
    legacy = {"backend": "reference", "slots": 2, "qos": "fifo",
              "frames_per_s": 100.0}                 # pre-elastic row
    write_bench([legacy], path)
    elastic = {"backend": "reference", "slots": 2, "qos": "fifo",
               "capacity": "elastic:2,4,8", "load": "burst",
               "frames_per_s": 300.0, "records": ["dropme"]}
    fixed_burst = {"backend": "reference", "slots": 2, "qos": "fifo",
                   "capacity": "fixed", "load": "burst",
                   "frames_per_s": 150.0}
    write_bench([elastic, fixed_burst], path)
    rows = json.loads(open(path).read())
    assert len(rows) == 3                            # nothing clobbered
    assert rows[0] == legacy
    assert rows[1]["capacity"] == "elastic:2,4,8"
    assert "records" not in rows[1]
    assert bench_key(legacy) == ("reference", 2, "fifo", "fixed", "poisson",
                                 1, 1, "demand", "", "ntu25", False, 0.0)
    assert bench_key(elastic) != bench_key(fixed_burst) != bench_key(legacy)
    # replace just the elastic row
    write_bench([{**elastic, "frames_per_s": 311.0}], path)
    rows = json.loads(open(path).read())
    assert len(rows) == 3
    assert rows[1]["frames_per_s"] == 311.0
    assert rows[0] == legacy and rows[2] == fixed_burst


def test_run_sessions_elastic_end_to_end():
    """run_sessions(capacity_tiers=..., load="burst"): every session
    completes, the elastic accounting is populated, and the row carries
    the capacity/load merge axes."""
    res = serving.run_sessions(CFG, slots=2, n_sessions=6,
                               mean_interarrival=8.0, lengths=(8,),
                               backend="reference", seed=0,
                               capacity_tiers=(2, 4, 8), load="burst")
    assert res["sessions"] == 6
    assert res["capacity"] == "elastic:2,4,8"
    assert res["load"] == "burst"
    assert res["migrations"] == (res["migrations_grow"]
                                 + res["migrations_shrink"])
    assert res["migrations"] >= 1
    assert res["migration_ms_mean"] >= 0.0
    assert sum(res["tier_ticks"].values()) > 0
    assert res["capacity_final"] in (2, 4, 8)
    for rec in res["records"]:
        assert np.isfinite(rec.logits).all()


def test_serve_batch_default_resolves_in_config():
    """--batch 0 family/mode defaults live in ModelConfig.serve_batch:
    explicit requests win, gcn clip/stream differ, LM families fall back
    to the global default — no per-subcommand branches."""
    gcn = get_config("agcn-2s", reduced=True)
    lm = get_config("smollm-360m", reduced=True)
    assert gcn.serve_batch("clip") == 8
    assert gcn.serve_batch("stream") == 4
    assert gcn.serve_batch("clip", 3) == 3
    assert lm.serve_batch("lm") == 4
    assert lm.serve_batch("lm", 16) == 16


def test_api_surface_gate_matches_checked_in_snapshot():
    """tools/check_api.py: the checked-in docs/api_surface.txt matches the
    source (the --docs tier gate), and drift is detected."""
    r = subprocess.run([sys.executable, str(REPO / "tools/check_api.py")],
                       capture_output=True, text=True)
    assert r.returncode == 0, r.stdout + r.stderr
    sys.path.insert(0, str(REPO / "tools"))
    try:
        import check_api
        surface = check_api.build_surface()
        assert "repro.serving.service.GcnService.open_session" in surface
        assert "repro.core.agcn.engine.step_frames" in surface
        # determinism: two builds render identically
        assert surface == check_api.build_surface()
    finally:
        sys.path.pop(0)


# ------------------------------------------- long-lived-service bugfixes

def test_deadline_expired_queue_never_grows_capacity(params):
    """Regression: under qos="deadline" the capacity manager used to see
    queued-but-already-expired sessions as demand and grow a tier for
    work it would immediately shed.  Expired sessions are swept *before*
    the demand observation, so an expired-heavy queue leaves capacity at
    the bottom tier with zero resize events."""
    plan = engine.build_execution_plan(params, CFG, backend="reference")
    bn = engine.collect_bn_stats(
        plan, jax.random.normal(jax.random.PRNGKey(1),
                                (2, CFG.gcn_frames, V, C)))
    svc = GcnService(CFG, plans=(plan,), bn_stats=(bn,), qos="deadline",
                     capacity_tiers=(2, 4), capacity_config=ELASTIC_CCFG)
    clip = np.zeros((6, V, C), np.float32)
    live = [svc.open_session(deadline=10_000) for _ in range(2)]
    dead = [svc.open_session(deadline=-1) for _ in range(4)]  # expired at 0
    for h in live + dead:
        svc.submit_clip(h, clip)
    svc.run_until_idle()
    assert svc.capman.events == []                # no spurious grow
    assert svc.capacity == 2
    m = svc.metrics()
    assert m["sessions"] == 2 and m["deadline_missed"] == 4
    for h in live:
        assert svc.poll(h).state == "done"
    for h in dead:
        assert svc.poll(h).state == "missed"


def test_advance_clock_idle_lull_shrinks_capacity(params):
    """Regression: an idle elastic service never saw shrink ticks (the
    capacity manager only observed inside tick()), so a traffic lull left
    it parked at the top tier forever.  advance_clock feeds the skipped
    ticks to the capacity manager, walks the ladder down and migrates
    once — capacity returns to the bottom tier before the next arrival."""
    plan = engine.build_execution_plan(params, CFG, backend="reference")
    bn = engine.collect_bn_stats(
        plan, jax.random.normal(jax.random.PRNGKey(1),
                                (2, CFG.gcn_frames, V, C)))
    # shrink_patience=6 > the drain tail, so the busy phase ends still
    # parked at the top tier — only the lull can walk it back down
    ccfg = CapacityConfig(tiers=(2, 4), grow_patience=1,
                          shrink_patience=6, cooldown=3)
    svc = GcnService(CFG, plans=(plan,), bn_stats=(bn,),
                     capacity_tiers=(2, 4), capacity_config=ccfg)
    rng = np.random.default_rng(6)
    arrivals = [(0, rng.standard_normal((8, V, C)).astype(np.float32), {})
                for _ in range(4)]
    _drive(svc, arrivals)                    # burst grows 2 -> 4
    assert any(e.new > e.old for e in svc.capman.events)
    assert svc.capacity == 4                 # still at the top tier
    svc.advance_clock(svc.now + 200)         # the lull
    assert svc.capacity == 2                 # walked back down
    assert svc.now >= 200
    # still serves correctly afterwards at the bottom tier
    h = svc.open_session()
    svc.submit_clip(h, arrivals[0][1])
    svc.run_until_idle()
    np.testing.assert_array_equal(svc.poll(h).logits,
                                  _drive(GcnService(CFG, plans=(plan,),
                                                    bn_stats=(bn,),
                                                    capacity_tiers=(2,)),
                                         arrivals[:1])[0])


def test_service_bookkeeping_bounded_and_keep_records(params):
    """Regression: a long-lived service accumulated per-session dicts and
    full record lists without bound.  With retain_records=3, serving 9
    sessions leaves every host map trimmed to the retention bound, while
    the lifetime aggregates in metrics() still count all 9;
    metrics(keep_records=1) caps the returned record list."""
    plan = engine.build_execution_plan(params, CFG, backend="reference")
    bn = engine.collect_bn_stats(
        plan, jax.random.normal(jax.random.PRNGKey(1),
                                (2, CFG.gcn_frames, V, C)))
    svc = GcnService(CFG, plans=(plan,), bn_stats=(bn,), capacity_tiers=(2,),
                     retain_records=3)
    clip = np.zeros((4, V, C), np.float32)
    for _ in range(9):
        h = svc.open_session()
        svc.submit_clip(h, clip)
        svc.run_until_idle()
        assert svc.poll(h).state == "done"   # newest is always pollable
    assert len(svc._records) <= 3
    assert len(svc._sessions) <= 3
    assert len(svc.sched.completed) <= 3
    m = svc.metrics()
    assert m["sessions"] == 9                # lifetime counter, not len()
    assert len(m["records"]) <= 3
    assert len(svc.metrics(keep_records=1)["records"]) == 1
    with pytest.raises(ValueError):
        GcnService(CFG, plans=(plan,), bn_stats=(bn,), retain_records=0)


# --------------------------------------------- SLO overload coverage gap

def test_overload_demand_queues_slo_sheds(params, prune_plan):
    """Sustained overload at a saturated top tier — the cell the demand
    policy has no answer for.  A drip of low-priority sessions keeps both
    slots of the (only) tier busy end-to-end; a high-priority session
    arrives mid-overload.  Under ``policy="demand"`` there is no higher
    tier to grow into and no admission control, so the high-priority
    session waits out a full slot turnover behind *active* low-priority
    work and breaches the 50-tick first-logit bound.  Under
    ``policy="slo"`` the controller sheds the late low-priority opens at
    the top tier, a slot is free when the high-priority session arrives,
    and its first-logit latency holds the bound — on the identical
    arrival sequence."""
    plan, bn = _plan_and_bn(params, prune_plan, "reference")
    rng = np.random.default_rng(6)
    T = 12
    target = 50
    # lows at 0, 2, then every 12 ticks; one high mid-overload at 70
    lows = [0, 2] + list(range(12, 97, 12))
    arrivals = [(t, 0) for t in lows] + [(70, 1)]

    def run(policy):
        svc = GcnService(
            CFG, plans=(plan,), bn_stats=(bn,), capacity_tiers=(2,),
            policy=policy,
            slo_config=(serving.SloConfig(
                target_p99_ticks=target, window=16, breach_patience=2,
                recover_patience=16, shed_mode="reject")
                if policy == "slo" else None))
        pending = sorted(arrivals)
        handles, i = [], 0
        while svc.now < 400:
            while i < len(pending) and pending[i][0] <= svc.now:
                at, prio = pending[i]
                h = svc.open_session(priority=prio, arrival=at)
                if svc.poll(h).state != "rejected":
                    svc.submit_clip(
                        h, rng.standard_normal((T, V, C)).astype(np.float32))
                handles.append((h, prio))
                i += 1
            if svc.idle():
                if i == len(pending):
                    break
                svc.advance_clock(pending[i][0])
                continue
            svc.tick()
        assert svc.idle()
        return svc, handles

    svc_d, hd = run("demand")
    svc_s, hs = run("slo")
    md, ms = svc_d.metrics(), svc_s.metrics()
    hp_d = md["latency_ms_by_priority"]["1"]["first_logit_p99_ticks"]
    hp_s = ms["latency_ms_by_priority"]["1"]["first_logit_p99_ticks"]
    # demand admits everything and the high-priority session eats the
    # turnover wait; slo sheds lows so it latches within the bound
    assert hp_d > target
    assert hp_s <= target
    assert ms["sessions_rejected"] > 0
    assert md.get("sessions_rejected", 0) == 0
    # every high-priority session completes under both policies, and the
    # rejected lows really are the shed ones (poll says so)
    assert all(svc_s.poll(h).state == "done" for h, p in hs if p == 1)
    assert sum(svc_s.poll(h).state == "rejected"
               for h, p in hs) == ms["sessions_rejected"]
    assert all(svc_d.poll(h).state == "done" for h, _ in hd)
