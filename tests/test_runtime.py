"""Runtime substrate tests: optimizer, checkpoint store, fault monitors,
elastic re-meshing, data pipeline determinism, quantization."""
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st  # optional test extra

from repro.common.config import TrainConfig
from repro.checkpoint import store
from repro.configs import get_config
from repro.core.quant import dequantize_int8, quantize_int8, quantize_q88
from repro.data.pipeline import DataConfig, lm_batches, skeleton_batches
from repro.fault.elastic import adjust_train_config, plan_degraded_mesh
from repro.fault.monitor import HeartbeatMonitor, StragglerDetector
from repro.optim import adamw


# --------------------------------------------------------------------- optim

def test_adamw_converges_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    tcfg = TrainConfig(learning_rate=0.1, warmup_steps=1, total_steps=200,
                       weight_decay=0.0)
    state = adamw.init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state, _ = adamw.update(params, grads, state, tcfg)
    assert float(jnp.abs(params["w"]).max()) < 0.1


def test_grad_clipping():
    g = {"a": jnp.full((10,), 100.0)}
    clipped, norm = adamw.clip_by_global_norm(g, 1.0)
    assert float(norm) > 100
    assert float(adamw.global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


def test_lr_schedule_shape():
    tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=10, total_steps=100)
    lrs = [float(adamw.schedule(jnp.asarray(s), tcfg)) for s in range(100)]
    assert lrs[0] < lrs[9]                          # warmup rises
    assert lrs[10] == pytest.approx(1e-3, rel=0.1)  # peak
    assert lrs[-1] < lrs[50] < lrs[10]              # cosine decays


# ---------------------------------------------------------------- checkpoint

def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(10.0), "b": {"c": jnp.ones((3, 4), jnp.bfloat16)}}
    store.save(str(tmp_path), 5, tree)
    assert store.latest_step(str(tmp_path)) == 5
    back = store.restore(str(tmp_path), 5, tree)
    np.testing.assert_array_equal(np.asarray(back["a"]), np.arange(10.0))
    assert back["b"]["c"].dtype == jnp.bfloat16


def test_checkpoint_detects_corruption(tmp_path):
    tree = {"a": jnp.arange(16.0)}
    path = store.save(str(tmp_path), 1, tree)
    leaf = next(pathlib.Path(path).glob("leaf_*.npy"))
    arr = np.load(leaf)
    arr[0] = 999.0
    np.save(leaf, arr)
    with pytest.raises(IOError, match="checksum"):
        store.restore(str(tmp_path), 1, tree)


def test_checkpoint_gc_keeps_latest(tmp_path):
    tree = {"a": jnp.zeros(4)}
    for s in (1, 2, 3, 4, 5):
        store.save(str(tmp_path), s, tree, keep=2)
    steps = sorted(int(p.name.split("_")[1]) for p in tmp_path.iterdir())
    assert steps == [4, 5]


def test_checkpoint_async(tmp_path):
    tree = {"a": jnp.arange(8.0)}
    t = store.save_async(str(tmp_path), 7, tree)
    t.join(timeout=30)
    assert store.latest_step(str(tmp_path)) == 7


# --------------------------------------------------------------------- fault

def test_heartbeat_detects_dead_host():
    hb = HeartbeatMonitor(num_hosts=4, timeout_s=10.0)
    for h in range(4):
        hb.beat(h, now=0.0)
    hb.beat(0, now=20.0)
    hb.beat(1, now=20.0)
    hb.beat(2, now=20.0)
    assert hb.dead_hosts(now=25.0) == [3]
    assert not hb.healthy(now=25.0)


def test_straggler_detection():
    sd = StragglerDetector(num_hosts=8, k=3.0)
    for step in range(5):
        for h in range(8):
            sd.record(h, 1.0 + (3.0 if h == 6 else 0.0))
    assert sd.stragglers() == {6}


def test_elastic_plan_and_microbatches():
    plan = plan_degraded_mesh(alive_chips=200, model=16, old_data=16)
    assert plan is not None
    assert plan.data == 8 and plan.chips == 128
    tcfg = adjust_train_config(TrainConfig(microbatches=1), plan)
    assert tcfg.microbatches == 2                 # global batch preserved
    assert plan_degraded_mesh(alive_chips=8, model=16) is None


def test_elastic_reshard_roundtrip(tmp_path):
    from repro.fault.elastic import reshard_checkpoint
    tree = {"w": jnp.arange(64.0).reshape(8, 8)}
    store.save(str(tmp_path), 3, tree)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    from jax.sharding import NamedSharding, PartitionSpec as P
    sh = {"w": NamedSharding(mesh, P(None, None))}
    back = reshard_checkpoint(str(tmp_path), 3, tree, mesh, sh)
    np.testing.assert_array_equal(np.asarray(back["w"]),
                                  np.arange(64.0).reshape(8, 8))


# ---------------------------------------------------------------------- data

def test_lm_batches_deterministic_and_host_sharded():
    cfg = get_config("smollm-360m", reduced=True)
    d0 = DataConfig(global_batch=8, seq_len=32, seed=1, host_index=0, host_count=2)
    d1 = DataConfig(global_batch=8, seq_len=32, seed=1, host_index=1, host_count=2)
    b0a = next(lm_batches(cfg, d0))
    b0b = next(lm_batches(cfg, d0))
    b1 = next(lm_batches(cfg, d1))
    np.testing.assert_array_equal(b0a["tokens"], b0b["tokens"])   # determinism
    assert b0a["tokens"].shape == (4, 32)                          # host slice
    assert not np.array_equal(b0a["tokens"], b1["tokens"])         # distinct


def test_skeleton_batches_shapes():
    cfg = get_config("agcn-2s", reduced=True)
    d = DataConfig(global_batch=4, seq_len=0, seed=0)
    b = next(skeleton_batches(cfg, d))
    assert b["x"].shape == (4 * cfg.gcn_persons, cfg.gcn_frames, 25, 3)
    assert b["labels"].shape == (4 * cfg.gcn_persons,)
    assert b["labels"].max() < cfg.gcn_num_classes


# --------------------------------------------------------------------- quant

@given(st.lists(st.floats(-100, 100), min_size=1, max_size=64))
@settings(max_examples=40, deadline=None)
def test_q88_error_bound(vals):
    x = jnp.asarray(vals, jnp.float32)
    q = quantize_q88(x)
    np.testing.assert_allclose(np.asarray(q), np.asarray(x), atol=1 / 512 + 1e-6)


def test_int8_roundtrip_small_error():
    w = jax.random.normal(jax.random.PRNGKey(0), (64, 32))
    q, s = quantize_int8(w, axis=0)
    back = dequantize_int8(q, s)
    rel = float(jnp.abs(back - w).max() / jnp.abs(w).max())
    assert rel < 0.02
