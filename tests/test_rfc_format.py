"""Property-based tests (hypothesis) for the RFC format invariants."""
import jax.numpy as jnp
import numpy as np
from hypothesis_compat import given, settings, st  # optional test extra

from repro.core.rfc.format import (
    expected_sparsity_categories, mbhot, minibank_depths, rfc_decode,
    rfc_encode, storage_cost,
)


def _random_activations(rng, rows, banks, bank, sparsity):
    x = rng.standard_normal((rows, banks * bank)).astype(np.float32)
    x[rng.random(x.shape) < sparsity] = -1.0      # ReLU will zero these
    return x


@st.composite
def activations(draw):
    rows = draw(st.integers(1, 16))
    banks = draw(st.integers(1, 8))
    sparsity = draw(st.floats(0.0, 1.0))
    seed = draw(st.integers(0, 2**31 - 1))
    return _random_activations(np.random.default_rng(seed), rows, banks, 16,
                               sparsity)


@st.composite
def banked_activations(draw):
    """(bank, x) over random bank widths — the codec is generic in C3's
    bank parameter even though the paper's accelerator fixes it at 16."""
    bank = draw(st.sampled_from([4, 8, 16, 32]))
    rows = draw(st.integers(1, 12))
    banks = draw(st.integers(1, 6))
    sparsity = draw(st.floats(0.0, 1.0))
    seed = draw(st.integers(0, 2**31 - 1))
    return bank, _random_activations(np.random.default_rng(seed), rows,
                                     banks, bank, sparsity)


@given(activations())
@settings(max_examples=50, deadline=None)
def test_roundtrip_is_relu(x):
    v, hot = rfc_encode(jnp.asarray(x))
    out = rfc_decode(v, hot)
    np.testing.assert_allclose(np.asarray(out), np.maximum(x, 0), atol=0)


@given(activations())
@settings(max_examples=50, deadline=None)
def test_compaction_front_packed(x):
    """All non-zeros of a bank sit before all zeros (front-packed)."""
    v, hot = rfc_encode(jnp.asarray(x))
    vb = np.asarray(v).reshape(-1, 16)
    nz = vb != 0
    for row in nz:
        idx = np.flatnonzero(~row)
        if idx.size:
            assert not row[idx[0]:].any()


@given(banked_activations())
@settings(max_examples=50, deadline=None)
def test_roundtrip_is_relu_any_bank(args):
    """rfc_decode(rfc_encode(x)) == relu(x) for every bank width."""
    bank, x = args
    v, hot = rfc_encode(jnp.asarray(x), bank=bank)
    out = rfc_decode(v, hot, bank=bank)
    np.testing.assert_allclose(np.asarray(out), np.maximum(x, 0), atol=0)


@given(banked_activations())
@settings(max_examples=50, deadline=None)
def test_hot_popcount_equals_nnz(args):
    """The hot code's popcount is exactly the bank's non-zero count — the
    property the mbhot/minibank storage accounting stands on."""
    bank, x = args
    v, hot = rfc_encode(jnp.asarray(x), bank=bank)
    hot = np.asarray(hot)
    relu = np.maximum(x, 0).reshape(-1, x.shape[-1] // bank, bank)
    np.testing.assert_array_equal(hot.sum(-1), (relu != 0).sum(-1))
    # ... and the compacted values hold exactly that many non-zeros
    np.testing.assert_array_equal(
        hot.sum(-1),
        (np.asarray(v).reshape(relu.shape) != 0).sum(-1))


def test_popcount_and_roundtrip_deterministic_grid():
    """Always-on (no-hypothesis) cover for the two properties above: a
    seeded grid over shapes × banks × sparsities."""
    rng = np.random.default_rng(3)
    for bank in (4, 8, 16, 32):
        for rows, banks in ((1, 1), (5, 3), (16, 4)):
            for sparsity in (0.0, 0.5, 0.9, 1.0):
                x = _random_activations(rng, rows, banks, bank, sparsity)
                v, hot = rfc_encode(jnp.asarray(x), bank=bank)
                out = rfc_decode(v, hot, bank=bank)
                np.testing.assert_allclose(np.asarray(out), np.maximum(x, 0),
                                           atol=0)
                hot_np = np.asarray(hot)
                relu = np.maximum(x, 0).reshape(rows, banks, bank)
                np.testing.assert_array_equal(hot_np.sum(-1),
                                              (relu != 0).sum(-1))


@given(activations())
@settings(max_examples=50, deadline=None)
def test_mbhot_counts(x):
    v, hot = rfc_encode(jnp.asarray(x))
    mb = np.asarray(mbhot(jnp.asarray(np.asarray(hot) > 0)))
    nnz = (np.asarray(hot) > 0).reshape(*mb.shape, 16).sum(-1)
    np.testing.assert_array_equal(mb, np.ceil(nnz / 4))


@given(activations())
@settings(max_examples=30, deadline=None)
def test_storage_cost_bounds(x):
    _, hot = rfc_encode(jnp.asarray(x))
    c = storage_cost(np.asarray(hot) > 0)
    # RFC never exceeds dense by more than the hot-code overhead
    assert c["rfc_bits"] <= c["dense_bits"] * (1 + (16 + 4) / (16 * 16)) + 1
    # and is within one mini-bank per bank of the information floor
    n_banks = x.size // 16
    nnz = (np.maximum(x, 0) > 0).sum()
    floor = nnz * 16
    assert c["rfc_bits"] >= floor


def test_storage_cost_paper_scenario():
    """Paper §V-C example: uniform quartile mix -> ~37.5% storage saving."""
    rng = np.random.default_rng(0)
    rows = []
    for lo in (0.0, 0.25, 0.5, 0.75):
        for _ in range(256):
            nnz = int(16 * (1 - (lo + 0.125)))
            row = np.zeros(16, bool)
            row[rng.choice(16, nnz, replace=False)] = True
            rows.append(row)
    hot = np.stack(rows)
    c = storage_cost(hot)
    assert 0.25 < c["rfc_vs_dense_reduction"] < 0.50


def test_minibank_depths_monotone():
    d = minibank_depths((0.25, 0.25, 0.25, 0.25), total_depth=64)
    assert len(d) == 4
    assert all(d[i] >= d[i + 1] for i in range(3))
    assert d[0] == 64                      # first mini-bank serves everyone


def test_sparsity_categories_sum_to_one():
    rng = np.random.default_rng(1)
    hot = rng.random((512, 16)) > 0.5
    cats = expected_sparsity_categories(hot)
    assert abs(sum(cats) - 1.0) < 1e-9
